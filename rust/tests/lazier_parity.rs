//! Parity suite for LazierThanLazyGreedy's Minoux-blocked within-sample
//! re-evaluation (ISSUE 3 satellite): against a hand-rolled replica of
//! the serial pop-one-at-a-time algorithm (which consumes the *same*
//! RNG stream, so samples are identical), the blocked optimizer must
//! reproduce the selection order, every accepted gain (bit-for-bit), and
//! the final value. Evaluation counts may differ only within the
//! block-boundary tolerance, exactly as in `lazy_parity`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::graph_cut::GraphCut;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric, SparseKernel};
use submodlib::optimizers::lazy::LAZY_STALE_BLOCK;
use submodlib::optimizers::stochastic::sample_size;
use submodlib::optimizers::{
    maximize, Budget, MaximizeOpts, OptimizerKind, ZERO_GAIN_EPS,
};
use submodlib::rng::Pcg64;

/// Replica of the lazier sample-heap entry: (bound descending, lowest id
/// on ties, total_cmp), plus the fresh flag.
struct Entry {
    bound: f64,
    e: usize,
    fresh: bool,
}
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound && self.e == other.e
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.bound.total_cmp(&other.bound).then_with(|| other.e.cmp(&self.e))
    }
}

/// The pre-blocking algorithm, verbatim: per iteration, partial-shuffle
/// a sample off the pool (identical RNG consumption to the optimizer),
/// heap the sample by stale bound (∞ = never evaluated), then pop →
/// recompute → reinsert ONE entry at a time, accepting the first fresh
/// top. Default stop rules, unit costs.
fn serial_lazier_reference(
    f: &dyn SetFunction,
    k: usize,
    epsilon: f64,
    seed: u64,
) -> (Vec<(usize, f64)>, f64, u64) {
    let n = f.n();
    let k = k.min(n);
    let s = sample_size(n, k, epsilon);
    let mut work = f.clone_box();
    work.init_memoization(&Subset::empty(n));
    let mut rng = Pcg64::new(seed);
    let mut upper = vec![f64::INFINITY; n];
    let mut pool: Vec<usize> = (0..n).collect();
    let mut order: Vec<(usize, f64)> = Vec::new();
    let mut value = 0f64;
    let mut evaluations = 0u64;
    for _ in 0..k {
        if pool.is_empty() {
            break;
        }
        let take = s.min(pool.len());
        for i in 0..take {
            let j = i + rng.next_below(pool.len() - i);
            pool.swap(i, j);
        }
        let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(take);
        for &e in &pool[..take] {
            heap.push(Entry { bound: upper[e], e, fresh: false });
        }
        let mut picked: Option<(usize, f64)> = None;
        while let Some(top) = heap.pop() {
            if top.fresh {
                picked = Some((top.e, top.bound));
                break;
            }
            let gain = work.marginal_gain_memoized(top.e);
            evaluations += 1;
            upper[top.e] = gain;
            heap.push(Entry { bound: gain, e: top.e, fresh: true });
        }
        let Some((e, gain)) = picked else { break };
        // default MaximizeOpts stop rules
        if gain == f64::NEG_INFINITY || gain < 0.0 || gain <= ZERO_GAIN_EPS {
            break;
        }
        work.update_memoization(e);
        value += gain;
        order.push((e, gain));
        let pos = pool[..take].iter().position(|&x| x == e).unwrap();
        pool.swap_remove(pos);
    }
    (order, value, evaluations)
}

fn assert_blocked_matches_serial(f: &dyn SetFunction, k: usize, epsilon: f64, seed: u64) {
    let (ref_order, ref_value, ref_evals) = serial_lazier_reference(f, k, epsilon, seed);
    assert!(!ref_order.is_empty(), "degenerate workload");
    for parallel in [true, false] {
        let sel = maximize(
            f,
            Budget::cardinality(k),
            OptimizerKind::LazierThanLazyGreedy,
            &MaximizeOpts { epsilon, seed, parallel, ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            sel.order.len(),
            ref_order.len(),
            "{} (parallel={parallel}): selection size diverged",
            f.name()
        );
        for (got, want) in sel.order.iter().zip(&ref_order) {
            assert_eq!(
                got.0, want.0,
                "{} (parallel={parallel}): selection order diverged",
                f.name()
            );
            assert_eq!(
                got.1.to_bits(),
                want.1.to_bits(),
                "{} (parallel={parallel}): gain of {} diverged",
                f.name(),
                got.0
            );
        }
        assert_eq!(
            sel.value.to_bits(),
            ref_value.to_bits(),
            "{} (parallel={parallel}): value diverged",
            f.name()
        );
        // Block overshoot tolerance: only the last drain of a pick's
        // cascade can recompute entries the serial algorithm would not
        // have touched, so the surplus is under one block per pick.
        let tolerance = (LAZY_STALE_BLOCK as u64) * (sel.order.len() as u64 + 1);
        assert!(
            sel.evaluations <= ref_evals + tolerance,
            "{} (parallel={parallel}): blocked evaluations {} exceed serial {} + tolerance {}",
            f.name(),
            sel.evaluations,
            ref_evals,
            tolerance
        );
    }
}

#[test]
fn blocked_matches_serial_on_facility_location() {
    let data = synthetic::blobs(300, 2, 8, 2.0, 81);
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    assert_blocked_matches_serial(&f, 20, 0.05, 7);
}

#[test]
fn blocked_matches_serial_on_sparse_facility_location() {
    // doubles as an end-to-end run over the streaming sparse build
    let data = synthetic::blobs(220, 2, 6, 1.5, 82);
    let f = FacilityLocation::sparse(
        SparseKernel::from_data(&data, Metric::Euclidean, 24).unwrap(),
    );
    assert_blocked_matches_serial(&f, 16, 0.1, 9);
}

#[test]
fn blocked_matches_serial_on_graph_cut() {
    let data = synthetic::blobs(250, 2, 6, 1.5, 83);
    let f = GraphCut::new(DenseKernel::from_data(&data, Metric::Euclidean), 0.4).unwrap();
    assert_blocked_matches_serial(&f, 15, 0.08, 11);
}

#[test]
fn blocked_matches_serial_on_log_determinant() {
    let data = synthetic::blobs(90, 3, 4, 1.0, 84);
    let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 });
    let f = LogDeterminant::with_regularization(k, 0.1).unwrap();
    assert_blocked_matches_serial(&f, 10, 0.1, 13);
}

#[test]
fn blocked_matches_serial_across_seeds() {
    // the invariance must hold for every sample sequence, not one lucky
    // draw — sweep seeds on one workload
    let data = synthetic::blobs(160, 2, 5, 1.5, 85);
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    for seed in [1u64, 2, 3, 17, 42] {
        assert_blocked_matches_serial(&f, 12, 0.1, seed);
    }
}

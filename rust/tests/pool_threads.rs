//! ISSUE 5 pool containment proof, extended by ISSUE 6 to the
//! coordinator: a full maximize run — kernel builds (dense direct-write
//! + mirror, sparse wavefront) and batched gain scans — AND a
//! coordinator `select()` (stage-1 fan-out now runs as one
//! `pool::run_indexed` job) must execute entirely on the persistent
//! pool, spawning no OS threads beyond it plus the coordinator's single
//! supervised drain thread.
//!
//! Per-call scoped threads join before their parallel section returns,
//! so sampling the thread count *after* a workload would pass even for
//! the pre-pool code. The assertion therefore runs a watcher thread
//! that samples `/proc/self/status` *while* the workload executes and
//! records the peak: any short-lived spawn on a hot path raises the
//! peak above the parked-pool baseline. This file deliberately holds a
//! single test — a sibling test starting or finishing concurrently
//! would move the process thread count for unrelated reasons.

use std::sync::atomic::{AtomicBool, Ordering};

use submodlib::config::CoordinatorConfig;
use submodlib::coordinator::{Coordinator, SelectRequest};
use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::kernel::{DenseKernel, Metric, SparseKernel};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::runtime::pool;

#[cfg(target_os = "linux")]
fn os_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

#[cfg(not(target_os = "linux"))]
fn os_threads() -> Option<usize> {
    None
}

/// One representative hot-path round: both kernel builds plus Naive and
/// Lazy maximizes over dense and sparse FL. n = 400 clears
/// `PARALLEL_MIN_CANDIDATES`, so the parallel scan path genuinely runs,
/// and every parallel section is entered many times.
fn workload() {
    let data = synthetic::blobs(400, 2, 8, 3.0, 11);
    let dense = DenseKernel::from_data(&data, Metric::Euclidean);
    let sparse = SparseKernel::from_data(&data, Metric::Euclidean, 12).unwrap();
    for f in [FacilityLocation::new(dense), FacilityLocation::sparse(sparse)] {
        for kind in [OptimizerKind::NaiveGreedy, OptimizerKind::LazyGreedy] {
            maximize(&f, Budget::cardinality(10), kind, &MaximizeOpts::default())
                .unwrap();
        }
    }
}

#[test]
fn maximize_spawns_no_threads_beyond_the_pool() {
    // pool topology: resolved width w means at most w − 1 detached
    // workers (the submitting thread is always a participant)
    assert!(pool::worker_count() < pool::configured_width());
    // a live coordinator contributes exactly one extra thread (the
    // supervised ingest drain); it is created — and its ground set
    // ingested — BEFORE the baseline so the drain is part of the settled
    // count and select() itself must add nothing
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        shard_capacity: 64,
        ingest_depth: 32,
        per_shard_factor: 2.0,
        min_shard_quorum: None,
        max_inflight: 4,
        admission_queue_depth: 16,
        breaker_threshold: None,
        breaker_probe_after: 4,
    });
    let h = coord.ingest_handle();
    let stream = synthetic::blobs(200, 2, 4, 1.5, 7);
    for i in 0..200 {
        h.ingest(stream.row(i).to_vec()).unwrap();
    }
    // warm once so lazy pool initialization is behind us
    workload();
    coord.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    if os_threads().is_none() {
        return; // non-linux: no portable thread count to read
    }
    let stop = AtomicBool::new(false);
    // lint: allow(thread-spawn) — pool-external watcher counting OS threads via /proc
    let peak = std::thread::scope(|scope| {
        let watcher = scope.spawn(|| {
            // baseline includes this watcher itself; sample as fast as
            // the /proc read allows so even short-lived threads are seen
            let mut peak = 0usize;
            while !stop.load(Ordering::Relaxed) {
                if let Some(t) = os_threads() {
                    peak = peak.max(t);
                }
            }
            peak
        });
        for _ in 0..3 {
            workload();
            // the coordinator's stage-1 fan-out rides the same pool: a
            // select must not raise the peak above the parked baseline
            coord.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        watcher.join().expect("watcher thread")
    });
    // the coordinator (and its drain thread) stays alive through this
    // read, so `settled` includes every persistent thread the workload had
    let settled = os_threads().expect("/proc stayed readable");
    // after the watcher exits, the settled count is main + harness +
    // parked pool workers + coordinator drain; during the workload
    // nothing may exceed the watcher-inclusive version of that same set
    assert!(
        peak <= settled + 1,
        "peak thread count {peak} exceeded settled {settled} + watcher \
         (a hot path spawned threads outside the pool)"
    );
    drop(coord);
}

//! Batch-gain contract suite (ISSUE 1):
//!
//!  B1 `marginal_gains_batch` == per-element `marginal_gain_memoized`,
//!     bit-for-bit, for every function after arbitrary
//!     `update_memoization` sequences (randomized per util::prop's seeded
//!     stream design);
//!  B2 the parallel optimizers return selections identical to the serial
//!     per-element path (`MaximizeOpts::parallel = false`) — same order,
//!     same value, same evaluation count;
//!  B3 parallel NaiveGreedy matches a hand-rolled replica of the serial
//!     seed implementation (scan ascending, first best wins).

use submodlib::functions::cg::Flcg;
use submodlib::functions::clustered::ClusteredFunction;
use submodlib::functions::cmi::Flcmi;
use submodlib::functions::disparity_min::DisparityMin;
use submodlib::functions::disparity_min_sum::DisparityMinSum;
use submodlib::functions::disparity_sum::DisparitySum;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::feature_based::{ConcaveShape, FeatureBased};
use submodlib::functions::generic::{ConditionalMutualInformation, MutualInformation};
use submodlib::functions::graph_cut::GraphCut;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::mi::{ConcaveOverModular, Flqmi, Flvmi, Gcmi, LogDetMi};
use submodlib::functions::mixture::Mixture;
use submodlib::functions::prob_set_cover::ProbabilisticSetCover;
use submodlib::functions::set_cover::SetCover;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric, RectKernel, SparseKernel};
use submodlib::linalg::Matrix;
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::rng::Pcg64;
use submodlib::util::prop::{check, gen};

/// Every function family over a random instance (sizes chosen to hit the
/// 4-wide blocked paths *and* their scalar remainders).
fn random_function(rng: &mut Pcg64) -> Box<dyn SetFunction> {
    let data = gen::matrix(rng, 9, 31, 2, 6);
    let n = data.rows();
    match rng.next_below(12) {
        0 => Box::new(FacilityLocation::new(DenseKernel::from_data(
            &data,
            Metric::Euclidean,
        ))),
        1 => {
            // rect mode: a smaller represented set U against ground V
            let u = gen::matrix(rng, 4, 12, data.cols(), data.cols());
            Box::new(FacilityLocation::with_represented(
                RectKernel::from_data(&u, &data, Metric::Euclidean).unwrap(),
            ))
        }
        2 => {
            let k = 2 + rng.next_below(n - 1);
            Box::new(FacilityLocation::sparse(
                SparseKernel::from_data(&data, Metric::Euclidean, k).unwrap(),
            ))
        }
        3 => Box::new(FacilityLocation::clustered_from_data(
            &data,
            2 + rng.next_below(3),
            Metric::Euclidean,
            7,
        )),
        4 => Box::new(
            GraphCut::new(
                DenseKernel::from_data(&data, Metric::Euclidean),
                0.1 + 0.8 * rng.next_f64(),
            )
            .unwrap(),
        ),
        5 => {
            let m = 16;
            let feats: Vec<Vec<(u32, f32)>> = (0..n)
                .map(|_| {
                    (0..4)
                        .map(|_| (rng.next_below(m) as u32, rng.next_f32()))
                        .collect()
                })
                .collect();
            Box::new(
                FeatureBased::new(feats, vec![1.0; m], ConcaveShape::Sqrt).unwrap(),
            )
        }
        6 => {
            let m = 12;
            let cover: Vec<Vec<u32>> = (0..n)
                .map(|_| (0..3).map(|_| rng.next_below(m) as u32).collect())
                .collect();
            Box::new(SetCover::new(cover, vec![1.0; m]).unwrap())
        }
        7 => {
            let m = 10;
            let probs: Vec<Vec<f32>> =
                (0..n).map(|_| (0..m).map(|_| rng.next_f32()).collect()).collect();
            Box::new(ProbabilisticSetCover::new(probs, vec![1.0; m]).unwrap())
        }
        8 => Box::new(DisparityMin::new(DenseKernel::distances_from_data(&data))),
        9 => Box::new(DisparitySum::new(DenseKernel::distances_from_data(&data))),
        10 => Box::new(DisparityMinSum::new(DenseKernel::distances_from_data(&data))),
        _ => {
            let k = DenseKernel::from_data(&data, Metric::Euclidean);
            Box::new(
                Mixture::new(vec![
                    (0.7, Box::new(FacilityLocation::new(k.clone()))
                        as Box<dyn SetFunction>),
                    (0.3, Box::new(GraphCut::new(k, 0.4).unwrap())
                        as Box<dyn SetFunction>),
                ])
                .unwrap(),
            )
        }
    }
}

/// B1 core: after each random update, the batch over all remaining
/// candidates must equal the per-element scalar path bit-for-bit (the
/// determinism contract in functions::traits).
fn assert_batch_matches(f: &mut dyn SetFunction, rng: &mut Pcg64) -> Result<(), String> {
    let n = f.n();
    f.init_memoization(&Subset::empty(n));
    let mut selected = vec![false; n];
    for step in 0..5usize {
        let candidates: Vec<usize> = (0..n).filter(|&e| !selected[e]).collect();
        if candidates.is_empty() {
            break;
        }
        let mut out = vec![0f64; candidates.len()];
        f.marginal_gains_batch(&candidates, &mut out);
        for (&e, &g) in candidates.iter().zip(&out) {
            let scalar = f.marginal_gain_memoized(e);
            if g.to_bits() != scalar.to_bits() {
                return Err(format!(
                    "{} step {step} e={e}: batch {g} != scalar {scalar}",
                    f.name()
                ));
            }
        }
        let e = candidates[rng.next_below(candidates.len())];
        f.update_memoization(e);
        selected[e] = true;
    }
    Ok(())
}

#[test]
fn batch_equals_scalar_all_functions_randomized() {
    check("batch == scalar gains", 0xBA7C4, 60, |rng| {
        let mut f = random_function(rng);
        assert_batch_matches(f.as_mut(), rng)
    });
}

#[test]
fn batch_equals_scalar_log_determinant_blocked_forward_substitution() {
    // LogDeterminant's override runs one blocked forward substitution
    // over K candidate columns against the shared incremental factor —
    // must stay bit-identical to per-candidate gains
    check("logdet blocked batch", 0x10DE7, 10, |rng| {
        let data = gen::matrix(rng, 8, 20, 2, 4);
        let mut f = LogDeterminant::with_regularization(
            DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 }),
            0.2,
        )
        .unwrap();
        assert_batch_matches(&mut f, rng)
    });
}

/// The MI / CMI / CG information-measure stack (the family PR 1 left on
/// the scalar default): every specialized or wrapper override must honor
/// the bit-identical batch == scalar contract.
fn random_info_measure(rng: &mut Pcg64) -> Box<dyn SetFunction> {
    let data = gen::matrix(rng, 9, 27, 2, 5);
    let n = data.rows();
    let d = data.cols();
    let queries = gen::matrix(rng, 2, 5, d, d);
    let privates = gen::matrix(rng, 2, 4, d, d);
    let qk = RectKernel::from_data(&queries, &data, Metric::Euclidean).unwrap();
    match rng.next_below(9) {
        0 => Box::new(Flqmi::new(qk, 0.3 + rng.next_f64()).unwrap()),
        1 => Box::new(
            Flvmi::new(
                DenseKernel::from_data(&data, Metric::Euclidean),
                qk,
                0.3 + rng.next_f64(),
            )
            .unwrap(),
        ),
        2 => Box::new(Gcmi::new(qk, 0.5).unwrap()),
        3 => Box::new(
            ConcaveOverModular::new(qk, 0.4 + rng.next_f64(), ConcaveShape::Sqrt)
                .unwrap(),
        ),
        4 => Box::new(
            Flcmi::new(
                DenseKernel::from_data(&data, Metric::Euclidean),
                qk,
                RectKernel::from_data(&privates, &data, Metric::Euclidean).unwrap(),
                1.0,
                0.5,
            )
            .unwrap(),
        ),
        5 => Box::new(
            Flcg::new(
                DenseKernel::from_data(&data, Metric::Euclidean),
                RectKernel::from_data(&privates, &data, Metric::Euclidean).unwrap(),
                0.5 + rng.next_f64(),
            )
            .unwrap(),
        ),
        6 => {
            // generic MI over an extended FL: last nq elements are Q
            let nq = queries.rows();
            let mut all = Matrix::zeros(n + nq, d);
            for i in 0..n {
                all.row_mut(i).copy_from_slice(data.row(i));
            }
            for q in 0..nq {
                all.row_mut(n + q).copy_from_slice(queries.row(q));
            }
            let base = FacilityLocation::new(DenseKernel::from_data(
                &all,
                Metric::Euclidean,
            ));
            Box::new(
                MutualInformation::new(Box::new(base), (n..n + nq).collect(), n)
                    .unwrap(),
            )
        }
        7 => {
            // generic CMI over an extended FL: Q then P past the prefix
            let nq = queries.rows();
            let np = privates.rows();
            let mut all = Matrix::zeros(n + nq + np, d);
            for i in 0..n {
                all.row_mut(i).copy_from_slice(data.row(i));
            }
            for q in 0..nq {
                all.row_mut(n + q).copy_from_slice(queries.row(q));
            }
            for p in 0..np {
                all.row_mut(n + nq + p).copy_from_slice(privates.row(p));
            }
            let base = FacilityLocation::new(DenseKernel::from_data(
                &all,
                Metric::Euclidean,
            ));
            Box::new(
                ConditionalMutualInformation::new(
                    Box::new(base),
                    (n..n + nq).collect(),
                    (n + nq..n + nq + np).collect(),
                    n,
                )
                .unwrap(),
            )
        }
        _ => Box::new(
            LogDetMi::new(
                DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 }),
                DenseKernel::from_data(&queries, Metric::Rbf { gamma: 0.5 }),
                RectKernel::from_data(&queries, &data, Metric::Rbf { gamma: 0.5 })
                    .unwrap(),
                0.7,
                0.1,
            )
            .unwrap(),
        ),
    }
}

#[test]
fn batch_equals_scalar_info_measures_randomized() {
    check("info-measure batch == scalar gains", 0x1F0E5, 54, |rng| {
        let mut f = random_info_measure(rng);
        assert_batch_matches(f.as_mut(), rng)
    });
}

#[test]
fn batch_equals_scalar_clustered_wrapper() {
    check("clustered wrapper batch", 0xC1057, 10, |rng| {
        let data = gen::matrix(rng, 12, 28, 2, 4);
        let mut f = ClusteredFunction::from_data(&data, 3, 5, |sub| {
            Ok(Box::new(FacilityLocation::new(DenseKernel::from_data(
                sub,
                Metric::Euclidean,
            ))))
        })
        .unwrap();
        assert_batch_matches(&mut f, rng)
    });
}

/// B2: identical selections from the parallel and serial scan paths.
/// n = 400 clears PARALLEL_MIN_CANDIDATES, so the threaded fan-out is
/// genuinely exercised.
fn assert_parallel_matches_serial(f: &dyn SetFunction, kind: OptimizerKind, k: usize) {
    let par = maximize(
        f,
        Budget::cardinality(k),
        kind,
        &MaximizeOpts::default(),
    )
    .unwrap();
    let ser = maximize(
        f,
        Budget::cardinality(k),
        kind,
        &MaximizeOpts { parallel: false, ..Default::default() },
    )
    .unwrap();
    assert_eq!(par.ids(), ser.ids(), "{kind:?}: order diverged");
    assert!((par.value - ser.value).abs() < 1e-9, "{kind:?}: value diverged");
    assert_eq!(par.evaluations, ser.evaluations, "{kind:?}: evaluations diverged");
}

#[test]
fn optimizers_deterministic_under_parallelism() {
    let data = submodlib::data::synthetic::blobs(400, 3, 8, 2.0, 99);
    let fl = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let gc = GraphCut::new(DenseKernel::from_data(&data, Metric::Euclidean), 0.4).unwrap();
    for kind in [
        OptimizerKind::NaiveGreedy,
        OptimizerKind::LazyGreedy,
        OptimizerKind::StochasticGreedy,
        OptimizerKind::LazierThanLazyGreedy,
    ] {
        assert_parallel_matches_serial(&fl, kind, 15);
        assert_parallel_matches_serial(&gc, kind, 15);
    }
}

#[test]
fn knapsack_naive_deterministic_under_parallelism() {
    let data = submodlib::data::synthetic::blobs(300, 2, 6, 1.5, 41);
    let fl = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let costs: Vec<f64> = (0..300).map(|i| 1.0 + (i % 5) as f64 * 0.5).collect();
    let budget = Budget::knapsack(20.0, costs).unwrap();
    let par = maximize(
        &fl,
        budget.clone(),
        OptimizerKind::NaiveGreedy,
        &MaximizeOpts::default(),
    )
    .unwrap();
    let ser = maximize(
        &fl,
        budget,
        OptimizerKind::NaiveGreedy,
        &MaximizeOpts { parallel: false, ..Default::default() },
    )
    .unwrap();
    assert_eq!(par.ids(), ser.ids());
    assert!((par.value - ser.value).abs() < 1e-9);
}

/// B3: hand-rolled replica of the pre-batch serial NaiveGreedy (ascending
/// scan, strictly-greater replacement, unit costs) — the parallel
/// implementation must reproduce it element for element.
#[test]
fn parallel_naive_matches_serial_seed_replica() {
    let data = submodlib::data::synthetic::blobs(350, 2, 7, 2.0, 17);
    let fl = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let k = 12;

    let mut reference = fl.clone_box();
    reference.init_memoization(&Subset::empty(350));
    let mut in_set = vec![false; 350];
    let mut expect: Vec<(usize, f64)> = Vec::new();
    for _ in 0..k {
        let mut best: Option<(usize, f64)> = None;
        for e in 0..350 {
            if in_set[e] {
                continue;
            }
            let gain = reference.marginal_gain_memoized(e);
            if best.map(|(_, bg)| gain > bg).unwrap_or(true) {
                best = Some((e, gain));
            }
        }
        let (e, gain) = best.unwrap();
        reference.update_memoization(e);
        in_set[e] = true;
        expect.push((e, gain));
    }

    let sel = maximize(
        &fl,
        Budget::cardinality(k),
        OptimizerKind::NaiveGreedy,
        &MaximizeOpts::default(),
    )
    .unwrap();
    assert_eq!(sel.order.len(), expect.len());
    for (got, want) in sel.order.iter().zip(&expect) {
        assert_eq!(got.0, want.0, "picked element diverged");
        assert_eq!(got.1.to_bits(), want.1.to_bits(), "gain diverged");
    }
}

//! Integration tests asserting the *paper's* claims end-to-end — every
//! qualitative statement the evaluation section makes about Tables 2/5 and
//! Figures 5/7/8/10 is checked programmatically here (DESIGN.md §7:
//! figures → testable assertions).

use submodlib::data::controlled;
use submodlib::experiments::{fig10, fig5, fig7, fig8, table2, table5};
use submodlib::experiments::figures::{fig6_cluster_of, nearest_query_dist};
use submodlib::kernel::KernelBackend;

#[test]
fn table2_optimizer_ordering_holds() {
    // paper Table 2: naive slowest; lazy & lazier much faster; stochastic
    // in between. Run at reduced scale for CI sanity; the bench binary
    // runs the full 500/100 workload.
    let rows = table2(400, 80, 2, 42).unwrap();
    let t = |name: &str| rows.iter().find(|r| r.optimizer == name).unwrap().seconds;
    let naive = t("NaiveGreedy");
    assert!(t("LazyGreedy") < naive, "lazy {} vs naive {naive}", t("LazyGreedy"));
    assert!(t("LazierThanLazyGreedy") < naive);
    assert!(t("StochasticGreedy") < naive);
    // (the finer lazy-vs-stochastic ordering — paper: 417 ms vs 1.17 s —
    // is asserted in the release-mode bench `optimizers`, where the
    // workload matches the paper's scale; debug-mode timing at reduced
    // scale is too noisy for it)
}

#[test]
fn table2_lazy_preserves_quality_stochastic_close() {
    let rows = table2(300, 50, 1, 7).unwrap();
    let v = |name: &str| rows.iter().find(|r| r.optimizer == name).unwrap().value;
    assert!((v("LazyGreedy") - v("NaiveGreedy")).abs() < 1e-6);
    assert!(v("StochasticGreedy") >= 0.9 * v("NaiveGreedy"));
    assert!(v("LazierThanLazyGreedy") >= 0.9 * v("NaiveGreedy"));
}

#[test]
fn table5_scaling_shape() {
    // near-quadratic growth dominated by kernel construction
    let rows = table5(&[100, 200, 400], 256, 20, 7, &KernelBackend::Native).unwrap();
    let t100 = rows[0].total_seconds;
    let t400 = rows[2].total_seconds;
    // 4x data → ≥4x time (quadratic would be 16x; allow thread noise)
    assert!(t400 > 2.0 * t100, "t400 {t400} vs t100 {t100}");
    // kernel build must dominate selection at the largest size (paper §9
    // implies end-to-end cost is kernel-bound)
    assert!(rows[2].kernel_seconds > rows[2].select_seconds * 0.5);
}

#[test]
fn fig5_fl_representation_vs_dsum_diversity() {
    let r = fig5(10).unwrap();
    // paper: FL picks cluster centers first; outlier only at the end
    let fl_rank = r.fl_first_outlier_rank.unwrap_or(usize::MAX);
    // paper: DisparitySum picks remote corners (outliers) first
    let ds_rank = r.dsum_first_outlier_rank.expect("dsum never picked an outlier");
    assert!(ds_rank <= 2, "DisparitySum outlier rank {ds_rank}");
    assert!(fl_rank > ds_rank, "FL rank {fl_rank} vs DSum rank {ds_rank}");
    // FL with budget < 10 would not pick the outlier at all:
    if fl_rank != usize::MAX {
        assert!(fl_rank >= 4, "FL picked outlier too early: {fl_rank}");
    }
}

#[test]
fn fig7_flqmi_eta_sweep_behaviour() {
    let (ground, queries, ranges, _) = controlled::fig6_dataset();
    let sels = fig7(&[0.0, 1.0, 100.0], 10).unwrap();

    // η=0: one pick per query then saturation (near-zero residual gains)
    let (_, s0) = &sels[0];
    assert!(s0.order[0].1 > 0.1 && s0.order[1].1 > 0.1);
    assert!(s0.order[2..].iter().all(|(_, g)| *g < 0.05), "no saturation at eta=0");
    let c0 = fig6_cluster_of(s0.order[0].0, &ranges);
    let c1 = fig6_cluster_of(s0.order[1].0, &ranges);
    assert_ne!(c0, c1, "first two picks must split the two query clusters");

    // η=100: picks become query-dominant — all near queries
    let (_, s100) = &sels[2];
    let near = s100
        .order
        .iter()
        .filter(|(e, _)| nearest_query_dist(&ground, &queries, *e) < 2.5)
        .count();
    assert!(near >= 8, "only {near}/10 picks query-adjacent at eta=100");
}

#[test]
fn fig8_gcmi_pure_retrieval_no_diversity() {
    let (ground, queries, ranges, _) = controlled::fig6_dataset();
    let sel = fig8(10).unwrap();
    // all picks query-adjacent...
    for &(e, _) in &sel.order {
        assert!(nearest_query_dist(&ground, &queries, e) < 2.5, "pick {e} too far");
    }
    // ...and confined to the two query clusters (no coverage of cluster 2)
    for &(e, _) in &sel.order {
        let c = fig6_cluster_of(e, &ranges);
        assert!(c < 2, "GCMI picked from non-query cluster {c}");
    }
}

#[test]
fn fig10_eta_controls_query_focus_on_vgg_features() {
    let rs = fig10(150, 128, 6, &[0.0, 3.0], 12).unwrap();
    let f0 = rs[0].query_cluster_fraction;
    let f3 = rs[1].query_cluster_fraction;
    // at η=0 FLQMI saturates after covering the queries and diversifies
    // into other clusters; at high η it stays query-dominant
    assert!(f3 >= f0, "eta=3 fraction {f3} < eta=0 fraction {f0}");
    assert!(f3 >= 0.8, "high-eta picks not query-dominated: {f3}");
    // η=0 must still start with one pick per query cluster
    let first2 = &rs[0].pick_clusters[..2];
    assert!(first2.contains(&0) && first2.contains(&1), "{first2:?}");
}

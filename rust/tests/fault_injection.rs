//! Deterministic fault-injection suite (ISSUE 6): every recovery path of
//! the fault-tolerant coordinator, forced via `coordinator::faults` and
//! pinned as a reproducible test. Requires the `faults` cargo feature
//! (see Cargo.toml `required-features`; CI runs this with
//! `--features faults`).
//!
//! The failpoint registry is process-global, so tests serialize on one
//! mutex and disarm every site on entry and exit (panic-safe guard) —
//! ordering between tests can never change an outcome.

use std::sync::Mutex;
use std::time::Duration;

use submodlib::config::CoordinatorConfig;
use submodlib::coordinator::faults::{self, FaultAction, FaultSpec, Trigger};
use submodlib::coordinator::{Coordinator, SelectRequest};
use submodlib::data::synthetic;
use submodlib::error::SubmodError;
use submodlib::runtime::cancel::CancelReason;

static SERIAL: Mutex<()> = Mutex::new(());

/// Serialize the test and guarantee a clean registry before and after,
/// even when the test panics.
struct FaultGuard(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl Drop for FaultGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

fn exclusive() -> FaultGuard {
    let g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faults::clear();
    FaultGuard(g)
}

const SHARD_CAP: usize = 32;
const N_ITEMS: usize = 96; // 3 shards: base ids 0, 32, 64

fn cfg(workers: usize, quorum: Option<usize>) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        shard_capacity: SHARD_CAP,
        ingest_depth: 64,
        per_shard_factor: 2.0,
        min_shard_quorum: quorum,
        // admission wide open and breakers off by default: the ISSUE 6
        // tests above exercise per-request fault paths, not overload
        max_inflight: 4,
        admission_queue_depth: 16,
        breaker_threshold: None,
        breaker_probe_after: 4,
    }
}

fn seeded(workers: usize, quorum: Option<usize>) -> Coordinator {
    seeded_cfg(cfg(workers, quorum))
}

fn seeded_cfg(cfg: CoordinatorConfig) -> Coordinator {
    let c = Coordinator::new(cfg);
    let data = synthetic::blobs(N_ITEMS, 2, 5, 1.5, 77);
    let h = c.ingest_handle();
    for i in 0..N_ITEMS {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    c
}

fn arm(site: &str, action: FaultAction, key: Option<usize>, trigger: Trigger) {
    faults::inject(site, FaultSpec { action, key, trigger });
}

// ---------------------------------------------------------------------
// Pillar 1: panic isolation, retry, quorum, degraded responses
// ---------------------------------------------------------------------

#[test]
fn stage1_panic_yields_degraded_response() {
    let _g = exclusive();
    // shard base_id 0 panics on BOTH attempts (first + retry) — key
    // filtering makes this deterministic under any claim interleaving
    arm(faults::STAGE1_EVAL, FaultAction::Panic, Some(0), Trigger::Times(2));
    let c = seeded(2, Some(1));
    let resp = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert!(resp.degraded, "a dropped shard must mark the response degraded");
    assert_eq!(resp.failed_shards, [0]); // acceptance: failed_shards ≥ 1
    assert_eq!(resp.shards, 3);
    assert_eq!(resp.ids.len(), 8);
    // nothing can be selected from the dead shard's id range
    assert!(resp.ids.iter().all(|&id| id >= SHARD_CAP), "{:?}", resp.ids);
    let m = c.metrics();
    assert_eq!(m.shard_retries, 1);
    assert_eq!(m.shard_failures, 1);
    assert_eq!(m.selections_degraded, 1);
    assert_eq!(m.selections_served, 1);
    assert_eq!(m.selections_failed, 0);
}

#[test]
fn quorum_policy_is_enforced() {
    let _g = exclusive();
    // same dead shard, but the default quorum (all shards) refuses to
    // serve a degraded answer
    arm(faults::STAGE1_EVAL, FaultAction::Panic, Some(0), Trigger::Times(2));
    let c = seeded(2, None);
    let err = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap_err();
    assert!(
        matches!(&err, SubmodError::Coordinator(m) if m.contains("quorum")),
        "{err}"
    );
    let m = c.metrics();
    assert_eq!(m.selections_failed, 1);
    assert_eq!(m.selections_served, 0);
    assert_eq!(m.shard_failures, 1);

    // quorum 2 tolerates one dead shard out of three...
    faults::clear();
    arm(faults::STAGE1_EVAL, FaultAction::Panic, Some(0), Trigger::Times(2));
    let c = seeded(2, Some(2));
    assert!(c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap().degraded);

    // ...but not two dead shards. A single worker claims shards serially
    // (base ids 0, 32, 64), so an unfiltered Times(4) kills exactly
    // shards 0 and 32 (two attempts each) deterministically.
    faults::clear();
    arm(faults::STAGE1_EVAL, FaultAction::Panic, None, Trigger::Times(4));
    let c = seeded(1, Some(2));
    let err = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap_err();
    assert!(
        matches!(&err, SubmodError::Coordinator(m) if m.contains("quorum")),
        "{err}"
    );
    let m = c.metrics();
    assert_eq!(m.shard_failures, 2);
    assert_eq!(m.shard_retries, 2);
}

#[test]
fn retried_shard_recovers_byte_identically() {
    let _g = exclusive();
    // baseline: no faults
    let baseline = seeded(2, None)
        .select(SelectRequest { budget: 8, ..Default::default() })
        .unwrap();
    // shard 0 panics once; the retry succeeds and the answer is
    // byte-identical to the healthy run (memoized-state determinism)
    arm(faults::STAGE1_EVAL, FaultAction::Panic, Some(0), Trigger::Times(1));
    let c = seeded(2, None);
    let resp = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert!(!resp.degraded);
    assert!(resp.failed_shards.is_empty());
    assert_eq!(resp.ids, baseline.ids);
    assert_eq!(resp.value.to_bits(), baseline.value.to_bits());
    let m = c.metrics();
    assert_eq!(m.shard_retries, 1);
    assert_eq!(m.shard_failures, 0);
    assert_eq!(m.selections_degraded, 0);
}

#[test]
fn injected_errors_degrade_like_panics() {
    let _g = exclusive();
    // typed-error faults (not panics) follow the same retry→drop path
    arm(faults::STAGE1_EVAL, FaultAction::Error, Some(64), Trigger::Times(2));
    let c = seeded(2, Some(1));
    let resp = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert!(resp.degraded);
    assert_eq!(resp.failed_shards, [64]);
    assert!(resp.ids.iter().all(|&id| id < 64));
}

#[test]
fn kernel_build_fault_is_retried_inside_the_shard() {
    let _g = exclusive();
    // a fault one layer deeper — objective/kernel construction — is
    // contained by the same shard isolation; single worker makes the
    // claim order (and thus which build fails) deterministic
    arm(faults::KERNEL_BUILD, FaultAction::Error, Some(SHARD_CAP), Trigger::Times(1));
    let c = seeded(1, None);
    let resp = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert!(!resp.degraded);
    let m = c.metrics();
    assert_eq!(m.shard_retries, 1);
    assert_eq!(m.shard_failures, 0);
}

// ---------------------------------------------------------------------
// Pillar 2: deadlines
// ---------------------------------------------------------------------

#[test]
fn injected_delay_past_deadline_fails_typed() {
    let _g = exclusive();
    // every stage-1 evaluation stalls 100 ms against a 20 ms deadline:
    // whichever shard runs first blows the budget, the remaining claims
    // are skipped, and the request fails with the typed error
    arm(
        faults::STAGE1_EVAL,
        FaultAction::Delay(Duration::from_millis(100)),
        None,
        Trigger::Times(u32::MAX),
    );
    let c = seeded(2, None);
    let err = c
        .select(SelectRequest {
            budget: 8,
            deadline: Some(Duration::from_millis(20)),
            ..Default::default()
        })
        .unwrap_err();
    assert!(matches!(err, SubmodError::DeadlineExceeded), "{err}");
    let m = c.metrics();
    assert_eq!(m.deadline_exceeded, 1);
    assert_eq!(m.selections_failed, 1);
    // deadline skips are not shard failures
    assert_eq!(m.shard_failures, 0);
    assert_eq!(m.shard_retries, 0);

    // the same coordinator still serves once the fault is cleared
    faults::clear();
    let resp = c
        .select(SelectRequest {
            budget: 8,
            deadline: Some(Duration::from_secs(600)),
            ..Default::default()
        })
        .unwrap();
    assert_eq!(resp.ids.len(), 8);
    assert_eq!(c.metrics().deadline_exceeded, 1);
}

// ---------------------------------------------------------------------
// Pillar 3: supervised ingest
// ---------------------------------------------------------------------

#[test]
fn killed_drain_is_respawned_and_ingest_resumes() {
    let _g = exclusive();
    let c = Coordinator::new(cfg(2, None));
    let h = c.ingest_handle();
    let data = synthetic::blobs(N_ITEMS, 2, 5, 1.5, 77);
    for i in 0..40 {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    // kill the drain on its next batch: the in-flight producer gets a
    // typed error (never a hang), the supervisor restarts the loop
    arm(faults::DRAIN_LOOP, FaultAction::Panic, None, Trigger::Times(1));
    let err = h.ingest(data.row(40).to_vec()).unwrap_err();
    assert!(matches!(err, SubmodError::Coordinator(_)), "{err}");
    // the restart is recorded (the supervisor increments after the
    // unwind completes, concurrently with this assertion — poll briefly)
    let mut restarts = 0;
    for _ in 0..200 {
        restarts = c.metrics().drain_restarts;
        if restarts > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(restarts, 1, "supervisor must record exactly one drain restart");
    // ingest resumes against the SAME store: ids continue where the
    // pre-crash state left off (the crashed row was dropped, at-most-once)
    let next_id = h.ingest(data.row(41).to_vec()).unwrap();
    assert_eq!(next_id, 40);
    assert_eq!(c.len(), 41);
    // and the coordinator still selects over everything ingested
    let resp = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
    assert_eq!(resp.ids.len(), 5);
    assert_eq!(c.metrics().items_ingested, 41);
}

#[test]
fn drain_error_fault_fails_batch_without_restart() {
    let _g = exclusive();
    let c = Coordinator::new(cfg(2, None));
    let h = c.ingest_handle();
    h.ingest(vec![1.0, 2.0]).unwrap();
    arm(faults::DRAIN_LOOP, FaultAction::Error, None, Trigger::Times(1));
    let err = h.ingest(vec![3.0, 4.0]).unwrap_err();
    assert!(matches!(&err, SubmodError::Coordinator(m) if m.contains("injected")), "{err}");
    // an error path keeps the drain alive — no restart, next item lands
    assert_eq!(h.ingest(vec![5.0, 6.0]).unwrap(), 1);
    assert_eq!(c.metrics().drain_restarts, 0);
}

// ---------------------------------------------------------------------
// Pillar 4: snapshot / restore
// ---------------------------------------------------------------------

#[test]
fn checkpoint_restore_select_is_byte_identical() {
    let _g = exclusive();
    let c = seeded(2, None);
    let req = || SelectRequest { budget: 10, ..Default::default() };
    let before = c.select(req()).unwrap();
    let blob = c.checkpoint();
    drop(c); // "crash" the original service

    let restored = Coordinator::from_checkpoint(cfg(2, None), &blob).unwrap();
    assert_eq!(restored.len(), N_ITEMS);
    let after = restored.select(req()).unwrap();
    assert_eq!(after.ids, before.ids, "restored selection must match pre-crash ids");
    assert_eq!(
        after.value.to_bits(),
        before.value.to_bits(),
        "restored objective value must be bit-identical"
    );
    assert_eq!(after.shards, before.shards);
    assert_eq!(after.stage1_candidates, before.stage1_candidates);

    // restore is repeatable: a second restore from the same blob agrees
    let again = Coordinator::from_checkpoint(cfg(2, None), &blob).unwrap();
    let r2 = again.select(req()).unwrap();
    assert_eq!(r2.ids, before.ids);

    // the restored service keeps living: ingest continues the id space
    let h = restored.ingest_handle();
    let extra = synthetic::blobs(8, 2, 2, 1.0, 5);
    for i in 0..8 {
        assert_eq!(h.ingest(extra.row(i).to_vec()).unwrap(), N_ITEMS + i);
    }
    assert_eq!(restored.len(), N_ITEMS + 8);
    assert!(restored.select(req()).is_ok());
}

// ---------------------------------------------------------------------
// Pillar 5 (ISSUE 8): admission under forced saturation
// ---------------------------------------------------------------------

#[test]
fn saturation_sheds_with_typed_overloaded() {
    let _g = exclusive();
    // uncontended baseline for the byte-identity check
    let baseline = seeded(2, None)
        .select(SelectRequest { budget: 8, ..Default::default() })
        .unwrap();

    // one permit, one queue slot; the first selection is held in flight
    // at the stage-2 merge by a Delay failpoint (generous vs the
    // microsecond-scale orchestration below — no timing asserts, the
    // delay only keeps the permit occupied while we saturate the gate)
    arm(
        faults::STAGE2_MERGE,
        FaultAction::Delay(Duration::from_millis(1500)),
        None,
        Trigger::Times(1),
    );
    let mut saturated = cfg(2, None);
    saturated.max_inflight = 1;
    saturated.admission_queue_depth = 1;
    let c = seeded_cfg(saturated);

    // lint: allow(thread-spawn) — tenants are external callers racing the admission gate, not pool work
    std::thread::scope(|scope| {
        // tenant A takes the only permit and stalls in stage 2
        let a = scope.spawn(|| c.select(SelectRequest { budget: 8, ..Default::default() }));
        while c.metrics().selections_inflight == 0 {
            std::thread::yield_now();
        }
        // tenant B fills the single queue slot
        let b = scope.spawn(|| c.select(SelectRequest { budget: 8, ..Default::default() }));
        while c.metrics().admission_waits == 0 {
            std::thread::yield_now();
        }
        // the gate is now saturated (permit held + queue full): a third
        // request sheds immediately with the typed overload error
        let err = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap_err();
        assert!(matches!(err, SubmodError::Overloaded), "{err}");

        // admission schedules *when*, never *what*: both admitted
        // selections are byte-identical to the uncontended baseline
        let ra = a.join().unwrap().unwrap();
        let rb = b.join().unwrap().unwrap();
        for r in [&ra, &rb] {
            assert_eq!(r.ids, baseline.ids);
            assert_eq!(r.value.to_bits(), baseline.value.to_bits());
            assert!(!r.degraded);
        }
    });

    let m = c.metrics();
    assert_eq!(m.selections_shed, 1);
    assert_eq!(m.admission_waits, 1);
    assert_eq!(m.selections_served, 2);
    assert_eq!(m.selections_failed, 1, "the shed request is the only failure");
    assert_eq!(m.selections_inflight, 0, "all permits returned");
    assert_eq!(m.deadline_exceeded, 0, "shed ≠ deadline-exceeded");
    assert_eq!(m.shard_failures, 0, "shedding charges no shard work");
    // survivorship-bias fix: the shed request's latency is visible in the
    // failed histogram, and the success percentiles exclude it
    assert!(m.failed_latency_p99_us > 0);
}

// ---------------------------------------------------------------------
// Pillar 6 (ISSUE 8): circuit-breaker lifecycle, request-count based
// ---------------------------------------------------------------------

#[test]
fn breaker_trips_quarantines_probes_and_recovers() {
    let _g = exclusive();
    let healthy = seeded(1, None)
        .select(SelectRequest { budget: 8, ..Default::default() })
        .unwrap();

    // shard 64 fails every evaluation until the registry is cleared
    arm(faults::STAGE1_EVAL, FaultAction::Error, Some(64), Trigger::Times(u32::MAX));
    let mut bcfg = cfg(1, Some(1));
    bcfg.breaker_threshold = Some(2);
    bcfg.breaker_probe_after = 2;
    let c = seeded_cfg(bcfg);
    let sel = || SelectRequest { budget: 8, ..Default::default() };

    // r1: first consecutive failure (eval + retry) — breaker still Closed
    let r1 = c.select(sel()).unwrap();
    assert!(r1.degraded);
    assert_eq!(r1.failed_shards, [64]);
    assert_eq!(c.metrics().breaker_trips, 0);

    // r2: second consecutive failure reaches the threshold — trips Open
    let r2 = c.select(sel()).unwrap();
    assert!(r2.degraded);
    let m = c.metrics();
    assert_eq!(m.breaker_trips, 1);
    assert_eq!(m.shards_quarantined, 1);
    assert_eq!(m.shard_failures, 2);
    assert_eq!(m.shard_retries, 2);

    // r3: quarantined shard is skipped — still degraded and counted in
    // failed_shards, but no evaluation (and no retry) is spent on it
    let r3 = c.select(sel()).unwrap();
    assert!(r3.degraded);
    assert_eq!(r3.failed_shards, [64]);
    let m = c.metrics();
    assert_eq!(m.shard_retries, 2, "skipped shard costs no evaluation");
    assert_eq!(m.shard_failures, 2);

    // r4: probe_after(2) requests seen since opening — Half-Open, this
    // request carries the probe; the shard still fails, so it re-opens
    let r4 = c.select(sel()).unwrap();
    assert!(r4.degraded);
    let m = c.metrics();
    assert_eq!(m.breaker_probes, 1);
    assert_eq!(m.breaker_recoveries, 0);
    assert_eq!(m.shards_quarantined, 1, "failed probe keeps the quarantine");
    assert_eq!(m.shard_failures, 3);

    // the shard heals
    faults::clear();

    // r5: the re-opened breaker still waits out probe_after requests —
    // skipped even though the shard would now succeed
    let r5 = c.select(sel()).unwrap();
    assert!(r5.degraded);
    assert_eq!(r5.failed_shards, [64]);
    assert_eq!(c.metrics().shard_retries, 3, "no evaluation while re-opened");

    // r6: second probe succeeds — Recovered, and the answer is
    // byte-identical to a never-faulted coordinator's
    let r6 = c.select(sel()).unwrap();
    assert!(!r6.degraded);
    assert!(r6.failed_shards.is_empty());
    assert_eq!(r6.ids, healthy.ids);
    assert_eq!(r6.value.to_bits(), healthy.value.to_bits());
    let m = c.metrics();
    assert_eq!(m.breaker_probes, 2);
    assert_eq!(m.breaker_recoveries, 1);
    assert_eq!(m.shards_quarantined, 0);

    // r7: back in steady state
    let r7 = c.select(sel()).unwrap();
    assert!(!r7.degraded);
    assert_eq!(r7.ids, healthy.ids);
    assert_eq!(c.metrics().selections_degraded, 5, "r1–r5 were degraded");
}

// ---------------------------------------------------------------------
// Pillar 7 (ISSUE 8): graceful shutdown drains in-flight work
// ---------------------------------------------------------------------

#[test]
fn shutdown_waits_for_inflight_selection() {
    let _g = exclusive();
    // hold one selection in flight at the stage-2 merge
    arm(
        faults::STAGE2_MERGE,
        FaultAction::Delay(Duration::from_millis(300)),
        None,
        Trigger::Times(1),
    );
    let c = seeded(2, None);
    // lint: allow(thread-spawn) — tenant is an external caller overlapping shutdown, not pool work
    std::thread::scope(|scope| {
        let inflight =
            scope.spawn(|| c.select(SelectRequest { budget: 8, ..Default::default() }));
        while c.metrics().selections_inflight == 0 {
            std::thread::yield_now();
        }
        // shutdown must block until the admitted selection completes —
        // proven by the counters after it returns, not by timing
        let blob = c.shutdown().unwrap();
        let resp = inflight.join().unwrap().unwrap();
        assert_eq!(resp.ids.len(), 8);
        let m = c.metrics();
        assert_eq!(m.selections_served, 1, "in-flight selection finished before shutdown");
        assert_eq!(m.selections_inflight, 0);

        // post-shutdown work is refused with typed errors, never a hang
        let err = c.select(SelectRequest::default()).unwrap_err();
        assert!(matches!(err, SubmodError::ShuttingDown), "{err}");
        assert!(c.ingest_handle().ingest(vec![0.0, 0.0]).is_err());

        // the final checkpoint restores a byte-identical service
        let restored = Coordinator::from_checkpoint(cfg(2, None), &blob).unwrap();
        let again =
            restored.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
        assert_eq!(again.ids, resp.ids);
        assert_eq!(again.value.to_bits(), resp.value.to_bits());
    });
}

// ---------------------------------------------------------------------
// Pillar 8 (ISSUE 10): cooperative cancellation through every compute
// layer. The poll-only sites (TILE_CLAIM, GAIN_CHUNK) + FaultAction::
// Cancel force a cancel at an exact depth — mid-kernel-build, mid-gain-
// scan, mid-merge — with no sleeps and no timing asserts. The contract
// everywhere: a typed `SubmodError::Cancelled`, `selections_cancelled`
// bumped, NO shard charged (cancel is the request's fault, not the
// shard's), and the same coordinator serving a byte-identical answer on
// the very next request.
// ---------------------------------------------------------------------

/// Shared scenario: arm `site` to fire the ambient cancel token on its
/// first hit, prove the typed abort + clean metrics, then prove the
/// coordinator is immediately reusable with a byte-identical answer.
fn assert_cancel_unwinds_cleanly(site: &str) {
    let baseline = seeded(2, None)
        .select(SelectRequest { budget: 8, ..Default::default() })
        .unwrap();
    arm(site, FaultAction::Cancel(CancelReason::Manual), None, Trigger::Times(1));
    let c = seeded(2, None);
    let err = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap_err();
    assert!(matches!(err, SubmodError::Cancelled), "{site}: {err}");
    let m = c.metrics();
    assert_eq!(m.selections_cancelled, 1, "{site}: mid-flight unwind counted");
    assert_eq!(m.selections_failed, 1);
    assert_eq!(m.deadline_exceeded, 0, "{site}: manual cancel ≠ deadline");
    assert_eq!(m.shard_failures, 0, "{site}: cancel never charges shards");
    assert_eq!(m.shard_retries, 0, "{site}: cancelled evaluations are not retried");
    assert_eq!(m.selections_inflight, 0, "{site}: permit returned");
    // cancelled latencies land in the failed histogram (ISSUE 8 split)
    assert!(m.failed_latency_p99_us > 0);
    // the pool, memoized states and builders are clean: the next request
    // on the SAME coordinator is byte-identical to an unfaulted run
    faults::clear();
    let again = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert_eq!(again.ids, baseline.ids, "{site}: post-cancel selection drifted");
    assert_eq!(again.value.to_bits(), baseline.value.to_bits(), "{site}");
    assert!(!again.degraded);
    assert_eq!(c.metrics().selections_served, 1);
}

#[test]
fn cancel_mid_gain_scan_unwinds_cleanly() {
    let _g = exclusive();
    // fires inside optimizers::batch_gains, between two GAIN_CHUNK
    // chunks of the first stage-1 shard scan
    assert_cancel_unwinds_cleanly(faults::GAIN_CHUNK);
}

#[test]
fn cancel_mid_kernel_build_unwinds_cleanly() {
    let _g = exclusive();
    // fires inside the kernel::tile claim loop of the first per-shard
    // dense kernel build — the partial kernel is discarded at
    // ObjectiveKind::build's check, never handed to an optimizer
    assert_cancel_unwinds_cleanly(faults::TILE_CLAIM);
}

#[test]
fn cancel_mid_stage2_merge_build_unwinds_cleanly() {
    let _g = exclusive();
    // key the TILE_CLAIM site by the stage-2 merge build's column count
    // (the stage-1 candidate union) so stage 1 completes untouched and
    // the cancel lands exactly inside the merge kernel build
    let baseline = seeded(2, None)
        .select(SelectRequest { budget: 8, ..Default::default() })
        .unwrap();
    assert_ne!(
        baseline.stage1_candidates, SHARD_CAP,
        "key must distinguish the merge build from per-shard builds"
    );
    arm(
        faults::TILE_CLAIM,
        FaultAction::Cancel(CancelReason::Manual),
        Some(baseline.stage1_candidates),
        Trigger::Times(1),
    );
    let c = seeded(2, None);
    let err = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap_err();
    assert!(matches!(err, SubmodError::Cancelled), "{err}");
    let m = c.metrics();
    assert_eq!(m.selections_cancelled, 1);
    // stage 1 ran to completion before the cancel: still no shard charged
    assert_eq!(m.shard_failures, 0);
    assert_eq!(m.shard_retries, 0);
    faults::clear();
    let again = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert_eq!(again.ids, baseline.ids);
    assert_eq!(again.value.to_bits(), baseline.value.to_bits());
}

#[test]
fn watchdog_fires_mid_kernel_build_as_typed_deadline() {
    let _g = exclusive();
    // a 200 ms stall inside the tile claim loop vs a 25 ms deadline: the
    // watchdog fires the request token while compute is stuck deep in a
    // kernel build, and the unwind surfaces under the deadline contract
    // (SubmodError::DeadlineExceeded, not a bare Cancelled)
    arm(
        faults::TILE_CLAIM,
        FaultAction::Delay(Duration::from_millis(200)),
        None,
        Trigger::Times(1),
    );
    let c = seeded(2, None);
    let err = c
        .select(SelectRequest {
            budget: 8,
            deadline: Some(Duration::from_millis(25)),
            ..Default::default()
        })
        .unwrap_err();
    assert!(matches!(err, SubmodError::DeadlineExceeded), "{err}");
    let m = c.metrics();
    assert_eq!(m.deadline_exceeded, 1);
    assert_eq!(m.selections_cancelled, 1, "preemptive unwind, not a rim check");
    assert_eq!(m.shard_failures, 0);
    assert_eq!(m.shard_retries, 0);
    // cleared, the same coordinator serves normally again
    faults::clear();
    let resp = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert_eq!(resp.ids.len(), 8);
}

#[test]
fn watchdog_fires_mid_gain_scan_as_typed_deadline() {
    let _g = exclusive();
    // same shape one layer up: the stall sits between gain-scan chunks
    arm(
        faults::GAIN_CHUNK,
        FaultAction::Delay(Duration::from_millis(200)),
        None,
        Trigger::Times(1),
    );
    let c = seeded(2, None);
    let err = c
        .select(SelectRequest {
            budget: 8,
            deadline: Some(Duration::from_millis(25)),
            ..Default::default()
        })
        .unwrap_err();
    assert!(matches!(err, SubmodError::DeadlineExceeded), "{err}");
    let m = c.metrics();
    assert_eq!(m.deadline_exceeded, 1);
    assert_eq!(m.selections_cancelled, 1);
    assert_eq!(m.shard_failures, 0);
}

#[test]
fn shutdown_with_grace_hard_cancels_a_stuck_selection() {
    let _g = exclusive();
    // hold one selection in flight at the stage-2 merge far past the
    // grace budget: shutdown must fire its token instead of waiting out
    // the full stall, and the caller sees the typed cancel
    arm(
        faults::STAGE2_MERGE,
        FaultAction::Delay(Duration::from_millis(600)),
        None,
        Trigger::Times(1),
    );
    let c = seeded(2, None);
    // lint: allow(thread-spawn) — tenant is an external caller overlapping shutdown, not pool work
    std::thread::scope(|scope| {
        let stuck =
            scope.spawn(|| c.select(SelectRequest { budget: 8, ..Default::default() }));
        while c.metrics().selections_inflight == 0 {
            std::thread::yield_now();
        }
        let blob = c.shutdown_with_grace(Duration::from_millis(40)).unwrap();
        let err = stuck.join().unwrap().unwrap_err();
        assert!(matches!(err, SubmodError::Cancelled), "{err}");
        let m = c.metrics();
        assert_eq!(m.selections_cancelled, 1);
        assert_eq!(m.selections_served, 0);
        assert_eq!(m.selections_inflight, 0, "permit returned through the unwind");
        assert_eq!(m.shard_failures, 0);
        // post-shutdown work is refused, and the checkpoint still
        // restores a fully working service
        assert!(matches!(
            c.select(SelectRequest::default()).unwrap_err(),
            SubmodError::ShuttingDown
        ));
        let restored = Coordinator::from_checkpoint(cfg(2, None), &blob).unwrap();
        let resp =
            restored.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
        assert_eq!(resp.ids.len(), 8);
    });
}

#[test]
fn checkpoint_survives_a_degraded_epoch() {
    let _g = exclusive();
    // checkpoint taken while a shard is failing still captures the full
    // ground set — recovery is about the data, not the fault
    arm(faults::STAGE1_EVAL, FaultAction::Panic, Some(0), Trigger::Times(2));
    let c = seeded(2, Some(1));
    let degraded = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert!(degraded.degraded);
    let blob = c.checkpoint();
    faults::clear();
    let restored = Coordinator::from_checkpoint(cfg(2, None), &blob).unwrap();
    let healthy = restored.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    assert!(!healthy.degraded);
    // the healthy run sees all three shards again, including shard 0
    assert_eq!(healthy.shards, 3);
}

//! Integration: the L3 streaming coordinator end-to-end — concurrent
//! producers, selection under a growing ground set, every objective,
//! metrics accounting, and quality vs the flat greedy baseline.

use submodlib::config::CoordinatorConfig;
use submodlib::coordinator::service::ObjectiveKind;
use submodlib::coordinator::{Coordinator, SelectRequest};
use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};

fn cfg(workers: usize, cap: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        workers,
        shard_capacity: cap,
        ingest_depth: 32,
        per_shard_factor: 2.0,
        min_shard_quorum: None,
        // admission wide enough that nothing in this suite queues or
        // sheds unless a test tightens it explicitly
        max_inflight: 8,
        admission_queue_depth: 32,
        breaker_threshold: None,
        breaker_probe_after: 4,
    }
}

#[test]
fn concurrent_ingest_then_select() {
    let c = Coordinator::new(cfg(4, 64));
    let data = synthetic::blobs(512, 4, 8, 1.5, 11);
    let rows: Vec<Vec<f32>> = (0..512).map(|i| data.row(i).to_vec()).collect();
    let mut threads = Vec::new();
    for chunk in rows.chunks(128) {
        let chunk: Vec<Vec<f32>> = chunk.to_vec();
        let h = c.ingest_handle();
        // lint: allow(thread-spawn) — test models external producer threads, not a compute fan-out
        threads.push(std::thread::spawn(move || {
            for r in chunk {
                h.ingest(r).unwrap();
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(c.len(), 512);
    let resp = c.select(SelectRequest { budget: 16, ..Default::default() }).unwrap();
    assert_eq!(resp.ids.len(), 16);
    assert_eq!(resp.shards, 8);
    let m = c.metrics();
    assert_eq!(m.items_ingested, 512);
    assert_eq!(m.selections_served, 1);
}

#[test]
fn quality_vs_flat_greedy_across_shard_counts() {
    let data = synthetic::blobs(300, 2, 6, 1.5, 22);
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let flat = maximize(
        &f,
        Budget::cardinality(10),
        OptimizerKind::LazyGreedy,
        &MaximizeOpts::default(),
    )
    .unwrap();
    for cap in [50, 100, 300] {
        let c = Coordinator::new(cfg(2, cap));
        let h = c.ingest_handle();
        for i in 0..300 {
            h.ingest(data.row(i).to_vec()).unwrap();
        }
        let resp = c.select(SelectRequest { budget: 10, ..Default::default() }).unwrap();
        let v = f.evaluate(&Subset::from_ids(300, &resp.ids));
        assert!(
            v >= 0.85 * flat.value,
            "cap {cap}: two-stage {v} vs flat {}",
            flat.value
        );
    }
}

#[test]
fn single_shard_candidates_contain_flat_solution() {
    // with one shard and factor 2.0, stage 1 runs the same greedy a flat
    // run would for 2×budget picks — so its candidate set must CONTAIN
    // the flat top-8 (greedy chains are prefixes of each other). Stage 2
    // then re-optimizes over the candidates-as-ground-set (GreeDi style),
    // which can pick a different but near-equal-value subset.
    let data = synthetic::blobs(120, 2, 4, 1.0, 33);
    let c = Coordinator::new(cfg(1, 1000));
    let h = c.ingest_handle();
    for i in 0..120 {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    let resp = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let flat = maximize(
        &f,
        Budget::cardinality(8),
        OptimizerKind::LazyGreedy,
        &MaximizeOpts::default(),
    )
    .unwrap();
    // quality of the final answer on the FULL objective
    let v = f.evaluate(&Subset::from_ids(120, &resp.ids));
    assert!(v >= 0.95 * flat.value, "single-shard {v} vs flat {}", flat.value);
}

#[test]
fn all_objectives_serve() {
    let c = Coordinator::new(cfg(2, 40));
    let data = synthetic::blobs(100, 3, 4, 1.0, 44);
    let h = c.ingest_handle();
    for i in 0..100 {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    for obj in [
        ObjectiveKind::FacilityLocation,
        ObjectiveKind::GraphCut { lambda: 0.3 },
        ObjectiveKind::LogDeterminant { reg: 0.1 },
        ObjectiveKind::DisparitySum,
    ] {
        let resp = c
            .select(SelectRequest { objective: obj, budget: 6, ..Default::default() })
            .unwrap();
        assert_eq!(resp.ids.len(), 6, "{obj:?}");
        let uniq: std::collections::HashSet<_> = resp.ids.iter().collect();
        assert_eq!(uniq.len(), 6, "{obj:?} returned duplicates");
    }
    assert_eq!(c.metrics().selections_served, 4);
}

#[test]
fn concurrent_selects_are_byte_identical_to_serial() {
    // multi-tenant service behavior: N clients hammering select() on a
    // frozen ground set must each get exactly the serial answer — the
    // fan-out's claim/slot structure and the shared pool may reorder
    // *work*, never *results*
    let c = Coordinator::new(cfg(2, 48));
    let data = synthetic::blobs(256, 3, 6, 1.2, 66);
    let h = c.ingest_handle();
    for i in 0..256 {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    let reqs = [
        SelectRequest { budget: 9, ..Default::default() },
        SelectRequest {
            objective: ObjectiveKind::GraphCut { lambda: 0.3 },
            budget: 7,
            ..Default::default()
        },
    ];
    // serial baselines first (store is frozen: no ingest from here on)
    let baselines: Vec<_> =
        reqs.iter().map(|r| c.select(r.clone()).unwrap()).collect();
    let served_before = c.metrics().selections_served;
    const TENANTS: usize = 6;
    const ROUNDS: usize = 4;
    // lint: allow(thread-spawn) — tenants are external callers racing the coordinator, not pool work
    std::thread::scope(|scope| {
        for t in 0..TENANTS {
            let c = &c;
            let req = &reqs[t % reqs.len()];
            let base = &baselines[t % reqs.len()];
            scope.spawn(move || {
                for _ in 0..ROUNDS {
                    let resp = c.select(req.clone()).unwrap();
                    assert_eq!(resp.ids, base.ids, "tenant {t} diverged from serial");
                    assert_eq!(
                        resp.value.to_bits(),
                        base.value.to_bits(),
                        "tenant {t} value not bit-identical"
                    );
                    assert!(!resp.degraded);
                }
            });
        }
    });
    let m = c.metrics();
    assert_eq!(m.selections_served, served_before + (TENANTS * ROUNDS) as u64);
    assert_eq!(m.selections_failed, 0);
    assert_eq!(m.shard_failures, 0);
}

#[test]
fn admission_bounded_tenants_byte_identical_to_serial() {
    // ISSUE 8 acceptance: with max_inflight strictly below the tenant
    // count, tenants are forced through the admission gate (some wait in
    // the FIFO queue) — yet every admitted selection is byte-identical
    // to the serial baseline, and a deep-enough queue sheds nothing.
    // Admission schedules *when* a selection runs, never *what* it
    // computes.
    const TENANTS: usize = 6;
    const ROUNDS: usize = 3;
    let mut config = cfg(2, 48);
    config.max_inflight = 2; // < TENANTS: contention is guaranteed
    config.admission_queue_depth = TENANTS * ROUNDS; // deep enough: no sheds
    let c = Coordinator::new(config);
    let data = synthetic::blobs(256, 3, 6, 1.2, 66);
    let h = c.ingest_handle();
    for i in 0..256 {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    let req = SelectRequest { budget: 9, ..Default::default() };
    let baseline = c.select(req.clone()).unwrap();
    // lint: allow(thread-spawn) — tenants are external callers racing the admission gate, not pool work
    std::thread::scope(|scope| {
        for t in 0..TENANTS {
            let c = &c;
            let req = &req;
            let baseline = &baseline;
            scope.spawn(move || {
                for _ in 0..ROUNDS {
                    let resp = c.select(req.clone()).unwrap();
                    assert_eq!(resp.ids, baseline.ids, "tenant {t} diverged under contention");
                    assert_eq!(
                        resp.value.to_bits(),
                        baseline.value.to_bits(),
                        "tenant {t} value not bit-identical under contention"
                    );
                }
            });
        }
    });
    let m = c.metrics();
    assert_eq!(m.selections_served, 1 + (TENANTS * ROUNDS) as u64);
    assert_eq!(m.selections_shed, 0, "a deep queue must not shed");
    assert_eq!(m.selections_failed, 0);
    assert_eq!(m.selections_inflight, 0, "all permits returned");
    // NOTE: admission_waits is not asserted > 0 here — whether tenants
    // actually overlap at the gate depends on OS scheduling (a
    // single-core box may serialize them legitimately). The queueing and
    // shedding paths are pinned deterministically by the saturation
    // failpoint test in tests/fault_injection.rs.
}

#[test]
fn latency_metrics_populated() {
    let c = Coordinator::new(cfg(2, 64));
    let data = synthetic::blobs(200, 2, 4, 1.0, 55);
    let h = c.ingest_handle();
    for i in 0..200 {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    for _ in 0..5 {
        c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
    }
    let m = c.metrics();
    assert_eq!(m.selections_served, 5);
    assert!(m.latency_p50_us > 0);
    assert!(m.latency_p99_us >= m.latency_p50_us);
}

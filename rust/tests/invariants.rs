//! Property-based invariant suite (util::prop, seeded PCG streams):
//!
//! For every function in the library:
//!  P1 marginal_gain(X,e) == evaluate(X∪e) − evaluate(X)
//!  P2 memoized gains == stateless gains after arbitrary update sequences
//!  P3 diminishing returns (submodular functions only): A⊆B ⇒ f(e|A) ≥ f(e|B)
//!  P4 monotonicity (monotone functions only): gains ≥ 0
//! For the optimizers:
//!  P5 LazyGreedy solution == NaiveGreedy solution (submodular f)
//!  P6 greedy value ≥ value of a random same-size subset
//! For the information measures:
//!  P7 generic-wrapper identities (MI/CG/CMI definitions) hold exactly

use submodlib::data::synthetic;
use submodlib::functions::cg::Flcg;
use submodlib::functions::disparity_min::DisparityMin;
use submodlib::functions::disparity_min_sum::DisparityMinSum;
use submodlib::functions::disparity_sum::DisparitySum;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::feature_based::{ConcaveShape, FeatureBased};
use submodlib::functions::generic::{ConditionalGain, ConditionalMutualInformation, MutualInformation};
use submodlib::functions::graph_cut::GraphCut;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::mi::{Flqmi, Flvmi, Gcmi};
use submodlib::functions::prob_set_cover::ProbabilisticSetCover;
use submodlib::functions::set_cover::SetCover;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric, RectKernel};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::rng::Pcg64;
use submodlib::util::prop::{check, gen};

/// Random instance of each function family over a random matrix.
fn random_function(rng: &mut Pcg64) -> Box<dyn SetFunction> {
    let data = gen::matrix(rng, 8, 24, 2, 6);
    let n = data.rows();
    match rng.next_below(9) {
        0 => Box::new(FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean))),
        8 => Box::new(DisparityMinSum::new(DenseKernel::distances_from_data(&data))),
        1 => Box::new(
            GraphCut::new(
                DenseKernel::from_data(&data, Metric::Euclidean),
                0.1 + 0.8 * rng.next_f64(),
            )
            .unwrap(),
        ),
        2 => Box::new(
            LogDeterminant::with_regularization(
                DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 }),
                0.2,
            )
            .unwrap(),
        ),
        3 => {
            let m = 12;
            let cover: Vec<Vec<u32>> = (0..n)
                .map(|_| (0..3).map(|_| rng.next_below(m) as u32).collect())
                .collect();
            let weights: Vec<f64> = (0..m).map(|_| rng.next_f64() * 4.0).collect();
            Box::new(SetCover::new(cover, weights).unwrap())
        }
        4 => {
            let m = 10;
            let probs: Vec<Vec<f32>> =
                (0..n).map(|_| (0..m).map(|_| rng.next_f32()).collect()).collect();
            let weights: Vec<f64> = (0..m).map(|_| rng.next_f64() * 4.0).collect();
            Box::new(ProbabilisticSetCover::new(probs, weights).unwrap())
        }
        5 => {
            let m = 8;
            let feats: Vec<Vec<(u32, f32)>> = (0..n)
                .map(|_| (0..3).map(|_| (rng.next_below(m) as u32, rng.next_f32())).collect())
                .collect();
            let shape = match rng.next_below(3) {
                0 => ConcaveShape::Log,
                1 => ConcaveShape::Sqrt,
                _ => ConcaveShape::Inverse,
            };
            Box::new(FeatureBased::new(feats, vec![1.0; m], shape).unwrap())
        }
        6 => Box::new(DisparitySum::new(DenseKernel::distances_from_data(&data))),
        _ => Box::new(DisparityMin::new(DenseKernel::distances_from_data(&data))),
    }
}

#[test]
fn p1_marginal_gain_is_evaluate_delta() {
    check("P1 gain == Δevaluate", 101, 60, |rng| {
        let f = random_function(rng);
        let n = f.n();
        let ids = gen::subset_ids(rng, n, n / 2);
        let s = Subset::from_ids(n, &ids);
        let Some(e) = gen::fresh_element(rng, n, &ids) else { return Ok(()) };
        let delta = f.evaluate(&s.union_with(&[e])) - f.evaluate(&s);
        let gain = f.marginal_gain(&s, e);
        if (delta - gain).abs() > 1e-4 * (1.0 + delta.abs()) {
            return Err(format!("{}: gain {gain} vs delta {delta}", f.name()));
        }
        Ok(())
    });
}

#[test]
fn p2_memoized_equals_stateless_after_updates() {
    check("P2 memoized == stateless", 202, 40, |rng| {
        let mut f = random_function(rng);
        let n = f.n();
        let init_ids = gen::subset_ids(rng, n, n / 3);
        let mut s = Subset::from_ids(n, &init_ids);
        f.init_memoization(&s);
        for _ in 0..3 {
            // probe a few candidates
            for _ in 0..4 {
                let Some(e) = gen::fresh_element(rng, n, s.order()) else { break };
                let fast = f.marginal_gain_memoized(e);
                let slow = f.marginal_gain(&s, e);
                // −∞ == −∞ allowed (singular logdet candidates)
                if fast == f64::NEG_INFINITY && slow == f64::NEG_INFINITY {
                    continue;
                }
                if (fast - slow).abs() > 1e-4 * (1.0 + slow.abs()) {
                    return Err(format!("{}: memoized {fast} vs stateless {slow}", f.name()));
                }
            }
            let Some(add) = gen::fresh_element(rng, n, s.order()) else { break };
            f.update_memoization(add);
            s.insert(add);
        }
        Ok(())
    });
}

#[test]
fn p3_diminishing_returns_for_submodular_functions() {
    check("P3 diminishing returns", 303, 50, |rng| {
        // submodular families only (skip DisparitySum/Min)
        let data = gen::matrix(rng, 8, 20, 2, 5);
        let n = data.rows();
        let f: Box<dyn SetFunction> = match rng.next_below(4) {
            0 => Box::new(FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean))),
            1 => Box::new(
                GraphCut::new(DenseKernel::from_data(&data, Metric::Euclidean), 0.5).unwrap(),
            ),
            2 => Box::new(
                LogDeterminant::with_regularization(
                    DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 }),
                    0.3,
                )
                .unwrap(),
            ),
            _ => {
                let m = 10;
                let cover: Vec<Vec<u32>> = (0..n)
                    .map(|_| (0..3).map(|_| rng.next_below(m) as u32).collect())
                    .collect();
                Box::new(SetCover::new(cover, vec![1.0; m]).unwrap())
            }
        };
        let a_ids = gen::subset_ids(rng, n, n / 3);
        let a = Subset::from_ids(n, &a_ids);
        // B ⊇ A
        let mut b = a.clone();
        for _ in 0..3 {
            if let Some(x) = gen::fresh_element(rng, n, b.order()) {
                b.insert(x);
            }
        }
        let Some(e) = gen::fresh_element(rng, n, b.order()) else { return Ok(()) };
        let ga = f.marginal_gain(&a, e);
        let gb = f.marginal_gain(&b, e);
        if gb > ga + 1e-5 * (1.0 + ga.abs()) {
            return Err(format!("{}: f(e|A)={ga} < f(e|B)={gb}", f.name()));
        }
        Ok(())
    });
}

#[test]
fn p4_monotone_functions_have_nonnegative_gains() {
    check("P4 monotonicity", 404, 50, |rng| {
        let data = gen::matrix(rng, 8, 20, 2, 5);
        let n = data.rows();
        // monotone families: FL, SC, PSC, FB, GC(λ≤0.5)
        let f: Box<dyn SetFunction> = match rng.next_below(3) {
            0 => Box::new(FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean))),
            1 => Box::new(
                GraphCut::new(DenseKernel::from_data(&data, Metric::Euclidean), 0.4).unwrap(),
            ),
            _ => {
                let m = 10;
                let probs: Vec<Vec<f32>> =
                    (0..n).map(|_| (0..m).map(|_| rng.next_f32()).collect()).collect();
                Box::new(ProbabilisticSetCover::new(probs, vec![1.0; m]).unwrap())
            }
        };
        let ids = gen::subset_ids(rng, n, n / 2);
        let s = Subset::from_ids(n, &ids);
        let Some(e) = gen::fresh_element(rng, n, &ids) else { return Ok(()) };
        let g = f.marginal_gain(&s, e);
        if g < -1e-6 {
            return Err(format!("{}: negative gain {g}", f.name()));
        }
        Ok(())
    });
}

#[test]
fn p5_lazy_equals_naive_on_submodular() {
    check("P5 lazy == naive", 505, 15, |rng| {
        let data = gen::matrix(rng, 20, 50, 2, 4);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let k = 3 + rng.next_below(8);
        let a = maximize(&f, Budget::cardinality(k), OptimizerKind::NaiveGreedy, &MaximizeOpts::default())
            .map_err(|e| e.to_string())?;
        let b = maximize(&f, Budget::cardinality(k), OptimizerKind::LazyGreedy, &MaximizeOpts::default())
            .map_err(|e| e.to_string())?;
        if (a.value - b.value).abs() > 1e-6 {
            return Err(format!("values differ: {} vs {}", a.value, b.value));
        }
        if a.ids() != b.ids() {
            return Err(format!("sets differ: {:?} vs {:?}", a.ids(), b.ids()));
        }
        Ok(())
    });
}

#[test]
fn p6_greedy_beats_random_subsets() {
    check("P6 greedy ≥ random", 606, 20, |rng| {
        let data = gen::matrix(rng, 20, 40, 2, 4);
        let n = data.rows();
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let k = 3 + rng.next_below(5);
        let sel = maximize(&f, Budget::cardinality(k), OptimizerKind::LazyGreedy, &MaximizeOpts::default())
            .map_err(|e| e.to_string())?;
        for _ in 0..5 {
            let ids = rng.sample_indices(n, k);
            let v = f.evaluate(&Subset::from_ids(n, &ids));
            if v > sel.value + 1e-6 {
                return Err(format!("random {v} beats greedy {}", sel.value));
            }
        }
        Ok(())
    });
}

#[test]
fn p7_information_measure_identities() {
    check("P7 MI/CG/CMI identities", 707, 12, |rng| {
        let data = gen::matrix(rng, 14, 22, 2, 4);
        let total = data.rows();
        let nq = 2 + rng.next_below(2);
        let np = 2 + rng.next_below(2);
        let n = total - nq - np;
        let kernel = DenseKernel::from_data(&data, Metric::Euclidean);
        let base = FacilityLocation::new(kernel.clone());
        let q_ids: Vec<usize> = (n..n + nq).collect();
        let p_ids: Vec<usize> = (n + nq..total).collect();

        let e = |ids: &[usize]| base.evaluate(&Subset::from_ids(total, ids));

        let mi = MutualInformation::new(base.clone_box(), q_ids.clone(), n)
            .map_err(|x| x.to_string())?;
        let cg = ConditionalGain::new(base.clone_box(), p_ids.clone(), n)
            .map_err(|x| x.to_string())?;
        let cmi = ConditionalMutualInformation::new(
            base.clone_box(),
            q_ids.clone(),
            p_ids.clone(),
            n,
        )
        .map_err(|x| x.to_string())?;

        let a_ids = gen::subset_ids(rng, n, n / 2);
        let s = Subset::from_ids(n, &a_ids);

        // MI identity
        let aq: Vec<usize> = a_ids.iter().copied().chain(q_ids.iter().copied()).collect();
        let want_mi = e(&a_ids) + e(&q_ids) - e(&aq);
        if (mi.evaluate(&s) - want_mi).abs() > 1e-6 {
            return Err(format!("MI identity: {} vs {want_mi}", mi.evaluate(&s)));
        }
        // CG identity
        let ap: Vec<usize> = a_ids.iter().copied().chain(p_ids.iter().copied()).collect();
        let want_cg = e(&ap) - e(&p_ids);
        if (cg.evaluate(&s) - want_cg).abs() > 1e-6 {
            return Err(format!("CG identity: {} vs {want_cg}", cg.evaluate(&s)));
        }
        // CMI identity
        let qp: Vec<usize> = q_ids.iter().copied().chain(p_ids.iter().copied()).collect();
        let aqp: Vec<usize> = a_ids.iter().copied().chain(qp.iter().copied()).collect();
        let want_cmi = e(&ap) + e(&qp) - e(&aqp) - e(&p_ids);
        if (cmi.evaluate(&s) - want_cmi).abs() > 1e-6 {
            return Err(format!("CMI identity: {} vs {want_cmi}", cmi.evaluate(&s)));
        }
        Ok(())
    });
}

#[test]
fn p8_specialized_mi_cg_match_generic() {
    check("P8 specialized == generic", 808, 10, |rng| {
        // build ground + query sets, compare FLVMI / FLCG fast paths with
        // the generic wrappers over the stacked kernel (η = ν = 1)
        let ground = gen::matrix(rng, 10, 18, 2, 3);
        let n = ground.rows();
        let queries = gen::matrix(rng, 2, 4, ground.cols(), ground.cols());
        let nq = queries.rows();
        let mut all = submodlib::linalg::Matrix::zeros(n + nq, ground.cols());
        for i in 0..n {
            all.row_mut(i).copy_from_slice(ground.row(i));
        }
        for q in 0..nq {
            all.row_mut(n + q).copy_from_slice(queries.row(q));
        }
        let ext = DenseKernel::from_data(&all, Metric::Euclidean);
        let gk = DenseKernel::from_data(&ground, Metric::Euclidean);
        let qk = RectKernel::from_data(&queries, &ground, Metric::Euclidean)
            .map_err(|e| e.to_string())?;

        // FLVMI == generic MI over FL with represented set V
        let mut rect = submodlib::linalg::Matrix::zeros(n, n + nq);
        for i in 0..n {
            for j in 0..n + nq {
                rect.set(i, j, ext.get(i, j));
            }
        }
        let gen_mi = MutualInformation::new(
            Box::new(FacilityLocation::with_represented(RectKernel::from_matrix(rect))),
            (n..n + nq).collect(),
            n,
        )
        .map_err(|e| e.to_string())?;
        let flvmi = Flvmi::new(gk.clone(), qk.clone(), 1.0).map_err(|e| e.to_string())?;

        // FLCG == generic CG over FL on the extended ground set
        let gen_cg = ConditionalGain::new(
            Box::new(FacilityLocation::new(ext.clone())),
            (n..n + nq).collect(),
            n,
        )
        .map_err(|e| e.to_string())?;
        let flcg = Flcg::new(gk.clone(), qk.clone(), 1.0).map_err(|e| e.to_string())?;

        let ids = gen::subset_ids(rng, n, n / 2);
        let s = Subset::from_ids(n, &ids);
        let (a, b) = (flvmi.evaluate(&s), gen_mi.evaluate(&s));
        if (a - b).abs() > 1e-4 {
            return Err(format!("FLVMI {a} vs generic MI {b}"));
        }
        let (c, d) = (flcg.evaluate(&s), gen_cg.evaluate(&s));
        if (c - d).abs() > 1e-4 {
            return Err(format!("FLCG {c} vs generic CG {d}"));
        }
        Ok(())
    });
}

#[test]
fn p9_mi_functions_are_monotone_nonneg() {
    check("P9 MI monotone", 909, 20, |rng| {
        let ground = gen::matrix(rng, 10, 20, 2, 3);
        let queries = gen::matrix(rng, 2, 3, ground.cols(), ground.cols());
        let n = ground.rows();
        let qk = RectKernel::from_data(&queries, &ground, Metric::Euclidean)
            .map_err(|e| e.to_string())?;
        let f: Box<dyn SetFunction> = match rng.next_below(2) {
            0 => Box::new(Flqmi::new(qk, 0.5 + rng.next_f64()).map_err(|e| e.to_string())?),
            _ => Box::new(Gcmi::new(qk, 0.5).map_err(|e| e.to_string())?),
        };
        let ids = gen::subset_ids(rng, n, n / 2);
        let s = Subset::from_ids(n, &ids);
        let Some(e) = gen::fresh_element(rng, n, &ids) else { return Ok(()) };
        if f.marginal_gain(&s, e) < -1e-8 {
            return Err(format!("{} negative MI gain", f.name()));
        }
        Ok(())
    });
}

#[test]
fn stochastic_quality_in_expectation() {
    // over several seeds, stochastic greedy averages ≥ 85% of naive
    let data = synthetic::blobs(150, 2, 6, 2.0, 4242);
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let naive =
        maximize(&f, Budget::cardinality(12), OptimizerKind::NaiveGreedy, &MaximizeOpts::default())
            .unwrap();
    let mut total = 0.0;
    let trials = 10;
    for seed in 0..trials {
        let sel = maximize(
            &f,
            Budget::cardinality(12),
            OptimizerKind::StochasticGreedy,
            &MaximizeOpts { seed, ..Default::default() },
        )
        .unwrap();
        total += sel.value;
    }
    let avg = total / trials as f64;
    assert!(avg >= 0.85 * naive.value, "avg {avg} vs naive {}", naive.value);
}

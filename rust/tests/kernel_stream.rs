//! Bit-equality suite for the streaming tiled kernel construction
//! (ISSUE 3) and the symmetric wavefront sparse build (ISSUE 4): the
//! tiled dense / rect / distance builds must reproduce a serial
//! reference *bit-for-bit* for every `Metric`, and the sparse build's CSR
//! (row_ptr / col_idx / vals) must equal a serial
//! materialize-upper-triangle-then-select reference exactly — including
//! rows containing NaN/±∞ similarities and tie-heavy integer-valued
//! kernels, where only the contract's `(value desc via total_cmp, col
//! asc)` order keeps the survivor set well-defined.
//!
//! The references below are *serial* builds routed through the same
//! process-wide compute backend (`kernel::backend::active()`) the tile
//! drivers dispatch to, with the same `j0` anchoring (full-width rows
//! for rect, row i anchored at column i + mirror for symmetric). Tiling
//! and pool scheduling may change, but within one backend the op order
//! never does — which is exactly what these tests pin. Each backend's
//! op order is itself pinned against a hand-written golden replica in
//! tests/backend_parity.rs (the scalar backend's replica being the
//! verbatim pre-refactor inner loops), so the two suites compose into
//! the old end-to-end guarantee under `SUBMODLIB_BACKEND=scalar`.

use submodlib::data::points::PointView;
use submodlib::kernel::backend;
use submodlib::kernel::{DenseKernel, Metric, RectKernel, SparseKernel};
use submodlib::linalg::Matrix;
use submodlib::rng::Pcg64;

fn rand_data(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect()).unwrap()
}

const ALL_METRICS: [Metric; 4] =
    [Metric::Euclidean, Metric::Cosine, Metric::Dot, Metric::Rbf { gamma: 0.6 }];

/// Serial replica of the *rectangular* builder: every row full-width
/// (`j0 = 0`), one backend `fill_row` call per row.
fn reference_rect(a: &Matrix, b: &Matrix, metric: Metric, distances: bool) -> Matrix {
    let k = backend::active();
    let m = a.rows();
    let n = b.rows();
    let sq_a = k.sq_norms(a);
    let sq_b = k.sq_norms(b);
    let bview = PointView::new(b, k.wants_soa());
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        k.fill_row(a.row(i), sq_a[i], &bview, &sq_b, 0, metric, distances, out.row_mut(i));
    }
    out
}

/// Serial replica of the *symmetric* builder: upper triangle with row i
/// anchored at column i (`j0 = i`), then a lower-triangle mirror.
fn reference_symmetric(a: &Matrix, metric: Metric, distances: bool) -> Matrix {
    let k = backend::active();
    let n = a.rows();
    let sq = k.sq_norms(a);
    let aview = PointView::new(a, k.wants_soa());
    let mut out = Matrix::zeros(n, n);
    for i in 0..n {
        let orow = &mut out.row_mut(i)[i..];
        k.fill_row(a.row(i), sq[i], &aview, &sq, i, metric, distances, orow);
    }
    for i in 1..n {
        for j in 0..i {
            let v = out.get(j, i);
            out.set(i, j, v);
        }
    }
    out
}

fn assert_matrices_bit_equal(got: &Matrix, want: &Matrix, what: &str) {
    assert_eq!((got.rows(), got.cols()), (want.rows(), want.cols()), "{what}: shape");
    for i in 0..got.rows() {
        for j in 0..got.cols() {
            assert_eq!(
                got.get(i, j).to_bits(),
                want.get(i, j).to_bits(),
                "{what}: ({i},{j}) {} vs {}",
                got.get(i, j),
                want.get(i, j)
            );
        }
    }
}

#[test]
fn tiled_dense_bit_equals_pre_refactor_builder_every_metric() {
    // odd n, well past the 64-row tile boundary, d chosen so the 8/4/
    // scalar column phases all fire
    let data = rand_data(147, 9, 21);
    for metric in ALL_METRICS {
        let tiled = DenseKernel::from_data(&data, metric);
        let reference = reference_symmetric(&data, metric, false);
        assert_matrices_bit_equal(tiled.matrix(), &reference, &format!("dense {metric:?}"));
    }
}

#[test]
fn tiled_distances_bit_equal_pre_refactor_builder() {
    let data = rand_data(131, 7, 22);
    let tiled = DenseKernel::distances_from_data(&data);
    let reference = reference_symmetric(&data, Metric::Euclidean, true);
    assert_matrices_bit_equal(tiled.matrix(), &reference, "distances");
}

#[test]
fn tiled_rect_bit_equals_pre_refactor_builder_every_metric() {
    let a = rand_data(90, 6, 23);
    let b = rand_data(141, 6, 24);
    for metric in ALL_METRICS {
        let tiled = RectKernel::from_data(&a, &b, metric).unwrap();
        let reference = reference_rect(&a, &b, metric, false);
        assert_matrices_bit_equal(tiled.matrix(), &reference, &format!("rect {metric:?}"));
    }
}

/// Serial materialize-upper-triangle-then-select reference: the
/// symmetric replica (upper triangle computed with row i anchored at
/// column i, lower triangle a bitwise mirror) materialized in full, then
/// a brute-force top-k per row — a *full sort* under the CSR contract's
/// strict total order `(value desc via total_cmp, col asc)`, take k,
/// re-sort survivors by column id. No partial-select shortcuts, so ties
/// and non-finite values are resolved by the ordering alone.
fn reference_sparse_csr(
    data: &Matrix,
    metric: Metric,
    k: usize,
) -> (Vec<usize>, Vec<u32>, Vec<f32>) {
    let n = data.rows();
    let dense = reference_symmetric(data, metric, false);
    let mut row_ptr = vec![0usize];
    let mut col_idx = Vec::new();
    let mut vals = Vec::new();
    for i in 0..n {
        let mut entries: Vec<(u32, f32)> =
            dense.row(i).iter().enumerate().map(|(j, &s)| (j as u32, s)).collect();
        entries.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        let mut top = entries[..k].to_vec();
        top.sort_unstable_by_key(|e| e.0);
        for &(j, s) in top.iter() {
            col_idx.push(j);
            vals.push(s);
        }
        row_ptr.push(col_idx.len());
    }
    (row_ptr, col_idx, vals)
}

fn assert_sparse_equals_reference(data: &Matrix, metric: Metric, k: usize, what: &str) {
    let n = data.rows();
    let streamed = SparseKernel::from_data(data, metric, k).unwrap();
    let (row_ptr, col_idx, vals) = reference_sparse_csr(data, metric, k);
    assert_eq!(streamed.nnz(), n * k, "{what}: nnz");
    let mut at = 0usize;
    for i in 0..n {
        let (cols, vs) = streamed.row(i);
        assert_eq!(row_ptr[i], at, "{what}: row_ptr[{i}]");
        assert_eq!(cols, &col_idx[at..at + cols.len()], "{what}: cols of row {i}");
        for (c, (got, want)) in cols.iter().zip(vs.iter().zip(&vals[at..at + vs.len()])) {
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{what}: value ({i},{c}) {got} vs {want}"
            );
        }
        at += cols.len();
    }
    assert_eq!(at, *row_ptr.last().unwrap(), "{what}: total nnz");
}

#[test]
fn streaming_sparse_csr_equals_materialize_then_select() {
    // sizes straddling the tile boundary; k from trivial to full-row
    for (n, seed) in [(12usize, 31u64), (64, 32), (97, 33), (150, 34)] {
        let data = rand_data(n, 5, seed);
        for metric in ALL_METRICS {
            for k in [1usize, 4, n.min(33), n] {
                assert_sparse_equals_reference(
                    &data,
                    metric,
                    k,
                    &format!("n={n} {metric:?} k={k}"),
                );
            }
        }
    }
}

#[test]
fn streaming_sparse_handles_nonfinite_rows() {
    // Dot-metric features engineered to produce ±∞ similarities (the
    // same non-finite class topk_total_order_handles_nonfinite_rows pins
    // at the unit level): f32 products of 1e20 overflow to ±∞, and with
    // single products per dot no NaN can form. −∞ must lose to every
    // finite value; +∞ must win; CSR must still match the
    // materialize-upper-triangle-then-select reference exactly.
    let feats: Vec<f32> = vec![1e20, -1e20, 0.0, 1.0, 2.0, -3.0, 0.5, -0.25, 4.0];
    let n = feats.len();
    let data = Matrix::from_vec(n, 1, feats).unwrap();
    for k in [1usize, 2, 4] {
        assert_sparse_equals_reference(&data, Metric::Dot, k, &format!("nonfinite k={k}"));
    }
    // spot-check the ordering semantics: row 0 (the +1e20 point) has
    // +∞ similarity with itself, −∞ with the −1e20 point — the −∞
    // entry must never survive a k=2 selection (finite 4e20 beats it)
    let sparse = SparseKernel::from_data(&data, Metric::Dot, 2).unwrap();
    let (cols, vals) = sparse.row(0);
    assert!(!cols.contains(&1), "−∞ neighbor survived: {cols:?} {vals:?}");
    assert!(vals.iter().all(|v| *v > 0.0));
}

#[test]
fn streaming_sparse_handles_nan_rows() {
    // Two-dimensional Dot features whose products overflow to opposite
    // infinities. What lands at s(0,1) is backend-dependent: an unfused
    // chain (scalar, wide) overflows both products and sums
    // ∞ + (−∞) = NaN, while a fused chain (avx2) computes
    // fma(x, y, +∞) = +∞ — the −1e40 product is exact inside the fma
    // and never materializes a −∞. Either way total_cmp gives the value
    // a deterministic rank, which is exactly why the selection must be
    // pinned against a reference running the same ops rather than a
    // hand-written expectation.
    let rows: Vec<[f32; 2]> = vec![
        [1e20, 1e20],
        [1e20, -1e20],
        [1.0, 2.0],
        [2.0, 1.0],
        [0.5, -0.5],
        [-1.0, 3.0],
        [0.25, 0.75],
    ];
    let n = rows.len();
    let data =
        Matrix::from_vec(n, 2, rows.iter().flat_map(|r| r.iter().copied()).collect())
            .unwrap();
    for k in [1usize, 2, 3, n] {
        assert_sparse_equals_reference(&data, Metric::Dot, k, &format!("nan k={k}"));
    }
    // with k = n every entry is stored: the CSR must hold exactly what
    // the active backend's gram chain produced for (0,1) — NaN class
    // preserved, otherwise bit-equal — and both mirrored endpoints hold
    // the same bits, so symmetry survives even non-finite arithmetic
    let sparse = SparseKernel::from_data(&data, Metric::Dot, n).unwrap();
    let kb = backend::active();
    let sq = kb.sq_norms(&data);
    let view = PointView::new(&data, kb.wants_soa());
    let mut row0 = vec![0f32; n];
    kb.fill_row(data.row(0), sq[0], &view, &sq, 0, Metric::Dot, false, &mut row0);
    let expect01 = row0[1];
    let s01 = sparse.get(0, 1);
    let s10 = sparse.get(1, 0);
    if expect01.is_nan() {
        assert!(s01.is_nan(), "expected NaN at (0,1), got {s01}");
    } else {
        assert_eq!(s01.to_bits(), expect01.to_bits(), "(0,1) diverged from backend row");
    }
    assert_eq!(s01.to_bits(), s10.to_bits(), "(0,1)/(1,0) pair not mirrored");
    assert!(sparse.get(0, 0).is_infinite() && sparse.get(0, 0) > 0.0);
}

#[test]
fn streaming_sparse_tie_heavy_integer_kernel() {
    // Integer-valued features under Dot give exact integer similarities
    // from a handful of distinct values — nearly every row is decided by
    // the (value desc, col asc) tie order, across shard boundaries
    // (n > 2·64) and straddling the k cut. Must still be bit-identical
    // to the serial reference.
    let mut rng = Pcg64::new(77);
    let n = 150;
    let d = 4;
    let feats: Vec<f32> =
        (0..n * d).map(|_| (rng.next_below(4) as f32) - 1.0).collect();
    let data = Matrix::from_vec(n, d, feats).unwrap();
    for k in [1usize, 5, 32, 64, n] {
        assert_sparse_equals_reference(&data, Metric::Dot, k, &format!("ties k={k}"));
    }
}

#[test]
fn sparse_symmetry_property_random_data_all_metrics() {
    // Property sweep: for random data across all metrics, every stored
    // pair agrees bitwise with the dense symmetric kernel of the same
    // data; whenever both endpoints keep a pair, the two stored values
    // are bit-equal (get(i,j) == get(j,i) exactly); and the per-row
    // survivor sets equal the brute-force (value desc, col asc)
    // reference — also under the heavy ties of rounded features.
    for (seed, quantize) in [(101u64, false), (102, true), (103, false)] {
        let mut rng = Pcg64::new(seed);
        let n = 130;
        let d = 4;
        let feats: Vec<f32> = (0..n * d)
            .map(|_| {
                let g = rng.next_gaussian() as f32;
                if quantize {
                    g.round()
                } else {
                    g
                }
            })
            .collect();
        let data = Matrix::from_vec(n, d, feats).unwrap();
        for metric in ALL_METRICS {
            let k = 9;
            let what = format!("seed={seed} {metric:?}");
            assert_sparse_equals_reference(&data, metric, k, &what);
            let sparse = SparseKernel::from_data(&data, metric, k).unwrap();
            let dense = DenseKernel::from_data(&data, metric);
            for i in 0..n {
                let (cols, vals) = sparse.row(i);
                for (c, v) in cols.iter().zip(vals) {
                    let j = *c as usize;
                    assert_eq!(
                        v.to_bits(),
                        dense.get(i, j).to_bits(),
                        "{what}: ({i},{j}) vs dense"
                    );
                    // membership can be asymmetric (kNN graphs are), but
                    // stored values never disagree between endpoints
                    let (jcols, jvals) = sparse.row(j);
                    if let Ok(pos) = jcols.binary_search(&(i as u32)) {
                        assert_eq!(
                            v.to_bits(),
                            jvals[pos].to_bits(),
                            "{what}: get({i},{j}) != get({j},{i})"
                        );
                    }
                }
            }
        }
    }
}

//! Per-backend bit-pinning and cross-backend ULP parity for the compute
//! backends (`kernel::backend`, ISSUE 9).
//!
//! The determinism contract is *per backend* (see the module docs):
//!
//! 1. **Golden bits.** Each backend's op order is pinned against a
//!    hand-written serial replica: the scalar backend against the
//!    verbatim pre-refactor inner loops (`dot8`/`dot4`/`dot`, the 8/4/1
//!    `j0`-anchored phases), the `avx2` backend against a scalar
//!    `f32::mul_add` chain (FMA is one correctly-rounded operation —
//!    lane and scalar agree bitwise), and the `wide` backend against a
//!    plain multiply-then-add chain. Bit-*equality*, not tolerance.
//! 2. **Position independence.** The SIMD backends' per-column chains
//!    cannot depend on `j0`, block grouping, or SoA-vs-row-major
//!    layout — asserted directly, because this is the property that
//!    makes them bit-stable across tile schedules and pool widths.
//! 3. **ULP parity.** Across backends the same entry may round
//!    differently; the sweep below bounds the divergence: ≤ 4 ULP on
//!    well-conditioned rows, and containment in an analytic error
//!    interval (gram error ≤ 8·d·ε·(|x|²+|y|²) pushed through the
//!    monotone metric map in f64) when cancellation makes a fixed ULP
//!    bound meaningless. Dims straddle every vector width
//!    (d ∈ {1,3,4,7,8,127,128}).
//! 4. **Non-finite classification.** Rows engineered to overflow must
//!    classify (NaN / +∞ / −∞ / finite) exactly as each backend's own
//!    golden replica dictates. The class is *not* cross-backend
//!    portable — `fma(x, y, +∞)` is +∞ where the unfused chain makes
//!    ∞ − ∞ = NaN — so the pin is per backend, under both layouts.
//! 5. **Pool-width stability.** Dense and sparse builds are bit-equal
//!    at widths 1 / 2 / default under whichever backend is active (CI
//!    runs this suite under `SUBMODLIB_THREADS=2` and with
//!    `SUBMODLIB_BACKEND=scalar` as part of the backend matrix).
//! 6. **The scalar anchor.** Under `SUBMODLIB_BACKEND=scalar`, full
//!    dense / rect / CSR builds must equal the pre-refactor builder
//!    byte for byte — the selections/CSR byte-identity acceptance
//!    criterion. (Gated on the active backend; the CI scalar step makes
//!    it bite.)

use submodlib::data::points::PointView;
use submodlib::kernel::backend::{self, InnerKernel};
use submodlib::kernel::{DenseKernel, Metric, RectKernel, SparseKernel};
use submodlib::linalg::{self, Matrix};
use submodlib::rng::Pcg64;
use submodlib::runtime::pool;

const ALL_METRICS: [Metric; 4] =
    [Metric::Euclidean, Metric::Cosine, Metric::Dot, Metric::Rbf { gamma: 0.6 }];

/// Dims straddling the 8-wide vector width and the 4-wide scalar block.
const DIMS: [usize; 7] = [1, 3, 4, 7, 8, 127, 128];

fn rand_data(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect()).unwrap()
}

fn sq_norms(m: &Matrix) -> Vec<f32> {
    (0..m.rows()).map(|i| linalg::dot(m.row(i), m.row(i))).collect()
}

/// Run one backend `fill_row` over columns `[j0, n)` and return the row.
#[allow(clippy::too_many_arguments)]
fn backend_row(
    k: &dyn InnerKernel,
    a: &Matrix,
    view: &PointView<'_>,
    sq: &[f32],
    i: usize,
    j0: usize,
    metric: Metric,
    distances: bool,
) -> Vec<f32> {
    let mut out = vec![0f32; view.rows() - j0];
    k.fill_row(a.row(i), sq[i], view, sq, j0, metric, distances, &mut out);
    out
}

/// Shared finalization (identical to `Metric::finalize_block`'s element
/// expression) — replicas differ only in how they produce the gram.
fn finalize(metric: Metric, distances: bool, g: f32, sq_ai: f32, sq_bj: f32) -> f32 {
    if distances {
        (sq_ai + sq_bj - 2.0 * g).max(0.0).sqrt()
    } else {
        metric.from_gram(g, sq_ai, sq_bj)
    }
}

/// Verbatim replica of the pre-refactor inner loop (the scalar
/// backend's golden op order): 8-wide `dot8` blocks, then a 4-wide
/// `dot4` block, then a `dot` tail, phases anchored at `j0`.
#[allow(clippy::too_many_arguments)]
fn replica_scalar_row(
    arow: &[f32],
    sq_ai: f32,
    b: &Matrix,
    sq_b: &[f32],
    j0: usize,
    metric: Metric,
    distances: bool,
) -> Vec<f32> {
    let n = b.rows();
    let mut orow = vec![0f32; n - j0];
    let mut j = j0;
    while j + 8 <= n {
        let g = linalg::dot8(
            arow,
            [
                b.row(j),
                b.row(j + 1),
                b.row(j + 2),
                b.row(j + 3),
                b.row(j + 4),
                b.row(j + 5),
                b.row(j + 6),
                b.row(j + 7),
            ],
        );
        for t in 0..8 {
            orow[j - j0 + t] = finalize(metric, distances, g[t], sq_ai, sq_b[j + t]);
        }
        j += 8;
    }
    while j + 4 <= n {
        let g = linalg::dot4(arow, b.row(j), b.row(j + 1), b.row(j + 2), b.row(j + 3));
        for t in 0..4 {
            orow[j - j0 + t] = finalize(metric, distances, g[t], sq_ai, sq_b[j + t]);
        }
        j += 4;
    }
    for jj in j..n {
        let g = linalg::dot(arow, b.row(jj));
        orow[jj - j0] = finalize(metric, distances, g, sq_ai, sq_b[jj]);
    }
    orow
}

/// Golden gram chain of the SIMD backends: sequential over features,
/// fused (`mul_add`, the avx2 spec) or unfused (the wide spec).
fn replica_simd_gram(fused: bool, arow: &[f32], brow: &[f32]) -> f32 {
    let mut s = 0f32;
    if fused {
        for (&x, &y) in arow.iter().zip(brow.iter()) {
            s = x.mul_add(y, s);
        }
    } else {
        for (&x, &y) in arow.iter().zip(brow.iter()) {
            s += x * y;
        }
    }
    s
}

/// Golden replica of a SIMD backend's row: per-column chains, by
/// construction independent of `j0` and of any block grouping.
#[allow(clippy::too_many_arguments)]
fn replica_simd_row(
    fused: bool,
    arow: &[f32],
    sq_ai: f32,
    b: &Matrix,
    sq_b: &[f32],
    j0: usize,
    metric: Metric,
    distances: bool,
) -> Vec<f32> {
    (j0..b.rows())
        .map(|j| {
            let g = replica_simd_gram(fused, arow, b.row(j));
            finalize(metric, distances, g, sq_ai, sq_b[j])
        })
        .collect()
}

fn assert_rows_bit_equal(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (t, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: entry {t} ({g} vs {w})");
    }
}

#[test]
fn scalar_backend_bit_equals_pre_refactor_op_order() {
    let k = backend::scalar();
    assert!(!k.wants_soa());
    for &d in &DIMS {
        let b = rand_data(41, d, 1000 + d as u64);
        let sq = sq_norms(&b);
        let view = PointView::new(&b, k.wants_soa());
        for metric in ALL_METRICS {
            for distances in [false, true] {
                for j0 in [0usize, 1, 5, 40] {
                    let got = backend_row(k, &b, &view, &sq, 2, j0, metric, distances);
                    let want =
                        replica_scalar_row(b.row(2), sq[2], &b, &sq, j0, metric, distances);
                    assert_rows_bit_equal(
                        &got,
                        &want,
                        &format!("scalar d={d} {metric:?} dist={distances} j0={j0}"),
                    );
                }
            }
        }
    }
}

#[test]
fn simd_backends_match_their_golden_replicas() {
    // n chosen to exercise the 32-block, the 8-block and the scalar
    // tail of the avx2 kernel (and wide's 8-block + tail)
    for k in backend::available() {
        if k.name() == "scalar" {
            continue;
        }
        let fused = k.name() == "avx2";
        for &d in &DIMS {
            for n in [1usize, 7, 8, 9, 33, 71] {
                let b = rand_data(n, d, 2000 + (n * 131 + d) as u64);
                let sq = sq_norms(&b);
                // both layouts must produce the same bits as the replica
                for with_soa in [true, false] {
                    let view = PointView::new(&b, with_soa);
                    for metric in ALL_METRICS {
                        for distances in [false, true] {
                            for j0 in [0usize, 1, n / 2] {
                                let got =
                                    backend_row(k, &b, &view, &sq, 0, j0, metric, distances);
                                let want = replica_simd_row(
                                    fused,
                                    b.row(0),
                                    sq[0],
                                    &b,
                                    &sq,
                                    j0,
                                    metric,
                                    distances,
                                );
                                assert_rows_bit_equal(
                                    &got,
                                    &want,
                                    &format!(
                                        "{} d={d} n={n} soa={with_soa} {metric:?} \
                                         dist={distances} j0={j0}",
                                        k.name()
                                    ),
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn simd_backends_are_position_independent() {
    // the property that buys bit-stability across tile schedules: the
    // row computed from j0 = q is exactly the suffix of the row from
    // j0 = 0 — for every grouping the kernel's block loops land on
    for k in backend::available() {
        if k.name() == "scalar" {
            continue;
        }
        let n = 67usize;
        let b = rand_data(n, 9, 77);
        let sq = sq_norms(&b);
        let view = PointView::new(&b, k.wants_soa());
        let full = backend_row(k, &b, &view, &sq, 3, 0, Metric::Cosine, false);
        for j0 in [1usize, 2, 7, 8, 31, 32, 33, 66] {
            let suffix = backend_row(k, &b, &view, &sq, 3, j0, Metric::Cosine, false);
            assert_rows_bit_equal(
                &suffix,
                &full[j0..],
                &format!("{} suffix j0={j0}", k.name()),
            );
        }
    }
}

/// Total-order ULP distance between two finite f32s.
fn ulp_diff(a: f32, b: f32) -> i64 {
    fn ord(x: f32) -> i64 {
        let bits = x.to_bits() as i64;
        if bits & 0x8000_0000 != 0 {
            0x8000_0000i64 - bits
        } else {
            bits
        }
    }
    (ord(a) - ord(b)).abs()
}

/// The metric map in f64 — every supported finalization is monotone in
/// the gram value, so an interval maps to an interval.
fn metric_value_f64(metric: Metric, distances: bool, g: f64, sq_ai: f64, sq_bj: f64) -> f64 {
    if distances {
        return (sq_ai + sq_bj - 2.0 * g).max(0.0).sqrt();
    }
    match metric {
        Metric::Dot => g,
        Metric::Cosine => g / (sq_ai.sqrt() * sq_bj.sqrt()).max(1e-12),
        Metric::Euclidean => 1.0 / (1.0 + (sq_ai + sq_bj - 2.0 * g).max(0.0).sqrt()),
        Metric::Rbf { gamma } => (-(gamma as f64) * (sq_ai + sq_bj - 2.0 * g).max(0.0)).exp(),
    }
}

#[test]
fn ulp_parity_simd_vs_scalar_across_dims_and_metrics() {
    let scalar = backend::scalar();
    let n = 100usize;
    for k in backend::available() {
        if k.name() == "scalar" {
            continue;
        }
        for &d in &DIMS {
            let b = rand_data(n, d, 3000 + d as u64);
            let sq = sq_norms(&b);
            let sview = PointView::new(&b, scalar.wants_soa());
            let kview = PointView::new(&b, k.wants_soa());
            for metric in ALL_METRICS {
                for distances in [false, true] {
                    for i in [0usize, 13, 57, 99] {
                        let s_row = backend_row(scalar, &b, &sview, &sq, i, 0, metric, distances);
                        let k_row = backend_row(k, &b, &kview, &sq, i, 0, metric, distances);
                        for j in 0..n {
                            let (s, v) = (s_row[j], k_row[j]);
                            assert!(s.is_finite() && v.is_finite(), "gaussian data non-finite");
                            if ulp_diff(s, v) <= 4 {
                                continue;
                            }
                            // Cancellation case: verify both values sit in
                            // the interval the gram error bound permits. The
                            // bound is generous (worst-case chain rounding is
                            // ~d·ε·(|x|²+|y|²)/2; we allow 8× that, plus a
                            // pad for the f32 finalization's own rounding) —
                            // real op-order bugs miss by orders of magnitude.
                            let g64: f64 = (0..d)
                                .map(|f| b.get(i, f) as f64 * b.get(j, f) as f64)
                                .sum();
                            let bound = 8.0
                                * d as f64
                                * f32::EPSILON as f64
                                * (sq[i] as f64 + sq[j] as f64 + 1e-30);
                            let (sqa, sqb) = (sq[i] as f64, sq[j] as f64);
                            let va = metric_value_f64(metric, distances, g64 - bound, sqa, sqb);
                            let vb = metric_value_f64(metric, distances, g64 + bound, sqa, sqb);
                            let (mut lo, mut hi) = if va <= vb { (va, vb) } else { (vb, va) };
                            let pad = lo.abs().max(hi.abs()).max(1e-30) * 1e-4 + 1e-9;
                            lo -= pad;
                            hi += pad;
                            for (label, x) in [("scalar", s), (k.name(), v)] {
                                assert!(
                                    (x as f64) >= lo && (x as f64) <= hi,
                                    "{} vs scalar d={d} {metric:?} dist={distances} \
                                     ({i},{j}): {label}={x} outside [{lo}, {hi}] \
                                     (ulp_diff={})",
                                    k.name(),
                                    ulp_diff(s, v)
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn nonfinite_rows_match_each_backends_golden_replica() {
    // ±1e20 features overflow products to ±∞ and force inf − inf = NaN
    // cancellations. Non-finite classification is NOT cross-backend
    // portable — a fused chain computing fma(x, y, +inf) never
    // materializes the second infinity a mul-then-add chain overflows
    // into, so `[1e20,1e20]·[1e20,-1e20]` is NaN under scalar/wide but
    // +∞ under avx2. The contract is therefore *per backend*: these
    // pathological rows must classify exactly as the backend's own
    // golden replica (which shares its fusion semantics) says, under
    // both layouts — NaNs stay NaNs, infinity signs match, finite
    // entries stay bit-equal. Scalar and wide replicas additionally
    // agree with each other (both unfused), which the replica equality
    // transitively pins.
    let sets: Vec<Matrix> = vec![
        Matrix::from_vec(9, 1, vec![1e20, -1e20, 0.0, 1.0, 2.0, -3.0, 0.5, -0.25, 4.0])
            .unwrap(),
        Matrix::from_vec(
            7,
            2,
            vec![
                1e20, 1e20, 1e20, -1e20, 1.0, 2.0, 2.0, 1.0, 0.5, -0.5, -1.0, 3.0, 0.25, 0.75,
            ],
        )
        .unwrap(),
    ];
    for (si, b) in sets.iter().enumerate() {
        let n = b.rows();
        let sq = sq_norms(b);
        for k in backend::available() {
            for with_soa in [k.wants_soa(), false] {
                let kview = PointView::new(b, with_soa);
                for i in 0..n {
                    let k_row = backend_row(k, b, &kview, &sq, i, 0, Metric::Dot, false);
                    let want = match k.name() {
                        "scalar" => {
                            replica_scalar_row(b.row(i), sq[i], b, &sq, 0, Metric::Dot, false)
                        }
                        name => replica_simd_row(
                            name == "avx2",
                            b.row(i),
                            sq[i],
                            b,
                            &sq,
                            0,
                            Metric::Dot,
                            false,
                        ),
                    };
                    for j in 0..n {
                        let (v, w) = (k_row[j], want[j]);
                        let what = format!(
                            "set {si} ({i},{j}) {} soa={with_soa} ({v} vs {w})",
                            k.name()
                        );
                        if w.is_nan() {
                            assert!(v.is_nan(), "{what}: NaN class");
                        } else {
                            // infinities and finite values alike: exact bits
                            assert_eq!(v.to_bits(), w.to_bits(), "{what}");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn kernel_builds_bit_stable_across_pool_widths() {
    // within the active backend, dense + sparse builds must not depend
    // on pool width (widths 1, 2, and whatever the env configured)
    let data = rand_data(150, 16, 4004);
    let dense_at = |w: usize| {
        pool::with_thread_limit(w, || DenseKernel::from_data(&data, Metric::Euclidean))
    };
    let sparse_at = |w: usize| {
        pool::with_thread_limit(w, || {
            SparseKernel::from_data(&data, Metric::Euclidean, 9).unwrap()
        })
    };
    let d1 = dense_at(1);
    let d2 = dense_at(2);
    let dd = DenseKernel::from_data(&data, Metric::Euclidean);
    for i in 0..150 {
        for j in 0..150 {
            let w = d1.get(i, j).to_bits();
            assert_eq!(d2.get(i, j).to_bits(), w, "dense width 2 ({i},{j})");
            assert_eq!(dd.get(i, j).to_bits(), w, "dense default width ({i},{j})");
        }
    }
    let s1 = sparse_at(1);
    let s2 = sparse_at(2);
    let sd = SparseKernel::from_data(&data, Metric::Euclidean, 9).unwrap();
    for i in 0..150 {
        let (c1, v1) = s1.row(i);
        for (label, s) in [("width 2", &s2), ("default", &sd)] {
            let (c, v) = s.row(i);
            assert_eq!(c, c1, "sparse {label} row {i} cols");
            let bits: Vec<u32> = v.iter().map(|x| x.to_bits()).collect();
            let bits1: Vec<u32> = v1.iter().map(|x| x.to_bits()).collect();
            assert_eq!(bits, bits1, "sparse {label} row {i} vals");
        }
    }
}

#[test]
fn scalar_backend_pins_full_builds_to_pre_refactor_bytes() {
    // The acceptance criterion: SUBMODLIB_BACKEND=scalar reproduces the
    // pre-refactor dense / rect / CSR bytes. The backend is process-wide,
    // so this bites when the suite runs under the CI scalar step (and is
    // a no-op skip under SIMD backends, which have their own pins above).
    if backend::active().name() != "scalar" {
        eprintln!(
            "skipping scalar byte-pin: active backend is {:?}",
            backend::active().name()
        );
        return;
    }
    let data = rand_data(97, 9, 5005);
    let sq = sq_norms(&data);
    for metric in ALL_METRICS {
        // dense: upper triangle anchored at j0 = i, mirrored — the
        // pre-refactor symmetric builder, via the verbatim replica
        let dense = DenseKernel::from_data(&data, metric);
        for i in 0..97 {
            let want = replica_scalar_row(data.row(i), sq[i], &data, &sq, i, metric, false);
            for (off, w) in want.iter().enumerate() {
                let j = i + off;
                assert_eq!(
                    dense.get(i, j).to_bits(),
                    w.to_bits(),
                    "dense {metric:?} ({i},{j})"
                );
                assert_eq!(
                    dense.get(j, i).to_bits(),
                    w.to_bits(),
                    "dense mirror {metric:?} ({j},{i})"
                );
            }
        }
    }
    // rect: full-width rows anchored at j0 = 0
    let b = rand_data(55, 9, 5006);
    let sq_b = sq_norms(&b);
    let rect = RectKernel::from_data(&data, &b, Metric::Cosine).unwrap();
    for i in 0..97 {
        let want = replica_scalar_row(data.row(i), sq[i], &b, &sq_b, 0, Metric::Cosine, false);
        for (j, w) in want.iter().enumerate() {
            assert_eq!(rect.get(i, j).to_bits(), w.to_bits(), "rect ({i},{j})");
        }
    }
    // CSR: materialize the replica's symmetric kernel, then brute-force
    // top-k under the contract's (value desc via total_cmp, col asc)
    let k = 9usize;
    let sparse = SparseKernel::from_data(&data, Metric::Euclidean, k).unwrap();
    let mut full = vec![vec![0f32; 97]; 97];
    for i in 0..97 {
        let row = replica_scalar_row(data.row(i), sq[i], &data, &sq, i, Metric::Euclidean, false);
        for (off, w) in row.iter().enumerate() {
            full[i][i + off] = *w;
            full[i + off][i] = *w;
        }
    }
    for i in 0..97 {
        let mut entries: Vec<(u32, f32)> =
            full[i].iter().enumerate().map(|(j, &s)| (j as u32, s)).collect();
        entries.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        let mut top = entries[..k].to_vec();
        top.sort_unstable_by_key(|e| e.0);
        let (cols, vals) = sparse.row(i);
        let want_cols: Vec<u32> = top.iter().map(|e| e.0).collect();
        assert_eq!(cols, &want_cols[..], "csr row {i} cols");
        for (t, (got, want)) in vals.iter().zip(top.iter()).enumerate() {
            assert_eq!(got.to_bits(), want.1.to_bits(), "csr row {i} val {t}");
        }
    }
}

//! Parity suite for LazyGreedy's Minoux-blocked stale re-evaluation
//! (ISSUE 2): against a hand-rolled replica of the serial
//! one-pop-at-a-time algorithm, the blocked optimizer must reproduce the
//! selection order, every accepted gain (bit-for-bit), and the final
//! value, on FL / GraphCut / LogDet / FLQMI workloads. Evaluation counts
//! may differ only within the block-boundary tolerance: the waste of one
//! partially-useful block per accepted element.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::graph_cut::GraphCut;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::mi::Flqmi;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric, RectKernel};
use submodlib::optimizers::lazy::LAZY_STALE_BLOCK;
use submodlib::optimizers::{
    maximize, Budget, MaximizeOpts, OptimizerKind, ZERO_GAIN_EPS,
};

/// Replica of the serial lazy heap entry: same ordering (key descending,
/// lowest id on ties, total_cmp) as `optimizers::lazy`.
struct Entry {
    key: f64,
    e: usize,
    iter: u64,
}
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.e == other.e
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key.total_cmp(&other.key).then_with(|| other.e.cmp(&self.e))
    }
}

/// The pre-blocking algorithm, verbatim: seed all bounds, then pop →
/// recompute → reinsert ONE stale entry at a time; accept only fresh
/// tops. Unit costs, default stop rules (the workloads below use both).
fn serial_lazy_reference(f: &dyn SetFunction, k: usize) -> (Vec<(usize, f64)>, f64, u64) {
    let n = f.n();
    let mut work = f.clone_box();
    work.init_memoization(&Subset::empty(n));
    let mut evaluations = 0u64;
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(n);
    for e in 0..n {
        let key = work.marginal_gain_memoized(e);
        evaluations += 1;
        heap.push(Entry { key, e, iter: 0 });
    }
    let mut order: Vec<(usize, f64)> = Vec::new();
    let mut value = 0f64;
    let mut iter = 0u64;
    while let Some(top) = heap.pop() {
        if top.iter == iter {
            if top.key == f64::NEG_INFINITY || top.key < 0.0 || top.key <= ZERO_GAIN_EPS
            {
                break;
            }
            work.update_memoization(top.e);
            value += top.key;
            order.push((top.e, top.key));
            iter += 1;
            if order.len() >= k {
                break;
            }
        } else {
            let key = work.marginal_gain_memoized(top.e);
            evaluations += 1;
            heap.push(Entry { key, e: top.e, iter });
        }
    }
    (order, value, evaluations)
}

fn assert_blocked_matches_serial(f: &dyn SetFunction, k: usize) {
    let (ref_order, ref_value, ref_evals) = serial_lazy_reference(f, k);
    assert!(!ref_order.is_empty(), "degenerate workload");
    for parallel in [true, false] {
        let sel = maximize(
            f,
            Budget::cardinality(k),
            OptimizerKind::LazyGreedy,
            &MaximizeOpts { parallel, ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            sel.order.len(),
            ref_order.len(),
            "{} (parallel={parallel}): selection size diverged",
            f.name()
        );
        for (got, want) in sel.order.iter().zip(&ref_order) {
            assert_eq!(
                got.0, want.0,
                "{} (parallel={parallel}): selection order diverged",
                f.name()
            );
            assert_eq!(
                got.1.to_bits(),
                want.1.to_bits(),
                "{} (parallel={parallel}): gain of {} diverged",
                f.name(),
                got.0
            );
        }
        assert_eq!(
            sel.value.to_bits(),
            ref_value.to_bits(),
            "{} (parallel={parallel}): value diverged",
            f.name()
        );
        // Block-boundary tolerance: recomputes forced by the serial
        // algorithm are a (tie-consistent) subset of what blocking may
        // evaluate; the surplus is bounded by one partially-useful block
        // per accepted element. Blocking can also *save* recomputes in
        // later iterations (earlier blocks leave tighter bounds), so no
        // lower bound beyond the seeding sweep applies.
        assert!(sel.evaluations >= f.n() as u64, "{}: lost the seed sweep", f.name());
        let tolerance = (LAZY_STALE_BLOCK as u64) * (sel.order.len() as u64 + 1);
        assert!(
            sel.evaluations <= ref_evals + tolerance,
            "{} (parallel={parallel}): blocked evaluations {} exceed serial {} + tolerance {}",
            f.name(),
            sel.evaluations,
            ref_evals,
            tolerance
        );
    }
}

#[test]
fn blocked_matches_serial_on_facility_location() {
    let data = synthetic::blobs(300, 2, 8, 2.0, 71);
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    assert_blocked_matches_serial(&f, 20);
}

#[test]
fn blocked_matches_serial_on_graph_cut() {
    let data = synthetic::blobs(250, 2, 6, 1.5, 72);
    let f = GraphCut::new(DenseKernel::from_data(&data, Metric::Euclidean), 0.4).unwrap();
    assert_blocked_matches_serial(&f, 15);
}

#[test]
fn blocked_matches_serial_on_log_determinant() {
    let data = synthetic::blobs(80, 3, 4, 1.0, 73);
    let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 });
    let f = LogDeterminant::with_regularization(k, 0.1).unwrap();
    assert_blocked_matches_serial(&f, 10);
}

#[test]
fn blocked_matches_serial_on_flqmi() {
    let ground = synthetic::blobs(200, 2, 6, 1.5, 74);
    let queries = synthetic::blobs(8, 2, 2, 1.0, 75);
    let k = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
    let f = Flqmi::new(k, 0.7).unwrap();
    assert_blocked_matches_serial(&f, 15);
}

#[test]
fn blocked_knapsack_still_matches_naive_ratio_greedy() {
    // knapsack path: blocking drains stale entries through the same
    // budget check a pop would apply; the lazy ratio-greedy result must
    // keep matching NaiveGreedy's (both are the serial ratio greedy)
    let data = synthetic::blobs(120, 2, 5, 1.5, 76);
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let costs: Vec<f64> = (0..120).map(|i| 1.0 + (i % 4) as f64 * 0.75).collect();
    let naive = maximize(
        &f,
        Budget::knapsack(12.0, costs.clone()).unwrap(),
        OptimizerKind::NaiveGreedy,
        &MaximizeOpts::default(),
    )
    .unwrap();
    let lazy = maximize(
        &f,
        Budget::knapsack(12.0, costs).unwrap(),
        OptimizerKind::LazyGreedy,
        &MaximizeOpts::default(),
    )
    .unwrap();
    assert_eq!(naive.ids(), lazy.ids());
    assert!((naive.value - lazy.value).abs() < 1e-9);
}

//! Integration: the AOT artifact path (L1 Pallas → L2 JAX → HLO text →
//! Rust PJRT runtime). Requires `make artifacts`; tests are skipped (with
//! a loud message) when artifacts/ is missing so `cargo test` stays green
//! in a fresh checkout.

use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{build_dense, DenseKernel, KernelBackend, Metric};
use submodlib::linalg::Matrix;
use submodlib::runtime::{tiled, Engine};

fn engine() -> Option<std::sync::Arc<Engine>> {
    if !cfg!(feature = "pjrt") {
        eprintln!("SKIP: built without the `pjrt` feature — Engine is a stub");
        return None;
    }
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return None;
    }
    Some(std::sync::Arc::new(Engine::load("artifacts").expect("engine load")))
}

#[test]
fn artifact_kernel_matches_native_exact_tile() {
    let Some(engine) = engine() else { return };
    // exactly one tile (256 × 1024): no padding path
    let data = synthetic::random_features(256, 1024, 1);
    let native = DenseKernel::from_data(&data, Metric::Euclidean);
    let pjrt = tiled::build_dense_kernel(&engine, &data, Metric::Euclidean).unwrap();
    for i in (0..256).step_by(31) {
        for j in (0..256).step_by(17) {
            assert!(
                (native.get(i, j) - pjrt.get(i, j)).abs() < 1e-3,
                "({i},{j}): {} vs {}",
                native.get(i, j),
                pjrt.get(i, j)
            );
        }
    }
}

#[test]
fn artifact_kernel_matches_native_with_padding() {
    let Some(engine) = engine() else { return };
    // 300 rows, 40 dims → row padding AND feature padding exercised
    let data = synthetic::random_features(300, 40, 2);
    for metric in [Metric::Euclidean, Metric::Cosine, Metric::Dot] {
        let native = DenseKernel::from_data(&data, metric);
        let pjrt = tiled::build_dense_kernel(&engine, &data, metric).unwrap();
        let mut max_err = 0f32;
        for i in (0..300).step_by(23) {
            for j in (0..300).step_by(19) {
                max_err = max_err.max((native.get(i, j) - pjrt.get(i, j)).abs());
            }
        }
        assert!(max_err < 1e-3, "{metric:?}: max err {max_err}");
    }
}

#[test]
fn artifact_rect_kernel_for_queries() {
    let Some(engine) = engine() else { return };
    let ground = synthetic::random_features(120, 64, 3);
    let queries = synthetic::random_features(5, 64, 4);
    let rect = tiled::build_rect_kernel(&engine, &queries, &ground, Metric::Euclidean).unwrap();
    assert_eq!(rect.rows(), 5);
    assert_eq!(rect.cols(), 120);
    for q in 0..5 {
        for j in (0..120).step_by(13) {
            let direct = Metric::Euclidean.similarity(queries.row(q), ground.row(j));
            assert!((rect.get(q, j) - direct).abs() < 1e-3);
        }
    }
}

#[test]
fn artifact_fl_gains_match_memoized_gains() {
    let Some(engine) = engine() else { return };
    // FL marginal gains through the Pallas fl_gains artifact vs the
    // memoized L3 implementation
    let data = synthetic::random_features(200, 32, 5);
    let kernel = DenseKernel::from_data(&data, Metric::Euclidean);
    let mut f = FacilityLocation::new(kernel.clone());
    let current = [3usize, 77, 150];
    f.init_memoization(&Subset::from_ids(200, &current));

    // memoized max_vec reconstruction
    let max_vec: Vec<f32> = (0..200)
        .map(|i| current.iter().map(|&j| kernel.get(i, j)).fold(0f32, f32::max))
        .collect();
    let cands = [0usize, 10, 42, 99, 199];
    let mut cols = Matrix::zeros(200, cands.len());
    for (c, &cand) in cands.iter().enumerate() {
        for i in 0..200 {
            cols.set(i, c, kernel.get(i, cand));
        }
    }
    let gains = tiled::fl_gains(&engine, &cols, &max_vec).unwrap();
    for (c, &cand) in cands.iter().enumerate() {
        let expect = f.marginal_gain_memoized(cand);
        assert!(
            (gains[c] as f64 - expect).abs() < 1e-3,
            "cand {cand}: pjrt {} vs memoized {expect}",
            gains[c]
        );
    }
}

#[test]
fn backend_dispatch_builds_equivalent_functions() {
    let Some(engine) = engine() else { return };
    let data = synthetic::random_features(100, 16, 6);
    let native = build_dense(&data, Metric::Euclidean, &KernelBackend::Native).unwrap();
    let pjrt = build_dense(&data, Metric::Euclidean, &KernelBackend::Pjrt(engine)).unwrap();
    let fa = FacilityLocation::new(native);
    let fb = FacilityLocation::new(pjrt);
    let s = Subset::from_ids(100, &[5, 50, 95]);
    assert!((fa.evaluate(&s) - fb.evaluate(&s)).abs() < 1e-2);
}

#[test]
fn oversized_feature_dim_rejected() {
    let Some(engine) = engine() else { return };
    let data = synthetic::random_features(10, 2048, 7); // > compiled D=1024
    assert!(tiled::build_dense_kernel(&engine, &data, Metric::Euclidean).is_err());
}

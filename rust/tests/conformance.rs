//! Tier-1 conformance gate: the determinism linter must report zero
//! violations on the repo's own tree, and every registered rule must
//! still fire on its canonical bad example (so the linter can never
//! silently rot into a no-op).
//!
//! Skipped under Miri: it reads the whole source tree from disk, which
//! is slow under the interpreter and adds nothing — the rule engine's
//! behavior is covered by the analysis module's unit tests.
#![cfg(not(miri))]

use std::path::Path;

use submodlib::analysis::{self, lint_source, RULES};

/// Repo root: Cargo.toml sits at the top, sources under rust/.
fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn tree_is_conformant() {
    let violations = analysis::lint_root(repo_root()).expect("lint walk failed");
    assert!(
        violations.is_empty(),
        "determinism conformance violations:\n{}",
        analysis::render(&violations)
    );
}

#[test]
fn every_rule_fires_on_its_bad_example() {
    for r in RULES {
        let fired: Vec<_> =
            lint_source(r.example_path, r.bad_example).into_iter().map(|v| v.rule).collect();
        assert!(
            fired.contains(&r.name),
            "rule {} no longer fires on its registered bad example (got {:?})",
            r.name,
            fired
        );
    }
}

#[test]
fn backend_unsafe_whitelist_is_exact() {
    // The AVX2 intrinsics backend is whitelisted for `unsafe`, but only
    // with a SAFETY justification on every line…
    let bare = "fn f(p: *const f32) -> f32 { unsafe { *p } }\n";
    let fired: Vec<_> = lint_source("rust/src/kernel/backend/avx2.rs", bare)
        .into_iter()
        .map(|v| v.rule)
        .collect();
    assert_eq!(fired, vec!["safety-comment"], "avx2 backend: unjustified unsafe");
    let justified = "// SAFETY: caller guarantees p is in-bounds.\nfn f(p: *const f32) -> f32 { unsafe { *p } }\n";
    assert!(
        lint_source("rust/src/kernel/backend/avx2.rs", justified).is_empty(),
        "justified unsafe in the avx2 backend must lint clean"
    );
    // …while the safe backend modules are NOT whitelisted: unsafe creep
    // anywhere else under kernel/backend/ stays confined.
    for path in [
        "rust/src/kernel/backend/mod.rs",
        "rust/src/kernel/backend/scalar.rs",
        "rust/src/kernel/backend/wide.rs",
    ] {
        let fired: Vec<_> = lint_source(path, bare).into_iter().map(|v| v.rule).collect();
        assert_eq!(fired, vec!["unsafe-confined"], "{path}");
    }
    // the real backend sources exist where the whitelist points
    for probe in [
        "rust/src/kernel/backend/mod.rs",
        "rust/src/kernel/backend/scalar.rs",
        "rust/src/kernel/backend/wide.rs",
        "rust/src/kernel/backend/avx2.rs",
        "rust/src/data/points.rs",
    ] {
        assert!(repo_root().join(probe).is_file(), "missing {probe}");
    }
}

#[test]
fn cancel_module_is_wall_clock_scoped() {
    // ISSUE 10: the cooperative-cancellation flag protocol is
    // compute-layer code, polled from kernel tiles and gain scans — a
    // clock read inside it would be a determinism leak, so the
    // wall-clock rule must cover it. Deadline-to-token translation is
    // allowed in exactly one place: the coordinator's watchdog, at the
    // rim with the rest of the timing code.
    let bad = "fn f() -> std::time::Instant { std::time::Instant::now() }\n";
    let fired: Vec<_> =
        lint_source("rust/src/runtime/cancel.rs", bad).into_iter().map(|v| v.rule).collect();
    assert_eq!(fired, vec!["wall-clock"], "cancel module must be wall-clock scoped");
    assert!(
        lint_source("rust/src/coordinator/watchdog.rs", bad).is_empty(),
        "the watchdog is the sanctioned deadline rim"
    );
    // the real files exist where the scoping points
    for probe in ["rust/src/runtime/cancel.rs", "rust/src/coordinator/watchdog.rs"] {
        assert!(repo_root().join(probe).is_file(), "missing {probe}");
    }
}

#[test]
fn scan_actually_covers_the_tree() {
    // Guard against a silent walker regression: planting a violation in a
    // copy of a real source path must be caught. We lint the synthetic
    // source under a path inside rust/src to prove path scoping is live.
    let vs = lint_source(
        "rust/src/optimizers/lazy.rs",
        "fn pick(xs: &[f64]) -> f64 { let t = std::time::Instant::now(); xs[0] }\n",
    );
    assert!(vs.iter().any(|v| v.rule == "wall-clock"), "{vs:?}");
    // …and the real tree has a meaningful number of files: the walker
    // found the optimizers, functions, kernel, and runtime layers.
    for probe in [
        "rust/src/optimizers/lazy.rs",
        "rust/src/functions/facility_location.rs",
        "rust/src/kernel/sparse.rs",
        "rust/src/runtime/pool.rs",
    ] {
        assert!(repo_root().join(probe).is_file(), "missing {probe}");
    }
}

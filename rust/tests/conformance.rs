//! Tier-1 conformance gate: the determinism linter must report zero
//! violations on the repo's own tree, and every registered rule must
//! still fire on its canonical bad example (so the linter can never
//! silently rot into a no-op).
//!
//! Skipped under Miri: it reads the whole source tree from disk, which
//! is slow under the interpreter and adds nothing — the rule engine's
//! behavior is covered by the analysis module's unit tests.
#![cfg(not(miri))]

use std::path::Path;

use submodlib::analysis::{self, lint_source, RULES};

/// Repo root: Cargo.toml sits at the top, sources under rust/.
fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn tree_is_conformant() {
    let violations = analysis::lint_root(repo_root()).expect("lint walk failed");
    assert!(
        violations.is_empty(),
        "determinism conformance violations:\n{}",
        analysis::render(&violations)
    );
}

#[test]
fn every_rule_fires_on_its_bad_example() {
    for r in RULES {
        let fired: Vec<_> =
            lint_source(r.example_path, r.bad_example).into_iter().map(|v| v.rule).collect();
        assert!(
            fired.contains(&r.name),
            "rule {} no longer fires on its registered bad example (got {:?})",
            r.name,
            fired
        );
    }
}

#[test]
fn scan_actually_covers_the_tree() {
    // Guard against a silent walker regression: planting a violation in a
    // copy of a real source path must be caught. We lint the synthetic
    // source under a path inside rust/src to prove path scoping is live.
    let vs = lint_source(
        "rust/src/optimizers/lazy.rs",
        "fn pick(xs: &[f64]) -> f64 { let t = std::time::Instant::now(); xs[0] }\n",
    );
    assert!(vs.iter().any(|v| v.rule == "wall-clock"), "{vs:?}");
    // …and the real tree has a meaningful number of files: the walker
    // found the optimizers, functions, kernel, and runtime layers.
    for probe in [
        "rust/src/optimizers/lazy.rs",
        "rust/src/functions/facility_location.rs",
        "rust/src/kernel/sparse.rs",
        "rust/src/runtime/pool.rs",
    ] {
        assert!(repo_root().join(probe).is_file(), "missing {probe}");
    }
}

//! ISSUE 5 cross-thread-count determinism matrix: every selection and
//! every kernel build must be **bit-identical** at pool width 1, 2, and
//! the default — the observable half of the pool's indexed-slot
//! determinism rule (`runtime::pool` module docs). Functions and their
//! kernels are built *inside* each width context, so the kernel
//! construction paths (dense direct-write + mirror, sparse wavefront)
//! are exercised at each width too, not just the gain scans.
//!
//! Widths are narrowed per-thread via `pool::with_thread_limit`, which
//! is what lets one process cover the whole matrix (the pool's spawned
//! size is fixed at first use); CI additionally runs the entire tier-1
//! suite under `SUBMODLIB_THREADS=2` so a non-default *configured*
//! width is exercised end-to-end on every push.

use submodlib::data::synthetic;
use submodlib::functions::clustered::ClusteredFunction;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::mi::Flqmi;
use submodlib::functions::traits::SetFunction;
use submodlib::kernel::{DenseKernel, Metric, RectKernel, SparseKernel};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::runtime::pool;

/// Ground-set size: above `PARALLEL_MIN_CANDIDATES` (256), so the gain
/// scans genuinely fan out instead of staying on the serial fast path.
const N: usize = 400;
const K: usize = 15;

/// `Some(w)` = cap this thread's parallel sections at w participants;
/// `None` = the full default width.
fn at_width<T>(width: Option<usize>, f: impl FnOnce() -> T) -> T {
    match width {
        Some(w) => pool::with_thread_limit(w, f),
        None => f(),
    }
}

/// Selection fingerprint: pick order with gain bits, plus value bits —
/// any nondeterminism in the parallel substrate shows up here.
fn fingerprint(f: &dyn SetFunction, kind: OptimizerKind) -> (Vec<(usize, u64)>, u64) {
    let sel =
        maximize(f, Budget::cardinality(K), kind, &MaximizeOpts::default()).unwrap();
    (sel.order.iter().map(|&(e, g)| (e, g.to_bits())).collect(), sel.value.to_bits())
}

/// Width 1 is the serial reference; widths 2 and default must reproduce
/// it exactly under both Naive and Lazy greedy.
fn assert_width_matrix(label: &str, build: impl Fn() -> Box<dyn SetFunction>) {
    for kind in [OptimizerKind::NaiveGreedy, OptimizerKind::LazyGreedy] {
        let reference = at_width(Some(1), || fingerprint(build().as_ref(), kind));
        for width in [Some(2), None] {
            let got = at_width(width, || fingerprint(build().as_ref(), kind));
            assert_eq!(got, reference, "{label} / {kind:?} at width {width:?}");
        }
    }
}

fn ground() -> submodlib::linalg::Matrix {
    synthetic::blobs(N, 2, 8, 3.0, 71)
}

#[test]
fn facility_location_dense_matrix() {
    let data = ground();
    assert_width_matrix("FL dense", || {
        Box::new(FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean)))
    });
}

#[test]
fn facility_location_sparse_matrix() {
    let data = ground();
    assert_width_matrix("FL sparse", || {
        Box::new(FacilityLocation::sparse(
            SparseKernel::from_data(&data, Metric::Euclidean, 24).unwrap(),
        ))
    });
}

#[test]
fn facility_location_clustered_matrix() {
    let data = ground();
    assert_width_matrix("FL clustered", || {
        Box::new(
            ClusteredFunction::from_data(&data, 5, 7, |sub| {
                Ok(Box::new(FacilityLocation::new(DenseKernel::from_data(
                    sub,
                    Metric::Euclidean,
                ))))
            })
            .unwrap(),
        )
    });
}

#[test]
fn log_determinant_matrix() {
    let data = ground();
    assert_width_matrix("LogDeterminant", || {
        Box::new(
            LogDeterminant::with_regularization(
                DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 }),
                0.1,
            )
            .unwrap(),
        )
    });
}

#[test]
fn flqmi_matrix() {
    let data = ground();
    let queries = synthetic::blobs(10, 2, 2, 1.0, 72);
    assert_width_matrix("FLQMI", || {
        Box::new(
            Flqmi::new(
                RectKernel::from_data(&queries, &data, Metric::Euclidean).unwrap(),
                1.0,
            )
            .unwrap(),
        )
    });
}

#[test]
fn maximize_opts_threads_cap_is_inert_on_results() {
    // the `MaximizeOpts::threads` knob must be a wall-clock knob only
    let data = ground();
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let budget = Budget::cardinality(K);
    let base = maximize(&f, budget.clone(), OptimizerKind::NaiveGreedy, &MaximizeOpts::default())
        .unwrap();
    for cap in [1usize, 2, usize::MAX] {
        let capped = maximize(
            &f,
            budget.clone(),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts { threads: Some(cap), ..Default::default() },
        )
        .unwrap();
        assert_eq!(capped.ids(), base.ids(), "threads cap {cap}");
        assert_eq!(capped.value.to_bits(), base.value.to_bits(), "threads cap {cap}");
    }
}

#[test]
fn unfired_cancel_token_is_byte_inert_at_every_width() {
    // ISSUE 10 never-fired contract: arming a cancel token that never
    // fires must not change a single output bit — the polls read an
    // atomic flag and touch no claim order. Covered here at every pool
    // width for both surfaces the token threads through: a selection
    // (MaximizeOpts::cancel + the gain-scan polls) and the kernel build
    // paths (the ambient scope the tile/wavefront claim loops poll),
    // including the sparse CSR output. CI's backend matrix runs this
    // file under the scalar backend too, so the contract is pinned
    // per-backend, not just for the auto-detected one.
    use submodlib::runtime::cancel::{self, CancelToken};
    let data = ground();
    let nk = 24;
    let reference = at_width(Some(1), || {
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        fingerprint(&f, OptimizerKind::LazyGreedy)
    });
    let ref_sparse =
        at_width(Some(1), || SparseKernel::from_data(&data, Metric::Euclidean, nk).unwrap());
    for width in [Some(1), Some(2), None] {
        let (sel, sparse) = at_width(width, || {
            // the ambient scope covers the kernel builds' claim loops
            cancel::with_scope(Some(CancelToken::new()), || {
                let f =
                    FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
                let sel = maximize(
                    &f,
                    Budget::cardinality(K),
                    OptimizerKind::LazyGreedy,
                    &MaximizeOpts { cancel: Some(CancelToken::new()), ..Default::default() },
                )
                .unwrap();
                let sparse = SparseKernel::from_data(&data, Metric::Euclidean, nk).unwrap();
                (sel, sparse)
            })
        });
        let got: (Vec<(usize, u64)>, u64) = (
            sel.order.iter().map(|&(e, g)| (e, g.to_bits())).collect(),
            sel.value.to_bits(),
        );
        assert_eq!(got, reference, "armed-unfired selection drifted at width {width:?}");
        for i in 0..data.rows() {
            let (gc, gv) = sparse.row(i);
            let (wc, wv) = ref_sparse.row(i);
            assert_eq!(gc, wc, "sparse cols row {i} width {width:?}");
            for (g, w) in gv.iter().zip(wv) {
                assert_eq!(g.to_bits(), w.to_bits(), "sparse vals row {i} width {width:?}");
            }
        }
    }
}

#[test]
fn kernel_builds_bit_identical_across_widths() {
    // several wedge/tile boundaries (n > 3·TILE_ROWS) so the width
    // actually changes the parallel schedule being tested
    let data = synthetic::blobs(3 * 64 + 17, 6, 5, 2.0, 99);
    let n = data.rows();
    let nk = 9;
    let (ref_dense, ref_sparse) = at_width(Some(1), || {
        (
            DenseKernel::from_data(&data, Metric::Euclidean),
            SparseKernel::from_data(&data, Metric::Euclidean, nk).unwrap(),
        )
    });
    for width in [Some(2), None] {
        let (dense, sparse) = at_width(width, || {
            (
                DenseKernel::from_data(&data, Metric::Euclidean),
                SparseKernel::from_data(&data, Metric::Euclidean, nk).unwrap(),
            )
        });
        for i in 0..n {
            let (got, want) = (dense.row(i), ref_dense.row(i));
            for (j, (g, w)) in got.iter().zip(want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "dense ({i},{j}) width {width:?}");
            }
            let (gc, gv) = sparse.row(i);
            let (wc, wv) = ref_sparse.row(i);
            assert_eq!(gc, wc, "sparse cols row {i} width {width:?}");
            for (g, w) in gv.iter().zip(wv) {
                assert_eq!(g.to_bits(), w.to_bits(), "sparse vals row {i} width {width:?}");
            }
        }
    }
}

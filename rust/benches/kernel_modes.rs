//! Ablation bench: paper §8 usage patterns — dense vs sparse vs clustered
//! FacilityLocation, and the kernel-construction cost itself (the knob the
//! paper exposes as `mode=` and `num_neighbors=`).

use submodlib::clustering::{kmeans, partition};
use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::kernel::{DenseKernel, Metric, SparseKernel};
use submodlib::linalg::Matrix;
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::util::bench::BenchRunner;

fn main() {
    let n = 1000;
    let k = 50;
    let dim = 32;
    let data = synthetic::blobs(n, dim, 10, 2.0, 42);

    let mut runner = BenchRunner::from_env();
    eprintln!("kernel modes: n={n}, dim={dim}, budget={k}");

    // construction costs
    runner.bench("build_dense_kernel", || DenseKernel::from_data(&data, Metric::Euclidean).n());
    runner.bench("build_sparse_kernel_k32", || {
        SparseKernel::from_data(&data, Metric::Euclidean, 32).unwrap().nnz()
    });

    // selection costs per mode
    let dense = DenseKernel::from_data(&data, Metric::Euclidean);
    let sparse = SparseKernel::from_data(&data, Metric::Euclidean, 32).unwrap();
    let km = kmeans(&data, 10, 30, 1);
    let parts = partition(&km.labels, 10);
    let clusters: Vec<(Vec<usize>, DenseKernel)> = parts
        .into_iter()
        .filter(|ids| !ids.is_empty())
        .map(|ids| {
            let mut sub = Matrix::zeros(ids.len(), dim);
            for (li, &g) in ids.iter().enumerate() {
                sub.row_mut(li).copy_from_slice(data.row(g));
            }
            (ids, DenseKernel::from_data(&sub, Metric::Euclidean))
        })
        .collect();

    let f_dense = FacilityLocation::new(dense);
    let f_sparse = FacilityLocation::sparse(sparse);
    let f_clustered = FacilityLocation::clustered(clusters, n);
    let opts = MaximizeOpts::default();

    let dense_val = runner
        .bench("select_dense", || {
            maximize(&f_dense, Budget::cardinality(k), OptimizerKind::LazyGreedy, &opts)
                .unwrap()
                .value
        })
        .median
        .as_secs_f64();
    runner.bench("select_sparse_k32", || {
        maximize(&f_sparse, Budget::cardinality(k), OptimizerKind::LazyGreedy, &opts)
            .unwrap()
            .value
    });
    runner.bench("select_clustered", || {
        maximize(&f_clustered, Budget::cardinality(k), OptimizerKind::LazyGreedy, &opts)
            .unwrap()
            .value
    });
    let _ = dense_val;

    // quality comparison (sparse/clustered trade accuracy for speed)
    let vd = maximize(&f_dense, Budget::cardinality(k), OptimizerKind::LazyGreedy, &opts)
        .unwrap()
        .value;
    let vs = maximize(&f_sparse, Budget::cardinality(k), OptimizerKind::LazyGreedy, &opts)
        .unwrap()
        .value;
    let vc = maximize(&f_clustered, Budget::cardinality(k), OptimizerKind::LazyGreedy, &opts)
        .unwrap()
        .value;
    eprintln!("objective: dense {vd:.2}, sparse {vs:.2}, clustered {vc:.2}");
    runner.finish("kernel_modes");
}

//! Bench: paper Table 5 — FacilityLocation selection time vs ground-set
//! size (1024-d random features, budget 100, kernel build included).
//! Reproduced claim: near-quadratic growth, tractable at n = 10 000.
//!
//! Full paper sizes run when `BENCH_FULL=1`; default sweep stops at 5000
//! to keep `cargo bench` turnaround sane.

use submodlib::experiments::table5::{render, run_size};
use submodlib::kernel::KernelBackend;

fn main() {
    let full = std::env::var("BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let sizes: &[usize] = if full {
        submodlib::experiments::table5::PAPER_SIZES
    } else {
        &[50, 100, 200, 500, 1000, 2000, 5000]
    };
    let mut rows = Vec::new();
    for &n in sizes {
        let row = run_size(n, 1024, 100, 7, &KernelBackend::Native).unwrap();
        eprintln!(
            "n={n:<6} kernel {:.4}s select {:.4}s total {:.4}s",
            row.kernel_seconds, row.select_seconds, row.total_seconds
        );
        rows.push(row);
    }
    // shape assertion: growth from n=500 to n=5000 must be superlinear in
    // total time (kernel build is O(n² d))
    let t = |n: usize| rows.iter().find(|r| r.n == n).unwrap().total_seconds;
    if sizes.contains(&500) && sizes.contains(&5000) {
        let ratio = t(5000) / t(500).max(1e-9);
        assert!(ratio > 10.0, "expected superlinear scaling, got {ratio:.1}x for 10x data");
        eprintln!("500→5000 scaling: {ratio:.1}x (paper: 0.0166s → 2.469s = 149x)");
    }
    println!("== table5_timing ==");
    print!("{}", render(&rows));
}

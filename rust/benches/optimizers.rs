//! Bench: paper Table 2 — running-time comparison of the four optimizers
//! on the §5.3.5 workload (500 points, 10 clusters, σ=4, FacilityLocation,
//! budget 100). Reproduced claim: LazierThanLazy ≤ Lazy < Stochastic <
//! Naive. (`BENCH_SAMPLES` env var controls sample count.)
//!
//! Additionally emits `BENCH_optimizers.json`, the perf-trajectory
//! snapshot future PRs compare against:
//!
//! * `table2`: wall-clock + `evaluations` + value for the Table 2
//!   workload at n=500, k=50, for FL / GraphCut / LogDet × naive / lazy /
//!   stochastic;
//! * `parallel_scaling`: NaiveGreedy on FacilityLocation at n=2000,
//!   k=100, batched-parallel gain scan vs the serial per-element path
//!   (`MaximizeOpts::parallel = false`) — the ISSUE 1 headline number.

use std::collections::BTreeMap;

use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::graph_cut::GraphCut;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::traits::SetFunction;
use submodlib::kernel::{DenseKernel, Metric};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::util::bench::BenchRunner;
use submodlib::util::json::Json;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() {
    let data = synthetic::blobs(500, 2, 10, 4.0, 42);
    let kernel = DenseKernel::from_data(&data, Metric::Euclidean);
    let f = FacilityLocation::new(kernel.clone());
    let opts = MaximizeOpts::default();
    let budget = Budget::cardinality(100);

    let mut runner = BenchRunner::from_env();
    eprintln!("Table 2 workload: n=500, 10 clusters, sigma=4, FL, budget=100");
    for (name, kind) in [
        ("NaiveGreedy", OptimizerKind::NaiveGreedy),
        ("StochasticGreedy", OptimizerKind::StochasticGreedy),
        ("LazyGreedy", OptimizerKind::LazyGreedy),
        ("LazierThanLazyGreedy", OptimizerKind::LazierThanLazyGreedy),
    ] {
        runner.bench(name, || {
            maximize(&f, budget.clone(), kind, &opts).unwrap().value
        });
    }

    // shape assertions (who wins) — a failed reproduction should be loud
    let rs = runner.results();
    let t = |n: &str| rs.iter().find(|r| r.name == n).unwrap().median.as_secs_f64();
    assert!(t("LazyGreedy") < t("NaiveGreedy"), "paper ordering violated: lazy vs naive");
    assert!(
        t("LazierThanLazyGreedy") < t("NaiveGreedy"),
        "paper ordering violated: lazier vs naive"
    );
    assert!(
        t("StochasticGreedy") < t("NaiveGreedy"),
        "paper ordering violated: stochastic vs naive"
    );
    eprintln!(
        "speedups vs naive: lazy {:.1}x, lazier {:.1}x, stochastic {:.1}x (paper: 9.4x, 9.7x, 3.4x)",
        t("NaiveGreedy") / t("LazyGreedy"),
        t("NaiveGreedy") / t("LazierThanLazyGreedy"),
        t("NaiveGreedy") / t("StochasticGreedy"),
    );

    // ---- snapshot: FL / GC / LogDet × naive / lazy / stochastic ---------
    eprintln!("snapshot workload: n=500, k=50, FL/GC/LogDet x naive/lazy/stochastic");
    let snap_budget = Budget::cardinality(50);
    let functions: Vec<(&str, Box<dyn SetFunction>)> = vec![
        ("FacilityLocation", Box::new(FacilityLocation::new(kernel.clone()))),
        ("GraphCut", Box::new(GraphCut::new(kernel.clone(), 0.4).unwrap())),
        (
            "LogDeterminant",
            Box::new(
                LogDeterminant::with_regularization(
                    DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 }),
                    0.1,
                )
                .unwrap(),
            ),
        ),
    ];
    let mut snapshot_rows: Vec<Json> = Vec::new();
    for (fname, func) in &functions {
        for (oname, kind) in [
            ("NaiveGreedy", OptimizerKind::NaiveGreedy),
            ("LazyGreedy", OptimizerKind::LazyGreedy),
            ("StochasticGreedy", OptimizerKind::StochasticGreedy),
        ] {
            let label = format!("{fname}/{oname}");
            let stats = runner.bench(&label, || {
                maximize(func.as_ref(), snap_budget.clone(), kind, &opts).unwrap().value
            });
            let (median_s, mean_s) =
                (stats.median.as_secs_f64(), stats.mean.as_secs_f64());
            let sel =
                maximize(func.as_ref(), snap_budget.clone(), kind, &opts).unwrap();
            snapshot_rows.push(obj(vec![
                ("function", Json::Str(fname.to_string())),
                ("optimizer", Json::Str(oname.to_string())),
                ("median_s", Json::Num(median_s)),
                ("mean_s", Json::Num(mean_s)),
                ("evaluations", Json::Num(sel.evaluations as f64)),
                ("value", Json::Num(sel.value)),
                ("selected", Json::Num(sel.order.len() as f64)),
            ]));
        }
    }

    // ---- parallel scaling: n=2000, k=100, FL, naive ---------------------
    let threads =
        std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    eprintln!("parallel scaling: n=2000, k=100, FL NaiveGreedy ({threads} threads)");
    let big = synthetic::blobs(2000, 2, 10, 4.0, 43);
    let big_fl = FacilityLocation::new(DenseKernel::from_data(&big, Metric::Euclidean));
    let big_budget = Budget::cardinality(100);
    let serial_stats = runner
        .bench("FL2000/NaiveGreedy/serial", || {
            maximize(
                &big_fl,
                big_budget.clone(),
                OptimizerKind::NaiveGreedy,
                &MaximizeOpts { parallel: false, ..Default::default() },
            )
            .unwrap()
            .value
        })
        .median
        .as_secs_f64();
    let parallel_stats = runner
        .bench("FL2000/NaiveGreedy/parallel", || {
            maximize(
                &big_fl,
                big_budget.clone(),
                OptimizerKind::NaiveGreedy,
                &MaximizeOpts::default(),
            )
            .unwrap()
            .value
        })
        .median
        .as_secs_f64();
    let speedup = serial_stats / parallel_stats;
    eprintln!(
        "  parallel gain scan speedup: {speedup:.2}x (serial {serial_stats:.3}s, parallel {parallel_stats:.3}s)"
    );

    let snapshot = obj(vec![
        ("schema", Json::Str("bench_optimizers/v1".to_string())),
        (
            "table2",
            obj(vec![
                (
                    "workload",
                    obj(vec![
                        ("n", Json::Num(500.0)),
                        ("k", Json::Num(50.0)),
                        ("clusters", Json::Num(10.0)),
                        ("sigma", Json::Num(4.0)),
                    ]),
                ),
                ("results", Json::Arr(snapshot_rows)),
            ]),
        ),
        (
            "parallel_scaling",
            obj(vec![
                (
                    "workload",
                    obj(vec![
                        ("n", Json::Num(2000.0)),
                        ("k", Json::Num(100.0)),
                        ("function", Json::Str("FacilityLocation".to_string())),
                        ("optimizer", Json::Str("NaiveGreedy".to_string())),
                    ]),
                ),
                ("threads", Json::Num(threads as f64)),
                ("serial_median_s", Json::Num(serial_stats)),
                ("parallel_median_s", Json::Num(parallel_stats)),
                ("speedup", Json::Num(speedup)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_optimizers.json", snapshot.to_string())
        .expect("write BENCH_optimizers.json");
    eprintln!("wrote BENCH_optimizers.json");

    runner.finish("table2_optimizers");
}

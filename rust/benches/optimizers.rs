//! Bench: paper Table 2 — running-time comparison of the four optimizers
//! on the §5.3.5 workload (500 points, 10 clusters, σ=4, FacilityLocation,
//! budget 100). Reproduced claim: LazierThanLazy ≤ Lazy < Stochastic <
//! Naive. (`BENCH_SAMPLES` env var controls sample count.)

use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::kernel::{DenseKernel, Metric};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::util::bench::BenchRunner;

fn main() {
    let data = synthetic::blobs(500, 2, 10, 4.0, 42);
    let kernel = DenseKernel::from_data(&data, Metric::Euclidean);
    let f = FacilityLocation::new(kernel);
    let opts = MaximizeOpts::default();
    let budget = Budget::cardinality(100);

    let mut runner = BenchRunner::from_env();
    eprintln!("Table 2 workload: n=500, 10 clusters, sigma=4, FL, budget=100");
    for (name, kind) in [
        ("NaiveGreedy", OptimizerKind::NaiveGreedy),
        ("StochasticGreedy", OptimizerKind::StochasticGreedy),
        ("LazyGreedy", OptimizerKind::LazyGreedy),
        ("LazierThanLazyGreedy", OptimizerKind::LazierThanLazyGreedy),
    ] {
        runner.bench(name, || {
            maximize(&f, budget.clone(), kind, &opts).unwrap().value
        });
    }

    // shape assertions (who wins) — a failed reproduction should be loud
    let rs = runner.results();
    let t = |n: &str| rs.iter().find(|r| r.name == n).unwrap().median.as_secs_f64();
    assert!(t("LazyGreedy") < t("NaiveGreedy"), "paper ordering violated: lazy vs naive");
    assert!(
        t("LazierThanLazyGreedy") < t("NaiveGreedy"),
        "paper ordering violated: lazier vs naive"
    );
    assert!(
        t("StochasticGreedy") < t("NaiveGreedy"),
        "paper ordering violated: stochastic vs naive"
    );
    eprintln!(
        "speedups vs naive: lazy {:.1}x, lazier {:.1}x, stochastic {:.1}x (paper: 9.4x, 9.7x, 3.4x)",
        t("NaiveGreedy") / t("LazyGreedy"),
        t("NaiveGreedy") / t("LazierThanLazyGreedy"),
        t("NaiveGreedy") / t("StochasticGreedy"),
    );
    runner.finish("table2_optimizers");
}

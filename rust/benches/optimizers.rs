//! Bench: paper Table 2 — running-time comparison of the four optimizers
//! on the §5.3.5 workload (500 points, 10 clusters, σ=4, FacilityLocation,
//! budget 100). Reproduced claim: LazierThanLazy ≤ Lazy < Stochastic <
//! Naive. (`BENCH_SAMPLES` env var controls sample count.)
//!
//! Additionally emits `BENCH_optimizers.json`, the perf-trajectory
//! snapshot future PRs compare against:
//!
//! * `table2`: wall-clock + `evaluations` + value for the Table 2
//!   workload at n=500, k=50, for FL / GraphCut / LogDet × naive / lazy /
//!   stochastic;
//! * `parallel_scaling`: NaiveGreedy on FacilityLocation at n=2000,
//!   k=100, batched-parallel gain scan vs the serial per-element path
//!   (`MaximizeOpts::parallel = false`) — the ISSUE 1 headline number;
//! * `lazy_stale_block`: LazyGreedy on the Table 2 FL workload with the
//!   Minoux-blocked stale re-evaluation (ISSUE 2 tentpole) — wall-clock,
//!   evaluation count, and the block cap, to compare against the PR 1
//!   one-pop-at-a-time snapshot;
//! * `mi_family`: FLQMI / FLVMI / GCMI / COM / LogDetMI at n=500 with 10
//!   queries, naive vs lazy — the targeted-selection stack that newly
//!   rides the batched gain path (ISSUE 2);
//! * `kernel_build` (schema v4, ISSUEs 3+4): Table 5-shaped
//!   kernel-construction wall-clock at n ∈ {500, 2000} for the dense
//!   build, the symmetric wavefront sparse build (`sparse_sym`, each
//!   pair computed once) and the full-width sparse baseline
//!   (`sparse_full`, the pre-wavefront algorithm kept to make the ~2×
//!   dot saving measurable in one snapshot), plus the analytic
//!   peak-allocation estimates from
//!   `kernel::tile::{dense,sparse}_peak_bytes`. The harness also
//!   *asserts* that dense and sparse builds of the same data agree
//!   bit-for-bit on shared entries — the wavefront's symmetry guarantee
//!   stays load-bearing here, not just in unit tests;
//! * `backends` (schema v6, ISSUE 9): the pluggable compute backends —
//!   which `kernel::backend` implementation is active (top-level
//!   `backend` tag too, so snapshots from different ISAs stay
//!   comparable), an inner-kernel sweep timing `fill_row` over
//!   `TILE_ROWS` rows at n=2000/d=128 for *every* available backend
//!   (scalar / wide / avx2 where detected), and a `simd_speedup` row
//!   (best SIMD backend vs the scalar anchor — the ISSUE 9 ≥1.5×
//!   acceptance number, warned about loudly when an AVX2 host comes in
//!   under target). The `kernel_build` section records the backend its
//!   builds ran under, since dense/sparse wall-clock now depends on it;
//! * `pool` (schema v5, ISSUE 5): the persistent worker-pool runtime —
//!   resolved width + spawned worker count, the Table 2 FL n=500
//!   NaiveGreedy wall-clock on the pool path, a per-call dispatch
//!   microcomparison (pool publish/park vs. the old per-call
//!   `std::thread::scope` spawn/join), and the sparse wavefront's
//!   shard-lock contention counters (`null` in release builds, where
//!   the debug-only instrumentation is compiled out). Top-level
//!   metadata records the resolved thread count so snapshots from
//!   different machines/widths stay comparable.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use submodlib::data::points::PointView;
use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::feature_based::ConcaveShape;
use submodlib::functions::graph_cut::GraphCut;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::mi::{ConcaveOverModular, Flqmi, Flvmi, Gcmi, LogDetMi};
use submodlib::functions::traits::SetFunction;
use submodlib::kernel::backend;
use submodlib::kernel::sparse::shard_contention;
use submodlib::kernel::{tile, DenseKernel, Metric, RectKernel, SparseKernel};
use submodlib::optimizers::lazy::LAZY_STALE_BLOCK;
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::runtime::pool;
use submodlib::util::bench::BenchRunner;
use submodlib::util::json::Json;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn main() {
    let data = synthetic::blobs(500, 2, 10, 4.0, 42);
    let kernel = DenseKernel::from_data(&data, Metric::Euclidean);
    let f = FacilityLocation::new(kernel.clone());
    let opts = MaximizeOpts::default();
    let budget = Budget::cardinality(100);

    let mut runner = BenchRunner::from_env();
    eprintln!("Table 2 workload: n=500, 10 clusters, sigma=4, FL, budget=100");
    for (name, kind) in [
        ("NaiveGreedy", OptimizerKind::NaiveGreedy),
        ("StochasticGreedy", OptimizerKind::StochasticGreedy),
        ("LazyGreedy", OptimizerKind::LazyGreedy),
        ("LazierThanLazyGreedy", OptimizerKind::LazierThanLazyGreedy),
    ] {
        runner.bench(name, || {
            maximize(&f, budget.clone(), kind, &opts).unwrap().value
        });
    }

    // shape assertions (who wins) — a failed reproduction should be loud
    let rs = runner.results();
    let t = |n: &str| rs.iter().find(|r| r.name == n).unwrap().median.as_secs_f64();
    assert!(t("LazyGreedy") < t("NaiveGreedy"), "paper ordering violated: lazy vs naive");
    assert!(
        t("LazierThanLazyGreedy") < t("NaiveGreedy"),
        "paper ordering violated: lazier vs naive"
    );
    assert!(
        t("StochasticGreedy") < t("NaiveGreedy"),
        "paper ordering violated: stochastic vs naive"
    );
    eprintln!(
        "speedups vs naive: lazy {:.1}x, lazier {:.1}x, stochastic {:.1}x (paper: 9.4x, 9.7x, 3.4x)",
        t("NaiveGreedy") / t("LazyGreedy"),
        t("NaiveGreedy") / t("LazierThanLazyGreedy"),
        t("NaiveGreedy") / t("StochasticGreedy"),
    );
    // the Table 2 FL NaiveGreedy wall-clock doubles as the pool section's
    // headline number (the whole run rides the pool now)
    let table2_fl_naive_s = t("NaiveGreedy");

    // ---- snapshot: FL / GC / LogDet × naive / lazy / stochastic ---------
    eprintln!("snapshot workload: n=500, k=50, FL/GC/LogDet x naive/lazy/stochastic");
    let snap_budget = Budget::cardinality(50);
    let functions: Vec<(&str, Box<dyn SetFunction>)> = vec![
        ("FacilityLocation", Box::new(FacilityLocation::new(kernel.clone()))),
        ("GraphCut", Box::new(GraphCut::new(kernel.clone(), 0.4).unwrap())),
        (
            "LogDeterminant",
            Box::new(
                LogDeterminant::with_regularization(
                    DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 }),
                    0.1,
                )
                .unwrap(),
            ),
        ),
    ];
    let mut snapshot_rows: Vec<Json> = Vec::new();
    // FL/LazyGreedy numbers double as the `lazy_stale_block` entry (the
    // ISSUE 2 acceptance comparison vs the PR 1 one-pop-at-a-time
    // snapshot) — captured here rather than re-benched
    let mut fl_lazy: Option<(f64, u64, f64)> = None;
    for (fname, func) in &functions {
        for (oname, kind) in [
            ("NaiveGreedy", OptimizerKind::NaiveGreedy),
            ("LazyGreedy", OptimizerKind::LazyGreedy),
            ("StochasticGreedy", OptimizerKind::StochasticGreedy),
        ] {
            let label = format!("{fname}/{oname}");
            let stats = runner.bench(&label, || {
                maximize(func.as_ref(), snap_budget.clone(), kind, &opts).unwrap().value
            });
            let (median_s, mean_s) =
                (stats.median.as_secs_f64(), stats.mean.as_secs_f64());
            let sel =
                maximize(func.as_ref(), snap_budget.clone(), kind, &opts).unwrap();
            if *fname == "FacilityLocation" && oname == "LazyGreedy" {
                fl_lazy = Some((median_s, sel.evaluations, sel.value));
            }
            snapshot_rows.push(obj(vec![
                ("function", Json::Str(fname.to_string())),
                ("optimizer", Json::Str(oname.to_string())),
                ("median_s", Json::Num(median_s)),
                ("mean_s", Json::Num(mean_s)),
                ("evaluations", Json::Num(sel.evaluations as f64)),
                ("value", Json::Num(sel.value)),
                ("selected", Json::Num(sel.order.len() as f64)),
            ]));
        }
    }

    // ---- lazy stale-block: Table 2 FL workload, n=500, k=50 -------------
    let (lazy_median_s, lazy_evals, lazy_value) =
        fl_lazy.expect("FL/LazyGreedy row collected above");
    eprintln!(
        "lazy stale-block: n=500, k=50, FL LazyGreedy (block cap {LAZY_STALE_BLOCK}): \
         {lazy_median_s:.4}s, {lazy_evals} evaluations"
    );
    let lazy_stale_block = obj(vec![
        (
            "workload",
            obj(vec![
                ("n", Json::Num(500.0)),
                ("k", Json::Num(50.0)),
                ("function", Json::Str("FacilityLocation".to_string())),
            ]),
        ),
        ("block_max", Json::Num(LAZY_STALE_BLOCK as f64)),
        ("median_s", Json::Num(lazy_median_s)),
        ("evaluations", Json::Num(lazy_evals as f64)),
        ("value", Json::Num(lazy_value)),
    ]);

    // ---- MI family: n=500 ground, 10 queries, k=50 ----------------------
    eprintln!("mi family: n=500, 10 queries, k=50, naive vs lazy");
    let queries = synthetic::blobs(10, 2, 2, 1.0, 44);
    let qrect = RectKernel::from_data(&queries, &data, Metric::Euclidean).unwrap();
    let mi_functions: Vec<(&str, Box<dyn SetFunction>)> = vec![
        ("FLQMI", Box::new(Flqmi::new(qrect.clone(), 1.0).unwrap())),
        ("FLVMI", Box::new(Flvmi::new(kernel.clone(), qrect.clone(), 1.0).unwrap())),
        ("GCMI", Box::new(Gcmi::new(qrect.clone(), 0.5).unwrap())),
        (
            "COM",
            Box::new(
                ConcaveOverModular::new(qrect.clone(), 0.5, ConcaveShape::Sqrt).unwrap(),
            ),
        ),
        (
            "LogDetMI",
            Box::new(
                LogDetMi::new(
                    DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 }),
                    DenseKernel::from_data(&queries, Metric::Rbf { gamma: 0.5 }),
                    RectKernel::from_data(&queries, &data, Metric::Rbf { gamma: 0.5 })
                        .unwrap(),
                    0.7,
                    0.1,
                )
                .unwrap(),
            ),
        ),
    ];
    let mut mi_rows: Vec<Json> = Vec::new();
    for (fname, func) in &mi_functions {
        for (oname, kind) in [
            ("NaiveGreedy", OptimizerKind::NaiveGreedy),
            ("LazyGreedy", OptimizerKind::LazyGreedy),
        ] {
            let label = format!("MI/{fname}/{oname}");
            let stats = runner.bench(&label, || {
                maximize(func.as_ref(), snap_budget.clone(), kind, &opts).unwrap().value
            });
            let (median_s, mean_s) =
                (stats.median.as_secs_f64(), stats.mean.as_secs_f64());
            let sel =
                maximize(func.as_ref(), snap_budget.clone(), kind, &opts).unwrap();
            mi_rows.push(obj(vec![
                ("function", Json::Str(fname.to_string())),
                ("optimizer", Json::Str(oname.to_string())),
                ("median_s", Json::Num(median_s)),
                ("mean_s", Json::Num(mean_s)),
                ("evaluations", Json::Num(sel.evaluations as f64)),
                ("value", Json::Num(sel.value)),
                ("selected", Json::Num(sel.order.len() as f64)),
            ]));
        }
    }

    // ---- kernel build: Table 5 trajectory, dense vs streaming sparse ----
    const KB_DIM: usize = 128;
    const KB_NEIGHBORS: usize = 32;
    eprintln!(
        "kernel build: dense vs streaming sparse, d={KB_DIM}, num_neighbors={KB_NEIGHBORS}"
    );
    // scope the (debug-only) shard-lock contention tallies to the sparse
    // builds below; the totals surface in the pool section
    shard_contention::reset();
    let mut kernel_build_rows: Vec<Json> = Vec::new();
    for &kn in &[500usize, 2000] {
        let kdata = synthetic::random_features(kn, KB_DIM, 45);
        let dense_s = runner
            .bench(&format!("KernelBuild/dense/n{kn}"), || {
                DenseKernel::from_data(&kdata, Metric::Euclidean).n()
            })
            .median
            .as_secs_f64();
        let sparse_sym_s = runner
            .bench(&format!("KernelBuild/sparse_sym/n{kn}"), || {
                SparseKernel::from_data(&kdata, Metric::Euclidean, KB_NEIGHBORS)
                    .unwrap()
                    .nnz()
            })
            .median
            .as_secs_f64();
        let sparse_full_s = runner
            .bench(&format!("KernelBuild/sparse_full/n{kn}"), || {
                SparseKernel::from_data_full_width(
                    &kdata,
                    Metric::Euclidean,
                    KB_NEIGHBORS,
                )
                .unwrap()
                .nnz()
            })
            .median
            .as_secs_f64();
        // dense/sparse agreement on shared entries: the wavefront build
        // anchors row i at column i exactly like the dense symmetric
        // path, so every stored sparse value must equal the dense
        // kernel's bit-for-bit (and mirrored pairs must agree) — a
        // broken wavefront fails the bench run loudly
        let dense_k = DenseKernel::from_data(&kdata, Metric::Euclidean);
        let sparse_k =
            SparseKernel::from_data(&kdata, Metric::Euclidean, KB_NEIGHBORS).unwrap();
        for i in 0..kn {
            let (cols, vals) = sparse_k.row(i);
            for (c, v) in cols.iter().zip(vals) {
                assert_eq!(
                    v.to_bits(),
                    dense_k.get(i, *c as usize).to_bits(),
                    "dense/sparse disagreement at ({i},{c})"
                );
            }
        }
        let dense_peak = tile::dense_peak_bytes(kn, KB_DIM);
        let sparse_peak = tile::sparse_peak_bytes(kn, KB_NEIGHBORS, KB_DIM);
        eprintln!(
            "  n={kn}: dense {dense_s:.4}s (~{} KB peak), sparse sym {sparse_sym_s:.4}s \
             vs full {sparse_full_s:.4}s ({:.2}x, ~{} KB peak)",
            dense_peak / 1024,
            sparse_full_s / sparse_sym_s,
            sparse_peak / 1024
        );
        kernel_build_rows.push(obj(vec![
            ("n", Json::Num(kn as f64)),
            ("dense_median_s", Json::Num(dense_s)),
            ("sparse_sym_median_s", Json::Num(sparse_sym_s)),
            ("sparse_full_median_s", Json::Num(sparse_full_s)),
            ("dense_peak_bytes", Json::Num(dense_peak as f64)),
            ("sparse_peak_bytes", Json::Num(sparse_peak as f64)),
        ]));
    }
    let kernel_build = obj(vec![
        (
            "workload",
            obj(vec![
                ("dim", Json::Num(KB_DIM as f64)),
                ("num_neighbors", Json::Num(KB_NEIGHBORS as f64)),
                ("metric", Json::Str("euclidean".to_string())),
                ("tile_rows", Json::Num(tile::TILE_ROWS as f64)),
                ("backend", Json::Str(backend::active().name().to_string())),
            ]),
        ),
        ("results", Json::Arr(kernel_build_rows)),
    ]);

    // ---- compute backends: inner-kernel sweep, scalar vs SIMD -----------
    // Times the backend seam in isolation: `fill_row` (gram + metric
    // finalization) over TILE_ROWS rows against n=2000 columns at d=128,
    // once per *available* backend — each through the layout it asked for
    // (`wants_soa`). The scalar anchor is the baseline; the best SIMD
    // backend over it is the ISSUE 9 acceptance number.
    let ik_n = 2000usize;
    let ik_rows = tile::TILE_ROWS;
    let ik_data = synthetic::random_features(ik_n, KB_DIM, 46);
    let backends_available = backend::available();
    eprintln!(
        "inner kernels: {ik_rows} rows x n={ik_n}, d={KB_DIM}, backends: {:?} (active: {})",
        backends_available.iter().map(|k| k.name()).collect::<Vec<_>>(),
        backend::active().name()
    );
    let mut backend_rows: Vec<Json> = Vec::new();
    let mut ik_times: Vec<(&'static str, f64)> = Vec::new();
    for k in &backends_available {
        let view = PointView::new(&ik_data, k.wants_soa());
        let sq = k.sq_norms(&ik_data);
        let mut orow = vec![0f32; ik_n];
        let median_s = runner
            .bench(&format!("InnerKernel/{}", k.name()), || {
                let mut acc = 0f32;
                for i in 0..ik_rows {
                    k.fill_row(
                        ik_data.row(i),
                        sq[i],
                        &view,
                        &sq,
                        0,
                        Metric::Euclidean,
                        false,
                        &mut orow,
                    );
                    acc += orow[ik_n - 1];
                }
                acc
            })
            .median
            .as_secs_f64();
        ik_times.push((k.name(), median_s));
        backend_rows.push(obj(vec![
            ("backend", Json::Str(k.name().to_string())),
            ("median_s", Json::Num(median_s)),
        ]));
    }
    let scalar_ik_s = ik_times
        .iter()
        .find(|(name, _)| *name == "scalar")
        .map(|&(_, s)| s)
        .expect("scalar backend is always available");
    // fold-style best (the conformance linter bans partial_cmp floats)
    let mut best_simd: Option<(&'static str, f64)> = None;
    for &(name, s) in &ik_times {
        if name == "scalar" {
            continue;
        }
        let better = match best_simd {
            None => true,
            Some((_, bs)) => s < bs,
        };
        if better {
            best_simd = Some((name, s));
        }
    }
    let simd_speedup = match best_simd {
        Some((name, s)) if s > 0.0 => {
            let factor = scalar_ik_s / s;
            eprintln!(
                "  scalar {:.2}us/row vs best SIMD ({name}) {:.2}us/row: {factor:.2}x",
                scalar_ik_s * 1e6 / ik_rows as f64,
                s * 1e6 / ik_rows as f64
            );
            if backend::avx2().is_some() && factor < 1.5 {
                eprintln!(
                    "  WARNING: avx2 detected but best SIMD speedup {factor:.2}x is under \
                     the 1.5x target — investigate before refreshing the snapshot"
                );
            }
            obj(vec![
                ("baseline", Json::Str("scalar".to_string())),
                ("best", Json::Str(name.to_string())),
                ("factor", Json::Num(factor)),
            ])
        }
        _ => Json::Null,
    };
    let backends_section = obj(vec![
        ("active", Json::Str(backend::active().name().to_string())),
        (
            "available",
            Json::Arr(
                backends_available
                    .iter()
                    .map(|k| Json::Str(k.name().to_string()))
                    .collect(),
            ),
        ),
        (
            "inner_kernel",
            obj(vec![
                (
                    "workload",
                    obj(vec![
                        ("rows", Json::Num(ik_rows as f64)),
                        ("n", Json::Num(ik_n as f64)),
                        ("dim", Json::Num(KB_DIM as f64)),
                        ("metric", Json::Str("euclidean".to_string())),
                    ]),
                ),
                ("results", Json::Arr(backend_rows)),
            ]),
        ),
        ("simd_speedup", simd_speedup),
    ]);

    // ---- parallel scaling: n=2000, k=100, FL, naive ---------------------
    let threads = pool::num_threads();
    eprintln!("parallel scaling: n=2000, k=100, FL NaiveGreedy ({threads} threads)");
    let big = synthetic::blobs(2000, 2, 10, 4.0, 43);
    let big_fl = FacilityLocation::new(DenseKernel::from_data(&big, Metric::Euclidean));
    let big_budget = Budget::cardinality(100);
    let serial_stats = runner
        .bench("FL2000/NaiveGreedy/serial", || {
            maximize(
                &big_fl,
                big_budget.clone(),
                OptimizerKind::NaiveGreedy,
                &MaximizeOpts { parallel: false, ..Default::default() },
            )
            .unwrap()
            .value
        })
        .median
        .as_secs_f64();
    let parallel_stats = runner
        .bench("FL2000/NaiveGreedy/parallel", || {
            maximize(
                &big_fl,
                big_budget.clone(),
                OptimizerKind::NaiveGreedy,
                &MaximizeOpts::default(),
            )
            .unwrap()
            .value
        })
        .median
        .as_secs_f64();
    let speedup = serial_stats / parallel_stats;
    eprintln!(
        "  parallel gain scan speedup: {speedup:.2}x (serial {serial_stats:.3}s, parallel {parallel_stats:.3}s)"
    );

    // ---- pool runtime: per-call dispatch vs the old scoped spawn --------
    // Every parallel section above already ran on the pool; this isolates
    // the per-call overhead the pool removed. One "call" is one parallel
    // section: pool = publish + park/wake, scoped = `threads` OS thread
    // spawns + joins (the shape every driver had before ISSUE 5).
    const DISPATCH_CALLS: usize = 256;
    eprintln!(
        "pool dispatch: {threads}-wide trivial section x{DISPATCH_CALLS}, pool vs scoped spawn"
    );
    let sink = AtomicUsize::new(0);
    let pool_per_call_s = runner
        .bench("Pool/dispatch", || {
            for _ in 0..DISPATCH_CALLS {
                pool::run(threads, &|w| {
                    sink.fetch_add(w + 1, Ordering::Relaxed);
                });
            }
            sink.load(Ordering::Relaxed)
        })
        .median
        .as_secs_f64()
        / DISPATCH_CALLS as f64;
    let scoped_per_call_s = runner
        .bench("Pool/scoped_spawn", || {
            let sink = &sink;
            for _ in 0..DISPATCH_CALLS {
                // lint: allow(thread-spawn) — the spawn-per-call baseline the pool is measured against
                std::thread::scope(|scope| {
                    for w in 0..threads {
                        scope.spawn(move || {
                            sink.fetch_add(w + 1, Ordering::Relaxed);
                        });
                    }
                });
            }
            sink.load(Ordering::Relaxed)
        })
        .median
        .as_secs_f64()
        / DISPATCH_CALLS as f64;
    let spawn_over_pool = if pool_per_call_s > 0.0 {
        scoped_per_call_s / pool_per_call_s
    } else {
        0.0
    };
    eprintln!(
        "  per call: pool {:.2}us vs scoped spawn {:.2}us ({spawn_over_pool:.1}x)",
        pool_per_call_s * 1e6,
        scoped_per_call_s * 1e6
    );
    let pool_section = obj(vec![
        ("threads", Json::Num(threads as f64)),
        ("workers", Json::Num(pool::worker_count() as f64)),
        (
            "table2_fl_naive",
            obj(vec![
                ("n", Json::Num(500.0)),
                ("k", Json::Num(100.0)),
                ("median_s", Json::Num(table2_fl_naive_s)),
            ]),
        ),
        (
            "dispatch_overhead",
            obj(vec![
                ("calls_per_sample", Json::Num(DISPATCH_CALLS as f64)),
                ("pool_per_call_s", Json::Num(pool_per_call_s)),
                ("scoped_spawn_per_call_s", Json::Num(scoped_per_call_s)),
                ("spawn_over_pool", Json::Num(spawn_over_pool)),
            ]),
        ),
        (
            "shard_contention",
            match shard_contention::stats() {
                Some((acq, waits)) => obj(vec![
                    ("acquisitions", Json::Num(acq as f64)),
                    ("waits", Json::Num(waits as f64)),
                ]),
                None => Json::Null,
            },
        ),
    ]);

    let snapshot = obj(vec![
        ("schema", Json::Str("bench_optimizers/v6".to_string())),
        ("threads", Json::Num(threads as f64)),
        ("backend", Json::Str(backend::active().name().to_string())),
        ("backends", backends_section),
        ("pool", pool_section),
        ("kernel_build", kernel_build),
        ("lazy_stale_block", lazy_stale_block),
        (
            "mi_family",
            obj(vec![
                (
                    "workload",
                    obj(vec![
                        ("n", Json::Num(500.0)),
                        ("queries", Json::Num(10.0)),
                        ("k", Json::Num(50.0)),
                    ]),
                ),
                ("results", Json::Arr(mi_rows)),
            ]),
        ),
        (
            "table2",
            obj(vec![
                (
                    "workload",
                    obj(vec![
                        ("n", Json::Num(500.0)),
                        ("k", Json::Num(50.0)),
                        ("clusters", Json::Num(10.0)),
                        ("sigma", Json::Num(4.0)),
                    ]),
                ),
                ("results", Json::Arr(snapshot_rows)),
            ]),
        ),
        (
            "parallel_scaling",
            obj(vec![
                (
                    "workload",
                    obj(vec![
                        ("n", Json::Num(2000.0)),
                        ("k", Json::Num(100.0)),
                        ("function", Json::Str("FacilityLocation".to_string())),
                        ("optimizer", Json::Str("NaiveGreedy".to_string())),
                    ]),
                ),
                ("threads", Json::Num(threads as f64)),
                ("serial_median_s", Json::Num(serial_stats)),
                ("parallel_median_s", Json::Num(parallel_stats)),
                ("speedup", Json::Num(speedup)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_optimizers.json", snapshot.to_string())
        .expect("write BENCH_optimizers.json");
    eprintln!("wrote BENCH_optimizers.json");

    runner.finish("table2_optimizers");
}

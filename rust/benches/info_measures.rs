//! Bench: the submodular information measures (paper Table 1/Table 4) —
//! specialized closed forms vs the generic wrappers they must agree with.
//! The specialization IS Submodlib's efficiency story for guided subset
//! selection; this bench quantifies it.

use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::generic::{ConditionalGain, MutualInformation};
use submodlib::functions::mi::{Flqmi, Flvmi, Gcmi};
use submodlib::functions::cg::Flcg;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric, RectKernel};
use submodlib::linalg::Matrix;
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::util::bench::BenchRunner;

fn run(f: &dyn SetFunction, k: usize) -> f64 {
    maximize(
        f,
        Budget::cardinality(k),
        OptimizerKind::NaiveGreedy,
        &MaximizeOpts {
            stop_if_zero_gain: false,
            stop_if_negative_gain: false,
            ..Default::default()
        },
    )
    .unwrap()
    .value
}

fn main() {
    let n = 400;
    let nq = 10;
    let k = 20;
    let dim = 8;
    let ground = synthetic::blobs(n, dim, 8, 2.0, 42);
    let queries = synthetic::blobs(nq, dim, 2, 1.0, 43);

    let gk = DenseKernel::from_data(&ground, Metric::Euclidean);
    let qk = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();

    // extended kernel for the generic wrappers: [V | Q]
    let mut all = Matrix::zeros(n + nq, dim);
    for i in 0..n {
        all.row_mut(i).copy_from_slice(ground.row(i));
    }
    for q in 0..nq {
        all.row_mut(n + q).copy_from_slice(queries.row(q));
    }
    let ext = DenseKernel::from_data(&all, Metric::Euclidean);
    // FL restricted to represented set V (for the MI identity)
    let rect_rows = {
        let mut m = Matrix::zeros(n, n + nq);
        for i in 0..n {
            for j in 0..n + nq {
                m.set(i, j, ext.get(i, j));
            }
        }
        RectKernel::from_matrix(m)
    };

    let mut runner = BenchRunner::from_env();
    eprintln!("info measures: n={n}, |Q|={nq}, budget={k}");

    let flqmi = Flqmi::new(qk.clone(), 1.0).unwrap();
    runner.bench("FLQMI_specialized", || run(&flqmi, k));

    let flvmi = Flvmi::new(gk.clone(), qk.clone(), 1.0).unwrap();
    runner.bench("FLVMI_specialized", || run(&flvmi, k));

    let generic_mi = MutualInformation::new(
        Box::new(FacilityLocation::with_represented(rect_rows.clone())),
        (n..n + nq).collect(),
        n,
    )
    .unwrap();
    runner.bench("FLVMI_generic_wrapper", || run(&generic_mi, k));

    let gcmi = Gcmi::new(qk.clone(), 0.5).unwrap();
    runner.bench("GCMI_specialized", || run(&gcmi, k));

    let flcg = Flcg::new(gk.clone(), qk.clone(), 1.0).unwrap();
    runner.bench("FLCG_specialized", || run(&flcg, k));

    let generic_cg = ConditionalGain::new(
        Box::new(FacilityLocation::new(ext.clone())),
        (n..n + nq).collect(),
        n,
    )
    .unwrap();
    runner.bench("FLCG_generic_wrapper", || run(&generic_cg, k));

    // correctness tie-back: FLVMI specialized == generic at eta=1
    let ids: Vec<usize> = (0..k).map(|i| i * (n / k)).collect();
    let s = Subset::from_ids(n, &ids);
    let a = flvmi.evaluate(&s);
    let b = generic_mi.evaluate(&s);
    assert!((a - b).abs() < 1e-3, "FLVMI specialized {a} vs generic {b}");
    eprintln!("FLVMI specialized == generic wrapper ✓");

    runner.finish("info_measures");
}

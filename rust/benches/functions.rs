//! Bench: per-function marginal-gain cost — the inner-loop primitive
//! every optimizer drives (paper §6: the point of memoization is making
//! this cheap). One row per regular function at n=500.

use submodlib::data::synthetic;
use submodlib::functions::disparity_min::DisparityMin;
use submodlib::functions::disparity_sum::DisparitySum;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::feature_based::{ConcaveShape, FeatureBased};
use submodlib::functions::graph_cut::GraphCut;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::prob_set_cover::ProbabilisticSetCover;
use submodlib::functions::set_cover::SetCover;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric};
use submodlib::rng::Pcg64;
use submodlib::util::bench::BenchRunner;

/// Time a full memoized greedy sweep of `k` picks (init + k×(scan+update)),
/// one `marginal_gain_memoized` call per candidate (the pre-ISSUE-1 shape).
fn sweep(f: &dyn SetFunction, k: usize) -> f64 {
    let mut w = f.clone_box();
    w.init_memoization(&Subset::empty(f.n()));
    let mut picked = vec![false; f.n()];
    let mut total = 0.0;
    for _ in 0..k {
        let mut best = (usize::MAX, f64::MIN);
        for e in 0..f.n() {
            if picked[e] {
                continue;
            }
            let g = w.marginal_gain_memoized(e);
            if g > best.1 {
                best = (e, g);
            }
        }
        w.update_memoization(best.0);
        picked[best.0] = true;
        total += best.1;
    }
    total
}

/// Same sweep through `marginal_gains_batch` (single-threaded: this bench
/// isolates the batch-locality win; the threaded fan-out on top of it is
/// measured by benches/optimizers.rs).
fn sweep_batch(f: &dyn SetFunction, k: usize) -> f64 {
    let mut w = f.clone_box();
    w.init_memoization(&Subset::empty(f.n()));
    let mut picked = vec![false; f.n()];
    let mut candidates: Vec<usize> = Vec::with_capacity(f.n());
    let mut gains: Vec<f64> = Vec::with_capacity(f.n());
    let mut total = 0.0;
    for _ in 0..k {
        candidates.clear();
        candidates.extend((0..f.n()).filter(|&e| !picked[e]));
        gains.clear();
        gains.resize(candidates.len(), 0.0);
        w.marginal_gains_batch(&candidates, &mut gains);
        let mut best = (usize::MAX, f64::MIN);
        for (&e, &g) in candidates.iter().zip(gains.iter()) {
            if g > best.1 {
                best = (e, g);
            }
        }
        w.update_memoization(best.0);
        picked[best.0] = true;
        total += best.1;
    }
    total
}

fn main() {
    let n = 500;
    let k = 20;
    let data = synthetic::blobs(n, 8, 10, 2.0, 42);
    let euclid = DenseKernel::from_data(&data, Metric::Euclidean);
    let rbf = DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.25 });
    let dist = DenseKernel::distances_from_data(&data);

    let mut rng = Pcg64::new(9);
    let n_concepts = 100;
    let cover: Vec<Vec<u32>> = (0..n)
        .map(|_| (0..5).map(|_| rng.next_below(n_concepts) as u32).collect())
        .collect();
    let probs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..n_concepts).map(|_| if rng.next_f32() < 0.05 { rng.next_f32() } else { 0.0 }).collect())
        .collect();
    let feats: Vec<Vec<(u32, f32)>> = (0..n)
        .map(|_| (0..8).map(|_| (rng.next_below(64) as u32, rng.next_f32())).collect())
        .collect();

    let mut runner = BenchRunner::from_env();
    eprintln!("per-function greedy sweep: n={n}, k={k}");

    let fl = FacilityLocation::new(euclid.clone());
    runner.bench("FacilityLocation", || sweep(&fl, k));
    runner.bench("FacilityLocation/batch", || sweep_batch(&fl, k));
    let gc = GraphCut::new(euclid.clone(), 0.4).unwrap();
    runner.bench("GraphCut", || sweep(&gc, k));
    runner.bench("GraphCut/batch", || sweep_batch(&gc, k));
    let ld = LogDeterminant::with_regularization(rbf, 0.1).unwrap();
    runner.bench("LogDeterminant", || sweep(&ld, k));
    let sc = SetCover::new(cover, vec![1.0; n_concepts]).unwrap();
    runner.bench("SetCover", || sweep(&sc, k));
    let psc = ProbabilisticSetCover::new(probs, vec![1.0; n_concepts]).unwrap();
    runner.bench("ProbabilisticSetCover", || sweep(&psc, k));
    let fb = FeatureBased::new(feats, vec![1.0; 64], ConcaveShape::Sqrt).unwrap();
    runner.bench("FeatureBased", || sweep(&fb, k));
    let dsum = DisparitySum::new(dist.clone());
    runner.bench("DisparitySum", || sweep(&dsum, k));
    let dmin = DisparityMin::new(dist);
    runner.bench("DisparityMin", || sweep(&dmin, k));

    runner.finish("function_sweeps");
}

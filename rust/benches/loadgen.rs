//! Sustained-load bench: drives `coordinator::loadgen` and emits
//! `BENCH_loadgen.json` (schema `bench_loadgen/v1`).
//!
//! Two modes:
//!
//! * default — a sustained multi-tenant run (chaos armed when the crate
//!   is built with `--features faults`, clean otherwise), sized to take
//!   seconds, not minutes;
//! * `--smoke` — the tiny configuration CI runs with `--features faults`
//!   to prove the chaos plumbing end-to-end without burning CI minutes.
//!   The smoke also arms a tight per-request deadline, so the watchdog →
//!   cancel-token → compute-layer-unwind path (ISSUE 10) runs under
//!   chaos traffic, with stage-2 delays pushing some requests over it.
//!
//! Either way the closed-loop accounting must balance: every issued
//! request resolves as served, shed, deadline-exceeded, cancelled, or
//! failed.

use submodlib::coordinator::loadgen::{run, LoadgenConfig};
use submodlib::runtime::pool;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // chaos requires the faults feature; without it, run clean
    let chaos = cfg!(feature = "faults");
    let cfg = if smoke {
        LoadgenConfig {
            items: 200,
            dim: 4,
            shard_capacity: 32,
            tenants: 3,
            requests_per_tenant: 6,
            budget: 5,
            max_inflight: 2,
            admission_queue_depth: 1,
            breaker_threshold: Some(2),
            breaker_probe_after: 2,
            stage1_panic_prob: if chaos { 0.10 } else { 0.0 },
            stage1_error_prob: if chaos { 0.05 } else { 0.0 },
            stage2_delay_prob: if chaos { 0.20 } else { 0.0 },
            stage2_delay_ms: 2,
            drain_panic_prob: if chaos { 0.05 } else { 0.0 },
            // tight enough that delayed requests overrun it (exercising
            // the preemptive cancel path), generous enough that a clean
            // request on a loaded CI box still usually finishes
            deadline_ms: Some(250),
            ..Default::default()
        }
    } else {
        LoadgenConfig {
            items: 1500,
            dim: 16,
            shard_capacity: 128,
            tenants: 6,
            requests_per_tenant: 24,
            budget: 10,
            max_inflight: pool::num_threads().max(2) / 2,
            admission_queue_depth: 2,
            breaker_threshold: Some(3),
            breaker_probe_after: 4,
            stage1_panic_prob: if chaos { 0.05 } else { 0.0 },
            stage1_error_prob: if chaos { 0.03 } else { 0.0 },
            stage2_delay_prob: if chaos { 0.10 } else { 0.0 },
            stage2_delay_ms: 5,
            drain_panic_prob: if chaos { 0.02 } else { 0.0 },
            ..Default::default()
        }
    };
    eprintln!(
        "loadgen{}: {} tenants × {} requests, max_inflight {}, queue {}, chaos {}",
        if smoke { " (smoke)" } else { "" },
        cfg.tenants,
        cfg.requests_per_tenant,
        cfg.max_inflight,
        cfg.admission_queue_depth,
        if chaos { "on" } else { "off (build with --features faults)" },
    );

    let report = run(&cfg).expect("loadgen run");

    // closed-loop accounting: every request resolved exactly once
    assert_eq!(
        report.served
            + report.shed
            + report.deadline_exceeded
            + report.cancelled
            + report.failed_other,
        report.requests_total,
        "loadgen accounting must balance"
    );
    assert_eq!(report.metrics.items_ingested as usize, cfg.items);
    assert_eq!(report.metrics.selections_inflight, 0, "all permits returned");
    assert!(report.throughput_rps > 0.0);

    eprintln!(
        "{} requests in {:.3}s ({:.1} req/s): served {} (degraded {}), shed {}, \
         deadline {}, cancelled {}, failed {}; breaker trips {}, recoveries {}, \
         drain restarts {}, preemptive cancels {}",
        report.requests_total,
        report.wall_s,
        report.throughput_rps,
        report.served,
        report.degraded,
        report.shed,
        report.deadline_exceeded,
        report.cancelled,
        report.failed_other,
        report.metrics.breaker_trips,
        report.metrics.breaker_recoveries,
        report.metrics.drain_restarts,
        report.metrics.selections_cancelled,
    );
    eprintln!("metrics: {}", report.metrics);

    std::fs::write("BENCH_loadgen.json", report.to_json(&cfg).to_string())
        .expect("write BENCH_loadgen.json");
    eprintln!("wrote BENCH_loadgen.json");
}

//! Ablation bench: the L3 streaming coordinator — selection latency vs
//! shard capacity and stage-1 candidate factor, plus ingest throughput.
//! (The design choices DESIGN.md §3 calls out for the two-stage scheme.)
//!
//! Also emits `BENCH_coordinator.json` (`bench_coordinator/v1`): the
//! service-level latency distribution — select p50/p99 as the metrics
//! histogram reports them — so the perf trajectory tracks what an
//! operator of the service would see, not only harness wall-clock.

use std::collections::BTreeMap;

use submodlib::config::CoordinatorConfig;
use submodlib::coordinator::{Coordinator, SelectRequest};
use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::runtime::pool;
use submodlib::util::bench::BenchRunner;
use submodlib::util::json::Json;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn build(items: usize, dim: usize, cap: usize, factor: f64) -> Coordinator {
    let cfg = CoordinatorConfig {
        // honors SUBMODLIB_THREADS like everything else (pool-resolved)
        workers: pool::num_threads(),
        shard_capacity: cap,
        ingest_depth: 256,
        per_shard_factor: factor,
        min_shard_quorum: None,
        // the ablation measures selection cost, not overload behavior:
        // gate wide open, breakers off (loadgen.rs benches those)
        max_inflight: pool::num_threads().max(1),
        admission_queue_depth: 64,
        breaker_threshold: None,
        breaker_probe_after: 4,
    };
    let c = Coordinator::new(cfg);
    let data = synthetic::blobs(items, dim, 10, 2.0, 321);
    let h = c.ingest_handle();
    for i in 0..items {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    c
}

fn main() {
    let items = 2000;
    let dim = 32;
    let budget = 25;

    let mut runner = BenchRunner::from_env();
    eprintln!("coordinator ablation: {items} items, dim {dim}, budget {budget}");

    // ingest throughput (fresh coordinator each sample)
    let data = synthetic::blobs(items, dim, 10, 2.0, 321);
    runner.bench("ingest_2000", || {
        let c = Coordinator::new(CoordinatorConfig {
            shard_capacity: 256,
            ..Default::default()
        });
        let h = c.ingest_handle();
        for i in 0..items {
            h.ingest(data.row(i).to_vec()).unwrap();
        }
        c.len()
    });

    // shard-capacity sweep (quadratic per-shard kernels → capacity is the
    // latency/quality knob)
    for cap in [128usize, 256, 512, 2000] {
        let c = build(items, dim, cap, 2.0);
        runner.bench(&format!("select_cap{cap}"), || {
            c.select(SelectRequest { budget, ..Default::default() }).unwrap().value
        });
    }

    // stage-1 factor sweep (more candidates → better merge, slower)
    for factor in [1.0f64, 2.0, 4.0] {
        let c = build(items, dim, 256, factor);
        runner.bench(&format!("select_factor{factor}"), || {
            c.select(SelectRequest { budget, ..Default::default() }).unwrap().value
        });
    }

    // quality vs flat baseline at each capacity
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let flat = maximize(
        &f,
        Budget::cardinality(budget),
        OptimizerKind::LazyGreedy,
        &MaximizeOpts::default(),
    )
    .unwrap();
    for cap in [128usize, 512, 2000] {
        let c = build(items, dim, cap, 2.0);
        let resp = c.select(SelectRequest { budget, ..Default::default() }).unwrap();
        let v = f.evaluate(&Subset::from_ids(items, &resp.ids));
        eprintln!(
            "quality cap={cap}: two-stage {v:.2} vs flat {:.2} ({:.1}%)",
            flat.value,
            100.0 * v / flat.value
        );
        assert!(v >= 0.85 * flat.value);
    }

    // ---- service latency snapshot (BENCH_coordinator.json) -----------
    // p50/p99 come from the coordinator's own metrics histogram — the
    // operator-facing numbers — over a fixed select load at the default
    // ablation point (cap 256, factor 2.0)
    const SNAPSHOT_SELECTS: usize = 30;
    let svc = build(items, dim, 256, 2.0);
    for _ in 0..SNAPSHOT_SELECTS {
        svc.select(SelectRequest { budget, ..Default::default() }).unwrap();
    }
    let m = svc.metrics();
    eprintln!("service metrics: {m}");
    assert_eq!(m.selections_served, SNAPSHOT_SELECTS as u64);
    let snapshot = obj(vec![
        ("schema", Json::Str("bench_coordinator/v1".to_string())),
        ("threads", Json::Num(pool::num_threads() as f64)),
        (
            "workload",
            obj(vec![
                ("items", Json::Num(items as f64)),
                ("dim", Json::Num(dim as f64)),
                ("budget", Json::Num(budget as f64)),
                ("shard_capacity", Json::Num(256.0)),
                ("per_shard_factor", Json::Num(2.0)),
                ("selects", Json::Num(SNAPSHOT_SELECTS as f64)),
            ]),
        ),
        (
            "select_latency",
            obj(vec![
                ("p50_us", Json::Num(m.latency_p50_us as f64)),
                ("p99_us", Json::Num(m.latency_p99_us as f64)),
                // failed/shed requests live in their own histogram
                // (survivorship-bias fix, ISSUE 8) — 0 in this clean run
                ("failed_p50_us", Json::Num(m.failed_latency_p50_us as f64)),
                ("failed_p99_us", Json::Num(m.failed_latency_p99_us as f64)),
            ]),
        ),
        (
            "counters",
            obj(vec![
                ("items_ingested", Json::Num(m.items_ingested as f64)),
                ("selections_served", Json::Num(m.selections_served as f64)),
                ("selections_failed", Json::Num(m.selections_failed as f64)),
                ("selections_degraded", Json::Num(m.selections_degraded as f64)),
                ("selections_shed", Json::Num(m.selections_shed as f64)),
                ("admission_waits", Json::Num(m.admission_waits as f64)),
                ("shard_failures", Json::Num(m.shard_failures as f64)),
                ("shard_retries", Json::Num(m.shard_retries as f64)),
                ("deadline_exceeded", Json::Num(m.deadline_exceeded as f64)),
                // preemptive cancels (ISSUE 10): 0 in this clean run;
                // cancelled latencies land in the failed histogram above
                ("selections_cancelled", Json::Num(m.selections_cancelled as f64)),
                ("drain_restarts", Json::Num(m.drain_restarts as f64)),
                ("backpressure_waits", Json::Num(m.backpressure_waits as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_coordinator.json", snapshot.to_string())
        .expect("write BENCH_coordinator.json");
    eprintln!("wrote BENCH_coordinator.json");

    runner.finish("coordinator_ablation");
}

//! Ablation bench: the L3 streaming coordinator — selection latency vs
//! shard capacity and stage-1 candidate factor, plus ingest throughput.
//! (The design choices DESIGN.md §3 calls out for the two-stage scheme.)

use submodlib::config::CoordinatorConfig;
use submodlib::coordinator::{Coordinator, SelectRequest};
use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::runtime::pool;
use submodlib::util::bench::BenchRunner;

fn build(items: usize, dim: usize, cap: usize, factor: f64) -> Coordinator {
    let cfg = CoordinatorConfig {
        // honors SUBMODLIB_THREADS like everything else (pool-resolved)
        workers: pool::num_threads(),
        shard_capacity: cap,
        ingest_depth: 256,
        per_shard_factor: factor,
    };
    let c = Coordinator::new(cfg);
    let data = synthetic::blobs(items, dim, 10, 2.0, 321);
    let h = c.ingest_handle();
    for i in 0..items {
        h.ingest(data.row(i).to_vec()).unwrap();
    }
    c
}

fn main() {
    let items = 2000;
    let dim = 32;
    let budget = 25;

    let mut runner = BenchRunner::from_env();
    eprintln!("coordinator ablation: {items} items, dim {dim}, budget {budget}");

    // ingest throughput (fresh coordinator each sample)
    let data = synthetic::blobs(items, dim, 10, 2.0, 321);
    runner.bench("ingest_2000", || {
        let c = Coordinator::new(CoordinatorConfig {
            shard_capacity: 256,
            ..Default::default()
        });
        let h = c.ingest_handle();
        for i in 0..items {
            h.ingest(data.row(i).to_vec()).unwrap();
        }
        c.len()
    });

    // shard-capacity sweep (quadratic per-shard kernels → capacity is the
    // latency/quality knob)
    for cap in [128usize, 256, 512, 2000] {
        let c = build(items, dim, cap, 2.0);
        runner.bench(&format!("select_cap{cap}"), || {
            c.select(SelectRequest { budget, ..Default::default() }).unwrap().value
        });
    }

    // stage-1 factor sweep (more candidates → better merge, slower)
    for factor in [1.0f64, 2.0, 4.0] {
        let c = build(items, dim, 256, factor);
        runner.bench(&format!("select_factor{factor}"), || {
            c.select(SelectRequest { budget, ..Default::default() }).unwrap().value
        });
    }

    // quality vs flat baseline at each capacity
    let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
    let flat = maximize(
        &f,
        Budget::cardinality(budget),
        OptimizerKind::LazyGreedy,
        &MaximizeOpts::default(),
    )
    .unwrap();
    for cap in [128usize, 512, 2000] {
        let c = build(items, dim, cap, 2.0);
        let resp = c.select(SelectRequest { budget, ..Default::default() }).unwrap();
        let v = f.evaluate(&Subset::from_ids(items, &resp.ids));
        eprintln!(
            "quality cap={cap}: two-stage {v:.2} vs flat {:.2} ({:.1}%)",
            flat.value,
            100.0 * v / flat.value
        );
        assert!(v >= 0.85 * flat.value);
    }
    runner.finish("coordinator_ablation");
}

//! Ablation bench: paper §6 / Tables 3–4 — memoization on vs off.
//!
//! "Off" drives the optimizers through the stateless `marginal_gain`
//! path (recomputing from scratch each query), "on" uses the memoized
//! statistics. The paper's efficiency claim rests on this gap.

use submodlib::data::synthetic;
use submodlib::functions::facility_location::FacilityLocation;
use submodlib::functions::graph_cut::GraphCut;
use submodlib::functions::log_determinant::LogDeterminant;
use submodlib::functions::traits::{SetFunction, Subset};
use submodlib::kernel::{DenseKernel, Metric};
use submodlib::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use submodlib::util::bench::BenchRunner;

/// Naive greedy WITHOUT memoization: stateless marginal gains.
fn greedy_stateless(f: &dyn SetFunction, k: usize) -> f64 {
    let n = f.n();
    let mut s = Subset::empty(n);
    let mut value = 0.0;
    for _ in 0..k {
        let mut best = (usize::MAX, f64::MIN);
        for e in 0..n {
            if s.contains(e) {
                continue;
            }
            let g = f.marginal_gain(&s, e);
            if g > best.1 {
                best = (e, g);
            }
        }
        if best.0 == usize::MAX || best.1 <= 0.0 {
            break;
        }
        s.insert(best.0);
        value += best.1;
    }
    value
}

fn main() {
    let n = 200;
    let k = 20;
    let data = synthetic::blobs(n, 2, 8, 2.0, 42);
    let kernel = DenseKernel::from_data(&data, Metric::Euclidean);
    let rbf = DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 });

    let mut runner = BenchRunner::from_env();
    eprintln!("memoization ablation: n={n}, budget={k}");

    let fl = FacilityLocation::new(kernel.clone());
    runner.bench("fl_memoized", || {
        maximize(&fl, Budget::cardinality(k), OptimizerKind::NaiveGreedy, &MaximizeOpts::default())
            .unwrap()
            .value
    });
    runner.bench("fl_stateless", || greedy_stateless(&fl, k));

    let gc = GraphCut::new(kernel.clone(), 0.4).unwrap();
    runner.bench("gc_memoized", || {
        maximize(&gc, Budget::cardinality(k), OptimizerKind::NaiveGreedy, &MaximizeOpts::default())
            .unwrap()
            .value
    });
    runner.bench("gc_stateless", || greedy_stateless(&gc, k));

    let ld = LogDeterminant::with_regularization(rbf, 0.1).unwrap();
    runner.bench("logdet_memoized", || {
        maximize(&ld, Budget::cardinality(k), OptimizerKind::NaiveGreedy, &MaximizeOpts::default())
            .unwrap()
            .value
    });
    runner.bench("logdet_stateless", || greedy_stateless(&ld, k));

    // memoized must beat stateless for every function
    let rs = runner.results();
    let t = |n: &str| rs.iter().find(|r| r.name == n).unwrap().median.as_secs_f64();
    for f in ["fl", "gc", "logdet"] {
        let speedup = t(&format!("{f}_stateless")) / t(&format!("{f}_memoized"));
        eprintln!("{f}: memoization speedup {speedup:.1}x");
        assert!(speedup > 1.5, "{f} memoization not paying off ({speedup:.2}x)");
    }
    runner.finish("memoization_ablation");
}

//! Graph Cut family (paper §2.1.2):
//!
//! ```text
//! f_GC(X) = Σ_{i∈U, j∈X} s_ij − λ Σ_{i,j∈X} s_ij
//! ```
//!
//! λ trades representation against diversity; monotone submodular for
//! λ ≤ 0.5, non-monotone submodular for λ > 0.5. U defaults to V.
//!
//! Memoization (Table 3 row 2): `total[j] = Σ_{i∈U} s_ij` precomputed and
//! `sum_in[j] = Σ_{i∈A} s_ij` maintained, so each gain is O(1) and each
//! update O(n).

use std::sync::Arc;

use super::traits::{ElementId, SetFunction, Subset};
use crate::error::{Result, SubmodError};
use crate::kernel::{DenseKernel, RectKernel};

/// Graph-Cut function. See module docs.
#[derive(Clone)]
pub struct GraphCut {
    /// V×V kernel for the diversity (second) term.
    ground: Arc<DenseKernel>,
    /// Precomputed Σ_{i∈U} s_ij per ground element j.
    total: Arc<Vec<f64>>,
    lambda: f64,
    /// memoized Σ_{i∈A} s_ij per ground element j.
    sum_in: Vec<f64>,
}

impl GraphCut {
    /// U = V: both terms over the same square kernel.
    pub fn new(kernel: DenseKernel, lambda: f64) -> Result<Self> {
        if !(0.0..=1.0).contains(&lambda) {
            return Err(SubmodError::InvalidParam(format!("lambda {lambda} outside [0,1]")));
        }
        let n = kernel.n();
        let total: Vec<f64> =
            (0..n).map(|j| (0..n).map(|i| kernel.get(i, j) as f64).sum()).collect();
        Ok(GraphCut {
            ground: Arc::new(kernel),
            total: Arc::new(total),
            lambda,
            sum_in: vec![0.0; n],
        })
    }

    /// Generic represented set U ≠ V: `master` rows are U, cols are V;
    /// `ground` is the V×V kernel for the diversity term.
    pub fn with_represented(master: RectKernel, ground: DenseKernel, lambda: f64) -> Result<Self> {
        if !(0.0..=1.0).contains(&lambda) {
            return Err(SubmodError::InvalidParam(format!("lambda {lambda} outside [0,1]")));
        }
        if master.cols() != ground.n() {
            return Err(SubmodError::Shape(format!(
                "master cols {} vs ground n {}",
                master.cols(),
                ground.n()
            )));
        }
        let n = ground.n();
        let total: Vec<f64> = (0..n)
            .map(|j| (0..master.rows()).map(|i| master.get(i, j) as f64).sum())
            .collect();
        Ok(GraphCut {
            ground: Arc::new(ground),
            total: Arc::new(total),
            lambda,
            sum_in: vec![0.0; n],
        })
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }
}

impl SetFunction for GraphCut {
    fn n(&self) -> usize {
        self.ground.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let rep: f64 = subset.order().iter().map(|&j| self.total[j]).sum();
        let mut div = 0f64;
        for &i in subset.order() {
            for &j in subset.order() {
                div += self.ground.get(i, j) as f64;
            }
        }
        rep - self.lambda * div
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.sum_in {
            *v = 0.0;
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        // Δ = total[e] − λ (2 Σ_{i∈A} s_ie + s_ee)   [symmetric kernel]
        self.total[e]
            - self.lambda * (2.0 * self.sum_in[e] + self.ground.get(e, e) as f64)
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // gains are O(1) reads of the memoized statistics; the batch win
        // is simply skipping a dyn dispatch per candidate
        debug_assert_eq!(candidates.len(), out.len());
        for (o, &e) in out.iter_mut().zip(candidates) {
            *o = self.total[e]
                - self.lambda * (2.0 * self.sum_in[e] + self.ground.get(e, e) as f64);
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        let row = self.ground.row(e);
        for (i, v) in self.sum_in.iter_mut().enumerate() {
            *v += row[i] as f64;
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "GraphCut"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::kernel::Metric;
    use crate::linalg::Matrix;

    fn gc(n: usize, lambda: f64, seed: u64) -> GraphCut {
        let data = synthetic::blobs(n, 2, 3, 1.0, seed);
        GraphCut::new(DenseKernel::from_data(&data, Metric::Euclidean), lambda).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(gc(10, 0.3, 1).evaluate(&Subset::empty(10)), 0.0);
    }

    #[test]
    fn invalid_lambda_rejected() {
        let data = synthetic::blobs(5, 2, 2, 1.0, 1);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        assert!(GraphCut::new(k.clone(), -0.1).is_err());
        assert!(GraphCut::new(k, 1.5).is_err());
    }

    #[test]
    fn singleton_value() {
        let f = gc(8, 0.4, 2);
        let s = Subset::from_ids(8, &[3]);
        // f({3}) = total[3] − λ s_33 = total[3] − λ·1
        let expect = f.total[3] - 0.4;
        assert!((f.evaluate(&s) - expect).abs() < 1e-6);
    }

    #[test]
    fn marginal_gain_matches_delta() {
        let f = gc(15, 0.45, 3);
        let s = Subset::from_ids(15, &[2, 11]);
        for e in [0usize, 7, 14] {
            let delta = f.evaluate(&s.union_with(&[e])) - f.evaluate(&s);
            assert!((f.marginal_gain(&s, e) - delta).abs() < 1e-6);
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = gc(20, 0.5, 4);
        let mut s = Subset::empty(20);
        f.init_memoization(&s);
        for &add in &[5usize, 0, 19, 10] {
            for e in 0..20 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn monotone_for_small_lambda() {
        let f = gc(12, 0.2, 5);
        let s = Subset::from_ids(12, &[1, 6]);
        for e in 0..12 {
            if !s.contains(e) {
                assert!(f.marginal_gain(&s, e) > -1e-9, "gain({e}) negative");
            }
        }
    }

    #[test]
    fn high_lambda_can_go_negative() {
        // duplicate points → adding the twin of a selected point should
        // hurt at λ close to 1
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[0.0, 0.0], &[100.0, 100.0]]);
        let f =
            GraphCut::new(DenseKernel::from_data(&data, Metric::Euclidean), 1.0).unwrap();
        let s = Subset::from_ids(3, &[0]);
        assert!(f.marginal_gain(&s, 1) < 0.0);
    }

    #[test]
    fn represented_set_variant() {
        let u = Matrix::from_rows(&[&[0.0, 0.0]]);
        let v = Matrix::from_rows(&[&[0.0, 1.0], &[3.0, 4.0]]);
        let master = RectKernel::from_data(&u, &v, Metric::Euclidean).unwrap();
        let ground = DenseKernel::from_data(&v, Metric::Euclidean);
        let f = GraphCut::with_represented(master.clone(), ground.clone(), 0.3).unwrap();
        let s = Subset::from_ids(2, &[1]);
        let expect = master.get(0, 1) as f64 - 0.3 * ground.get(1, 1) as f64;
        assert!((f.evaluate(&s) - expect).abs() < 1e-6);
    }

    #[test]
    fn diminishing_returns_spot_check() {
        let f = gc(15, 0.5, 6);
        let a = Subset::from_ids(15, &[2]);
        let b = Subset::from_ids(15, &[2, 8, 12]);
        for e in [0usize, 5, 14] {
            assert!(f.marginal_gain(&a, e) >= f.marginal_gain(&b, e) - 1e-9);
        }
    }
}

//! Weighted mixtures of set functions — submodular mixtures in the sense
//! of Lin & Bilmes 2012 / Gygli et al. 2015 (both cited by the paper as
//! primary applications): `f(X) = Σ_k w_k f_k(X)`, w_k ≥ 0.
//!
//! A nonnegative combination of submodular functions is submodular, so the
//! mixture composes with every optimizer; its memoization simply fans out.

use super::traits::{ElementId, SetFunction, Subset};
use crate::error::{Result, SubmodError};

/// `Σ_k w_k f_k` over a shared ground set.
pub struct Mixture {
    parts: Vec<(f64, Box<dyn SetFunction>)>,
    n: usize,
}

impl Mixture {
    pub fn new(parts: Vec<(f64, Box<dyn SetFunction>)>) -> Result<Self> {
        if parts.is_empty() {
            return Err(SubmodError::InvalidParam("empty mixture".into()));
        }
        if parts.iter().any(|(w, _)| *w < 0.0) {
            return Err(SubmodError::InvalidParam("negative mixture weight".into()));
        }
        let n = parts[0].1.n();
        if parts.iter().any(|(_, f)| f.n() != n) {
            return Err(SubmodError::Shape("mixture components disagree on n".into()));
        }
        Ok(Mixture { parts, n })
    }
}

impl Clone for Mixture {
    fn clone(&self) -> Self {
        Mixture {
            parts: self.parts.iter().map(|(w, f)| (*w, f.clone_box())).collect(),
            n: self.n,
        }
    }
}

impl SetFunction for Mixture {
    fn n(&self) -> usize {
        self.n
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        self.parts.iter().map(|(w, f)| w * f.evaluate(subset)).sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for (_, f) in &mut self.parts {
            f.init_memoization(subset);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.parts.iter().map(|(w, f)| w * f.marginal_gain_memoized(e)).sum()
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // fan the batch out to each component so their specialized
        // implementations kick in; per-element accumulation runs in part
        // order starting from 0.0, exactly like the scalar sum()
        debug_assert_eq!(candidates.len(), out.len());
        out.fill(0.0);
        let mut scratch = vec![0f64; candidates.len()];
        for (w, f) in &self.parts {
            f.marginal_gains_batch(candidates, &mut scratch);
            for (o, &g) in out.iter_mut().zip(scratch.iter()) {
                *o += w * g;
            }
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        for (_, f) in &mut self.parts {
            f.update_memoization(e);
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "Mixture"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::functions::graph_cut::GraphCut;
    use crate::kernel::{DenseKernel, Metric};

    fn mix(n: usize, seed: u64) -> Mixture {
        let data = synthetic::blobs(n, 2, 3, 1.0, seed);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        Mixture::new(vec![
            (0.7, Box::new(FacilityLocation::new(k.clone()))),
            (0.3, Box::new(GraphCut::new(k, 0.4).unwrap())),
        ])
        .unwrap()
    }

    #[test]
    fn weighted_sum_of_parts() {
        let data = synthetic::blobs(10, 2, 2, 1.0, 1);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        let fl = FacilityLocation::new(k.clone());
        let gc = GraphCut::new(k.clone(), 0.4).unwrap();
        let m = Mixture::new(vec![(0.7, fl.clone_box()), (0.3, gc.clone_box())]).unwrap();
        let s = Subset::from_ids(10, &[2, 7]);
        let expect = 0.7 * fl.evaluate(&s) + 0.3 * gc.evaluate(&s);
        assert!((m.evaluate(&s) - expect).abs() < 1e-9);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut m = mix(12, 2);
        let mut s = Subset::empty(12);
        m.init_memoization(&s);
        for &add in &[1usize, 8] {
            for e in 0..12 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (m.marginal_gain_memoized(e) - m.marginal_gain(&s, e)).abs() < 1e-6
                );
            }
            m.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn validation() {
        let data = synthetic::blobs(5, 2, 2, 1.0, 3);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        assert!(Mixture::new(vec![]).is_err());
        assert!(Mixture::new(vec![(
            -0.5,
            Box::new(FacilityLocation::new(k.clone())) as Box<dyn SetFunction>
        )])
        .is_err());
        let data2 = synthetic::blobs(6, 2, 2, 1.0, 3);
        let k2 = DenseKernel::from_data(&data2, Metric::Euclidean);
        assert!(Mixture::new(vec![
            (0.5, Box::new(FacilityLocation::new(k)) as Box<dyn SetFunction>),
            (0.5, Box::new(FacilityLocation::new(k2)) as Box<dyn SetFunction>),
        ])
        .is_err());
    }

    #[test]
    fn clone_box_independent_state() {
        let mut m = mix(8, 4);
        m.init_memoization(&Subset::empty(8));
        let mut c = m.clone_box();
        m.update_memoization(0);
        // clone's memoization unaffected by original's update
        c.init_memoization(&Subset::empty(8));
        assert!((c.marginal_gain_memoized(0) - {
            let fresh = mix(8, 4);
            fresh.marginal_gain(&Subset::empty(8), 0)
        })
        .abs()
            < 1e-9);
    }
}

//! Log Determinant / DPP MAP (paper §2.2.2):
//!
//! ```text
//! f_LogDet(X) = log det(L_X)
//! ```
//!
//! with L a similarity kernel. Implementation follows the paper's note
//! (§5.2.1): greedy maximization uses *Fast Greedy MAP Inference* (Chen et
//! al. 2018) — an incrementally maintained Cholesky factor
//! ([`crate::linalg::IncrementalLogDet`], Table 3 "DPP: SVD(S_A)" row in
//! spirit) so each marginal gain is one forward substitution; batched
//! gain scans run one *blocked* forward substitution over K candidate
//! columns against the shared factor (`IncrementalLogDet::gains_batch`).
//!
//! An optional diagonal regularizer `reg` evaluates `log det(L_X + reg·I)`,
//! which keeps near-duplicate ground sets numerically PD (Submodlib's
//! kernels are similarly conditioned by construction).

use std::sync::Arc;

use super::traits::{ElementId, SetFunction, Subset};
use crate::error::{Result, SubmodError};
use crate::kernel::DenseKernel;
use crate::linalg::{Cholesky, IncrementalLogDet};

/// Log-determinant function with incremental-Cholesky memoization.
#[derive(Clone)]
pub struct LogDeterminant {
    kernel: Arc<DenseKernel>,
    reg: f64,
    /// memoized incremental factor + the insertion order it reflects
    inc: IncrementalLogDet,
    committed: Vec<ElementId>,
    /// set when `update_memoization` was driven onto a singular candidate
    /// (one whose gain is −∞). The factor cannot represent that set, and
    /// f of it — and of every superset — is −∞, so all subsequent gains
    /// report −∞ rather than silently answering for a *different* set
    /// than the caller committed. The optimizers never trip this: they
    /// refuse to accept a −∞ gain (see `optimizers::should_stop`).
    singular: bool,
}

impl LogDeterminant {
    pub fn new(kernel: DenseKernel) -> Self {
        Self::with_regularization(kernel, 0.0).unwrap()
    }

    /// `reg ≥ 0` is added to the kernel diagonal.
    pub fn with_regularization(kernel: DenseKernel, reg: f64) -> Result<Self> {
        if reg < 0.0 {
            return Err(SubmodError::InvalidParam(format!("reg {reg} < 0")));
        }
        Ok(LogDeterminant {
            kernel: Arc::new(kernel),
            reg,
            inc: IncrementalLogDet::new(),
            committed: Vec::new(),
            singular: false,
        })
    }

    fn diag(&self, e: ElementId) -> f32 {
        self.kernel.get(e, e) + self.reg as f32
    }

    fn col(&self, e: ElementId, order: &[ElementId]) -> Vec<f32> {
        order.iter().map(|&j| self.kernel.get(e, j)).collect()
    }
}

impl SetFunction for LogDeterminant {
    fn n(&self) -> usize {
        self.kernel.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        if subset.is_empty() {
            return 0.0;
        }
        let mut sub = self.kernel.matrix().principal_submatrix(subset.order());
        if self.reg > 0.0 {
            for i in 0..sub.rows() {
                let v = sub.get(i, i) + self.reg as f32;
                sub.set(i, i, v);
            }
        }
        match Cholesky::factor(&sub) {
            Ok(c) => c.log_det(),
            Err(_) => f64::NEG_INFINITY, // singular principal minor
        }
    }

    fn init_memoization(&mut self, subset: &Subset) {
        self.inc = IncrementalLogDet::new();
        self.committed.clear();
        self.singular = false;
        for &e in subset.order() {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        if self.singular {
            return f64::NEG_INFINITY;
        }
        self.inc.gain(&self.col(e, &self.committed), self.diag(e))
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        if self.singular {
            out.fill(f64::NEG_INFINITY);
            return;
        }
        // One blocked forward substitution over all candidate columns
        // against the shared factor (IncrementalLogDet::gains_batch reads
        // each packed L row once per 4 candidates); bit-identical to
        // per-candidate `gain` calls by its contract.
        let cols: Vec<Vec<f32>> =
            candidates.iter().map(|&e| self.col(e, &self.committed)).collect();
        let diags: Vec<f32> = candidates.iter().map(|&e| self.diag(e)).collect();
        self.inc.gains_batch(&cols, &diags, out);
    }

    fn update_memoization(&mut self, e: ElementId) {
        let col = self.col(e, &self.committed);
        // A failed push means the candidate makes the kernel singular:
        // f(committed ∪ {e}) = −∞. The factor cannot absorb the element,
        // so poison the memoized state instead of silently dropping it —
        // every further gain reports −∞, consistent with `evaluate` of
        // the set the caller actually built.
        if self.inc.push(&col, self.diag(e)).is_ok() {
            self.committed.push(e);
        } else {
            self.singular = true;
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "LogDeterminant"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::kernel::Metric;
    use crate::linalg::Matrix;

    fn ld(n: usize, seed: u64) -> LogDeterminant {
        let data = synthetic::blobs(n, 3, 3, 1.0, seed);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 });
        LogDeterminant::with_regularization(k, 0.05).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(ld(10, 1).evaluate(&Subset::empty(10)), 0.0);
    }

    #[test]
    fn singleton_is_log_diag() {
        let f = ld(8, 2);
        let s = Subset::from_ids(8, &[4]);
        let expect = (f.kernel.get(4, 4) as f64 + 0.05).ln();
        assert!((f.evaluate(&s) - expect).abs() < 1e-5);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = ld(15, 3);
        let mut s = Subset::empty(15);
        f.init_memoization(&s);
        for &add in &[2usize, 9, 14] {
            for e in 0..15 {
                if s.contains(e) {
                    continue;
                }
                let fast = f.marginal_gain_memoized(e);
                let slow = f.marginal_gain(&s, e);
                assert!(
                    (fast - slow).abs() < 1e-4,
                    "e={e}: fast {fast} slow {slow}"
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn negative_reg_rejected() {
        let data = synthetic::blobs(5, 2, 2, 1.0, 4);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 1.0 });
        assert!(LogDeterminant::with_regularization(k, -1.0).is_err());
    }

    #[test]
    fn duplicate_item_gain_is_neg_infinity() {
        let data = Matrix::from_rows(&[&[1.0, 2.0], &[1.0, 2.0], &[5.0, 5.0]]);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 1.0 });
        let mut f = LogDeterminant::new(k);
        f.init_memoization(&Subset::empty(3));
        f.update_memoization(0);
        assert_eq!(f.marginal_gain_memoized(1), f64::NEG_INFINITY);
        assert!(f.marginal_gain_memoized(2) > f64::NEG_INFINITY);
    }

    #[test]
    fn optimizer_never_accepts_singular_candidate() {
        use crate::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
        // Duplicate rows, no regularization: once one duplicate is picked
        // the other's gain is −∞ forever. Even with every stop rule
        // disabled the optimizer must terminate instead of committing it,
        // so the reported selection's evaluate() equals the reported value
        // (the pre-fix behavior dropped the element from the memoized
        // state but still recorded it as selected).
        let data = Matrix::from_rows(&[
            &[1.0, 2.0],
            &[1.0, 2.0],
            &[5.0, 5.0],
            &[0.0, 1.0],
        ]);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 1.0 });
        let f = LogDeterminant::new(k);
        let opts = MaximizeOpts {
            stop_if_zero_gain: false,
            stop_if_negative_gain: false,
            ..Default::default()
        };
        for kind in [OptimizerKind::NaiveGreedy, OptimizerKind::LazyGreedy] {
            let sel = maximize(&f, Budget::cardinality(4), kind, &opts).unwrap();
            assert!(sel.order.len() < 4, "{kind:?} accepted a singular candidate");
            assert!(sel.order.iter().all(|&(_, g)| g.is_finite()), "{kind:?}");
            let v = f.evaluate(&sel.subset(4));
            assert!(
                (v - sel.value).abs() < 1e-6,
                "{kind:?}: evaluate {v} vs accumulated {}",
                sel.value
            );
        }
    }

    #[test]
    fn forced_singular_update_poisons_memoized_state() {
        let data = Matrix::from_rows(&[&[1.0, 2.0], &[1.0, 2.0], &[5.0, 5.0]]);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 1.0 });
        let mut f = LogDeterminant::new(k);
        f.init_memoization(&Subset::empty(3));
        f.update_memoization(0);
        f.update_memoization(1); // duplicate of 0 → committed set singular
        // f({0,1}) = −∞, so every further gain must report −∞ too instead
        // of silently answering for {0} (the old dropped-element behavior)
        assert_eq!(f.marginal_gain_memoized(2), f64::NEG_INFINITY);
        let mut out = vec![0f64; 1];
        f.marginal_gains_batch(&[2], &mut out);
        assert_eq!(out[0], f64::NEG_INFINITY);
        assert_eq!(f.evaluate(&Subset::from_ids(3, &[0, 1])), f64::NEG_INFINITY);
        // re-initializing clears the poisoned state
        f.init_memoization(&Subset::empty(3));
        assert!(f.marginal_gain_memoized(2).is_finite());
    }

    #[test]
    fn prefers_diverse_items() {
        // two near-duplicates + one distant: after picking 0, gain(2) > gain(1)
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[0.1, 0.0], &[5.0, 5.0]]);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 1.0 });
        let mut f = LogDeterminant::with_regularization(k, 0.01).unwrap();
        f.init_memoization(&Subset::empty(3));
        f.update_memoization(0);
        assert!(f.marginal_gain_memoized(2) > f.marginal_gain_memoized(1));
    }

    #[test]
    fn submodularity_spot_check() {
        let f = ld(12, 5);
        let a = Subset::from_ids(12, &[1]);
        let b = Subset::from_ids(12, &[1, 6]);
        for e in [0usize, 4, 11] {
            assert!(f.marginal_gain(&a, e) >= f.marginal_gain(&b, e) - 1e-6);
        }
    }
}

//! The `SetFunction` abstraction — the paper's de-coupled
//! function / optimizer paradigm (§5.1): "an appropriate function is first
//! instantiated and then maximize() is called on it".
//!
//! Every function exposes two evaluation paths:
//!
//! * **stateless** — `evaluate` / `marginal_gain` compute from scratch;
//!   used by tests, the generic information-measure wrappers, and anywhere
//!   correctness matters more than speed.
//! * **memoized** — `init_memoization` / `marginal_gain_memoized` /
//!   `update_memoization` implement the paper's §6 pre-computed statistics
//!   (Tables 3–4). This is the path the greedy optimizers drive; the
//!   proptest suite asserts memoized gains equal stateless gains after any
//!   update sequence.

use crate::error::Result;

/// Index of an element within the ground set `{0, 1, …, n−1}`.
pub type ElementId = usize;

/// An ordered subset of the ground set with O(1) membership tests.
#[derive(Debug, Clone, Default)]
pub struct Subset {
    order: Vec<ElementId>,
    member: Vec<bool>,
}

impl Subset {
    /// Empty subset over a ground set of size `n`.
    pub fn empty(n: usize) -> Self {
        Subset { order: Vec::new(), member: vec![false; n] }
    }

    /// Subset from explicit ids (panics on duplicates / out-of-range).
    pub fn from_ids(n: usize, ids: &[ElementId]) -> Self {
        let mut s = Subset::empty(n);
        for &id in ids {
            s.insert(id);
        }
        s
    }

    /// Add an element (panics if already present or out of range).
    pub fn insert(&mut self, id: ElementId) {
        assert!(id < self.member.len(), "element {id} out of range");
        assert!(!self.member[id], "element {id} already in subset");
        self.member[id] = true;
        self.order.push(id);
    }

    #[inline]
    pub fn contains(&self, id: ElementId) -> bool {
        self.member[id]
    }

    /// Elements in insertion order.
    #[inline]
    pub fn order(&self) -> &[ElementId] {
        &self.order
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.order.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Ground-set size this subset indexes into.
    #[inline]
    pub fn ground_n(&self) -> usize {
        self.member.len()
    }

    /// Union with additional ids (panics on overlap).
    pub fn union_with(&self, ids: &[ElementId]) -> Subset {
        let mut s = self.clone();
        for &id in ids {
            if !s.contains(id) {
                s.insert(id);
            }
        }
        s
    }
}

/// A set function over a fixed ground set, with the dual stateless /
/// memoized interface described in the module docs.
///
/// Contract the optimizers rely on (and the proptests verify):
///
/// 1. `marginal_gain(X, e) == evaluate(X ∪ e) − evaluate(X)` up to float
///    tolerance;
/// 2. after `init_memoization(X)` and any sequence of
///    `update_memoization(e_i)`, `marginal_gain_memoized(e)` equals
///    `marginal_gain(X ∪ {e_i…}, e)`;
/// 3. `clone_box` yields an independent instance (memoization state is
///    *not* shared).
pub trait SetFunction: Send {
    /// Ground-set size n.
    fn n(&self) -> usize;

    /// f(X), computed from scratch.
    fn evaluate(&self, subset: &Subset) -> f64;

    /// f(X ∪ {e}) − f(X), computed from scratch.
    fn marginal_gain(&self, subset: &Subset, e: ElementId) -> f64 {
        let with = subset.union_with(&[e]);
        self.evaluate(&with) - self.evaluate(subset)
    }

    /// Reset memoized statistics to represent `subset`.
    fn init_memoization(&mut self, subset: &Subset);

    /// Marginal gain of `e` w.r.t. the memoized subset.
    fn marginal_gain_memoized(&self, e: ElementId) -> f64;

    /// Commit `e` into the memoized subset.
    fn update_memoization(&mut self, e: ElementId);

    /// Independent clone (for optimizers that fork state, the generic
    /// wrappers, and the coordinator's per-worker copies).
    fn clone_box(&self) -> Box<dyn SetFunction>;

    /// Human-readable name (metrics, verbose optimizer traces).
    fn name(&self) -> &'static str {
        "SetFunction"
    }
}

impl Clone for Box<dyn SetFunction> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// Validate that ids fit the ground set (shared constructor helper).
pub fn check_ids(n: usize, ids: &[ElementId]) -> Result<()> {
    for &id in ids {
        if id >= n {
            return Err(crate::error::SubmodError::OutOfGroundSet { id, n });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_basics() {
        let mut s = Subset::empty(5);
        assert!(s.is_empty());
        s.insert(3);
        s.insert(1);
        assert_eq!(s.order(), &[3, 1]);
        assert!(s.contains(3));
        assert!(!s.contains(0));
        assert_eq!(s.len(), 2);
        assert_eq!(s.ground_n(), 5);
    }

    #[test]
    #[should_panic]
    fn subset_duplicate_panics() {
        let mut s = Subset::empty(3);
        s.insert(1);
        s.insert(1);
    }

    #[test]
    #[should_panic]
    fn subset_out_of_range_panics() {
        let mut s = Subset::empty(3);
        s.insert(3);
    }

    #[test]
    fn union_with_dedups() {
        let s = Subset::from_ids(6, &[0, 2]);
        let u = s.union_with(&[2, 4]);
        assert_eq!(u.order(), &[0, 2, 4]);
    }

    #[test]
    fn check_ids_rejects() {
        assert!(check_ids(3, &[0, 1, 2]).is_ok());
        assert!(check_ids(3, &[3]).is_err());
    }
}

//! The `SetFunction` abstraction — the paper's de-coupled
//! function / optimizer paradigm (§5.1): "an appropriate function is first
//! instantiated and then maximize() is called on it".
//!
//! Every function exposes two evaluation paths:
//!
//! * **stateless** — `evaluate` / `marginal_gain` compute from scratch;
//!   used by tests, the generic information-measure wrappers, and anywhere
//!   correctness matters more than speed.
//! * **memoized** — `init_memoization` / `marginal_gain_memoized` /
//!   `update_memoization` implement the paper's §6 pre-computed statistics
//!   (Tables 3–4). This is the path the greedy optimizers drive; the
//!   proptest suite asserts memoized gains equal stateless gains after any
//!   update sequence.
//!
//! ## Batched evaluation
//!
//! On top of the memoized path sits [`SetFunction::marginal_gains_batch`]:
//! one call evaluates the gains of many candidates against the *same*
//! memoized state. Two things make this the hot-path entry point:
//!
//! 1. **Locality.** The memoized statistics (FL's `max_vec`, GraphCut's
//!    `sum_in`, PSC's `prod`, …) are shared across all candidates of an
//!    iteration; a batch implementation streams them once per candidate
//!    block instead of once per candidate. The specialized overrides use
//!    the same register-blocking shape as `kernel::tile::build_pairwise`.
//! 2. **Parallelism.** The trait requires `Sync`, so the optimizers can
//!    hand one `&dyn SetFunction` to several scoped threads, each calling
//!    `marginal_gains_batch` on a disjoint candidate chunk (gain
//!    evaluation never mutates state — only `update_memoization` does).
//!
//! **Determinism contract for implementors:** batch results must be
//! *identical* to per-element `marginal_gain_memoized` calls — not merely
//! close. The parallel optimizers reproduce the serial selection
//! bit-for-bit by scanning the gathered gains in candidate order, which is
//! only sound when the numbers themselves are unchanged. Vectorized
//! overrides must therefore keep each element's floating-point
//! accumulation order exactly as in the scalar path (block across
//! *candidates*, never across a single candidate's reduction).

use crate::error::Result;

/// Index of an element within the ground set `{0, 1, …, n−1}`.
pub type ElementId = usize;

/// An ordered subset of the ground set with O(1) membership tests.
#[derive(Debug, Clone, Default)]
pub struct Subset {
    order: Vec<ElementId>,
    member: Vec<bool>,
}

impl Subset {
    /// Empty subset over a ground set of size `n`.
    pub fn empty(n: usize) -> Self {
        Subset { order: Vec::new(), member: vec![false; n] }
    }

    /// Subset from explicit ids (panics on duplicates / out-of-range).
    pub fn from_ids(n: usize, ids: &[ElementId]) -> Self {
        let mut s = Subset::empty(n);
        for &id in ids {
            s.insert(id);
        }
        s
    }

    /// Add an element (panics if already present or out of range).
    pub fn insert(&mut self, id: ElementId) {
        assert!(id < self.member.len(), "element {id} out of range");
        assert!(!self.member[id], "element {id} already in subset");
        self.member[id] = true;
        self.order.push(id);
    }

    #[inline]
    pub fn contains(&self, id: ElementId) -> bool {
        self.member[id]
    }

    /// Elements in insertion order.
    #[inline]
    pub fn order(&self) -> &[ElementId] {
        &self.order
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.order.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Ground-set size this subset indexes into.
    #[inline]
    pub fn ground_n(&self) -> usize {
        self.member.len()
    }

    /// Union with additional ids (panics on overlap).
    pub fn union_with(&self, ids: &[ElementId]) -> Subset {
        let mut s = self.clone();
        for &id in ids {
            if !s.contains(id) {
                s.insert(id);
            }
        }
        s
    }
}

/// A set function over a fixed ground set, with the dual stateless /
/// memoized interface described in the module docs.
///
/// Contract the optimizers rely on (and the proptests verify):
///
/// 1. `marginal_gain(X, e) == evaluate(X ∪ e) − evaluate(X)` up to float
///    tolerance;
/// 2. after `init_memoization(X)` and any sequence of
///    `update_memoization(e_i)`, `marginal_gain_memoized(e)` equals
///    `marginal_gain(X ∪ {e_i…}, e)`;
/// 3. `clone_box` yields an independent instance (memoization state is
///    *not* shared);
/// 4. `marginal_gains_batch` returns exactly the same numbers as
///    per-element `marginal_gain_memoized` calls (see the module docs'
///    determinism contract).
///
/// `Send + Sync` is required so optimizers can fan gain evaluation out
/// across scoped threads sharing one `&dyn SetFunction`.
pub trait SetFunction: Send + Sync {
    /// Ground-set size n.
    fn n(&self) -> usize;

    /// f(X), computed from scratch.
    fn evaluate(&self, subset: &Subset) -> f64;

    /// f(X ∪ {e}) − f(X), computed from scratch.
    fn marginal_gain(&self, subset: &Subset, e: ElementId) -> f64 {
        let with = subset.union_with(&[e]);
        self.evaluate(&with) - self.evaluate(subset)
    }

    /// Reset memoized statistics to represent `subset`.
    fn init_memoization(&mut self, subset: &Subset);

    /// Marginal gain of `e` w.r.t. the memoized subset.
    fn marginal_gain_memoized(&self, e: ElementId) -> f64;

    /// Batch variant of [`marginal_gain_memoized`]: writes the gain of
    /// `candidates[i]` into `out[i]` (slices must have equal length).
    ///
    /// Results must be identical — bit-for-bit, not approximately — to
    /// calling `marginal_gain_memoized` on each candidate; the parallel
    /// optimizers rely on this to reproduce serial selections exactly.
    /// Override when candidates can share reads of the memoized
    /// statistics (contiguous kernel rows, common accumulators); the
    /// default simply loops.
    ///
    /// [`marginal_gain_memoized`]: SetFunction::marginal_gain_memoized
    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        for (o, &e) in out.iter_mut().zip(candidates) {
            *o = self.marginal_gain_memoized(e);
        }
    }

    /// Commit `e` into the memoized subset.
    fn update_memoization(&mut self, e: ElementId);

    /// Independent clone (for optimizers that fork state, the generic
    /// wrappers, and the coordinator's per-worker copies).
    fn clone_box(&self) -> Box<dyn SetFunction>;

    /// Human-readable name (metrics, verbose optimizer traces).
    fn name(&self) -> &'static str {
        "SetFunction"
    }
}

impl Clone for Box<dyn SetFunction> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// Validate that ids fit the ground set (shared constructor helper).
pub fn check_ids(n: usize, ids: &[ElementId]) -> Result<()> {
    for &id in ids {
        if id >= n {
            return Err(crate::error::SubmodError::OutOfGroundSet { id, n });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_basics() {
        let mut s = Subset::empty(5);
        assert!(s.is_empty());
        s.insert(3);
        s.insert(1);
        assert_eq!(s.order(), &[3, 1]);
        assert!(s.contains(3));
        assert!(!s.contains(0));
        assert_eq!(s.len(), 2);
        assert_eq!(s.ground_n(), 5);
    }

    #[test]
    #[should_panic]
    fn subset_duplicate_panics() {
        let mut s = Subset::empty(3);
        s.insert(1);
        s.insert(1);
    }

    #[test]
    #[should_panic]
    fn subset_out_of_range_panics() {
        let mut s = Subset::empty(3);
        s.insert(3);
    }

    #[test]
    fn union_with_dedups() {
        let s = Subset::from_ids(6, &[0, 2]);
        let u = s.union_with(&[2, 4]);
        assert_eq!(u.order(), &[0, 2, 4]);
    }

    #[test]
    fn check_ids_rejects() {
        assert!(check_ids(3, &[0, 1, 2]).is_ok());
        assert!(check_ids(3, &[3]).is_err());
    }
}

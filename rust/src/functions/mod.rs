//! The function suite (paper §2, §3, §5.2 and Table 1).
//!
//! * Regular submodular functions: [`facility_location`], [`graph_cut`],
//!   [`log_determinant`], [`set_cover`], [`prob_set_cover`],
//!   [`feature_based`], [`disparity_sum`], [`disparity_min`], plus the
//!   [`clustered`] wrapper and weighted [`mixture`]s.
//! * Submodular information measures: specialized MI / CG / CMI
//!   instantiations in [`mi`], [`cg`], [`cmi`], and the [`generic`]
//!   wrappers that lift *any* `SetFunction` into I_f(A;Q), f(A|P),
//!   I_f(A;Q|P) exactly as §3 defines them.

pub mod cg;
pub mod clustered;
pub mod cmi;
pub mod disparity_min;
pub mod disparity_min_sum;
pub mod disparity_sum;
pub mod facility_location;
pub mod feature_based;
pub mod generic;
pub mod graph_cut;
pub mod log_determinant;
pub mod mi;
pub mod mixture;
pub mod prob_set_cover;
pub mod set_cover;
pub mod traits;

pub use traits::{ElementId, SetFunction, Subset};

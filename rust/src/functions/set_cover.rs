//! Set Cover (paper §2.3.1):
//!
//! ```text
//! f_SC(X) = w(γ(X)) = Σ_{u∈C} w_u · min(c_u(X), 1)
//! ```
//!
//! Each ground element covers a set of concepts; the function value is the
//! total weight of covered concepts. Memoization (Table 3 row 4): the set
//! of covered concepts, as a bitmap.
//!
//! The MI / CG / CMI instantiations (SCMI, SCCG, SCCMI — Table 1 row 1)
//! reduce to Set Cover with *filtered cover sets* (paper §5.2.2–5.2.4);
//! [`SetCover::with_concept_filter`] implements that reduction.

use std::sync::Arc;

use super::traits::{check_ids, ElementId, SetFunction, Subset};
use crate::error::{Result, SubmodError};

/// Weighted set-cover function.
#[derive(Clone)]
pub struct SetCover {
    /// cover[i] = concepts covered by ground element i (sorted, deduped)
    cover: Arc<Vec<Vec<u32>>>,
    /// concept weights
    weights: Arc<Vec<f64>>,
    /// memoized: concept → already covered?
    covered: Vec<bool>,
}

impl SetCover {
    /// `cover[i]` lists the concept ids covered by element i; `weights[u]`
    /// is the weight of concept u.
    pub fn new(cover: Vec<Vec<u32>>, weights: Vec<f64>) -> Result<Self> {
        let n_concepts = weights.len();
        if weights.iter().any(|&w| w < 0.0) {
            return Err(SubmodError::InvalidParam("negative concept weight".into()));
        }
        let mut cover = cover;
        for c in &mut cover {
            c.sort_unstable();
            c.dedup();
            if c.iter().any(|&u| u as usize >= n_concepts) {
                return Err(SubmodError::InvalidParam(format!(
                    "concept id exceeds weight vector ({n_concepts})"
                )));
            }
        }
        Ok(SetCover {
            cover: Arc::new(cover),
            weights: Arc::new(weights),
            covered: vec![false; n_concepts],
        })
    }

    /// The SCMI / SCCG / SCCMI reduction: keep only concepts for which
    /// `keep(u)` is true (e.g. `u ∈ γ(Q)`, `u ∉ γ(P)`, or both), zeroing
    /// the rest out of every cover set.
    pub fn with_concept_filter(&self, keep: impl Fn(u32) -> bool) -> SetCover {
        let cover: Vec<Vec<u32>> = self
            .cover
            .iter()
            .map(|cs| cs.iter().copied().filter(|&u| keep(u)).collect())
            .collect();
        SetCover {
            cover: Arc::new(cover),
            weights: self.weights.clone(),
            covered: vec![false; self.weights.len()],
        }
    }

    /// Concepts covered by a set of elements (γ of a subset given as ids).
    pub fn concepts_of(&self, ids: &[ElementId]) -> Result<Vec<u32>> {
        check_ids(self.n(), ids)?;
        let mut out: Vec<u32> = ids.iter().flat_map(|&i| self.cover[i].iter().copied()).collect();
        out.sort_unstable();
        out.dedup();
        Ok(out)
    }

    pub fn n_concepts(&self) -> usize {
        self.weights.len()
    }
}

impl SetFunction for SetCover {
    fn n(&self) -> usize {
        self.cover.len()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let mut seen = vec![false; self.weights.len()];
        let mut total = 0f64;
        for &i in subset.order() {
            for &u in &self.cover[i] {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    total += self.weights[u as usize];
                }
            }
        }
        total
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for c in &mut self.covered {
            *c = false;
        }
        for &i in subset.order() {
            for &u in &self.cover[i] {
                self.covered[u as usize] = true;
            }
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.cover[e]
            .iter()
            .filter(|&&u| !self.covered[u as usize])
            .map(|&u| self.weights[u as usize])
            .sum()
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        for (o, &e) in out.iter_mut().zip(candidates) {
            *o = self.cover[e]
                .iter()
                .filter(|&&u| !self.covered[u as usize])
                .map(|&u| self.weights[u as usize])
                .sum();
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        for &u in &self.cover[e] {
            self.covered[u as usize] = true;
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "SetCover"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sc() -> SetCover {
        SetCover::new(
            vec![vec![0, 1], vec![1, 2], vec![3], vec![0, 1, 2, 3], vec![]],
            vec![1.0, 2.0, 4.0, 8.0],
        )
        .unwrap()
    }

    #[test]
    fn empty_zero_and_full() {
        let f = sc();
        assert_eq!(f.evaluate(&Subset::empty(5)), 0.0);
        let full = Subset::from_ids(5, &[0, 1, 2, 3, 4]);
        assert_eq!(f.evaluate(&full), 15.0);
    }

    #[test]
    fn covering_counted_once() {
        let f = sc();
        let s = Subset::from_ids(5, &[0, 1]); // covers {0,1,2} = 1+2+4
        assert_eq!(f.evaluate(&s), 7.0);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = sc();
        let mut s = Subset::empty(5);
        f.init_memoization(&s);
        for &add in &[0usize, 2, 1] {
            for e in 0..5 {
                if s.contains(e) {
                    continue;
                }
                assert_eq!(f.marginal_gain_memoized(e), f.marginal_gain(&s, e));
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn element_with_no_concepts_zero_gain() {
        let mut f = sc();
        f.init_memoization(&Subset::empty(5));
        assert_eq!(f.marginal_gain_memoized(4), 0.0);
    }

    #[test]
    fn invalid_inputs_rejected() {
        assert!(SetCover::new(vec![vec![5]], vec![1.0]).is_err());
        assert!(SetCover::new(vec![vec![0]], vec![-1.0]).is_err());
    }

    #[test]
    fn concept_filter_reduction() {
        let f = sc();
        // keep only concepts {1, 3} (as SCMI with γ(Q)={1,3})
        let g = f.with_concept_filter(|u| u == 1 || u == 3);
        let s = Subset::from_ids(5, &[0, 2]); // covers {0,1} ∪ {3} → kept: {1,3}
        assert_eq!(g.evaluate(&s), 2.0 + 8.0);
    }

    #[test]
    fn concepts_of_unions() {
        let f = sc();
        assert_eq!(f.concepts_of(&[0, 2]).unwrap(), vec![0, 1, 3]);
        assert!(f.concepts_of(&[9]).is_err());
    }

    #[test]
    fn monotone_and_submodular_spot() {
        let f = sc();
        let a = Subset::from_ids(5, &[0]);
        let b = Subset::from_ids(5, &[0, 1]);
        for e in [2usize, 3] {
            assert!(f.marginal_gain(&a, e) >= f.marginal_gain(&b, e));
            assert!(f.marginal_gain(&b, e) >= 0.0);
        }
    }
}

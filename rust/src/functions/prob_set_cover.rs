//! Probabilistic Set Cover (paper §2.3.2):
//!
//! ```text
//! f_PSC(X) = Σ_{u∈C} w_u (1 − Π_{x∈X} (1 − p_xu))
//! ```
//!
//! The stochastic softening of Set Cover. Memoization (Table 3 row 5):
//! `prod[u] = Π_{x∈A} (1 − p_xu)` maintained per concept.
//!
//! The MI / CG / CMI instantiations (Table 1 row 2) reduce to PSC with
//! reweighted concepts:
//! * PSCMI  — `w_u ← w_u · P̄_u(Q)`··· implemented by zeroing concepts not
//!   in the query per §5.2.2 (binary query coverage), or generally by
//!   scaling with `1 − Π_{j∈Q}(1−p_ju)`;
//! * PSCCG  — `w_u ← w_u · Π_{j∈P}(1−p_ju)`;
//! * PSCCMI — both.
//! [`ProbabilisticSetCover::with_reweighted`] provides the scaling hook.

use std::sync::Arc;

use super::traits::{ElementId, SetFunction, Subset};
use crate::error::{Result, SubmodError};

/// Probabilistic set cover over dense per-item concept probabilities.
#[derive(Clone)]
pub struct ProbabilisticSetCover {
    /// probs[i][u] = probability element i covers concept u
    probs: Arc<Vec<Vec<f32>>>,
    weights: Arc<Vec<f64>>,
    /// memoized Π_{x∈A}(1 − p_xu) per concept u
    prod: Vec<f64>,
}

impl ProbabilisticSetCover {
    pub fn new(probs: Vec<Vec<f32>>, weights: Vec<f64>) -> Result<Self> {
        let m = weights.len();
        if weights.iter().any(|&w| w < 0.0) {
            return Err(SubmodError::InvalidParam("negative concept weight".into()));
        }
        for (i, row) in probs.iter().enumerate() {
            if row.len() != m {
                return Err(SubmodError::Shape(format!(
                    "probs[{i}] has {} entries, expected {m}",
                    row.len()
                )));
            }
            if row.iter().any(|&p| !(0.0..=1.0).contains(&p)) {
                return Err(SubmodError::InvalidParam(format!("probs[{i}] outside [0,1]")));
            }
        }
        Ok(ProbabilisticSetCover {
            probs: Arc::new(probs),
            weights: Arc::new(weights),
            prod: vec![1.0; m],
        })
    }

    /// Reweight concepts (the PSCMI / PSCCG / PSCCMI reduction).
    pub fn with_reweighted(&self, scale: impl Fn(usize) -> f64) -> Result<Self> {
        let weights: Vec<f64> =
            (0..self.weights.len()).map(|u| self.weights[u] * scale(u)).collect();
        if weights.iter().any(|&w| w < 0.0) {
            return Err(SubmodError::InvalidParam("reweight produced negative weight".into()));
        }
        Ok(ProbabilisticSetCover {
            probs: self.probs.clone(),
            weights: Arc::new(weights),
            prod: vec![1.0; self.weights.len()],
        })
    }

    /// `Π_{j∈ids}(1 − p_ju)` for an external item set with the given probs
    /// — helper for building the CG/CMI reweightings from private/query
    /// item probability rows.
    pub fn survival_product(rows: &[Vec<f32>], u: usize) -> f64 {
        rows.iter().map(|r| (1.0 - r[u] as f64).max(0.0)).product()
    }

    pub fn n_concepts(&self) -> usize {
        self.weights.len()
    }
}

impl SetFunction for ProbabilisticSetCover {
    fn n(&self) -> usize {
        self.probs.len()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let m = self.weights.len();
        let mut total = 0f64;
        for u in 0..m {
            let surv: f64 =
                subset.order().iter().map(|&i| 1.0 - self.probs[i][u] as f64).product();
            total += self.weights[u] * (1.0 - surv);
        }
        total
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for p in &mut self.prod {
            *p = 1.0;
        }
        for &i in subset.order() {
            for (u, p) in self.prod.iter_mut().enumerate() {
                *p *= 1.0 - self.probs[i][u] as f64;
            }
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        // Δ = Σ_u w_u · prod[u] · p_eu
        let row = &self.probs[e];
        self.prod
            .iter()
            .zip(self.weights.iter())
            .zip(row.iter())
            .map(|((pr, w), p)| w * pr * *p as f64)
            .sum()
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        // blocked across candidates: prod/weights stream once per 4
        // probability rows. Per-candidate accumulation stays in ascending
        // concept order with the same `w * pr * p` expression, so results
        // are bit-identical to the scalar path.
        let mut c = 0;
        while c + 4 <= candidates.len() {
            let rows = [
                &self.probs[candidates[c]],
                &self.probs[candidates[c + 1]],
                &self.probs[candidates[c + 2]],
                &self.probs[candidates[c + 3]],
            ];
            let mut g = [0f64; 4];
            for (u, (pr, w)) in self.prod.iter().zip(self.weights.iter()).enumerate() {
                for t in 0..4 {
                    g[t] += w * pr * rows[t][u] as f64;
                }
            }
            out[c..c + 4].copy_from_slice(&g);
            c += 4;
        }
        for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
            *o = self.marginal_gain_memoized(e);
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        let row = &self.probs[e];
        for (p, pe) in self.prod.iter_mut().zip(row.iter()) {
            *p *= 1.0 - *pe as f64;
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "ProbabilisticSetCover"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn psc() -> ProbabilisticSetCover {
        ProbabilisticSetCover::new(
            vec![
                vec![0.9, 0.1, 0.0],
                vec![0.2, 0.8, 0.3],
                vec![0.0, 0.0, 1.0],
            ],
            vec![1.0, 2.0, 3.0],
        )
        .unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(psc().evaluate(&Subset::empty(3)), 0.0);
    }

    #[test]
    fn deterministic_coverage() {
        // element 2 covers concept 2 with p=1 → value includes full w=3
        let f = psc();
        let s = Subset::from_ids(3, &[2]);
        assert!((f.evaluate(&s) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn matches_formula() {
        let f = psc();
        let s = Subset::from_ids(3, &[0, 1]);
        let expect = 1.0 * (1.0 - (1.0 - 0.9) * (1.0 - 0.2))
            + 2.0 * (1.0 - (1.0 - 0.1) * (1.0 - 0.8))
            + 3.0 * (1.0 - (1.0 - 0.0) * (1.0 - 0.3));
        assert!((f.evaluate(&s) - expect).abs() < 1e-6);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = psc();
        let mut s = Subset::empty(3);
        f.init_memoization(&s);
        for &add in &[1usize, 0] {
            for e in 0..3 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-9
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn validation() {
        assert!(ProbabilisticSetCover::new(vec![vec![0.5]], vec![-1.0]).is_err());
        assert!(ProbabilisticSetCover::new(vec![vec![1.5]], vec![1.0]).is_err());
        assert!(ProbabilisticSetCover::new(vec![vec![0.5, 0.5]], vec![1.0]).is_err());
    }

    #[test]
    fn reweighting_scales_value() {
        let f = psc();
        let g = f.with_reweighted(|u| if u == 2 { 0.0 } else { 1.0 }).unwrap();
        let s = Subset::from_ids(3, &[2]);
        assert!(g.evaluate(&s).abs() < 1e-9); // only covered concept zeroed
    }

    #[test]
    fn survival_product_helper() {
        let rows = vec![vec![0.5f32, 0.0], vec![0.5, 1.0]];
        assert!((ProbabilisticSetCover::survival_product(&rows, 0) - 0.25).abs() < 1e-9);
        assert!(ProbabilisticSetCover::survival_product(&rows, 1).abs() < 1e-9);
    }

    #[test]
    fn monotone_submodular_spot() {
        let f = psc();
        let a = Subset::from_ids(3, &[0]);
        let b = Subset::from_ids(3, &[0, 2]);
        assert!(f.marginal_gain(&a, 1) >= f.marginal_gain(&b, 1) - 1e-12);
        assert!(f.marginal_gain(&b, 1) >= 0.0);
    }
}

//! Disparity Min (paper §2.2.1):
//!
//! ```text
//! f_DMin(X) = min_{i,j∈X, i≠j} d_ij
//! ```
//!
//! **Not submodular** (the paper is explicit about this), but still
//! efficiently optimized by the greedy algorithm (Dasgupta et al. 2013).
//! Convention (matching Submodlib): `f(∅) = f({x}) = 0`.
//!
//! Memoization (Table 3 row "Dispersion Min"): the current minimum plus
//! `min_d[j] = min_{i∈A} d_ij` per candidate, giving O(1) gains.
//!
//! Because the function is non-submodular, the LazyGreedy optimizer
//! refuses it (`is_submodular() == false`).

use std::sync::Arc;

use super::traits::{ElementId, SetFunction, Subset};
use crate::kernel::DenseKernel;

/// Disparity-min diversity function over a distance kernel.
#[derive(Clone)]
pub struct DisparityMin {
    dist: Arc<DenseKernel>,
    /// memoized min_{i∈A} d_ij per candidate j (∞ when A empty)
    min_d: Vec<f64>,
    /// memoized current f(A)
    current: f64,
    k: usize,
}

impl DisparityMin {
    pub fn new(dist: DenseKernel) -> Self {
        let n = dist.n();
        DisparityMin {
            dist: Arc::new(dist),
            min_d: vec![f64::INFINITY; n],
            current: 0.0,
            k: 0,
        }
    }

    /// Greedy with this function is heuristic (non-submodular); lazy
    /// evaluation is invalid for it.
    pub fn is_submodular(&self) -> bool {
        false
    }
}

impl SetFunction for DisparityMin {
    fn n(&self) -> usize {
        self.dist.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let o = subset.order();
        if o.len() < 2 {
            return 0.0;
        }
        let mut m = f64::INFINITY;
        for (a, &i) in o.iter().enumerate() {
            for &j in &o[a + 1..] {
                m = m.min(self.dist.get(i, j) as f64);
            }
        }
        m
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.min_d {
            *v = f64::INFINITY;
        }
        self.current = 0.0;
        self.k = 0;
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        match self.k {
            0 => 0.0,                         // f({e}) − f(∅) = 0
            1 => self.min_d[e],               // first real pair distance
            _ => self.current.min(self.min_d[e]) - self.current,
        }
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        match self.k {
            0 => out.fill(0.0),
            1 => {
                for (o, &e) in out.iter_mut().zip(candidates) {
                    *o = self.min_d[e];
                }
            }
            _ => {
                for (o, &e) in out.iter_mut().zip(candidates) {
                    *o = self.current.min(self.min_d[e]) - self.current;
                }
            }
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        if self.k >= 1 {
            self.current = if self.k == 1 {
                self.min_d[e]
            } else {
                self.current.min(self.min_d[e])
            };
        }
        let row = self.dist.row(e);
        for (j, v) in self.min_d.iter_mut().enumerate() {
            let d = row[j] as f64;
            if d < *v {
                *v = d;
            }
        }
        self.k += 1;
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "DisparityMin"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::Matrix;

    #[test]
    fn small_sets_zero() {
        let data = synthetic::blobs(6, 2, 2, 1.0, 1);
        let f = DisparityMin::new(DenseKernel::distances_from_data(&data));
        assert_eq!(f.evaluate(&Subset::empty(6)), 0.0);
        assert_eq!(f.evaluate(&Subset::from_ids(6, &[2])), 0.0);
    }

    #[test]
    fn pair_and_triple() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[3.0, 4.0], &[0.0, 1.0]]);
        let f = DisparityMin::new(DenseKernel::distances_from_data(&data));
        assert!((f.evaluate(&Subset::from_ids(3, &[0, 1])) - 5.0).abs() < 1e-5);
        // adding point 2 (dist 1 from point 0) drops the min to 1
        assert!((f.evaluate(&Subset::from_ids(3, &[0, 1, 2])) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn memoized_matches_stateless() {
        let data = synthetic::blobs(12, 2, 3, 1.0, 2);
        let mut f = DisparityMin::new(DenseKernel::distances_from_data(&data));
        let mut s = Subset::empty(12);
        f.init_memoization(&s);
        for &add in &[4usize, 9, 0, 7] {
            for e in 0..12 {
                if s.contains(e) {
                    continue;
                }
                let fast = f.marginal_gain_memoized(e);
                let slow = f.marginal_gain(&s, e);
                assert!((fast - slow).abs() < 1e-5, "e={e}: {fast} vs {slow}");
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn gains_nonpositive_after_two() {
        let data = synthetic::blobs(10, 2, 2, 1.0, 3);
        let mut f = DisparityMin::new(DenseKernel::distances_from_data(&data));
        f.init_memoization(&Subset::empty(10));
        f.update_memoization(0);
        f.update_memoization(5);
        for e in 1..5 {
            assert!(f.marginal_gain_memoized(e) <= 1e-12);
        }
    }

    #[test]
    fn not_submodular_flag() {
        let data = synthetic::blobs(4, 2, 2, 1.0, 4);
        assert!(!DisparityMin::new(DenseKernel::distances_from_data(&data)).is_submodular());
    }
}

//! FLCG — Facility Location Conditional Gain (Table 1 "FL (v1)" CG):
//!
//! ```text
//! f(A|P) = Σ_{i∈V} max(max_{j∈A} S_ij − ν max_{j∈P} S_ij, 0)
//! ```
//!
//! ν ≥ 0 is the privacy-hardness parameter (paper §3.4/§3.7 discussion):
//! larger ν suppresses any pick resembling the private set. Memoized like
//! FL: `max_vec[i]`, against a precomputed private cap `ν max_{j∈P} S_ij`.
//!
//! Empty maxima use the `−∞` sentinel (see `mi::flqmi`'s module docs) so
//! negative similarities are not clamped at zero; the definition's outer
//! `max(·, 0)` maps the empty row term to 0 (f(∅|P) = 0) without a
//! special case, and non-negative kernels are unchanged.

use std::sync::Arc;

use crate::error::{Result, SubmodError};
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};

/// FLCG. See module docs.
#[derive(Clone)]
pub struct Flcg {
    ground: Arc<DenseKernel>,
    /// ν · max_{j∈P} S_ij per ground row i
    pcap: Arc<Vec<f32>>,
    nu: f64,
    /// memoized max_{j∈A} S_ij
    max_vec: Vec<f32>,
}

impl Flcg {
    /// `ground` is V×V; `privates` is P×V; `nu ≥ 0`.
    pub fn new(ground: DenseKernel, privates: RectKernel, nu: f64) -> Result<Self> {
        if nu < 0.0 {
            return Err(SubmodError::InvalidParam(format!("nu {nu} < 0")));
        }
        if privates.cols() != ground.n() {
            return Err(SubmodError::Shape(format!(
                "private kernel cols {} vs ground n {}",
                privates.cols(),
                ground.n()
            )));
        }
        let n = ground.n();
        let np = privates.rows();
        let pcap: Vec<f32> = (0..n)
            .map(|i| {
                if np == 0 {
                    return 0.0; // empty P exerts no influence
                }
                nu as f32
                    * (0..np)
                        .map(|p| privates.get(p, i))
                        .fold(f32::NEG_INFINITY, f32::max)
            })
            .collect();
        Ok(Flcg {
            ground: Arc::new(ground),
            pcap: Arc::new(pcap),
            nu,
            max_vec: vec![f32::NEG_INFINITY; n],
        })
    }

    pub fn nu(&self) -> f64 {
        self.nu
    }
}

impl SetFunction for Flcg {
    fn n(&self) -> usize {
        self.ground.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        (0..self.ground.n())
            .map(|i| {
                // −∞ fold base: the outer max(·, 0) maps an empty subset's
                // row term to 0, matching f(∅|P) = 0
                let ma = subset
                    .order()
                    .iter()
                    .map(|&j| self.ground.get(i, j))
                    .fold(f32::NEG_INFINITY, f32::max);
                (ma - self.pcap[i]).max(0.0) as f64
            })
            .sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.max_vec {
            *v = f32::NEG_INFINITY; // empty-set sentinel (module docs)
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        // symmetric kernel: row e read contiguously (s_ie == s_ei)
        let row = self.ground.row(e);
        let mut g = 0f64;
        for i in 0..row.len() {
            let cap = self.pcap[i];
            let mv = self.max_vec[i];
            let s = row[i];
            let before = (mv - cap).max(0.0);
            let after = (mv.max(s) - cap).max(0.0);
            g += (after - before) as f64;
        }
        g
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        // Blocked across candidates: max_vec / pcap stream once per 4
        // contiguous kernel rows, "before" computed once per row.
        // Ascending-i accumulation per candidate is bit-identical to the
        // scalar path.
        let mut c = 0;
        while c + 4 <= candidates.len() {
            let rows = [
                self.ground.row(candidates[c]),
                self.ground.row(candidates[c + 1]),
                self.ground.row(candidates[c + 2]),
                self.ground.row(candidates[c + 3]),
            ];
            let mut g = [0f64; 4];
            for i in 0..self.max_vec.len() {
                let cap = self.pcap[i];
                let mv = self.max_vec[i];
                let before = (mv - cap).max(0.0);
                for t in 0..4 {
                    let s = rows[t][i];
                    let after = (mv.max(s) - cap).max(0.0);
                    g[t] += (after - before) as f64;
                }
            }
            out[c..c + 4].copy_from_slice(&g);
            c += 4;
        }
        for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
            *o = self.marginal_gain_memoized(e);
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        let row = self.ground.row(e);
        for (mv, &s) in self.max_vec.iter_mut().zip(row) {
            if s > *mv {
                *mv = s;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "FLCG"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(nu: f64) -> Flcg {
        let (ground, _, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let p = RectKernel::from_data(&privates, &ground, Metric::Euclidean).unwrap();
        Flcg::new(g, p, nu).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(setup(1.0).evaluate(&Subset::empty(46)), 0.0);
    }

    #[test]
    fn nu_zero_reduces_to_fl() {
        use crate::functions::facility_location::FacilityLocation;
        let (ground, _, _, _) = controlled::fig6_dataset();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let fl = FacilityLocation::new(g);
        let cg = setup(0.0);
        for ids in [vec![0usize, 5], vec![20, 40, 44]] {
            let s = Subset::from_ids(46, &ids);
            assert!((cg.evaluate(&s) - fl.evaluate(&s)).abs() < 1e-5);
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(1.0);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[14usize, 2, 43] {
            for e in (0..46).step_by(7) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-5
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn private_adjacent_elements_suppressed() {
        // the private set sits near clusters 1 and 2 → picking inside
        // cluster 1 (ids 14..28) should gain less under large ν than under ν=0
        let f_strict = setup(3.0);
        let f_free = setup(0.0);
        let s = Subset::empty(46);
        let g_strict = f_strict.marginal_gain(&s, 14); // cluster-1 center
        let g_free = f_free.marginal_gain(&s, 14);
        assert!(g_strict < g_free * 0.6, "{g_strict} vs {g_free}");
    }

    #[test]
    fn higher_nu_monotonically_tightens() {
        let s = Subset::from_ids(46, &[0]);
        let mut last = f64::INFINITY;
        for nu in [0.0, 0.5, 1.0, 2.0] {
            let v = setup(nu).evaluate(&s);
            assert!(v <= last + 1e-9);
            last = v;
        }
    }
}

//! LogDetCG — Log Determinant Conditional Gain (paper §5.2.3): "first a
//! Log Determinant function is instantiated with appropriate kernel and
//! then a Conditional Gain function is instantiated using it".
//!
//! The extended (V∪P) kernel has the V↔P cross block scaled by ν,
//! realizing Table 1's `log det(S_A − ν² S_AP S_P⁻¹ S_APᵀ)` through the
//! generic identity f(A|P) = f(A∪P) − f(P).

use crate::error::Result;
use crate::functions::generic::ConditionalGain;
use crate::functions::log_determinant::LogDeterminant;
use crate::functions::mi::logdetmi::extended_kernel;
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};

/// LogDetCG as a `SetFunction` over V.
pub struct LogDetCg {
    inner: ConditionalGain,
}

impl LogDetCg {
    /// `ground` V×V, `privates_k` P×P, `cross` P×V, ν privacy hardness,
    /// `reg` LogDet diagonal regularizer.
    pub fn new(
        ground: DenseKernel,
        privates_k: DenseKernel,
        cross: RectKernel,
        nu: f64,
        reg: f64,
    ) -> Result<Self> {
        let n = ground.n();
        let m = privates_k.n();
        let ext = extended_kernel(&ground, &privates_k, &cross, nu)?;
        let base = LogDeterminant::with_regularization(ext, reg)?;
        let inner = ConditionalGain::new(Box::new(base), (n..n + m).collect(), n)?;
        Ok(LogDetCg { inner })
    }
}

impl Clone for LogDetCg {
    fn clone(&self) -> Self {
        LogDetCg { inner: self.inner.clone() }
    }
}

impl SetFunction for LogDetCg {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        self.inner.evaluate(subset)
    }

    fn init_memoization(&mut self, subset: &Subset) {
        self.inner.init_memoization(subset);
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.inner.marginal_gain_memoized(e)
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // forwards to generic CG → LogDeterminant's blocked forward
        // substitution over the shared incremental factor
        self.inner.marginal_gains_batch(candidates, out);
    }

    fn update_memoization(&mut self, e: ElementId) {
        self.inner.update_memoization(e);
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "LogDetCG"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(nu: f64) -> LogDetCg {
        let (ground, _, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let g = DenseKernel::from_data(&ground, Metric::Rbf { gamma: 0.5 });
        let pk = DenseKernel::from_data(&privates, Metric::Rbf { gamma: 0.5 });
        let c = RectKernel::from_data(&privates, &ground, Metric::Rbf { gamma: 0.5 }).unwrap();
        LogDetCg::new(g, pk, c, nu, 0.1).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert!(setup(0.8).evaluate(&Subset::empty(46)).abs() < 1e-9);
    }

    #[test]
    fn nu_zero_reduces_to_plain_logdet() {
        let (ground, _, _, _) = controlled::fig6_dataset();
        let g = DenseKernel::from_data(&ground, Metric::Rbf { gamma: 0.5 });
        let plain = LogDeterminant::with_regularization(g, 0.1).unwrap();
        let f = setup(0.0);
        for ids in [vec![4usize], vec![0, 20, 40]] {
            let s = Subset::from_ids(46, &ids);
            assert!((f.evaluate(&s) - plain.evaluate(&s)).abs() < 1e-4, "{ids:?}");
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(0.6);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[1usize, 22] {
            for e in (0..46).step_by(15) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-4
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn private_similar_items_devalued() {
        // id 14 (cluster-1 center) is close to a private point; under
        // larger ν its singleton value must shrink
        let v_free = setup(0.0).evaluate(&Subset::from_ids(46, &[14]));
        let v_strict = setup(0.9).evaluate(&Subset::from_ids(46, &[14]));
        assert!(v_strict < v_free);
    }
}

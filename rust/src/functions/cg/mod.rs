//! Specialized Conditional Gain instantiations (paper §3.1, Table 1 column
//! "CG") — query-irrelevant / privacy-preserving selection: the chosen
//! subset must be *different* from the private (conditioning) set P.
//!
//! | name | expression (Table 1) | module |
//! |------|----------------------|--------|
//! | FLCG | Σ_{i∈V} max(max_{j∈A} S_ij − ν max_{j∈P} S_ij, 0) | [`flcg`] |
//! | GCCG | f_λ(A) − 2λν Σ_{i∈A, j∈P} S_ij | [`gccg`] |
//! | LogDetCG | via generic CG over a ν-scaled extended kernel | [`logdetcg`] |
//! | SCCG | w(γ(A) \ γ(P)) | [`sccg()`](sccg::sccg) |
//! | PSCCG | Σ_u w_u P̄_u(A) P_u(P) | [`psccg()`](psccg::psccg) |

pub mod flcg;
pub mod gccg;
pub mod logdetcg;
pub mod psccg;
pub mod sccg;

pub use flcg::Flcg;
pub use gccg::Gccg;
pub use logdetcg::LogDetCg;
pub use psccg::psccg;
pub use sccg::sccg;

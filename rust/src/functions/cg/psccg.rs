//! PSCCG — Probabilistic Set Cover Conditional Gain (paper §5.2.3,
//! Table 1):
//!
//! ```text
//! f(A|P) = Σ_u w_u · P̄_u(A) · P_u(P)
//! ```
//!
//! where P_u(P) = Π_{j∈P}(1 − p_ju) is the probability the private set
//! does NOT cover concept u. Reduction: PSC with weights scaled by
//! P_u(P) (the paper's binary special case zeroes concepts present in P).

use crate::error::Result;
use crate::functions::prob_set_cover::ProbabilisticSetCover;

/// Build PSCCG from a base PSC and the private items' probability rows.
pub fn psccg(
    base: &ProbabilisticSetCover,
    private_probs: &[Vec<f32>],
) -> Result<ProbabilisticSetCover> {
    base.with_reweighted(|u| ProbabilisticSetCover::survival_product(private_probs, u))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::traits::{SetFunction, Subset};

    fn base() -> ProbabilisticSetCover {
        ProbabilisticSetCover::new(
            vec![vec![0.9, 0.2], vec![0.1, 0.8]],
            vec![1.0, 2.0],
        )
        .unwrap()
    }

    #[test]
    fn matches_table1_formula() {
        let pp = vec![vec![0.5f32, 0.0]];
        let f = psccg(&base(), &pp).unwrap();
        // A = {0}: u=0: 1.0·0.9·(1−0.5)=0.45 ; u=1: 2.0·0.2·1.0=0.4
        let s = Subset::from_ids(2, &[0]);
        assert!((f.evaluate(&s) - 0.85).abs() < 1e-6);
    }

    #[test]
    fn deterministic_private_coverage_zeroes_concept() {
        let pp = vec![vec![1.0f32, 0.0]];
        let f = psccg(&base(), &pp).unwrap();
        // concept 0 certainly covered by P → drops out entirely
        let s = Subset::from_ids(2, &[0, 1]);
        let expect = 2.0 * (1.0 - (1.0 - 0.2) * (1.0 - 0.8));
        assert!((f.evaluate(&s) - expect).abs() < 1e-6);
    }

    #[test]
    fn empty_private_is_base() {
        let b = base();
        let f = psccg(&b, &[]).unwrap();
        let s = Subset::from_ids(2, &[1]);
        assert!((f.evaluate(&s) - b.evaluate(&s)).abs() < 1e-12);
    }
}

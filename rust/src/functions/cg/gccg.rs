//! GCCG — Graph Cut Conditional Gain (paper §3.7, Table 1):
//!
//! ```text
//! f(A|P) = f_λ(A) − 2λν Σ_{i∈A, j∈P} S_ij
//! ```
//!
//! i.e. the plain Graph Cut objective minus a modular privacy penalty.
//! Memoization = GraphCut's (Table 4 row GCCG) plus the precomputed
//! per-element private affinity.

use std::sync::Arc;

use crate::error::{Result, SubmodError};
use crate::functions::graph_cut::GraphCut;
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};

/// GCCG. See module docs.
#[derive(Clone)]
pub struct Gccg {
    gc: GraphCut,
    /// 2λν Σ_{j∈P} S_ij per ground element i
    penalty: Arc<Vec<f64>>,
    nu: f64,
}

impl Gccg {
    /// `ground` V×V kernel; `privates` P×V kernel; λ the GC trade-off,
    /// ν ≥ 0 privacy hardness.
    pub fn new(ground: DenseKernel, privates: RectKernel, lambda: f64, nu: f64) -> Result<Self> {
        if nu < 0.0 {
            return Err(SubmodError::InvalidParam(format!("nu {nu} < 0")));
        }
        if privates.cols() != ground.n() {
            return Err(SubmodError::Shape(format!(
                "private kernel cols {} vs ground n {}",
                privates.cols(),
                ground.n()
            )));
        }
        let n = ground.n();
        let np = privates.rows();
        let penalty: Vec<f64> = (0..n)
            .map(|i| {
                2.0 * lambda * nu * (0..np).map(|p| privates.get(p, i) as f64).sum::<f64>()
            })
            .collect();
        Ok(Gccg { gc: GraphCut::new(ground, lambda)?, penalty: Arc::new(penalty), nu })
    }

    pub fn nu(&self) -> f64 {
        self.nu
    }
}

impl SetFunction for Gccg {
    fn n(&self) -> usize {
        self.gc.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        self.gc.evaluate(subset)
            - subset.order().iter().map(|&i| self.penalty[i]).sum::<f64>()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        self.gc.init_memoization(subset);
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.gc.marginal_gain_memoized(e) - self.penalty[e]
    }

    fn update_memoization(&mut self, e: ElementId) {
        self.gc.update_memoization(e);
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "GCCG"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(nu: f64) -> Gccg {
        let (ground, _, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let p = RectKernel::from_data(&privates, &ground, Metric::Euclidean).unwrap();
        Gccg::new(g, p, 0.4, nu).unwrap()
    }

    #[test]
    fn nu_zero_is_plain_graph_cut() {
        let (ground, _, _, _) = controlled::fig6_dataset();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let gc = GraphCut::new(g, 0.4).unwrap();
        let f = setup(0.0);
        for ids in [vec![3usize], vec![10, 25, 44]] {
            let s = Subset::from_ids(46, &ids);
            assert!((f.evaluate(&s) - gc.evaluate(&s)).abs() < 1e-6);
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(1.5);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[8usize, 30] {
            for e in (0..46).step_by(9) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn penalty_reduces_private_adjacent_gain() {
        let f0 = setup(0.0);
        let f3 = setup(3.0);
        let s = Subset::empty(46);
        // cluster-1 center (id 14) is near a private point
        assert!(f3.marginal_gain(&s, 14) < f0.marginal_gain(&s, 14));
    }

    #[test]
    fn negative_nu_rejected() {
        let (ground, _, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let p = RectKernel::from_data(&privates, &ground, Metric::Euclidean).unwrap();
        assert!(Gccg::new(g, p, 0.4, -1.0).is_err());
    }
}

//! SCCG — Set Cover Conditional Gain (paper §5.2.3, Table 1):
//!
//! ```text
//! f(A|P) = w(γ(A) \ γ(P))
//! ```
//!
//! Reduction: Set Cover with each element's cover set stripped of the
//! concepts the private set already covers.

use crate::error::Result;
use crate::functions::set_cover::SetCover;

/// Build SCCG from a base SetCover and the concepts covered by the
/// private set, `gamma_p`.
pub fn sccg(base: &SetCover, gamma_p: &[u32]) -> Result<SetCover> {
    let drop: std::collections::HashSet<u32> = gamma_p.iter().copied().collect();
    Ok(base.with_concept_filter(|u| !drop.contains(&u)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::traits::{SetFunction, Subset};

    fn base() -> SetCover {
        SetCover::new(
            vec![vec![0, 1], vec![1, 2], vec![2, 3], vec![3]],
            vec![1.0, 2.0, 4.0, 8.0],
        )
        .unwrap()
    }

    #[test]
    fn private_concepts_excluded() {
        let f = sccg(&base(), &[1, 3]).unwrap();
        // A = {0,3}: γ(A)={0,1,3}; minus γ(P)={1,3} → {0} → w=1
        assert_eq!(f.evaluate(&Subset::from_ids(4, &[0, 3])), 1.0);
    }

    #[test]
    fn empty_private_is_base() {
        let b = base();
        let f = sccg(&b, &[]).unwrap();
        for ids in [vec![0usize], vec![1, 2], vec![0, 1, 2, 3]] {
            let s = Subset::from_ids(4, &ids);
            assert_eq!(f.evaluate(&s), b.evaluate(&s));
        }
    }

    #[test]
    fn all_private_zeroes() {
        let f = sccg(&base(), &[0, 1, 2, 3]).unwrap();
        assert_eq!(f.evaluate(&Subset::from_ids(4, &[0, 1, 2, 3])), 0.0);
    }
}

//! Feature-Based functions (paper §2.3.3): sums of concave over modular,
//!
//! ```text
//! f_FB(X) = Σ_{f∈F} w_f · g(m_f(X)),   m_f(X) = Σ_{x∈X} score_f(x)
//! ```
//!
//! with g a concave shape — Submodlib supports logarithmic, square-root
//! and inverse (`x/(1+x)`); we add `pow(a)` for 0<a<1 as an extension.
//! Memoization (Table 3 row 3): the accumulated `m_f(A)` per feature.

use std::sync::Arc;

use super::traits::{ElementId, SetFunction, Subset};
use crate::error::{Result, SubmodError};

/// Concave shapes for feature-based functions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ConcaveShape {
    /// g(x) = ln(1 + x)
    Log,
    /// g(x) = √x
    Sqrt,
    /// g(x) = x / (1 + x)
    Inverse,
    /// g(x) = x^a, 0 < a < 1
    Pow(f64),
}

impl ConcaveShape {
    #[inline]
    pub fn apply(&self, x: f64) -> f64 {
        debug_assert!(x >= 0.0);
        match *self {
            ConcaveShape::Log => (1.0 + x).ln(),
            ConcaveShape::Sqrt => x.sqrt(),
            ConcaveShape::Inverse => x / (1.0 + x),
            ConcaveShape::Pow(a) => x.powf(a),
        }
    }

    fn validate(&self) -> Result<()> {
        if let ConcaveShape::Pow(a) = *self {
            if !(0.0 < a && a < 1.0) {
                return Err(SubmodError::InvalidParam(format!(
                    "pow exponent {a} outside (0,1)"
                )));
            }
        }
        Ok(())
    }
}

/// Feature-based function over sparse non-negative feature scores.
#[derive(Clone)]
pub struct FeatureBased {
    /// features[i] = sparse (feature id, score ≥ 0) list for element i
    features: Arc<Vec<Vec<(u32, f32)>>>,
    weights: Arc<Vec<f64>>,
    shape: ConcaveShape,
    /// memoized m_f(A) per feature f
    accum: Vec<f64>,
}

impl FeatureBased {
    pub fn new(
        features: Vec<Vec<(u32, f32)>>,
        weights: Vec<f64>,
        shape: ConcaveShape,
    ) -> Result<Self> {
        shape.validate()?;
        let m = weights.len();
        if weights.iter().any(|&w| w < 0.0) {
            return Err(SubmodError::InvalidParam("negative feature weight".into()));
        }
        let mut features = features;
        for (i, row) in features.iter_mut().enumerate() {
            for &(f, v) in row.iter() {
                if f as usize >= m {
                    return Err(SubmodError::InvalidParam(format!(
                        "feature id {f} in element {i} exceeds weight vector"
                    )));
                }
                if v < 0.0 {
                    return Err(SubmodError::InvalidParam(format!(
                        "negative feature score in element {i}"
                    )));
                }
            }
            // coalesce duplicate feature ids (the memoized gain computes
            // per-entry concave deltas, which is only correct when each
            // feature appears at most once per element)
            row.sort_unstable_by_key(|e| e.0);
            let mut out: Vec<(u32, f32)> = Vec::with_capacity(row.len());
            for &(f, v) in row.iter() {
                match out.last_mut() {
                    Some(last) if last.0 == f => last.1 += v,
                    _ => out.push((f, v)),
                }
            }
            *row = out;
        }
        Ok(FeatureBased {
            features: Arc::new(features),
            weights: Arc::new(weights),
            shape,
            accum: vec![0.0; m],
        })
    }

    /// Dense-feature convenience constructor (e.g. ConvNet activations):
    /// every (element, feature) score from a row-major matrix; uniform
    /// weights.
    pub fn from_dense(matrix: &crate::linalg::Matrix, shape: ConcaveShape) -> Result<Self> {
        let m = matrix.cols();
        let features: Vec<Vec<(u32, f32)>> = (0..matrix.rows())
            .map(|i| {
                matrix
                    .row(i)
                    .iter()
                    .enumerate()
                    .filter(|(_, v)| **v > 0.0)
                    .map(|(f, &v)| (f as u32, v))
                    .collect()
            })
            .collect();
        FeatureBased::new(features, vec![1.0; m], shape)
    }
}

impl SetFunction for FeatureBased {
    fn n(&self) -> usize {
        self.features.len()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let mut acc = vec![0f64; self.weights.len()];
        for &i in subset.order() {
            for &(f, v) in &self.features[i] {
                acc[f as usize] += v as f64;
            }
        }
        acc.iter()
            .zip(self.weights.iter())
            .map(|(&a, &w)| w * self.shape.apply(a))
            .sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for a in &mut self.accum {
            *a = 0.0;
        }
        for &i in subset.order() {
            for &(f, v) in &self.features[i] {
                self.accum[f as usize] += v as f64;
            }
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.features[e]
            .iter()
            .map(|&(f, v)| {
                let a = self.accum[f as usize];
                self.weights[f as usize]
                    * (self.shape.apply(a + v as f64) - self.shape.apply(a))
            })
            .sum()
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // each candidate touches only its own sparse feature list; the
        // shared reads (accum, weights) already hit cache — inline the
        // scalar formula to skip per-candidate dyn dispatch
        debug_assert_eq!(candidates.len(), out.len());
        for (o, &e) in out.iter_mut().zip(candidates) {
            *o = self.features[e]
                .iter()
                .map(|&(f, v)| {
                    let a = self.accum[f as usize];
                    self.weights[f as usize]
                        * (self.shape.apply(a + v as f64) - self.shape.apply(a))
                })
                .sum();
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        for &(f, v) in &self.features[e] {
            self.accum[f as usize] += v as f64;
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "FeatureBased"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fb(shape: ConcaveShape) -> FeatureBased {
        FeatureBased::new(
            vec![
                vec![(0, 1.0), (1, 2.0)],
                vec![(1, 3.0)],
                vec![(0, 2.0), (2, 1.0)],
            ],
            vec![1.0, 0.5, 2.0],
            shape,
        )
        .unwrap()
    }

    #[test]
    fn empty_zero_for_all_shapes() {
        for shape in [
            ConcaveShape::Log,
            ConcaveShape::Sqrt,
            ConcaveShape::Inverse,
            ConcaveShape::Pow(0.5),
        ] {
            assert_eq!(fb(shape).evaluate(&Subset::empty(3)), 0.0);
        }
    }

    #[test]
    fn matches_formula_log() {
        let f = fb(ConcaveShape::Log);
        let s = Subset::from_ids(3, &[0, 1]);
        let expect = 1.0 * (1.0 + 1.0f64).ln() + 0.5 * (1.0 + 5.0f64).ln();
        assert!((f.evaluate(&s) - expect).abs() < 1e-9);
    }

    #[test]
    fn memoized_matches_stateless_all_shapes() {
        for shape in [
            ConcaveShape::Log,
            ConcaveShape::Sqrt,
            ConcaveShape::Inverse,
            ConcaveShape::Pow(0.3),
        ] {
            let mut f = fb(shape);
            let mut s = Subset::empty(3);
            f.init_memoization(&s);
            for &add in &[2usize, 0] {
                for e in 0..3 {
                    if s.contains(e) {
                        continue;
                    }
                    assert!(
                        (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs()
                            < 1e-9,
                        "{shape:?}"
                    );
                }
                f.update_memoization(add);
                s.insert(add);
            }
        }
    }

    #[test]
    fn diminishing_returns() {
        let f = fb(ConcaveShape::Sqrt);
        let a = Subset::empty(3);
        let b = Subset::from_ids(3, &[1]);
        // element 1 hits feature 1; adding 0 (also feature 1) gains less after
        assert!(f.marginal_gain(&a, 0) > f.marginal_gain(&b, 0));
    }

    #[test]
    fn validation() {
        assert!(FeatureBased::new(vec![vec![(3, 1.0)]], vec![1.0], ConcaveShape::Log).is_err());
        assert!(FeatureBased::new(vec![vec![(0, -1.0)]], vec![1.0], ConcaveShape::Log).is_err());
        assert!(FeatureBased::new(vec![], vec![-1.0], ConcaveShape::Log).is_err());
        assert!(FeatureBased::new(vec![], vec![], ConcaveShape::Pow(1.5)).is_err());
    }

    #[test]
    fn from_dense() {
        let m = crate::linalg::Matrix::from_rows(&[&[1.0, 0.0], &[0.5, 2.0]]);
        let f = FeatureBased::from_dense(&m, ConcaveShape::Sqrt).unwrap();
        let s = Subset::from_ids(2, &[0, 1]);
        let expect = (1.5f64).sqrt() + (2.0f64).sqrt();
        assert!((f.evaluate(&s) - expect).abs() < 1e-6);
    }
}

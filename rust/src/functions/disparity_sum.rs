//! Disparity Sum (paper §2.2.1):
//!
//! ```text
//! f_DSum(X) = Σ_{i,j∈X} d_ij      (unordered pairs)
//! ```
//!
//! A *supermodular* diversity model — happily selects outliers (the Fig 5b
//! behaviour). Memoization (Table 3 row "Dispersion Sum"):
//! `sum_d[j] = Σ_{i∈A} d_ij`, so the gain of adding j is exactly `sum_d[j]`.

use std::sync::Arc;

use super::traits::{ElementId, SetFunction, Subset};
use crate::kernel::DenseKernel;

/// Disparity-sum diversity function over a distance kernel.
#[derive(Clone)]
pub struct DisparitySum {
    /// distance matrix (square, symmetric, zero diagonal)
    dist: Arc<DenseKernel>,
    /// memoized Σ_{i∈A} d_ij per element j
    sum_d: Vec<f64>,
}

impl DisparitySum {
    /// `dist` must be a distance kernel (`DenseKernel::distances_from_data`).
    pub fn new(dist: DenseKernel) -> Self {
        let n = dist.n();
        DisparitySum { dist: Arc::new(dist), sum_d: vec![0.0; n] }
    }
}

impl SetFunction for DisparitySum {
    fn n(&self) -> usize {
        self.dist.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let o = subset.order();
        let mut total = 0f64;
        for (a, &i) in o.iter().enumerate() {
            for &j in &o[a + 1..] {
                total += self.dist.get(i, j) as f64;
            }
        }
        total
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.sum_d {
            *v = 0.0;
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.sum_d[e]
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        for (o, &e) in out.iter_mut().zip(candidates) {
            *o = self.sum_d[e];
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        let row = self.dist.row(e);
        for (j, v) in self.sum_d.iter_mut().enumerate() {
            *v += row[j] as f64;
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "DisparitySum"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::Matrix;

    fn ds(n: usize, seed: u64) -> DisparitySum {
        let data = synthetic::blobs(n, 2, 3, 1.0, seed);
        DisparitySum::new(DenseKernel::distances_from_data(&data))
    }

    #[test]
    fn empty_and_singleton_zero() {
        let f = ds(10, 1);
        assert_eq!(f.evaluate(&Subset::empty(10)), 0.0);
        assert_eq!(f.evaluate(&Subset::from_ids(10, &[4])), 0.0);
    }

    #[test]
    fn pair_is_distance() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[3.0, 4.0]]);
        let f = DisparitySum::new(DenseKernel::distances_from_data(&data));
        assert!((f.evaluate(&Subset::from_ids(2, &[0, 1])) - 5.0).abs() < 1e-5);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = ds(15, 2);
        let mut s = Subset::empty(15);
        f.init_memoization(&s);
        for &add in &[3usize, 12, 7] {
            for e in 0..15 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-4
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn supermodular_increasing_gains() {
        // gains grow (not shrink) with the base set: f(e|A) ≤ f(e|B), A⊆B
        let f = ds(12, 3);
        let a = Subset::from_ids(12, &[1]);
        let b = Subset::from_ids(12, &[1, 5, 9]);
        for e in [0usize, 3, 11] {
            assert!(f.marginal_gain(&b, e) >= f.marginal_gain(&a, e) - 1e-9);
        }
    }

    #[test]
    fn prefers_distant_points() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[1.0, 0.0], &[100.0, 0.0]]);
        let mut f = DisparitySum::new(DenseKernel::distances_from_data(&data));
        f.init_memoization(&Subset::empty(3));
        f.update_memoization(0);
        assert!(f.marginal_gain_memoized(2) > f.marginal_gain_memoized(1));
    }
}

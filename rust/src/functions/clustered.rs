//! Generic Clustered Function (paper §8, alternative 2):
//!
//! ```text
//! f(A) = Σ_i f_{C_i}(A)
//! ```
//!
//! where `f_{C_i}` operates on cluster `C_i` as its sub-groundset and
//! interprets A as `A ∩ C_i`. Works for **any** inner `SetFunction` built
//! per cluster (in cluster-local ids); this wrapper does the global↔local
//! id translation and fans the memoization out.

use super::traits::{ElementId, SetFunction, Subset};
use crate::error::{Result, SubmodError};

/// Mixture-over-clusters wrapper. See module docs.
pub struct ClusteredFunction {
    /// (global ids of cluster, inner function over local ids 0..len)
    clusters: Vec<(Vec<ElementId>, Box<dyn SetFunction>)>,
    /// global id → (cluster idx, local idx); u32::MAX = unassigned
    lookup: Vec<(u32, u32)>,
    n: usize,
}

impl ClusteredFunction {
    /// `clusters[k]` = (global element ids of cluster k, function whose
    /// ground set is exactly those ids in local order). `n` = global size.
    pub fn new(
        clusters: Vec<(Vec<ElementId>, Box<dyn SetFunction>)>,
        n: usize,
    ) -> Result<Self> {
        let mut lookup = vec![(u32::MAX, 0u32); n];
        for (ci, (ids, f)) in clusters.iter().enumerate() {
            if f.n() != ids.len() {
                return Err(SubmodError::Shape(format!(
                    "cluster {ci}: inner n {} vs {} ids",
                    f.n(),
                    ids.len()
                )));
            }
            for (li, &g) in ids.iter().enumerate() {
                if g >= n {
                    return Err(SubmodError::OutOfGroundSet { id: g, n });
                }
                if lookup[g].0 != u32::MAX {
                    return Err(SubmodError::InvalidParam(format!(
                        "element {g} assigned to two clusters"
                    )));
                }
                lookup[g] = (ci as u32, li as u32);
            }
        }
        Ok(ClusteredFunction { clusters, lookup, n })
    }

    /// The paper's §8 "let SUBMODLIB do the clustering internally"
    /// convenience: k-means the data, then build one inner function per
    /// cluster with `build` (which receives the cluster's feature rows).
    pub fn from_data<F>(
        data: &crate::linalg::Matrix,
        k: usize,
        seed: u64,
        build: F,
    ) -> Result<Self>
    where
        F: Fn(&crate::linalg::Matrix) -> Result<Box<dyn SetFunction>>,
    {
        let km = crate::clustering::kmeans(data, k, 50, seed);
        let parts = crate::clustering::partition(&km.labels, k);
        let mut clusters = Vec::new();
        for ids in parts.into_iter().filter(|ids| !ids.is_empty()) {
            let mut sub = crate::linalg::Matrix::zeros(ids.len(), data.cols());
            for (li, &g) in ids.iter().enumerate() {
                sub.row_mut(li).copy_from_slice(data.row(g));
            }
            clusters.push((ids, build(&sub)?));
        }
        ClusteredFunction::new(clusters, data.rows())
    }

    fn local_subset(&self, ci: usize, subset: &Subset) -> Subset {
        let ids = &self.clusters[ci].0;
        let mut local = Subset::empty(ids.len());
        // preserve global insertion order
        for &g in subset.order() {
            let (c, l) = self.lookup[g];
            if c as usize == ci {
                local.insert(l as usize);
            }
        }
        local
    }
}

impl Clone for ClusteredFunction {
    fn clone(&self) -> Self {
        ClusteredFunction {
            clusters: self
                .clusters
                .iter()
                .map(|(ids, f)| (ids.clone(), f.clone_box()))
                .collect(),
            lookup: self.lookup.clone(),
            n: self.n,
        }
    }
}

impl SetFunction for ClusteredFunction {
    fn n(&self) -> usize {
        self.n
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        (0..self.clusters.len())
            .map(|ci| self.clusters[ci].1.evaluate(&self.local_subset(ci, subset)))
            .sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for ci in 0..self.clusters.len() {
            let local = self.local_subset(ci, subset);
            self.clusters[ci].1.init_memoization(&local);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        let (ci, li) = self.lookup[e];
        if ci == u32::MAX {
            return 0.0;
        }
        self.clusters[ci as usize].1.marginal_gain_memoized(li as usize)
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // group candidates per cluster so each inner function sees one
        // contiguous batch (and its specialized implementation applies);
        // out[i] slots are independent, so regrouping cannot change values
        debug_assert_eq!(candidates.len(), out.len());
        let mut groups: Vec<Vec<(usize, usize)>> = // (out index, local id)
            vec![Vec::new(); self.clusters.len()];
        for (i, &e) in candidates.iter().enumerate() {
            let (ci, li) = self.lookup[e];
            if ci == u32::MAX {
                out[i] = 0.0;
            } else {
                groups[ci as usize].push((i, li as usize));
            }
        }
        let mut locals: Vec<usize> = Vec::new();
        let mut gains: Vec<f64> = Vec::new();
        for (ci, group) in groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            locals.clear();
            locals.extend(group.iter().map(|&(_, li)| li));
            gains.clear();
            gains.resize(locals.len(), 0.0);
            self.clusters[ci].1.marginal_gains_batch(&locals, &mut gains);
            for (&(i, _), &g) in group.iter().zip(gains.iter()) {
                out[i] = g;
            }
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        let (ci, li) = self.lookup[e];
        if ci == u32::MAX {
            return;
        }
        self.clusters[ci as usize].1.update_memoization(li as usize);
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "ClusteredFunction"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::{kmeans, partition};
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::kernel::{DenseKernel, Metric};
    use crate::linalg::Matrix;

    fn build(n: usize, k: usize, seed: u64) -> (ClusteredFunction, Matrix) {
        let data = synthetic::blobs(n, 2, k, 0.5, seed);
        let km = kmeans(&data, k, 30, 1);
        let parts = partition(&km.labels, k);
        let clusters: Vec<(Vec<usize>, Box<dyn SetFunction>)> = parts
            .into_iter()
            .filter(|ids| !ids.is_empty())
            .map(|ids| {
                let mut sub = Matrix::zeros(ids.len(), 2);
                for (li, &g) in ids.iter().enumerate() {
                    sub.row_mut(li).copy_from_slice(data.row(g));
                }
                let f: Box<dyn SetFunction> = Box::new(FacilityLocation::new(
                    DenseKernel::from_data(&sub, Metric::Euclidean),
                ));
                (ids, f)
            })
            .collect();
        (ClusteredFunction::new(clusters, n).unwrap(), data)
    }

    #[test]
    fn sums_inner_functions() {
        let (f, _) = build(20, 2, 1);
        let s = Subset::from_ids(20, &[0, 10, 19]);
        // evaluate is a sum of per-cluster FL evaluations by construction;
        // sanity: strictly positive, bounded by n
        let v = f.evaluate(&s);
        assert!(v > 0.0 && v <= 20.0);
    }

    #[test]
    fn memoized_matches_stateless() {
        let (mut f, _) = build(18, 3, 2);
        let mut s = Subset::empty(18);
        f.init_memoization(&s);
        for &add in &[0usize, 9, 17] {
            for e in 0..18 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6,
                    "e={e}"
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn from_data_internal_clustering() {
        let data = synthetic::blobs(24, 2, 3, 0.4, 9);
        let mut f = ClusteredFunction::from_data(&data, 3, 1, |sub| {
            Ok(Box::new(FacilityLocation::new(DenseKernel::from_data(
                sub,
                Metric::Euclidean,
            ))))
        })
        .unwrap();
        assert_eq!(f.n(), 24);
        // memoized == stateless over the auto-clustered instance
        let mut s = Subset::empty(24);
        f.init_memoization(&s);
        for &add in &[0usize, 12, 23] {
            for e in (0..24).step_by(5) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn fl_clustered_from_data_matches_manual() {
        let data = synthetic::blobs(20, 2, 2, 0.3, 10);
        let f = FacilityLocation::clustered_from_data(&data, 2, Metric::Euclidean, 1);
        assert_eq!(f.n(), 20);
        let s = Subset::from_ids(20, &[0, 10]);
        let v = f.evaluate(&s);
        assert!(v > 0.0 && v <= 20.0);
    }

    #[test]
    fn validation() {
        let data = synthetic::blobs(6, 2, 2, 1.0, 3);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        // inner n mismatch
        let bad: Vec<(Vec<usize>, Box<dyn SetFunction>)> =
            vec![(vec![0, 1], Box::new(FacilityLocation::new(k.clone())))];
        assert!(ClusteredFunction::new(bad, 6).is_err());
        // overlapping clusters
        let k2 = {
            let sub = Matrix::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]);
            DenseKernel::from_data(&sub, Metric::Euclidean)
        };
        let overlapping: Vec<(Vec<usize>, Box<dyn SetFunction>)> = vec![
            (vec![0, 1], Box::new(FacilityLocation::new(k2.clone()))),
            (vec![1, 2], Box::new(FacilityLocation::new(k2))),
        ];
        assert!(ClusteredFunction::new(overlapping, 6).is_err());
    }
}

//! Disparity Min-Sum (paper §2.2.1):
//!
//! ```text
//! f_DMinSum(X) = Σ_{i∈X} min_{j∈X, j≠i} d_ij
//! ```
//!
//! "a combination of the two forms of models" — each selected element
//! contributes its distance to its nearest selected neighbor. The paper
//! (citing Chakraborty et al. 2015) labels this variant submodular;
//! conventions: `f(∅) = f({x}) = 0`.
//!
//! Memoization: `min_d[j] = min_{i∈A, i≠j} d_ij` per element, plus the
//! current Σ; a gain is O(|A|) (each member's nearest-neighbor distance
//! can only shrink toward the candidate) and an update is O(n).

use std::sync::Arc;

use super::traits::{ElementId, SetFunction, Subset};
use crate::kernel::DenseKernel;

/// Disparity min-sum diversity function over a distance kernel.
#[derive(Clone)]
pub struct DisparityMinSum {
    dist: Arc<DenseKernel>,
    /// memoized: selected elements in insertion order
    selected: Vec<ElementId>,
    /// memoized: per selected element, distance to its nearest other
    /// selected element (parallel to `selected`; ∞ while alone)
    nn: Vec<f64>,
    /// memoized: min_{i∈A} d_ij for every ground element j
    min_d: Vec<f64>,
}

impl DisparityMinSum {
    pub fn new(dist: DenseKernel) -> Self {
        let n = dist.n();
        DisparityMinSum {
            dist: Arc::new(dist),
            selected: Vec::new(),
            nn: Vec::new(),
            min_d: vec![f64::INFINITY; n],
        }
    }
}

impl SetFunction for DisparityMinSum {
    fn n(&self) -> usize {
        self.dist.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let o = subset.order();
        if o.len() < 2 {
            return 0.0;
        }
        let mut total = 0f64;
        for &i in o {
            let mut best = f64::INFINITY;
            for &j in o {
                if j != i {
                    best = best.min(self.dist.get(i, j) as f64);
                }
            }
            total += best;
        }
        total
    }

    fn init_memoization(&mut self, subset: &Subset) {
        self.selected.clear();
        self.nn.clear();
        for v in &mut self.min_d {
            *v = f64::INFINITY;
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        match self.selected.len() {
            0 => 0.0,
            1 => 2.0 * self.dist.get(self.selected[0], e) as f64,
            _ => {
                // candidate's own contribution = min_d[e]; each member's
                // contribution may shrink from nn[k] to d(member, e)
                let mut delta = self.min_d[e];
                for (k, &m) in self.selected.iter().enumerate() {
                    let d = self.dist.get(m, e) as f64;
                    if d < self.nn[k] {
                        delta += d - self.nn[k];
                    }
                }
                delta
            }
        }
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        if self.selected.len() < 2 {
            for (o, &e) in out.iter_mut().zip(candidates) {
                *o = self.marginal_gain_memoized(e);
            }
            return;
        }
        // blocked across candidates: each member's distance row is read
        // once per 4 candidates. Per-candidate accumulation stays in
        // member order — bit-identical to the scalar path.
        let mut c = 0;
        while c + 4 <= candidates.len() {
            let es = [
                candidates[c],
                candidates[c + 1],
                candidates[c + 2],
                candidates[c + 3],
            ];
            let mut delta = [
                self.min_d[es[0]],
                self.min_d[es[1]],
                self.min_d[es[2]],
                self.min_d[es[3]],
            ];
            for (k, &m) in self.selected.iter().enumerate() {
                let row = self.dist.row(m);
                for t in 0..4 {
                    let d = row[es[t]] as f64;
                    if d < self.nn[k] {
                        delta[t] += d - self.nn[k];
                    }
                }
            }
            out[c..c + 4].copy_from_slice(&delta);
            c += 4;
        }
        for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
            *o = self.marginal_gain_memoized(e);
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        // update members' nearest-neighbor distances
        for (k, &m) in self.selected.iter().enumerate() {
            let d = self.dist.get(m, e) as f64;
            if d < self.nn[k] {
                self.nn[k] = d;
            }
        }
        // candidate's own nn = min_d[e] (∞ when first)
        self.selected.push(e);
        self.nn.push(self.min_d[e]);
        // refresh min_d for all ground elements
        let row = self.dist.row(e);
        for (j, v) in self.min_d.iter_mut().enumerate() {
            let d = row[j] as f64;
            if j != e && d < *v {
                *v = d;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "DisparityMinSum"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::linalg::Matrix;

    #[test]
    fn tiny_sets_zero() {
        let data = synthetic::blobs(6, 2, 2, 1.0, 1);
        let f = DisparityMinSum::new(DenseKernel::distances_from_data(&data));
        assert_eq!(f.evaluate(&Subset::empty(6)), 0.0);
        assert_eq!(f.evaluate(&Subset::from_ids(6, &[3])), 0.0);
    }

    #[test]
    fn pair_counts_both_directions() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[3.0, 4.0]]);
        let f = DisparityMinSum::new(DenseKernel::distances_from_data(&data));
        // both elements have nearest-neighbor distance 5 → total 10
        assert!((f.evaluate(&Subset::from_ids(2, &[0, 1])) - 10.0).abs() < 1e-5);
    }

    #[test]
    fn triple_by_hand() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[1.0, 0.0], &[10.0, 0.0]]);
        let f = DisparityMinSum::new(DenseKernel::distances_from_data(&data));
        // nn: 0→1 (1), 1→0 (1), 2→1 (9) ⇒ 11
        assert!((f.evaluate(&Subset::from_ids(3, &[0, 1, 2])) - 11.0).abs() < 1e-4);
    }

    #[test]
    fn memoized_matches_stateless() {
        let data = synthetic::blobs(14, 2, 3, 1.0, 2);
        let mut f = DisparityMinSum::new(DenseKernel::distances_from_data(&data));
        let mut s = Subset::empty(14);
        f.init_memoization(&s);
        for &add in &[5usize, 11, 0, 8] {
            for e in 0..14 {
                if s.contains(e) {
                    continue;
                }
                let fast = f.marginal_gain_memoized(e);
                let slow = f.marginal_gain(&s, e);
                assert!((fast - slow).abs() < 1e-5, "e={e}: {fast} vs {slow}");
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn init_mid_set_consistent() {
        let data = synthetic::blobs(10, 2, 2, 1.0, 3);
        let mut f = DisparityMinSum::new(DenseKernel::distances_from_data(&data));
        let s = Subset::from_ids(10, &[2, 7, 4]);
        f.init_memoization(&s);
        for e in [0usize, 9] {
            assert!((f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-5);
        }
    }

    #[test]
    fn prefers_spread_points() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[0.1, 0.0], &[5.0, 0.0], &[10.0, 0.0]]);
        let mut f = DisparityMinSum::new(DenseKernel::distances_from_data(&data));
        f.init_memoization(&Subset::empty(4));
        f.update_memoization(0);
        // second pick: the farthest point gains the most
        assert!(f.marginal_gain_memoized(3) > f.marginal_gain_memoized(1));
    }
}

//! Generic submodular information measures (paper §3): lift **any**
//! `SetFunction` defined over an *extended* ground set (V ∪ Q ∪ P) into
//!
//! * conditional gain       `f(A|P) = f(A∪P) − f(P)`            ([`cg::ConditionalGain`])
//! * mutual information     `I_f(A;Q) = f(A) + f(Q) − f(A∪Q)`   ([`mi::MutualInformation`])
//! * conditional MI         `I_f(A;Q|P) = f(A∪P) + f(Q∪P) − f(A∪Q∪P) − f(P)`
//!                                                              ([`cmi::ConditionalMutualInformation`])
//!
//! This is exactly how the paper says Submodlib builds LogDetMI, FLCG,
//! LogDetCG, FLCMI, LogDetCMI (§5.2.2–5.2.4: "first a <base> function is
//! instantiated with appropriate kernel and then a \<wrapper\> function is
//! instantiated using it"). The specialized closed forms in
//! `functions::{mi,cg,cmi}` are the fast paths; these wrappers are the
//! semantics of record the proptest suite checks them against.
//!
//! Convention: the base function's ground set is laid out as
//! `[0, n_v)` = V, then query ids, then private ids (any ids ≥ n_v work —
//! the wrappers only need them disjoint from V and each other).

pub mod cg;
pub mod cmi;
pub mod mi;

pub use cg::ConditionalGain;
pub use cmi::ConditionalMutualInformation;
pub use mi::MutualInformation;

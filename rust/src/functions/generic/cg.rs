//! Generic Conditional Gain: `f(A|P) = f(A ∪ P) − f(P)` (paper §3.1).
//!
//! Memoization: keep the base function's memoized state initialized with P
//! committed; every gain / update then happens "on top of" P, so
//! `marginal_gain_memoized` is exactly the base function's.

use crate::error::{Result, SubmodError};
use crate::functions::traits::{check_ids, ElementId, SetFunction, Subset};

/// `f(· | P)` over the selectable ground set `[0, n_v)`.
pub struct ConditionalGain {
    base: Box<dyn SetFunction>,
    private: Vec<ElementId>,
    n_v: usize,
    f_p: f64,
}

impl ConditionalGain {
    /// `base` is defined over the extended ground set; `private` are the
    /// (extended) ids of P; `n_v` is the selectable prefix size.
    pub fn new(
        base: Box<dyn SetFunction>,
        private: Vec<ElementId>,
        n_v: usize,
    ) -> Result<Self> {
        check_ids(base.n(), &private)?;
        if n_v > base.n() {
            return Err(SubmodError::Shape(format!(
                "n_v {} exceeds base ground set {}",
                n_v,
                base.n()
            )));
        }
        if private.iter().any(|&p| p < n_v) {
            return Err(SubmodError::InvalidParam(
                "private ids must lie outside the selectable prefix".into(),
            ));
        }
        let f_p = base.evaluate(&Subset::from_ids(base.n(), &private));
        Ok(ConditionalGain { base, private, n_v, f_p })
    }

    fn extended(&self, subset: &Subset) -> Subset {
        let mut s = Subset::empty(self.base.n());
        for &p in &self.private {
            s.insert(p);
        }
        for &e in subset.order() {
            s.insert(e);
        }
        s
    }
}

impl Clone for ConditionalGain {
    fn clone(&self) -> Self {
        ConditionalGain {
            base: self.base.clone_box(),
            private: self.private.clone(),
            n_v: self.n_v,
            f_p: self.f_p,
        }
    }
}

impl SetFunction for ConditionalGain {
    fn n(&self) -> usize {
        self.n_v
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        self.base.evaluate(&self.extended(subset)) - self.f_p
    }

    fn init_memoization(&mut self, subset: &Subset) {
        let ext = self.extended(subset);
        self.base.init_memoization(&ext);
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.base.marginal_gain_memoized(e)
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // gains "on top of P" are exactly the base's — forward the whole
        // batch so the base's vectorized override is reached
        self.base.marginal_gains_batch(candidates, out);
    }

    fn update_memoization(&mut self, e: ElementId) {
        self.base.update_memoization(e);
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "ConditionalGain"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::kernel::{DenseKernel, Metric};

    /// extended FL over 12 items: first 8 = V, last 4 = P
    fn setup() -> ConditionalGain {
        let data = synthetic::blobs(12, 2, 3, 1.0, 7);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        ConditionalGain::new(
            Box::new(FacilityLocation::new(k)),
            vec![8, 9, 10, 11],
            8,
        )
        .unwrap()
    }

    #[test]
    fn empty_is_zero() {
        let f = setup();
        assert!(f.evaluate(&Subset::empty(8)).abs() < 1e-9);
    }

    #[test]
    fn definition_holds() {
        let f = setup();
        let s = Subset::from_ids(8, &[1, 5]);
        // f(A|P) = f(A∪P) − f(P), recomputed by hand
        let base = f.base.clone_box();
        let a_p = Subset::from_ids(12, &[8, 9, 10, 11, 1, 5]);
        let p = Subset::from_ids(12, &[8, 9, 10, 11]);
        let expect = base.evaluate(&a_p) - base.evaluate(&p);
        assert!((f.evaluate(&s) - expect).abs() < 1e-9);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup();
        let mut s = Subset::empty(8);
        f.init_memoization(&s);
        for &add in &[2usize, 7] {
            for e in 0..8 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn private_overlap_with_v_rejected() {
        let data = synthetic::blobs(10, 2, 2, 1.0, 8);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        assert!(ConditionalGain::new(
            Box::new(FacilityLocation::new(k)),
            vec![3],
            8
        )
        .is_err());
    }

    #[test]
    fn cg_bounded_by_plain_gain() {
        // f(A|P) ≤ f(A) for monotone submodular f
        let f = setup();
        let plain = f.base.clone_box();
        let s = Subset::from_ids(8, &[0, 4, 6]);
        let plain_val = plain.evaluate(&Subset::from_ids(12, &[0, 4, 6]));
        assert!(f.evaluate(&s) <= plain_val + 1e-9);
    }
}

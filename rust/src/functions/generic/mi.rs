//! Generic Submodular Mutual Information:
//! `I_f(A;Q) = f(A) + f(Q) − f(A∪Q)` (paper §3.2).
//!
//! As a function of A this is `f(Q) + [f(A) − f(A∪Q)]`, so the marginal
//! gain of adding `a` is `f(a|A) − f(a|A∪Q)` — we maintain **two** copies
//! of the base memoization, one tracking A and one tracking A∪Q, and
//! subtract. Monotone for submodular f (gains ≥ 0 by submodularity since
//! A ⊆ A∪Q).

use crate::error::{Result, SubmodError};
use crate::functions::traits::{check_ids, ElementId, SetFunction, Subset};

/// `I_f(·; Q)` over the selectable ground set `[0, n_v)`.
pub struct MutualInformation {
    /// tracks A
    base_a: Box<dyn SetFunction>,
    /// tracks A ∪ Q
    base_aq: Box<dyn SetFunction>,
    query: Vec<ElementId>,
    n_v: usize,
    f_q: f64,
}

impl MutualInformation {
    /// `base` over the extended ground set; `query` = extended ids of Q.
    pub fn new(base: Box<dyn SetFunction>, query: Vec<ElementId>, n_v: usize) -> Result<Self> {
        check_ids(base.n(), &query)?;
        if n_v > base.n() {
            return Err(SubmodError::Shape(format!(
                "n_v {} exceeds base ground set {}",
                n_v,
                base.n()
            )));
        }
        if query.iter().any(|&q| q < n_v) {
            return Err(SubmodError::InvalidParam(
                "query ids must lie outside the selectable prefix".into(),
            ));
        }
        let f_q = base.evaluate(&Subset::from_ids(base.n(), &query));
        let base_aq = base.clone_box();
        Ok(MutualInformation { base_a: base, base_aq, query, n_v, f_q })
    }

    fn extend_with_q(&self, subset: &Subset) -> Subset {
        let mut s = Subset::empty(self.base_a.n());
        for &q in &self.query {
            s.insert(q);
        }
        for &e in subset.order() {
            s.insert(e);
        }
        s
    }

    fn lift(&self, subset: &Subset) -> Subset {
        let mut s = Subset::empty(self.base_a.n());
        for &e in subset.order() {
            s.insert(e);
        }
        s
    }
}

impl Clone for MutualInformation {
    fn clone(&self) -> Self {
        MutualInformation {
            base_a: self.base_a.clone_box(),
            base_aq: self.base_aq.clone_box(),
            query: self.query.clone(),
            n_v: self.n_v,
            f_q: self.f_q,
        }
    }
}

impl SetFunction for MutualInformation {
    fn n(&self) -> usize {
        self.n_v
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let a = self.lift(subset);
        let aq = self.extend_with_q(subset);
        self.base_a.evaluate(&a) + self.f_q - self.base_a.evaluate(&aq)
    }

    fn init_memoization(&mut self, subset: &Subset) {
        let a = self.lift(subset);
        let aq = self.extend_with_q(subset);
        self.base_a.init_memoization(&a);
        self.base_aq.init_memoization(&aq);
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.base_a.marginal_gain_memoized(e) - self.base_aq.marginal_gain_memoized(e)
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        // one batch against each tracked state, subtracted elementwise;
        // both bases honor the batch == scalar contract, so f(a|A) −
        // f(a|A∪Q) comes out bit-identical to the scalar path
        self.base_a.marginal_gains_batch(candidates, out);
        let mut aq = vec![0f64; candidates.len()];
        self.base_aq.marginal_gains_batch(candidates, &mut aq);
        for (o, g) in out.iter_mut().zip(&aq) {
            *o -= g;
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        self.base_a.update_memoization(e);
        self.base_aq.update_memoization(e);
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "MutualInformation"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::functions::log_determinant::LogDeterminant;
    use crate::kernel::{DenseKernel, Metric};

    /// extended FL over 12 items: first 9 = V, last 3 = Q
    fn setup() -> MutualInformation {
        let data = synthetic::blobs(12, 2, 3, 1.0, 9);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        MutualInformation::new(Box::new(FacilityLocation::new(k)), vec![9, 10, 11], 9)
            .unwrap()
    }

    #[test]
    fn empty_is_zero() {
        let f = setup();
        assert!(f.evaluate(&Subset::empty(9)).abs() < 1e-9);
    }

    #[test]
    fn definition_holds() {
        let f = setup();
        let s = Subset::from_ids(9, &[2, 6]);
        let base = f.base_a.clone_box();
        let a = Subset::from_ids(12, &[2, 6]);
        let q = Subset::from_ids(12, &[9, 10, 11]);
        let aq = Subset::from_ids(12, &[2, 6, 9, 10, 11]);
        let expect = base.evaluate(&a) + base.evaluate(&q) - base.evaluate(&aq);
        assert!((f.evaluate(&s) - expect).abs() < 1e-9);
    }

    #[test]
    fn mi_gains_nonnegative_for_submodular_base() {
        let f = setup();
        let s = Subset::from_ids(9, &[1]);
        for e in 0..9 {
            if !s.contains(e) {
                assert!(f.marginal_gain(&s, e) >= -1e-9);
            }
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup();
        let mut s = Subset::empty(9);
        f.init_memoization(&s);
        for &add in &[0usize, 8, 4] {
            for e in 0..9 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn works_with_logdet_base() {
        // LogDetMI is built exactly this way in Submodlib (§5.2.2)
        let data = synthetic::blobs(10, 2, 2, 1.0, 10);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 });
        let ld = LogDeterminant::with_regularization(k, 0.1).unwrap();
        let mut f = MutualInformation::new(Box::new(ld), vec![8, 9], 8).unwrap();
        let mut s = Subset::empty(8);
        f.init_memoization(&s);
        for &add in &[3usize, 6] {
            for e in 0..8 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-4,
                    "e={e}"
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn query_in_prefix_rejected() {
        let data = synthetic::blobs(10, 2, 2, 1.0, 11);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        assert!(
            MutualInformation::new(Box::new(FacilityLocation::new(k)), vec![2], 8).is_err()
        );
    }
}

//! Generic Conditional Mutual Information (paper §3.3):
//!
//! ```text
//! I_f(A;Q|P) = f(A∪P) + f(Q∪P) − f(A∪Q∪P) − f(P)
//! ```
//!
//! As a function of A the gain of adding `a` is
//! `f(a | A∪P) − f(a | A∪Q∪P)` — two memoized base copies, one seeded
//! with P and one with Q∪P. This mirrors the paper's own construction
//! (§5.2.4: CMI = MI over a CG-wrapped base).

use crate::error::{Result, SubmodError};
use crate::functions::traits::{check_ids, ElementId, SetFunction, Subset};

/// `I_f(·; Q | P)` over the selectable ground set `[0, n_v)`.
pub struct ConditionalMutualInformation {
    /// tracks A ∪ P
    base_ap: Box<dyn SetFunction>,
    /// tracks A ∪ Q ∪ P
    base_aqp: Box<dyn SetFunction>,
    query: Vec<ElementId>,
    private: Vec<ElementId>,
    n_v: usize,
    /// f(Q∪P) − f(P), the constant part
    offset: f64,
}

impl ConditionalMutualInformation {
    pub fn new(
        base: Box<dyn SetFunction>,
        query: Vec<ElementId>,
        private: Vec<ElementId>,
        n_v: usize,
    ) -> Result<Self> {
        check_ids(base.n(), &query)?;
        check_ids(base.n(), &private)?;
        if n_v > base.n() {
            return Err(SubmodError::Shape(format!(
                "n_v {} exceeds base ground set {}",
                n_v,
                base.n()
            )));
        }
        if query.iter().chain(private.iter()).any(|&x| x < n_v) {
            return Err(SubmodError::InvalidParam(
                "query/private ids must lie outside the selectable prefix".into(),
            ));
        }
        if query.iter().any(|q| private.contains(q)) {
            return Err(SubmodError::InvalidParam("query ∩ private must be empty".into()));
        }
        let p = Subset::from_ids(base.n(), &private);
        let qp = p.union_with(&query);
        let offset = base.evaluate(&qp) - base.evaluate(&p);
        let base_aqp = base.clone_box();
        Ok(ConditionalMutualInformation {
            base_ap: base,
            base_aqp,
            query,
            private,
            n_v,
            offset,
        })
    }

    fn seed(&self, subset: &Subset, with_q: bool) -> Subset {
        let mut s = Subset::empty(self.base_ap.n());
        for &p in &self.private {
            s.insert(p);
        }
        if with_q {
            for &q in &self.query {
                s.insert(q);
            }
        }
        for &e in subset.order() {
            s.insert(e);
        }
        s
    }
}

impl Clone for ConditionalMutualInformation {
    fn clone(&self) -> Self {
        ConditionalMutualInformation {
            base_ap: self.base_ap.clone_box(),
            base_aqp: self.base_aqp.clone_box(),
            query: self.query.clone(),
            private: self.private.clone(),
            n_v: self.n_v,
            offset: self.offset,
        }
    }
}

impl SetFunction for ConditionalMutualInformation {
    fn n(&self) -> usize {
        self.n_v
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        // I = f(A∪P) + f(Q∪P) − f(A∪Q∪P) − f(P)
        //   = f(A∪P) − f(A∪Q∪P) + offset
        let ap = self.seed(subset, false);
        let aqp = self.seed(subset, true);
        self.base_ap.evaluate(&ap) - self.base_ap.evaluate(&aqp) + self.offset
    }

    fn init_memoization(&mut self, subset: &Subset) {
        let ap = self.seed(subset, false);
        let aqp = self.seed(subset, true);
        self.base_ap.init_memoization(&ap);
        self.base_aqp.init_memoization(&aqp);
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.base_ap.marginal_gain_memoized(e) - self.base_aqp.marginal_gain_memoized(e)
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        // same shape as generic MI: one batch per tracked state,
        // subtracted elementwise — bit-identical to the scalar path by
        // the bases' batch == scalar contract
        self.base_ap.marginal_gains_batch(candidates, out);
        let mut aqp = vec![0f64; candidates.len()];
        self.base_aqp.marginal_gains_batch(candidates, &mut aqp);
        for (o, g) in out.iter_mut().zip(&aqp) {
            *o -= g;
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        self.base_ap.update_memoization(e);
        self.base_aqp.update_memoization(e);
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "ConditionalMutualInformation"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::kernel::{DenseKernel, Metric};

    /// extended FL over 14 items: V = 0..9, Q = {9,10}, P = {11,12,13}
    fn setup() -> ConditionalMutualInformation {
        let data = synthetic::blobs(14, 2, 3, 1.0, 12);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        ConditionalMutualInformation::new(
            Box::new(FacilityLocation::new(k)),
            vec![9, 10],
            vec![11, 12, 13],
            9,
        )
        .unwrap()
    }

    #[test]
    fn empty_is_zero() {
        let f = setup();
        assert!(f.evaluate(&Subset::empty(9)).abs() < 1e-9);
    }

    #[test]
    fn definition_holds() {
        let f = setup();
        let s = Subset::from_ids(9, &[0, 5]);
        let base = f.base_ap.clone_box();
        let e = |ids: &[usize]| base.evaluate(&Subset::from_ids(14, ids));
        let expect = e(&[0, 5, 11, 12, 13]) + e(&[9, 10, 11, 12, 13])
            - e(&[0, 5, 9, 10, 11, 12, 13])
            - e(&[11, 12, 13]);
        assert!((f.evaluate(&s) - expect).abs() < 1e-9);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup();
        let mut s = Subset::empty(9);
        f.init_memoization(&s);
        for &add in &[4usize, 8] {
            for e in 0..9 {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn overlapping_q_p_rejected() {
        let data = synthetic::blobs(12, 2, 2, 1.0, 13);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        assert!(ConditionalMutualInformation::new(
            Box::new(FacilityLocation::new(k)),
            vec![9, 10],
            vec![10, 11],
            9
        )
        .is_err());
    }

    #[test]
    fn reduces_to_mi_with_empty_private() {
        let data = synthetic::blobs(12, 2, 3, 1.0, 14);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        let cmi = ConditionalMutualInformation::new(
            Box::new(FacilityLocation::new(k.clone())),
            vec![9, 10, 11],
            vec![],
            9,
        )
        .unwrap();
        let mi = super::super::mi::MutualInformation::new(
            Box::new(FacilityLocation::new(k)),
            vec![9, 10, 11],
            9,
        )
        .unwrap();
        for ids in [vec![], vec![0], vec![2, 7], vec![1, 3, 8]] {
            let s = Subset::from_ids(9, &ids);
            assert!((cmi.evaluate(&s) - mi.evaluate(&s)).abs() < 1e-9, "{ids:?}");
        }
    }
}

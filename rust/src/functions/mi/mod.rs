//! Specialized Submodular Mutual Information instantiations (paper §3.4–
//! §3.7 and Table 1, column "MI"), used for query-focused / targeted
//! subset selection and summarization.
//!
//! | name | expression (Table 1) | module |
//! |------|----------------------|--------|
//! | FLVMI | Σ_{i∈V} min(max_{j∈A} S_ij, η max_{j∈Q} S_ij) | [`flvmi`] |
//! | FLQMI | Σ_{i∈Q} max_{j∈A} S_ij + η Σ_{i∈A} max_{j∈Q} S_ij | [`flqmi`] |
//! | GCMI  | 2λ Σ_{i∈A} Σ_{j∈Q} S_ij | [`gcmi`] |
//! | COM   | η Σ_{i∈A} ψ(Σ_{j∈Q} S_ij) + Σ_{j∈Q} ψ(Σ_{i∈A} S_ij) | [`com`] |
//! | LogDetMI | via generic MI over an η-scaled extended kernel | [`logdetmi`] |
//! | SCMI  | w(γ(A) ∩ γ(Q)) — Set Cover with filtered concepts | [`scmi()`](scmi::scmi) |
//! | PSCMI | PSC with query-restricted weights | [`pscmi()`](pscmi::pscmi) |

pub mod com;
pub mod flqmi;
pub mod flvmi;
pub mod gcmi;
pub mod logdetmi;
pub mod pscmi;
pub mod scmi;

pub use com::ConcaveOverModular;
pub use flqmi::Flqmi;
pub use flvmi::Flvmi;
pub use gcmi::Gcmi;
pub use logdetmi::LogDetMi;
pub use pscmi::pscmi;
pub use scmi::scmi;

//! SCMI — Set Cover Mutual Information (paper §5.2.2, Table 1):
//!
//! ```text
//! I(A;Q) = w(γ(A) ∩ γ(Q))
//! ```
//!
//! "essentially the same as Set Cover with [each element's] cover set
//! modified to contain only those concepts which are in the query set" —
//! implemented as exactly that reduction via
//! [`SetCover::with_concept_filter`].

use crate::error::Result;
use crate::functions::set_cover::SetCover;

/// Build SCMI from a base SetCover and the concept set covered by the
/// query, `gamma_q` (concept ids).
pub fn scmi(base: &SetCover, gamma_q: &[u32]) -> Result<SetCover> {
    let keep: std::collections::HashSet<u32> = gamma_q.iter().copied().collect();
    Ok(base.with_concept_filter(|u| keep.contains(&u)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::traits::{SetFunction, Subset};

    fn base() -> SetCover {
        SetCover::new(
            vec![vec![0, 1], vec![1, 2], vec![2, 3], vec![3]],
            vec![1.0, 2.0, 4.0, 8.0],
        )
        .unwrap()
    }

    #[test]
    fn only_query_concepts_count() {
        let f = scmi(&base(), &[1, 2]).unwrap();
        // A = {0, 3}: γ(A) = {0,1,3}; ∩ γ(Q)={1,2} → {1} → w=2
        let s = Subset::from_ids(4, &[0, 3]);
        assert_eq!(f.evaluate(&s), 2.0);
    }

    #[test]
    fn equals_definition_for_all_singletons() {
        let b = base();
        let gq = [0u32, 2];
        let f = scmi(&b, &gq).unwrap();
        for e in 0..4 {
            let s = Subset::from_ids(4, &[e]);
            // w(γ({e}) ∩ γ(Q)) by hand
            let concepts = b.concepts_of(&[e]).unwrap();
            let expect: f64 = concepts
                .iter()
                .filter(|u| gq.contains(u))
                .map(|&u| [1.0, 2.0, 4.0, 8.0][u as usize])
                .sum();
            assert_eq!(f.evaluate(&s), expect);
        }
    }

    #[test]
    fn empty_query_zeroes_function() {
        let f = scmi(&base(), &[]).unwrap();
        let s = Subset::from_ids(4, &[0, 1, 2, 3]);
        assert_eq!(f.evaluate(&s), 0.0);
    }
}

//! COM — Concave Over Modular mutual information (paper §3.6, Table 1):
//!
//! ```text
//! I(A;Q) = η Σ_{i∈A} ψ(Σ_{j∈Q} S_ij) + Σ_{j∈Q} ψ(Σ_{i∈A} S_ij)
//! ```
//!
//! ψ concave (log / sqrt / inverse, as in FeatureBased). The first term is
//! modular (precomputed); the second term's memoization (Table 4 row 4)
//! is the per-query accumulated sum `Σ_{i∈A} S_ij`.

use std::sync::Arc;

use crate::error::{Result, SubmodError};
use crate::functions::feature_based::ConcaveShape;
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::RectKernel;

/// COM mutual-information function. See module docs.
#[derive(Clone)]
pub struct ConcaveOverModular {
    /// Q × V kernel
    kernel: Arc<RectKernel>,
    /// η ψ(Σ_{j∈Q} S_ij) per ground element (modular term, precomputed)
    modular: Arc<Vec<f64>>,
    shape: ConcaveShape,
    eta: f64,
    /// memoized Σ_{i∈A} S_qi per query q
    qsum: Vec<f64>,
}

impl ConcaveOverModular {
    /// `kernel` rows are queries, cols are ground elements. Kernel values
    /// must be non-negative (similarities), as ψ's domain is [0, ∞).
    pub fn new(kernel: RectKernel, eta: f64, shape: ConcaveShape) -> Result<Self> {
        if eta < 0.0 {
            return Err(SubmodError::InvalidParam(format!("eta {eta} < 0")));
        }
        let nq = kernel.rows();
        let n = kernel.cols();
        for q in 0..nq {
            if kernel.row(q).iter().any(|&s| s < 0.0) {
                return Err(SubmodError::InvalidParam(
                    "COM requires non-negative similarities".into(),
                ));
            }
        }
        let modular: Vec<f64> = (0..n)
            .map(|i| {
                let s: f64 = (0..nq).map(|q| kernel.get(q, i) as f64).sum();
                eta * shape.apply(s)
            })
            .collect();
        Ok(ConcaveOverModular {
            kernel: Arc::new(kernel),
            modular: Arc::new(modular),
            shape,
            eta,
            qsum: vec![0.0; nq],
        })
    }

    pub fn eta(&self) -> f64 {
        self.eta
    }
}

impl SetFunction for ConcaveOverModular {
    fn n(&self) -> usize {
        self.kernel.cols()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        let first: f64 = subset.order().iter().map(|&i| self.modular[i]).sum();
        let second: f64 = (0..self.kernel.rows())
            .map(|q| {
                let s: f64 =
                    subset.order().iter().map(|&i| self.kernel.get(q, i) as f64).sum();
                self.shape.apply(s)
            })
            .sum();
        first + second
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.qsum {
            *v = 0.0;
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        let mut g = self.modular[e];
        for (q, &acc) in self.qsum.iter().enumerate() {
            let s = self.kernel.get(q, e) as f64;
            g += self.shape.apply(acc + s) - self.shape.apply(acc);
        }
        g
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        // Blocked across candidates: each query row streams once per 4
        // candidates and ψ(acc) — identical for every candidate of a row —
        // is computed once per row instead of once per (row, candidate).
        // Ascending-q accumulation per candidate matches the scalar path
        // bit-for-bit.
        let mut c = 0;
        while c + 4 <= candidates.len() {
            let es = [
                candidates[c],
                candidates[c + 1],
                candidates[c + 2],
                candidates[c + 3],
            ];
            let mut g = [
                self.modular[es[0]],
                self.modular[es[1]],
                self.modular[es[2]],
                self.modular[es[3]],
            ];
            for (q, &acc) in self.qsum.iter().enumerate() {
                let row = self.kernel.row(q);
                let base = self.shape.apply(acc);
                for t in 0..4 {
                    let s = row[es[t]] as f64;
                    g[t] += self.shape.apply(acc + s) - base;
                }
            }
            out[c..c + 4].copy_from_slice(&g);
            c += 4;
        }
        for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
            *o = self.marginal_gain_memoized(e);
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        for (q, acc) in self.qsum.iter_mut().enumerate() {
            *acc += self.kernel.get(q, e) as f64;
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "ConcaveOverModular"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(eta: f64, shape: ConcaveShape) -> ConcaveOverModular {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let k = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        ConcaveOverModular::new(k, eta, shape).unwrap()
    }

    #[test]
    fn empty_zero() {
        for shape in [ConcaveShape::Log, ConcaveShape::Sqrt, ConcaveShape::Inverse] {
            assert_eq!(setup(1.0, shape).evaluate(&Subset::empty(46)), 0.0);
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(0.6, ConcaveShape::Sqrt);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[4usize, 19, 33] {
            for e in (0..46).step_by(6) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-9
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn diminishing_returns() {
        let f = setup(0.0, ConcaveShape::Log);
        let a = Subset::empty(46);
        let b = Subset::from_ids(46, &[1, 2, 3]);
        for e in [0usize, 10, 30] {
            assert!(f.marginal_gain(&a, e) >= f.marginal_gain(&b, e) - 1e-12);
        }
    }

    #[test]
    fn negative_similarity_rejected() {
        use crate::linalg::Matrix;
        let m = Matrix::from_rows(&[&[0.5, -0.1]]);
        let k = RectKernel::from_matrix(m);
        assert!(ConcaveOverModular::new(k, 1.0, ConcaveShape::Log).is_err());
    }

    #[test]
    fn eta_scales_modular_term() {
        let f1 = setup(1.0, ConcaveShape::Log);
        let f2 = setup(2.0, ConcaveShape::Log);
        let s = Subset::from_ids(46, &[7]);
        let d1 = f1.evaluate(&s);
        let d2 = f2.evaluate(&s);
        // doubling η doubles the modular part only → d2 − d1 = modular(7)
        assert!((d2 - d1 - f1.modular[7]).abs() < 1e-9);
    }
}

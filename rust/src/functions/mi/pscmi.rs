//! PSCMI — Probabilistic Set Cover Mutual Information (paper §5.2.2,
//! Table 1):
//!
//! ```text
//! I(A;Q) = Σ_u w_u · P̄_u(A) · P̄_u(Q)
//! ```
//!
//! where P̄_u(X) = 1 − Π_{x∈X}(1 − p_xu). Reduction: PSC with weights
//! scaled by the query coverage probability `P̄_u(Q)` (generalizing the
//! paper's binary "zero the weights of concepts not in the query set").

use crate::error::Result;
use crate::functions::prob_set_cover::ProbabilisticSetCover;

/// Build PSCMI from a base PSC and the query items' probability rows
/// (`query_probs[j][u]` = probability query item j covers concept u).
pub fn pscmi(
    base: &ProbabilisticSetCover,
    query_probs: &[Vec<f32>],
) -> Result<ProbabilisticSetCover> {
    base.with_reweighted(|u| {
        1.0 - ProbabilisticSetCover::survival_product(query_probs, u)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::traits::{SetFunction, Subset};

    fn base() -> ProbabilisticSetCover {
        ProbabilisticSetCover::new(
            vec![vec![0.9, 0.2], vec![0.1, 0.8]],
            vec![1.0, 2.0],
        )
        .unwrap()
    }

    #[test]
    fn matches_table1_formula() {
        let qp = vec![vec![0.5f32, 0.0]];
        let f = pscmi(&base(), &qp).unwrap();
        // A = {0}: Σ_u w_u P̄_u(A) P̄_u(Q)
        // u=0: 1.0 · 0.9 · 0.5 ; u=1: 2.0 · 0.2 · 0.0
        let s = Subset::from_ids(2, &[0]);
        assert!((f.evaluate(&s) - 0.45).abs() < 1e-6);
    }

    #[test]
    fn binary_query_matches_paper_reduction() {
        // query covering concept 1 with p=1 (binary): weights of concepts
        // not in the query drop to zero
        let qp = vec![vec![0.0f32, 1.0]];
        let f = pscmi(&base(), &qp).unwrap();
        let s = Subset::from_ids(2, &[0, 1]);
        // only concept 1 counts: w=2, P̄_1(A) = 1 − (1−0.2)(1−0.8) = 0.84
        assert!((f.evaluate(&s) - 2.0 * 0.84).abs() < 1e-6);
    }

    #[test]
    fn empty_query_zeroes() {
        let f = pscmi(&base(), &[]).unwrap();
        let s = Subset::from_ids(2, &[0, 1]);
        assert!(f.evaluate(&s).abs() < 1e-12);
    }
}

//! FLQMI — Facility Location *Variant* Mutual Information (paper §3.5,
//! Table 1 "FL (v2)"):
//!
//! ```text
//! I(A;Q) = Σ_{i∈Q} max_{j∈A} S_ij + η Σ_{i∈A} max_{j∈Q} S_ij
//! ```
//!
//! Only needs a Q × V kernel, which makes it the cheapest targeted
//! selection objective in the suite. Unlike FLVMI it never saturates:
//! the second (modular) term keeps rewarding query-similar picks, with η
//! trading query coverage against query relevance (Fig 7/10 behaviour:
//! η = 0 picks one element per query then plateaus; large η turns it into
//! pure retrieval).
//!
//! Memoization (Table 4 row 2): `max_per_query[q] = max_{j∈A} S_qj`; the
//! modular term's per-element value is precomputed.
//!
//! ## Empty-set sentinel
//!
//! `max_{j∈A}` over the empty set is represented as `−∞`, not `0`: with
//! `0` a kernel whose similarities can be negative (e.g. dot-product
//! features) had `max_{j∈A} S_qj` silently clamped at zero, diverging
//! from the paper's I(A;Q) definition. The empty *set's* contribution is
//! still 0 (I(∅;Q) = 0); the sentinel only marks "no element yet", so
//! the first element's contribution is its true — possibly negative —
//! similarity. For the non-negative kernels of the paper's experiments
//! both conventions produce identical values.
//!
//! Caveat: on kernels with negative similarities the definition itself
//! (and hence this implementation — same for FLVMI/FLCMI/FLCG) is no
//! longer submodular: a row's first-element contribution can be negative
//! and *grow* toward zero as the set expands. LazyGreedy's stale-bound
//! pruning assumes diminishing gains, so on such kernels use NaiveGreedy
//! (see `optimizers::lazy`'s module docs).

use std::sync::Arc;

use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::RectKernel;

/// FLQMI. See module docs.
#[derive(Clone)]
pub struct Flqmi {
    /// Q × V kernel
    kernel: Arc<RectKernel>,
    /// η Σ-side modular values: eta * max_{q∈Q} S_qi per ground element i
    modular: Arc<Vec<f64>>,
    eta: f64,
    /// memoized max_{j∈A} S_qj per query q
    max_per_query: Vec<f32>,
}

impl Flqmi {
    /// `kernel` rows are queries, columns are ground elements;
    /// `eta ≥ 0` is the paper's queryDiversityEta.
    pub fn new(kernel: RectKernel, eta: f64) -> crate::error::Result<Self> {
        if eta < 0.0 {
            return Err(crate::error::SubmodError::InvalidParam(format!(
                "eta {eta} < 0"
            )));
        }
        let nq = kernel.rows();
        let n = kernel.cols();
        // max over the (nonempty) query set; −∞ fold base so negative
        // similarities survive. An empty query set contributes nothing.
        let modular: Vec<f64> = (0..n)
            .map(|i| {
                if nq == 0 {
                    return 0.0;
                }
                eta * (0..nq)
                    .map(|q| kernel.get(q, i))
                    .fold(f32::NEG_INFINITY, f32::max) as f64
            })
            .collect();
        Ok(Flqmi {
            kernel: Arc::new(kernel),
            modular: Arc::new(modular),
            eta,
            max_per_query: vec![f32::NEG_INFINITY; nq],
        })
    }

    pub fn eta(&self) -> f64 {
        self.eta
    }
}

impl SetFunction for Flqmi {
    fn n(&self) -> usize {
        self.kernel.cols()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        if subset.is_empty() {
            return 0.0; // I(∅;Q) = 0, not Σ_q (empty max)
        }
        let nq = self.kernel.rows();
        let mut total = 0f64;
        for q in 0..nq {
            total += subset
                .order()
                .iter()
                .map(|&j| self.kernel.get(q, j))
                .fold(f32::NEG_INFINITY, f32::max) as f64;
        }
        total + subset.order().iter().map(|&i| self.modular[i]).sum::<f64>()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.max_per_query {
            *v = f32::NEG_INFINITY; // empty-set sentinel (module docs)
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        let mut g = self.modular[e];
        for (q, &mv) in self.max_per_query.iter().enumerate() {
            let s = self.kernel.get(q, e);
            if mv == f32::NEG_INFINITY {
                // first element: the query row's term goes 0 → s
                g += s as f64;
            } else if s > mv {
                g += (s - mv) as f64;
            }
        }
        g
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        // Blocked across candidates: each query row is streamed once per
        // 4 candidates instead of strided down 4 full columns. Ascending-q
        // accumulation per candidate matches the scalar path bit-for-bit.
        let mut c = 0;
        while c + 4 <= candidates.len() {
            let es = [
                candidates[c],
                candidates[c + 1],
                candidates[c + 2],
                candidates[c + 3],
            ];
            let mut g = [
                self.modular[es[0]],
                self.modular[es[1]],
                self.modular[es[2]],
                self.modular[es[3]],
            ];
            for (q, &mv) in self.max_per_query.iter().enumerate() {
                let row = self.kernel.row(q);
                for t in 0..4 {
                    let s = row[es[t]];
                    if mv == f32::NEG_INFINITY {
                        g[t] += s as f64;
                    } else if s > mv {
                        g[t] += (s - mv) as f64;
                    }
                }
            }
            out[c..c + 4].copy_from_slice(&g);
            c += 4;
        }
        for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
            *o = self.marginal_gain_memoized(e);
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        for (q, mv) in self.max_per_query.iter_mut().enumerate() {
            let s = self.kernel.get(q, e);
            if s > *mv {
                *mv = s;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "FLQMI"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(eta: f64) -> Flqmi {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let k = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        Flqmi::new(k, eta).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(setup(1.0).evaluate(&Subset::empty(46)), 0.0);
    }

    #[test]
    fn negative_eta_rejected() {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let k = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        assert!(Flqmi::new(k, -0.5).is_err());
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(0.8);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[0usize, 20, 44] {
            for e in (0..46).step_by(5) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn eta_zero_saturates_after_one_per_query() {
        // paper Fig 7: at η=0, one query-relevant pick per query, then all
        // remaining gains are (near) zero
        let mut f = setup(0.0);
        f.init_memoization(&Subset::empty(46));
        // greedily take 2 elements (= number of queries)
        for _ in 0..2 {
            let best = (0..46)
                .max_by(|&a, &b| {
                    f.marginal_gain_memoized(a).total_cmp(&f.marginal_gain_memoized(b))
                })
                .unwrap();
            f.update_memoization(best);
        }
        let residual = (0..46)
            .map(|e| f.marginal_gain_memoized(e))
            .fold(f64::MIN, f64::max);
        assert!(residual < 0.05, "not saturated: residual max gain {residual}");
    }

    #[test]
    fn negative_similarities_follow_definition() {
        use crate::linalg::Matrix;
        // dot-product kernel with all-negative similarities: the paper's
        // I(A;Q) is negative here; the old 0-initialized maxima clamped
        // every term at zero.
        let q = Matrix::from_rows(&[&[1.0f32]]);
        let ground = Matrix::from_rows(&[&[-2.0f32], &[-1.0]]);
        let k = RectKernel::from_data(&q, &ground, Metric::Dot).unwrap();
        let f = Flqmi::new(k, 0.5).unwrap();
        assert_eq!(f.evaluate(&Subset::empty(2)), 0.0);
        // A = {1}: max term = −1, modular term = η·max_q S_q1 = 0.5·(−1)
        let s1 = Subset::from_ids(2, &[1]);
        assert!((f.evaluate(&s1) - (-1.0 + 0.5 * -1.0)).abs() < 1e-6);
        // memoized path agrees, including the first (negative) pick
        let mut m = f.clone();
        m.init_memoization(&Subset::empty(2));
        for e in 0..2 {
            let fast = m.marginal_gain_memoized(e);
            let slow = m.marginal_gain(&Subset::empty(2), e);
            assert!((fast - slow).abs() < 1e-9, "e={e}: {fast} vs {slow}");
        }
        m.update_memoization(1);
        let fast = m.marginal_gain_memoized(0);
        let slow = f.marginal_gain(&s1, 0);
        assert!((fast - slow).abs() < 1e-9, "{fast} vs {slow}");
    }

    #[test]
    fn higher_eta_boosts_query_relevant_gains() {
        let f0 = setup(0.0);
        let f2 = setup(2.0);
        let s = Subset::empty(46);
        // element 0 is a cluster-0 center, near query 0
        assert!(f2.marginal_gain(&s, 0) > f0.marginal_gain(&s, 0));
    }

    #[test]
    fn matches_definition_by_hand() {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let k = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        let f = Flqmi::new(k.clone(), 0.7).unwrap();
        let ids = [3usize, 17, 40];
        let s = Subset::from_ids(46, &ids);
        let mut expect = 0f64;
        for q in 0..2 {
            expect += ids.iter().map(|&j| k.get(q, j)).fold(0f32, f32::max) as f64;
        }
        for &i in &ids {
            expect += 0.7 * (0..2).map(|q| k.get(q, i)).fold(0f32, f32::max) as f64;
        }
        assert!((f.evaluate(&s) - expect).abs() < 1e-6);
    }
}

//! FLVMI — Facility Location Mutual Information over V (paper §3.5,
//! Table 1 "FL (v1)"):
//!
//! ```text
//! I(A;Q) = Σ_{i∈V} min(max_{j∈A} S_ij, η max_{j∈Q} S_ij)
//! ```
//!
//! Saturating behaviour: once the query influence is matched
//! (max_{j∈A} ≥ η max_{j∈Q}) a ground row contributes nothing more — the
//! qualitative contrast with FLQMI in the paper's Fig 7 discussion.
//!
//! Memoization (Table 4 row 1): `max_vec[i] = max_{j∈A} S_ij`; the query
//! side `η max_{j∈Q} S_ij` is a precomputed constant vector.
//!
//! Empty maxima use the `−∞` sentinel (see `flqmi`'s module docs): with
//! the old `0` convention a kernel with negative similarities had both
//! `max_{j∈A}` and the precomputed query cap silently clamped at zero,
//! diverging from the Table 1 definition. I(∅;Q) is still 0; values on
//! non-negative kernels are unchanged.

use std::sync::Arc;

use crate::error::{Result, SubmodError};
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};

/// FLVMI. See module docs.
#[derive(Clone)]
pub struct Flvmi {
    /// V × V kernel
    ground: Arc<DenseKernel>,
    /// η · max_{j∈Q} S_ij per ground row i (precomputed)
    qcap: Arc<Vec<f32>>,
    eta: f64,
    /// memoized max_{j∈A} S_ij
    max_vec: Vec<f32>,
    /// Q = ∅ ⇒ I(·;∅) ≡ 0 — there is no cap value that expresses this
    /// through `min` for negative kernels, so it is a dedicated flag
    no_queries: bool,
}

impl Flvmi {
    /// `ground` is the V×V kernel; `queries` is the Q×V kernel;
    /// `eta ≥ 0` (paper's magnificationEta).
    pub fn new(ground: DenseKernel, queries: RectKernel, eta: f64) -> Result<Self> {
        if eta < 0.0 {
            return Err(SubmodError::InvalidParam(format!("eta {eta} < 0")));
        }
        if queries.cols() != ground.n() {
            return Err(SubmodError::Shape(format!(
                "query kernel cols {} vs ground n {}",
                queries.cols(),
                ground.n()
            )));
        }
        let n = ground.n();
        let nq = queries.rows();
        let qcap: Vec<f32> = (0..n)
            .map(|i| {
                if nq == 0 {
                    return 0.0; // unused: `no_queries` short-circuits everything
                }
                eta as f32
                    * (0..nq)
                        .map(|q| queries.get(q, i))
                        .fold(f32::NEG_INFINITY, f32::max)
            })
            .collect();
        Ok(Flvmi {
            ground: Arc::new(ground),
            qcap: Arc::new(qcap),
            eta,
            max_vec: vec![f32::NEG_INFINITY; n],
            no_queries: nq == 0,
        })
    }

    pub fn eta(&self) -> f64 {
        self.eta
    }
}

impl SetFunction for Flvmi {
    fn n(&self) -> usize {
        self.ground.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        if self.no_queries || subset.is_empty() {
            return 0.0; // I(∅;Q) = I(A;∅) = 0
        }
        (0..self.ground.n())
            .map(|i| {
                let ma = subset
                    .order()
                    .iter()
                    .map(|&j| self.ground.get(i, j))
                    .fold(f32::NEG_INFINITY, f32::max);
                ma.min(self.qcap[i]) as f64
            })
            .sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.max_vec {
            *v = f32::NEG_INFINITY; // empty-set sentinel (module docs)
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        if self.no_queries {
            return 0.0;
        }
        // symmetric kernel: row e read contiguously (s_ie == s_ei)
        let row = self.ground.row(e);
        let mut g = 0f64;
        for i in 0..row.len() {
            let mv = self.max_vec[i];
            let cap = self.qcap[i];
            let s = row[i];
            // empty set contributes 0, not min(−∞, cap)
            let before = if mv == f32::NEG_INFINITY { 0.0 } else { mv.min(cap) };
            let after = mv.max(s).min(cap);
            g += (after - before) as f64;
        }
        g
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        if self.no_queries {
            out.fill(0.0);
            return;
        }
        // Blocked across candidates: max_vec / qcap stream once per 4
        // contiguous kernel rows (same shape as FL dense). Ascending-i
        // accumulation per candidate matches the scalar path bit-for-bit.
        let mut c = 0;
        while c + 4 <= candidates.len() {
            let rows = [
                self.ground.row(candidates[c]),
                self.ground.row(candidates[c + 1]),
                self.ground.row(candidates[c + 2]),
                self.ground.row(candidates[c + 3]),
            ];
            let mut g = [0f64; 4];
            for i in 0..self.max_vec.len() {
                let mv = self.max_vec[i];
                let cap = self.qcap[i];
                let before = if mv == f32::NEG_INFINITY { 0.0 } else { mv.min(cap) };
                for t in 0..4 {
                    let s = rows[t][i];
                    let after = mv.max(s).min(cap);
                    g[t] += (after - before) as f64;
                }
            }
            out[c..c + 4].copy_from_slice(&g);
            c += 4;
        }
        for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
            *o = self.marginal_gain_memoized(e);
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        let row = self.ground.row(e);
        for (mv, &s) in self.max_vec.iter_mut().zip(row) {
            if s > *mv {
                *mv = s;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "FLVMI"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(eta: f64) -> Flvmi {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let q = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        Flvmi::new(g, q, eta).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(setup(1.0).evaluate(&Subset::empty(46)), 0.0);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(1.0);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[5usize, 30, 43] {
            for e in (0..46).step_by(7) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-5
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn value_capped_by_eta_query_term() {
        // f(A) ≤ Σ_i η max_q S_iq for any A
        let f = setup(0.5);
        let cap: f64 = f.qcap.iter().map(|&c| c as f64).sum();
        let all = Subset::from_ids(46, &(0..46).collect::<Vec<_>>());
        assert!(f.evaluate(&all) <= cap + 1e-6);
    }

    #[test]
    fn eta_zero_is_identically_zero() {
        let f = setup(0.0);
        let s = Subset::from_ids(46, &[0, 10, 20]);
        assert!(f.evaluate(&s).abs() < 1e-9);
    }

    #[test]
    fn negative_similarities_follow_definition() {
        use crate::linalg::Matrix;
        // dot-product features with negative cross-similarities: Table 1's
        // Σ_i min(max_{j∈A} S_ij, η max_{j∈Q} S_ij) goes negative; the old
        // 0-initialized maxima clamped both sides at zero.
        let ground = Matrix::from_rows(&[&[1.0f32], &[-1.0]]);
        let queries = Matrix::from_rows(&[&[-2.0f32]]);
        let gk = DenseKernel::from_data(&ground, Metric::Dot);
        let qk = RectKernel::from_data(&queries, &ground, Metric::Dot).unwrap();
        let f = Flvmi::new(gk, qk, 1.0).unwrap();
        assert_eq!(f.evaluate(&Subset::empty(2)), 0.0);
        // qcap = [−2, 2]; A = {0}: Σ_i min(S_i0, qcap_i)
        //   i=0: min(1, −2) = −2 ; i=1: min(−1, 2) = −1  → −3
        let s0 = Subset::from_ids(2, &[0]);
        assert!((f.evaluate(&s0) - (-3.0)).abs() < 1e-6, "{}", f.evaluate(&s0));
        // memoized first-pick gain must agree with the stateless delta
        let mut m = f.clone();
        m.init_memoization(&Subset::empty(2));
        for e in 0..2 {
            let fast = m.marginal_gain_memoized(e);
            let slow = m.marginal_gain(&Subset::empty(2), e);
            assert!((fast - slow).abs() < 1e-9, "e={e}: {fast} vs {slow}");
        }
    }

    #[test]
    fn empty_query_set_is_identically_zero() {
        use crate::linalg::Matrix;
        // I(A;∅) = 0 for every A — including on negative-similarity
        // kernels, where no finite qcap value could express this via min
        let ground = Matrix::from_rows(&[&[1.0f32], &[-1.0]]);
        let gk = DenseKernel::from_data(&ground, Metric::Dot);
        let qk = RectKernel::from_matrix(Matrix::zeros(0, 2));
        let mut f = Flvmi::new(gk, qk, 1.0).unwrap();
        assert_eq!(f.evaluate(&Subset::from_ids(2, &[0, 1])), 0.0);
        f.init_memoization(&Subset::empty(2));
        assert_eq!(f.marginal_gain_memoized(1), 0.0);
        let mut out = vec![1.0f64; 2];
        f.marginal_gains_batch(&[0, 1], &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn monotone_gains_nonnegative() {
        let mut f = setup(1.0);
        f.init_memoization(&Subset::empty(46));
        f.update_memoization(3);
        for e in (0..46).step_by(5) {
            assert!(f.marginal_gain_memoized(e) >= -1e-9);
        }
    }

    #[test]
    fn matches_generic_mi_on_extended_kernel() {
        // FLVMI(A;Q) with η=1 must equal generic MI over FL on V∪Q with
        // the concatenated kernel (paper: FLVMI *is* FL's MI; [25])
        use crate::functions::facility_location::FacilityLocation;
        use crate::functions::generic::MutualInformation;
        use crate::linalg::Matrix;

        let (ground, queries, _, _) = controlled::fig6_dataset();
        let n = ground.rows();
        let nq = queries.rows();
        // stacked data → extended kernel
        let mut all = Matrix::zeros(n + nq, 2);
        for i in 0..n {
            all.row_mut(i).copy_from_slice(ground.row(i));
        }
        for q in 0..nq {
            all.row_mut(n + q).copy_from_slice(queries.row(q));
        }
        let ext = DenseKernel::from_data(&all, Metric::Euclidean);
        // generic MI over FL restricted to represented set V:
        // FL's represented set must stay V for the identity to hold
        let rect = crate::kernel::RectKernel::from_matrix({
            let mut m = Matrix::zeros(n, n + nq);
            for i in 0..n {
                for j in 0..n + nq {
                    m.set(i, j, ext.get(i, j));
                }
            }
            m
        });
        let base = FacilityLocation::with_represented(rect);
        let gen = MutualInformation::new(
            Box::new(base),
            (n..n + nq).collect(),
            n,
        )
        .unwrap();
        let fast = setup(1.0);
        for ids in [vec![], vec![0usize], vec![3, 17], vec![1, 20, 40]] {
            let s = Subset::from_ids(n, &ids);
            let a = gen.evaluate(&s);
            let b = fast.evaluate(&s);
            assert!((a - b).abs() < 1e-5, "{ids:?}: generic {a} vs fast {b}");
        }
    }
}

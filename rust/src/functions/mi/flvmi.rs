//! FLVMI — Facility Location Mutual Information over V (paper §3.5,
//! Table 1 "FL (v1)"):
//!
//! ```text
//! I(A;Q) = Σ_{i∈V} min(max_{j∈A} S_ij, η max_{j∈Q} S_ij)
//! ```
//!
//! Saturating behaviour: once the query influence is matched
//! (max_{j∈A} ≥ η max_{j∈Q}) a ground row contributes nothing more — the
//! qualitative contrast with FLQMI in the paper's Fig 7 discussion.
//!
//! Memoization (Table 4 row 1): `max_vec[i] = max_{j∈A} S_ij`; the query
//! side `η max_{j∈Q} S_ij` is a precomputed constant vector.

use std::sync::Arc;

use crate::error::{Result, SubmodError};
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};

/// FLVMI. See module docs.
#[derive(Clone)]
pub struct Flvmi {
    /// V × V kernel
    ground: Arc<DenseKernel>,
    /// η · max_{j∈Q} S_ij per ground row i (precomputed)
    qcap: Arc<Vec<f32>>,
    eta: f64,
    /// memoized max_{j∈A} S_ij
    max_vec: Vec<f32>,
}

impl Flvmi {
    /// `ground` is the V×V kernel; `queries` is the Q×V kernel;
    /// `eta ≥ 0` (paper's magnificationEta).
    pub fn new(ground: DenseKernel, queries: RectKernel, eta: f64) -> Result<Self> {
        if eta < 0.0 {
            return Err(SubmodError::InvalidParam(format!("eta {eta} < 0")));
        }
        if queries.cols() != ground.n() {
            return Err(SubmodError::Shape(format!(
                "query kernel cols {} vs ground n {}",
                queries.cols(),
                ground.n()
            )));
        }
        let n = ground.n();
        let nq = queries.rows();
        let qcap: Vec<f32> = (0..n)
            .map(|i| {
                eta as f32 * (0..nq).map(|q| queries.get(q, i)).fold(0f32, f32::max)
            })
            .collect();
        Ok(Flvmi {
            ground: Arc::new(ground),
            qcap: Arc::new(qcap),
            eta,
            max_vec: vec![0.0; n],
        })
    }

    pub fn eta(&self) -> f64 {
        self.eta
    }
}

impl SetFunction for Flvmi {
    fn n(&self) -> usize {
        self.ground.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        (0..self.ground.n())
            .map(|i| {
                let ma = subset
                    .order()
                    .iter()
                    .map(|&j| self.ground.get(i, j))
                    .fold(0f32, f32::max);
                ma.min(self.qcap[i]) as f64
            })
            .sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.max_vec {
            *v = 0.0;
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        // symmetric kernel: row e read contiguously (s_ie == s_ei)
        let row = self.ground.row(e);
        let mut g = 0f64;
        for i in 0..row.len() {
            let mv = self.max_vec[i];
            let cap = self.qcap[i];
            let s = row[i];
            let before = mv.min(cap);
            let after = mv.max(s).min(cap);
            g += (after - before) as f64;
        }
        g
    }

    fn update_memoization(&mut self, e: ElementId) {
        let row = self.ground.row(e);
        for (mv, &s) in self.max_vec.iter_mut().zip(row) {
            if s > *mv {
                *mv = s;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "FLVMI"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(eta: f64) -> Flvmi {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let q = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        Flvmi::new(g, q, eta).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(setup(1.0).evaluate(&Subset::empty(46)), 0.0);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(1.0);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[5usize, 30, 43] {
            for e in (0..46).step_by(7) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-5
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn value_capped_by_eta_query_term() {
        // f(A) ≤ Σ_i η max_q S_iq for any A
        let f = setup(0.5);
        let cap: f64 = f.qcap.iter().map(|&c| c as f64).sum();
        let all = Subset::from_ids(46, &(0..46).collect::<Vec<_>>());
        assert!(f.evaluate(&all) <= cap + 1e-6);
    }

    #[test]
    fn eta_zero_is_identically_zero() {
        let f = setup(0.0);
        let s = Subset::from_ids(46, &[0, 10, 20]);
        assert!(f.evaluate(&s).abs() < 1e-9);
    }

    #[test]
    fn monotone_gains_nonnegative() {
        let mut f = setup(1.0);
        f.init_memoization(&Subset::empty(46));
        f.update_memoization(3);
        for e in (0..46).step_by(5) {
            assert!(f.marginal_gain_memoized(e) >= -1e-9);
        }
    }

    #[test]
    fn matches_generic_mi_on_extended_kernel() {
        // FLVMI(A;Q) with η=1 must equal generic MI over FL on V∪Q with
        // the concatenated kernel (paper: FLVMI *is* FL's MI; [25])
        use crate::functions::facility_location::FacilityLocation;
        use crate::functions::generic::MutualInformation;
        use crate::linalg::Matrix;

        let (ground, queries, _, _) = controlled::fig6_dataset();
        let n = ground.rows();
        let nq = queries.rows();
        // stacked data → extended kernel
        let mut all = Matrix::zeros(n + nq, 2);
        for i in 0..n {
            all.row_mut(i).copy_from_slice(ground.row(i));
        }
        for q in 0..nq {
            all.row_mut(n + q).copy_from_slice(queries.row(q));
        }
        let ext = DenseKernel::from_data(&all, Metric::Euclidean);
        // generic MI over FL restricted to represented set V:
        // FL's represented set must stay V for the identity to hold
        let rect = crate::kernel::RectKernel::from_matrix({
            let mut m = Matrix::zeros(n, n + nq);
            for i in 0..n {
                for j in 0..n + nq {
                    m.set(i, j, ext.get(i, j));
                }
            }
            m
        });
        let base = FacilityLocation::with_represented(rect);
        let gen = MutualInformation::new(
            Box::new(base),
            (n..n + nq).collect(),
            n,
        )
        .unwrap();
        let fast = setup(1.0);
        for ids in [vec![], vec![0usize], vec![3, 17], vec![1, 20, 40]] {
            let s = Subset::from_ids(n, &ids);
            let a = gen.evaluate(&s);
            let b = fast.evaluate(&s);
            assert!((a - b).abs() < 1e-5, "{ids:?}: generic {a} vs fast {b}");
        }
    }
}

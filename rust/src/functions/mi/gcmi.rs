//! GCMI — Graph Cut Mutual Information (paper §3.7, Table 1 row GC):
//!
//! ```text
//! I(A;Q) = 2λ Σ_{i∈A} Σ_{j∈Q} S_ij
//! ```
//!
//! A purely *modular* retrieval objective: maximizing it picks the
//! elements most similar to the query set with no diversity pressure
//! (Fig 8 behaviour). Memoization (Table 4 row 3) is the running sum —
//! each per-element query affinity is precomputed once.

use std::sync::Arc;

use crate::error::{Result, SubmodError};
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::RectKernel;

/// GCMI. See module docs.
#[derive(Clone)]
pub struct Gcmi {
    /// 2λ Σ_{j∈Q} S_ij per ground element i
    affinity: Arc<Vec<f64>>,
    lambda: f64,
    /// memoized running Σ over A (only needed for evaluate-of-state)
    total: f64,
}

impl Gcmi {
    /// `kernel` rows are queries, columns are ground elements.
    pub fn new(kernel: RectKernel, lambda: f64) -> Result<Self> {
        if lambda <= 0.0 {
            return Err(SubmodError::InvalidParam(format!("lambda {lambda} must be > 0")));
        }
        let n = kernel.cols();
        let nq = kernel.rows();
        let affinity: Vec<f64> = (0..n)
            .map(|i| 2.0 * lambda * (0..nq).map(|q| kernel.get(q, i) as f64).sum::<f64>())
            .collect();
        Ok(Gcmi { affinity: Arc::new(affinity), lambda, total: 0.0 })
    }

    pub fn lambda(&self) -> f64 {
        self.lambda
    }
}

impl SetFunction for Gcmi {
    fn n(&self) -> usize {
        self.affinity.len()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        subset.order().iter().map(|&i| self.affinity[i]).sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        self.total = self.evaluate(subset);
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.affinity[e]
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // purely modular: the gain is a precomputed table read, so the
        // batch win is just skipping a dyn dispatch per candidate
        debug_assert_eq!(candidates.len(), out.len());
        for (o, &e) in out.iter_mut().zip(candidates) {
            *o = self.affinity[e];
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        self.total += self.affinity[e];
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "GCMI"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup() -> Gcmi {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let k = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        Gcmi::new(k, 0.5).unwrap()
    }

    #[test]
    fn modular_additivity() {
        let f = setup();
        let a = Subset::from_ids(46, &[1]);
        let b = Subset::from_ids(46, &[2]);
        let ab = Subset::from_ids(46, &[1, 2]);
        assert!((f.evaluate(&ab) - f.evaluate(&a) - f.evaluate(&b)).abs() < 1e-9);
    }

    #[test]
    fn gain_is_independent_of_set() {
        let f = setup();
        let empty = Subset::empty(46);
        let big = Subset::from_ids(46, &[0, 10, 20, 30]);
        for e in [5usize, 15, 40] {
            assert!((f.marginal_gain(&empty, e) - f.marginal_gain(&big, e)).abs() < 1e-9);
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup();
        f.init_memoization(&Subset::empty(46));
        for e in (0..46).step_by(9) {
            assert!(
                (f.marginal_gain_memoized(e) - f.marginal_gain(&Subset::empty(46), e)).abs()
                    < 1e-9
            );
        }
    }

    #[test]
    fn prefers_query_adjacent_elements() {
        // element 0 (cluster-0 center, near query 0) must beat an outlier
        let f = setup();
        let s = Subset::empty(46);
        assert!(f.marginal_gain(&s, 0) > f.marginal_gain(&s, 42));
    }

    #[test]
    fn invalid_lambda_rejected() {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let k = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        assert!(Gcmi::new(k, 0.0).is_err());
    }
}

//! LogDetMI — Log Determinant Mutual Information (paper §3.4, §5.2.2).
//!
//! Built exactly the way the paper describes: "first a Log Determinant
//! function is instantiated with appropriate kernel and then a Mutual
//! Information function is instantiated using it". The "appropriate
//! kernel" is the extended (V∪Q) kernel with the V↔Q cross-similarities
//! scaled by η (paper §3.4), which realizes Table 1's closed form
//! `log det(S_A) − log det(S_A − η² S_AQ S_Q⁻¹ S_AQᵀ)` through the generic
//! identity I(A;Q) = f(A) + f(Q) − f(A∪Q).

use crate::error::Result;
use crate::functions::generic::MutualInformation;
use crate::functions::log_determinant::LogDeterminant;
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};
use crate::linalg::Matrix;

/// Build the extended (V∪X) kernel with cross-block scaled by `scale`.
/// Layout: indices [0, n) = V (ground kernel), [n, n+m) = X.
pub fn extended_kernel(
    ground: &DenseKernel,
    other: &DenseKernel,
    cross: &RectKernel, // X × V
    scale: f64,
) -> Result<DenseKernel> {
    let n = ground.n();
    let m = other.n();
    if cross.rows() != m || cross.cols() != n {
        return Err(crate::error::SubmodError::Shape(format!(
            "cross kernel {}x{} vs expected {}x{}",
            cross.rows(),
            cross.cols(),
            m,
            n
        )));
    }
    let mut ext = Matrix::zeros(n + m, n + m);
    for i in 0..n {
        for j in 0..n {
            ext.set(i, j, ground.get(i, j));
        }
    }
    for a in 0..m {
        for b in 0..m {
            ext.set(n + a, n + b, other.get(a, b));
        }
    }
    for a in 0..m {
        for j in 0..n {
            let v = (scale as f32) * cross.get(a, j);
            ext.set(n + a, j, v);
            ext.set(j, n + a, v);
        }
    }
    DenseKernel::from_matrix(ext)
}

/// LogDetMI as a `SetFunction` over V.
pub struct LogDetMi {
    inner: MutualInformation,
}

impl LogDetMi {
    /// `ground` V×V kernel, `queries` Q×Q kernel, `cross` Q×V kernel,
    /// η the query-relevance scale, `reg` the LogDet diagonal regularizer.
    pub fn new(
        ground: DenseKernel,
        queries: DenseKernel,
        cross: RectKernel,
        eta: f64,
        reg: f64,
    ) -> Result<Self> {
        let n = ground.n();
        let m = queries.n();
        let ext = extended_kernel(&ground, &queries, &cross, eta)?;
        let base = LogDeterminant::with_regularization(ext, reg)?;
        let inner =
            MutualInformation::new(Box::new(base), (n..n + m).collect::<Vec<_>>(), n)?;
        Ok(LogDetMi { inner })
    }
}

impl Clone for LogDetMi {
    fn clone(&self) -> Self {
        LogDetMi { inner: self.inner.clone() }
    }
}

impl SetFunction for LogDetMi {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        self.inner.evaluate(subset)
    }

    fn init_memoization(&mut self, subset: &Subset) {
        self.inner.init_memoization(subset);
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.inner.marginal_gain_memoized(e)
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // forwards to generic MI → two LogDeterminant blocked forward
        // substitutions over the shared incremental factors
        self.inner.marginal_gains_batch(candidates, out);
    }

    fn update_memoization(&mut self, e: ElementId) {
        self.inner.update_memoization(e);
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "LogDetMI"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;
    use crate::linalg::Cholesky;

    fn setup(eta: f64) -> LogDetMi {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let g = DenseKernel::from_data(&ground, Metric::Rbf { gamma: 0.5 });
        let q = DenseKernel::from_data(&queries, Metric::Rbf { gamma: 0.5 });
        let c = RectKernel::from_data(&queries, &ground, Metric::Rbf { gamma: 0.5 }).unwrap();
        LogDetMi::new(g, q, c, eta, 0.1).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert!(setup(1.0).evaluate(&Subset::empty(46)).abs() < 1e-9);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(0.8);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[2usize, 25] {
            for e in (0..46).step_by(11) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-4
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn matches_closed_form_singleton() {
        // Table 1: I({a};Q) = log det(S_a) − log det(S_a − η² S_aQ S_Q⁻¹ S_aQᵀ)
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let reg = 0.1f64;
        let g = DenseKernel::from_data(&ground, Metric::Rbf { gamma: 0.5 });
        let qk = DenseKernel::from_data(&queries, Metric::Rbf { gamma: 0.5 });
        let c = RectKernel::from_data(&queries, &ground, Metric::Rbf { gamma: 0.5 }).unwrap();
        let eta = 0.7f64;
        let f = LogDetMi::new(g.clone(), qk.clone(), c.clone(), eta, reg).unwrap();

        let a = 5usize;
        // S_a (with reg), S_Q (with reg), S_aQ (scaled by η)
        let s_a = g.get(a, a) as f64 + reg;
        let mut sq = qk.matrix().clone();
        for i in 0..sq.rows() {
            let v = sq.get(i, i) + reg as f32;
            sq.set(i, i, v);
        }
        let chol = Cholesky::factor(&sq).unwrap();
        let s_aq: Vec<f64> = (0..qk.n()).map(|q| eta * c.get(q, a) as f64).collect();
        let sol = chol.solve(&s_aq);
        let quad: f64 = s_aq.iter().zip(&sol).map(|(x, y)| x * y).sum();
        let expect = s_a.ln() - (s_a - quad).ln();

        let got = f.evaluate(&Subset::from_ids(46, &[a]));
        assert!((got - expect).abs() < 1e-4, "{got} vs {expect}");
    }

    #[test]
    fn eta_zero_decouples() {
        // η=0 → cross block zero → I(A;Q) = 0 for all A
        let f = setup(0.0);
        let s = Subset::from_ids(46, &[1, 9, 30]);
        assert!(f.evaluate(&s).abs() < 1e-6);
    }
}

//! SCCMI — Set Cover Conditional Mutual Information (paper §5.2.4,
//! Table 1):
//!
//! ```text
//! I(A;Q|P) = w(γ(A) ∩ γ(Q) \ γ(P))
//! ```
//!
//! Reduction: Set Cover keeping only concepts in the query's cover and
//! not in the private set's cover.

use crate::error::Result;
use crate::functions::set_cover::SetCover;

/// Build SCCMI from a base SetCover, γ(Q), and γ(P).
pub fn sccmi(base: &SetCover, gamma_q: &[u32], gamma_p: &[u32]) -> Result<SetCover> {
    let keep: std::collections::HashSet<u32> = gamma_q.iter().copied().collect();
    let drop: std::collections::HashSet<u32> = gamma_p.iter().copied().collect();
    Ok(base.with_concept_filter(|u| keep.contains(&u) && !drop.contains(&u)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::traits::{SetFunction, Subset};

    fn base() -> SetCover {
        SetCover::new(
            vec![vec![0, 1], vec![1, 2], vec![2, 3], vec![0, 3]],
            vec![1.0, 2.0, 4.0, 8.0],
        )
        .unwrap()
    }

    #[test]
    fn intersection_minus_private() {
        // γ(Q) = {1,2,3}, γ(P) = {3} → countable concepts {1,2}
        let f = sccmi(&base(), &[1, 2, 3], &[3]).unwrap();
        // A = {2,3}: γ(A) = {0,2,3} → kept: {2} → w=4
        assert_eq!(f.evaluate(&Subset::from_ids(4, &[2, 3])), 4.0);
    }

    #[test]
    fn consistency_with_scmi_and_sccg() {
        use crate::functions::cg::sccg;
        use crate::functions::mi::scmi;
        // SCCMI = SCMI of SCCG-filtered base = SCCG of SCMI-filtered base
        let b = base();
        let gq = [0u32, 2];
        let gp = [2u32, 3];
        let direct = sccmi(&b, &gq, &gp).unwrap();
        let via_cg = scmi(&sccg(&b, &gp).unwrap(), &gq).unwrap();
        for ids in [vec![0usize], vec![1, 3], vec![0, 1, 2, 3]] {
            let s = Subset::from_ids(4, &ids);
            assert_eq!(direct.evaluate(&s), via_cg.evaluate(&s), "{ids:?}");
        }
    }

    #[test]
    fn disjoint_query_private_full_query_kept() {
        let f = sccmi(&base(), &[0, 1], &[2, 3]).unwrap();
        // A = full: γ(A) = all → kept {0,1} → 3.0
        assert_eq!(f.evaluate(&Subset::from_ids(4, &[0, 1, 2, 3])), 3.0);
    }
}

//! Specialized Conditional Mutual Information instantiations (paper §3.3,
//! Table 1 column "CMI") — *joint* query-focused and privacy-preserving
//! selection: similar to Q, dissimilar from P, simultaneously.
//!
//! | name | expression (Table 1) | module |
//! |------|----------------------|--------|
//! | FLCMI | Σ_{i∈V} max(min(max_{j∈A} S_ij, η max_{j∈Q} S_ij) − ν max_{j∈P} S_ij, 0) | [`flcmi`] |
//! | LogDetCMI | via generic CMI over the extended kernel | [`logdetcmi`] |
//! | SCCMI | w(γ(A) ∩ γ(Q) \ γ(P)) | [`sccmi()`](sccmi::sccmi) |
//! | PSCCMI | Σ_u w_u P̄_u(A) P̄_u(Q) P_u(P) | [`psccmi()`](psccmi::psccmi) |
//!
//! (GCCMI equals GCMI — the paper notes the GC CMI "does not involve the
//! private set and is exactly the same as the MI version"; use
//! [`crate::functions::mi::Gcmi`].)

pub mod flcmi;
pub mod logdetcmi;
pub mod psccmi;
pub mod sccmi;

pub use flcmi::Flcmi;
pub use logdetcmi::LogDetCmi;
pub use psccmi::psccmi;
pub use sccmi::sccmi;

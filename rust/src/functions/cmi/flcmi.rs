//! FLCMI — Facility Location Conditional Mutual Information (Table 1
//! "FL (v1)" CMI):
//!
//! ```text
//! I(A;Q|P) = Σ_{i∈V} max( min(max_{j∈A} S_ij, η max_{j∈Q} S_ij)
//!                         − ν max_{j∈P} S_ij, 0 )
//! ```
//!
//! The FLVMI saturation capped from below by the private influence:
//! η magnifies query relevance, ν tightens privacy. Memoization is the
//! usual FL `max_vec` against two precomputed row caps.
//!
//! Empty maxima use the `−∞` sentinel (see `mi::flqmi`'s module docs) so
//! negative similarities are not clamped at zero; the outer `max(·, 0)`
//! of the definition maps the `−∞` row term to 0, so I(∅;Q|P) = 0 falls
//! out without a special case, and non-negative kernels are unchanged.

use std::sync::Arc;

use crate::error::{Result, SubmodError};
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};

/// FLCMI. See module docs.
#[derive(Clone)]
pub struct Flcmi {
    ground: Arc<DenseKernel>,
    /// η · max_{j∈Q} S_ij per row
    qcap: Arc<Vec<f32>>,
    /// ν · max_{j∈P} S_ij per row
    pcap: Arc<Vec<f32>>,
    eta: f64,
    nu: f64,
    max_vec: Vec<f32>,
}

impl Flcmi {
    /// `ground` V×V; `queries` Q×V; `privates` P×V; η, ν ≥ 0.
    pub fn new(
        ground: DenseKernel,
        queries: RectKernel,
        privates: RectKernel,
        eta: f64,
        nu: f64,
    ) -> Result<Self> {
        if eta < 0.0 || nu < 0.0 {
            return Err(SubmodError::InvalidParam(format!("eta {eta} / nu {nu} < 0")));
        }
        let n = ground.n();
        if queries.cols() != n || privates.cols() != n {
            return Err(SubmodError::Shape(
                "query/private kernel cols must equal ground n".into(),
            ));
        }
        // `empty` is the cap for a kernel with no rows. Q = ∅ ⇒ qcap −∞:
        // min(ma, −∞) feeds the outer max(·, 0) and zeroes every row —
        // I(A;∅|P) = 0 even on negative kernels (the sentinel is applied
        // unscaled; η·(−∞) would be NaN at η = 0). P = ∅ ⇒ pcap 0: no
        // private influence to subtract.
        let colmax = |k: &RectKernel, scale: f64, empty: f32| -> Vec<f32> {
            (0..n)
                .map(|i| {
                    if k.rows() == 0 {
                        return empty;
                    }
                    scale as f32
                        * (0..k.rows())
                            .map(|r| k.get(r, i))
                            .fold(f32::NEG_INFINITY, f32::max)
                })
                .collect()
        };
        Ok(Flcmi {
            qcap: Arc::new(colmax(&queries, eta, f32::NEG_INFINITY)),
            pcap: Arc::new(colmax(&privates, nu, 0.0)),
            ground: Arc::new(ground),
            eta,
            nu,
            max_vec: vec![f32::NEG_INFINITY; n],
        })
    }

    pub fn eta(&self) -> f64 {
        self.eta
    }

    pub fn nu(&self) -> f64 {
        self.nu
    }

    #[inline]
    fn row_value(&self, i: usize, ma: f32) -> f32 {
        (ma.min(self.qcap[i]) - self.pcap[i]).max(0.0)
    }
}

impl SetFunction for Flcmi {
    fn n(&self) -> usize {
        self.ground.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        (0..self.ground.n())
            .map(|i| {
                // −∞ fold base: row_value's outer max(·, 0) maps an empty
                // subset's −∞ to 0, matching I(∅;Q|P) = 0
                let ma = subset
                    .order()
                    .iter()
                    .map(|&j| self.ground.get(i, j))
                    .fold(f32::NEG_INFINITY, f32::max);
                self.row_value(i, ma) as f64
            })
            .sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.max_vec {
            *v = f32::NEG_INFINITY; // empty-set sentinel (module docs)
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        // symmetric kernel: row e read contiguously (s_ie == s_ei)
        let row = self.ground.row(e);
        let mut g = 0f64;
        for (i, &s) in row.iter().enumerate() {
            let mv = self.max_vec[i];
            g += (self.row_value(i, mv.max(s)) - self.row_value(i, mv)) as f64;
        }
        g
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        // Blocked across candidates: max_vec and the two caps stream once
        // per 4 contiguous kernel rows, and the "before" row value —
        // identical for every candidate — is computed once per row.
        // Ascending-i accumulation per candidate is bit-identical to the
        // scalar path.
        let mut c = 0;
        while c + 4 <= candidates.len() {
            let rows = [
                self.ground.row(candidates[c]),
                self.ground.row(candidates[c + 1]),
                self.ground.row(candidates[c + 2]),
                self.ground.row(candidates[c + 3]),
            ];
            let mut g = [0f64; 4];
            for i in 0..self.max_vec.len() {
                let mv = self.max_vec[i];
                let before = self.row_value(i, mv);
                for t in 0..4 {
                    let s = rows[t][i];
                    g[t] += (self.row_value(i, mv.max(s)) - before) as f64;
                }
            }
            out[c..c + 4].copy_from_slice(&g);
            c += 4;
        }
        for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
            *o = self.marginal_gain_memoized(e);
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        let row = self.ground.row(e);
        for (mv, &s) in self.max_vec.iter_mut().zip(row) {
            if s > *mv {
                *mv = s;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "FLCMI"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(eta: f64, nu: f64) -> Flcmi {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let q = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        let p = RectKernel::from_data(&privates, &ground, Metric::Euclidean).unwrap();
        Flcmi::new(g, q, p, eta, nu).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(setup(1.0, 1.0).evaluate(&Subset::empty(46)), 0.0);
    }

    #[test]
    fn nu_zero_reduces_to_flvmi() {
        use crate::functions::mi::Flvmi;
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let q = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        let flvmi = Flvmi::new(g, q, 1.3).unwrap();
        let cmi = setup(1.3, 0.0);
        for ids in [vec![0usize, 9], vec![15, 30, 44]] {
            let s = Subset::from_ids(46, &ids);
            assert!((cmi.evaluate(&s) - flvmi.evaluate(&s)).abs() < 1e-5);
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(1.0, 0.7);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[6usize, 28, 44] {
            for e in (0..46).step_by(8) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-5
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn empty_query_set_is_identically_zero() {
        use crate::linalg::Matrix;
        // I(A;∅|P) = 0 for every A, even on negative-similarity kernels
        // with a negative private cap: the −∞ query sentinel zeroes every
        // row through the outer max(·, 0)
        let ground = Matrix::from_rows(&[&[1.0f32], &[-1.0]]);
        let gk = DenseKernel::from_data(&ground, Metric::Dot);
        let qk = RectKernel::from_matrix(Matrix::zeros(0, 2));
        let pk = RectKernel::from_data(
            &Matrix::from_rows(&[&[-0.5f32]]),
            &ground,
            Metric::Dot,
        )
        .unwrap();
        let mut f = Flcmi::new(gk, qk, pk, 1.0, 1.0).unwrap();
        assert_eq!(f.evaluate(&Subset::from_ids(2, &[0, 1])), 0.0);
        f.init_memoization(&Subset::empty(2));
        assert_eq!(f.marginal_gain_memoized(0), 0.0);
    }

    #[test]
    fn query_relevant_but_private_adjacent_suppressed() {
        // query 1 sits near cluster 1 and so does a private point; with
        // strict ν the cluster-1 picks lose value vs nu=0
        let free = setup(1.0, 0.0);
        let strict = setup(1.0, 2.0);
        let s = Subset::empty(46);
        assert!(strict.marginal_gain(&s, 14) < free.marginal_gain(&s, 14));
    }

    #[test]
    fn invalid_params_rejected() {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let q = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        let p = RectKernel::from_data(&privates, &ground, Metric::Euclidean).unwrap();
        assert!(Flcmi::new(g, q, p, -1.0, 0.0).is_err());
    }
}

//! FLCMI — Facility Location Conditional Mutual Information (Table 1
//! "FL (v1)" CMI):
//!
//! ```text
//! I(A;Q|P) = Σ_{i∈V} max( min(max_{j∈A} S_ij, η max_{j∈Q} S_ij)
//!                         − ν max_{j∈P} S_ij, 0 )
//! ```
//!
//! The FLVMI saturation capped from below by the private influence:
//! η magnifies query relevance, ν tightens privacy. Memoization is the
//! usual FL `max_vec` against two precomputed row caps.

use std::sync::Arc;

use crate::error::{Result, SubmodError};
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};

/// FLCMI. See module docs.
#[derive(Clone)]
pub struct Flcmi {
    ground: Arc<DenseKernel>,
    /// η · max_{j∈Q} S_ij per row
    qcap: Arc<Vec<f32>>,
    /// ν · max_{j∈P} S_ij per row
    pcap: Arc<Vec<f32>>,
    eta: f64,
    nu: f64,
    max_vec: Vec<f32>,
}

impl Flcmi {
    /// `ground` V×V; `queries` Q×V; `privates` P×V; η, ν ≥ 0.
    pub fn new(
        ground: DenseKernel,
        queries: RectKernel,
        privates: RectKernel,
        eta: f64,
        nu: f64,
    ) -> Result<Self> {
        if eta < 0.0 || nu < 0.0 {
            return Err(SubmodError::InvalidParam(format!("eta {eta} / nu {nu} < 0")));
        }
        let n = ground.n();
        if queries.cols() != n || privates.cols() != n {
            return Err(SubmodError::Shape(
                "query/private kernel cols must equal ground n".into(),
            ));
        }
        let colmax = |k: &RectKernel, scale: f64| -> Vec<f32> {
            (0..n)
                .map(|i| {
                    scale as f32
                        * (0..k.rows()).map(|r| k.get(r, i)).fold(0f32, f32::max)
                })
                .collect()
        };
        Ok(Flcmi {
            qcap: Arc::new(colmax(&queries, eta)),
            pcap: Arc::new(colmax(&privates, nu)),
            ground: Arc::new(ground),
            eta,
            nu,
            max_vec: vec![0.0; n],
        })
    }

    pub fn eta(&self) -> f64 {
        self.eta
    }

    pub fn nu(&self) -> f64 {
        self.nu
    }

    #[inline]
    fn row_value(&self, i: usize, ma: f32) -> f32 {
        (ma.min(self.qcap[i]) - self.pcap[i]).max(0.0)
    }
}

impl SetFunction for Flcmi {
    fn n(&self) -> usize {
        self.ground.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        (0..self.ground.n())
            .map(|i| {
                let ma = subset
                    .order()
                    .iter()
                    .map(|&j| self.ground.get(i, j))
                    .fold(0f32, f32::max);
                self.row_value(i, ma) as f64
            })
            .sum()
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.max_vec {
            *v = 0.0;
        }
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        // symmetric kernel: row e read contiguously (s_ie == s_ei)
        let row = self.ground.row(e);
        let mut g = 0f64;
        for (i, &s) in row.iter().enumerate() {
            let mv = self.max_vec[i];
            g += (self.row_value(i, mv.max(s)) - self.row_value(i, mv)) as f64;
        }
        g
    }

    fn update_memoization(&mut self, e: ElementId) {
        let row = self.ground.row(e);
        for (mv, &s) in self.max_vec.iter_mut().zip(row) {
            if s > *mv {
                *mv = s;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "FLCMI"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(eta: f64, nu: f64) -> Flcmi {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let q = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        let p = RectKernel::from_data(&privates, &ground, Metric::Euclidean).unwrap();
        Flcmi::new(g, q, p, eta, nu).unwrap()
    }

    #[test]
    fn empty_zero() {
        assert_eq!(setup(1.0, 1.0).evaluate(&Subset::empty(46)), 0.0);
    }

    #[test]
    fn nu_zero_reduces_to_flvmi() {
        use crate::functions::mi::Flvmi;
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let q = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        let flvmi = Flvmi::new(g, q, 1.3).unwrap();
        let cmi = setup(1.3, 0.0);
        for ids in [vec![0usize, 9], vec![15, 30, 44]] {
            let s = Subset::from_ids(46, &ids);
            assert!((cmi.evaluate(&s) - flvmi.evaluate(&s)).abs() < 1e-5);
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(1.0, 0.7);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[6usize, 28, 44] {
            for e in (0..46).step_by(8) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-5
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn query_relevant_but_private_adjacent_suppressed() {
        // query 1 sits near cluster 1 and so does a private point; with
        // strict ν the cluster-1 picks lose value vs nu=0
        let free = setup(1.0, 0.0);
        let strict = setup(1.0, 2.0);
        let s = Subset::empty(46);
        assert!(strict.marginal_gain(&s, 14) < free.marginal_gain(&s, 14));
    }

    #[test]
    fn invalid_params_rejected() {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let g = DenseKernel::from_data(&ground, Metric::Euclidean);
        let q = RectKernel::from_data(&queries, &ground, Metric::Euclidean).unwrap();
        let p = RectKernel::from_data(&privates, &ground, Metric::Euclidean).unwrap();
        assert!(Flcmi::new(g, q, p, -1.0, 0.0).is_err());
    }
}

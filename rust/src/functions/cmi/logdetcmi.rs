//! LogDetCMI — Log Determinant Conditional Mutual Information (paper
//! §5.2.4): built per the paper's recipe — LogDet over the extended
//! (V∪Q∪P) kernel, lifted through the generic CMI identity
//! `I(A;Q|P) = f(A∪P) + f(Q∪P) − f(A∪Q∪P) − f(P)`.

use crate::error::Result;
use crate::functions::generic::ConditionalMutualInformation;
use crate::functions::log_determinant::LogDeterminant;
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel};
use crate::linalg::Matrix;

/// LogDetCMI as a `SetFunction` over V.
pub struct LogDetCmi {
    inner: ConditionalMutualInformation,
}

impl LogDetCmi {
    /// Kernels: `ground` V×V, `queries_k` Q×Q, `privates_k` P×P,
    /// `cross_q` Q×V, `cross_p` P×V, `cross_qp` Q×P. η scales V↔Q,
    /// ν scales V↔P (paper §3.4; CMI presented at η=ν=1 in Table 1).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        ground: DenseKernel,
        queries_k: DenseKernel,
        privates_k: DenseKernel,
        cross_q: RectKernel,
        cross_p: RectKernel,
        cross_qp: RectKernel,
        eta: f64,
        nu: f64,
        reg: f64,
    ) -> Result<Self> {
        let n = ground.n();
        let q = queries_k.n();
        let p = privates_k.n();
        if cross_q.rows() != q
            || cross_q.cols() != n
            || cross_p.rows() != p
            || cross_p.cols() != n
            || cross_qp.rows() != q
            || cross_qp.cols() != p
        {
            return Err(crate::error::SubmodError::Shape(
                "cross kernel shapes inconsistent with V/Q/P sizes".into(),
            ));
        }
        // extended kernel layout: [V | Q | P]
        let total = n + q + p;
        let mut ext = Matrix::zeros(total, total);
        for i in 0..n {
            for j in 0..n {
                ext.set(i, j, ground.get(i, j));
            }
        }
        for a in 0..q {
            for b in 0..q {
                ext.set(n + a, n + b, queries_k.get(a, b));
            }
        }
        for a in 0..p {
            for b in 0..p {
                ext.set(n + q + a, n + q + b, privates_k.get(a, b));
            }
        }
        for a in 0..q {
            for j in 0..n {
                let v = eta as f32 * cross_q.get(a, j);
                ext.set(n + a, j, v);
                ext.set(j, n + a, v);
            }
        }
        for a in 0..p {
            for j in 0..n {
                let v = nu as f32 * cross_p.get(a, j);
                ext.set(n + q + a, j, v);
                ext.set(j, n + q + a, v);
            }
        }
        for a in 0..q {
            for b in 0..p {
                let v = cross_qp.get(a, b);
                ext.set(n + a, n + q + b, v);
                ext.set(n + q + b, n + a, v);
            }
        }
        let base = LogDeterminant::with_regularization(DenseKernel::from_matrix(ext)?, reg)?;
        let inner = ConditionalMutualInformation::new(
            Box::new(base),
            (n..n + q).collect(),
            (n + q..total).collect(),
            n,
        )?;
        Ok(LogDetCmi { inner })
    }
}

impl Clone for LogDetCmi {
    fn clone(&self) -> Self {
        LogDetCmi { inner: self.inner.clone() }
    }
}

impl SetFunction for LogDetCmi {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        self.inner.evaluate(subset)
    }

    fn init_memoization(&mut self, subset: &Subset) {
        self.inner.init_memoization(subset);
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        self.inner.marginal_gain_memoized(e)
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        // forwards to generic CMI → two LogDeterminant blocked forward
        // substitutions over the shared incremental factors
        self.inner.marginal_gains_batch(candidates, out);
    }

    fn update_memoization(&mut self, e: ElementId) {
        self.inner.update_memoization(e);
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "LogDetCMI"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::controlled;
    use crate::kernel::Metric;

    fn setup(eta: f64, nu: f64) -> LogDetCmi {
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let m = Metric::Rbf { gamma: 0.5 };
        LogDetCmi::new(
            DenseKernel::from_data(&ground, m),
            DenseKernel::from_data(&queries, m),
            DenseKernel::from_data(&privates, m),
            RectKernel::from_data(&queries, &ground, m).unwrap(),
            RectKernel::from_data(&privates, &ground, m).unwrap(),
            RectKernel::from_data(&queries, &privates, m).unwrap(),
            eta,
            nu,
            0.1,
        )
        .unwrap()
    }

    #[test]
    fn empty_zero() {
        assert!(setup(1.0, 1.0).evaluate(&Subset::empty(46)).abs() < 1e-9);
    }

    #[test]
    fn memoized_matches_stateless() {
        let mut f = setup(0.8, 0.5);
        let mut s = Subset::empty(46);
        f.init_memoization(&s);
        for &add in &[3usize, 27] {
            for e in (0..46).step_by(17) {
                if s.contains(e) {
                    continue;
                }
                assert!(
                    (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-4
                );
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn fully_decoupled_query_gives_zero_cmi() {
        // when BOTH the V↔Q and Q↔P blocks are zero, Q is independent of
        // everything and I(A;Q|P) must vanish identically. (η=0 alone is
        // not enough: Q and A can still be correlated *through* P.)
        use crate::linalg::Matrix;
        let (ground, queries, _, _) = controlled::fig6_dataset();
        let privates = controlled::private_set_for_fig6();
        let m = Metric::Rbf { gamma: 0.5 };
        let f = LogDetCmi::new(
            DenseKernel::from_data(&ground, m),
            DenseKernel::from_data(&queries, m),
            DenseKernel::from_data(&privates, m),
            RectKernel::from_data(&queries, &ground, m).unwrap(),
            RectKernel::from_data(&privates, &ground, m).unwrap(),
            RectKernel::from_matrix(Matrix::zeros(2, 2)), // Q⊥P
            0.0,                                          // Q⊥V
            0.5,
            0.1,
        )
        .unwrap();
        let s = Subset::from_ids(46, &[2, 18]);
        assert!(f.evaluate(&s).abs() < 1e-4, "{}", f.evaluate(&s));
    }
}

//! PSCCMI — Probabilistic Set Cover Conditional Mutual Information (paper
//! §5.2.4, Table 1):
//!
//! ```text
//! I(A;Q|P) = Σ_u w_u · P̄_u(A) · P̄_u(Q) · P_u(P)
//! ```
//!
//! Reduction: PSC with weights scaled by both the query coverage
//! probability and the private *non*-coverage probability.

use crate::error::Result;
use crate::functions::prob_set_cover::ProbabilisticSetCover;

/// Build PSCCMI from a base PSC, query probability rows and private
/// probability rows.
pub fn psccmi(
    base: &ProbabilisticSetCover,
    query_probs: &[Vec<f32>],
    private_probs: &[Vec<f32>],
) -> Result<ProbabilisticSetCover> {
    base.with_reweighted(|u| {
        let q_cov = 1.0 - ProbabilisticSetCover::survival_product(query_probs, u);
        let p_non = ProbabilisticSetCover::survival_product(private_probs, u);
        q_cov * p_non
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::traits::{SetFunction, Subset};

    fn base() -> ProbabilisticSetCover {
        ProbabilisticSetCover::new(
            vec![vec![0.9, 0.2], vec![0.1, 0.8]],
            vec![1.0, 2.0],
        )
        .unwrap()
    }

    #[test]
    fn matches_table1_formula() {
        let qp = vec![vec![0.5f32, 1.0]];
        let pp = vec![vec![0.0f32, 0.25]];
        let f = psccmi(&base(), &qp, &pp).unwrap();
        // A={1}: u=0: 1.0·0.1·0.5·1.0 = 0.05 ; u=1: 2.0·0.8·1.0·0.75 = 1.2
        let s = Subset::from_ids(2, &[1]);
        assert!((f.evaluate(&s) - 1.25).abs() < 1e-6);
    }

    #[test]
    fn composes_mi_then_cg() {
        use crate::functions::cg::psccg;
        use crate::functions::mi::pscmi;
        let b = base();
        let qp = vec![vec![0.3f32, 0.6]];
        let pp = vec![vec![0.2f32, 0.9]];
        let direct = psccmi(&b, &qp, &pp).unwrap();
        let composed = psccg(&pscmi(&b, &qp).unwrap(), &pp).unwrap();
        for ids in [vec![0usize], vec![0, 1]] {
            let s = Subset::from_ids(2, &ids);
            assert!((direct.evaluate(&s) - composed.evaluate(&s)).abs() < 1e-12);
        }
    }

    #[test]
    fn certain_private_coverage_zeroes() {
        let qp = vec![vec![1.0f32, 1.0]];
        let pp = vec![vec![1.0f32, 1.0]];
        let f = psccmi(&base(), &qp, &pp).unwrap();
        assert!(f.evaluate(&Subset::from_ids(2, &[0, 1])).abs() < 1e-12);
    }
}

//! Facility Location (paper §2.1.1) — the library's workhorse
//! representation function:
//!
//! ```text
//! f_FL(X) = Σ_{i∈U} max_{j∈X} s_ij
//! ```
//!
//! with U the *represented set* (defaults to the ground set V). Three
//! kernel modes, mirroring the paper's §8 usage patterns:
//!
//! * **dense** — N×N kernel; memoized statistic `max_vec[i] = max_{j∈A} s_ij`
//!   (Table 3 row 1) makes each gain O(|U|).
//! * **sparse** — kNN kernel; gains touch only stored neighbors.
//! * **clustered** — `f(A) = Σ_l Σ_{i∈C_l} max_{j∈A∩C_l} s_ij` over a
//!   provided clustering, kernels built per cluster.
//!
//! Like Submodlib, FL assumes *non-negative* similarities: empty maxima
//! are represented as 0, so a kernel with negative entries is silently
//! clamped at zero per row. This is load-bearing for the sparse mode
//! (absent CSR entries read as 0 and must never beat a stored max) and is
//! deliberately NOT the empty-set sentinel the MI family uses (see
//! `functions::mi::flqmi` for the contrast).

use std::sync::Arc;

use super::traits::{ElementId, SetFunction, Subset};
use crate::kernel::{DenseKernel, RectKernel, SparseKernel};

#[derive(Clone)]
enum Mode {
    /// represented set = ground set, square kernel
    Dense(Arc<DenseKernel>),
    /// represented set U ≠ V: rows = U, cols = V
    Rect(Arc<RectKernel>),
    /// kNN kernel (assumed symmetric metric)
    Sparse(Arc<SparseKernel>),
    /// per-cluster dense kernels over global-id lists
    Clustered { clusters: Arc<Vec<(Vec<ElementId>, DenseKernel)>>, n: usize },
}

/// Facility-Location function. See module docs.
#[derive(Clone)]
pub struct FacilityLocation {
    mode: Mode,
    /// memoized: for each represented row i, max_{j∈A} s_ij
    /// (clustered mode: concatenated per-cluster max vectors)
    max_vec: Vec<f32>,
    /// clustered mode: global id → (cluster idx, local idx, max_vec offset)
    lookup: Vec<(u32, u32, u32)>,
}

impl FacilityLocation {
    /// Dense mode over a square ground-set kernel.
    pub fn new(kernel: DenseKernel) -> Self {
        let n = kernel.n();
        FacilityLocation {
            mode: Mode::Dense(Arc::new(kernel)),
            max_vec: vec![0.0; n],
            lookup: Vec::new(),
        }
    }

    /// Generic represented set: `kernel` rows are U, columns are V.
    pub fn with_represented(kernel: RectKernel) -> Self {
        let rows = kernel.rows();
        FacilityLocation {
            mode: Mode::Rect(Arc::new(kernel)),
            max_vec: vec![0.0; rows],
            lookup: Vec::new(),
        }
    }

    /// Sparse (kNN) mode.
    pub fn sparse(kernel: SparseKernel) -> Self {
        let n = kernel.n();
        FacilityLocation {
            mode: Mode::Sparse(Arc::new(kernel)),
            max_vec: vec![0.0; n],
            lookup: Vec::new(),
        }
    }

    /// Clustered mode with internal k-means (the paper's "let SUBMODLIB
    /// do the clustering" path): clusters `data` into `k` groups and
    /// builds one per-cluster kernel.
    pub fn clustered_from_data(
        data: &crate::linalg::Matrix,
        k: usize,
        metric: crate::kernel::Metric,
        seed: u64,
    ) -> Self {
        let km = crate::clustering::kmeans(data, k, 50, seed);
        let parts = crate::clustering::partition(&km.labels, k);
        let clusters: Vec<(Vec<ElementId>, DenseKernel)> = parts
            .into_iter()
            .filter(|ids| !ids.is_empty())
            .map(|ids| {
                let mut sub = crate::linalg::Matrix::zeros(ids.len(), data.cols());
                for (li, &g) in ids.iter().enumerate() {
                    sub.row_mut(li).copy_from_slice(data.row(g));
                }
                (ids, DenseKernel::from_data(&sub, metric))
            })
            .collect();
        FacilityLocation::clustered(clusters, data.rows())
    }

    /// Clustered mode: `clusters[l]` = (global ids of cluster l, kernel over
    /// those ids). `n` is the global ground-set size.
    pub fn clustered(clusters: Vec<(Vec<ElementId>, DenseKernel)>, n: usize) -> Self {
        let mut lookup = vec![(u32::MAX, 0u32, 0u32); n];
        let mut offset = 0u32;
        let mut total = 0usize;
        for (ci, (ids, k)) in clusters.iter().enumerate() {
            assert_eq!(ids.len(), k.n(), "cluster {ci} ids vs kernel size");
            for (li, &g) in ids.iter().enumerate() {
                lookup[g] = (ci as u32, li as u32, offset);
            }
            offset += ids.len() as u32;
            total += ids.len();
        }
        FacilityLocation {
            mode: Mode::Clustered { clusters: Arc::new(clusters), n },
            max_vec: vec![0.0; total],
            lookup,
        }
    }
}

impl SetFunction for FacilityLocation {
    fn n(&self) -> usize {
        match &self.mode {
            Mode::Dense(k) => k.n(),
            Mode::Rect(k) => k.cols(),
            Mode::Sparse(k) => k.n(),
            Mode::Clustered { n, .. } => *n,
        }
    }

    fn evaluate(&self, subset: &Subset) -> f64 {
        match &self.mode {
            Mode::Dense(k) => (0..k.n())
                .map(|i| {
                    subset
                        .order()
                        .iter()
                        .map(|&j| k.get(i, j))
                        .fold(0f32, f32::max) as f64
                })
                .sum(),
            Mode::Rect(k) => (0..k.rows())
                .map(|i| {
                    subset
                        .order()
                        .iter()
                        .map(|&j| k.get(i, j))
                        .fold(0f32, f32::max) as f64
                })
                .sum(),
            Mode::Sparse(k) => (0..k.n())
                .map(|i| {
                    subset
                        .order()
                        .iter()
                        .map(|&j| k.get(i, j))
                        .fold(0f32, f32::max) as f64
                })
                .sum(),
            Mode::Clustered { clusters, .. } => {
                let mut total = 0f64;
                for (ids, k) in clusters.iter() {
                    let local: Vec<usize> = ids
                        .iter()
                        .enumerate()
                        .filter(|(_, g)| subset.contains(**g))
                        .map(|(l, _)| l)
                        .collect();
                    if local.is_empty() {
                        continue;
                    }
                    for i in 0..k.n() {
                        total += local
                            .iter()
                            .map(|&j| k.get(i, j))
                            .fold(0f32, f32::max) as f64;
                    }
                }
                total
            }
        }
    }

    fn init_memoization(&mut self, subset: &Subset) {
        for v in &mut self.max_vec {
            *v = 0.0;
        }
        // replay inserts through update_memoization for a single code path
        let order: Vec<ElementId> = subset.order().to_vec();
        for e in order {
            self.update_memoization(e);
        }
    }

    fn marginal_gain_memoized(&self, e: ElementId) -> f64 {
        match &self.mode {
            Mode::Dense(k) => {
                // symmetric kernel: read row e contiguously (s_ie == s_ei)
                // instead of striding down column e (§Perf iteration —
                // EXPERIMENTS.md L3 hot path 2)
                let row = k.row(e);
                let mut g = 0f64;
                for (&s, &mv) in row.iter().zip(self.max_vec.iter()) {
                    if s > mv {
                        g += (s - mv) as f64;
                    }
                }
                g
            }
            Mode::Rect(k) => {
                let mut g = 0f64;
                for (i, &mv) in self.max_vec.iter().enumerate() {
                    let s = k.get(i, e);
                    if s > mv {
                        g += (s - mv) as f64;
                    }
                }
                g
            }
            Mode::Sparse(k) => {
                // symmetric kernel: s_ie for stored neighbors i of e; all
                // other rows see similarity 0 ≤ max_vec[i] (max_vec ≥ 0).
                let (cols, vals) = k.row(e);
                let mut g = 0f64;
                for (&i, &s) in cols.iter().zip(vals) {
                    let mv = self.max_vec[i as usize];
                    if s > mv {
                        g += (s - mv) as f64;
                    }
                }
                g
            }
            Mode::Clustered { clusters, .. } => {
                let (ci, li, off) = self.lookup[e];
                if ci == u32::MAX {
                    return 0.0; // element not in any cluster contributes nothing
                }
                let (_, k) = &clusters[ci as usize];
                let mut g = 0f64;
                for i in 0..k.n() {
                    let mv = self.max_vec[off as usize + i];
                    let s = k.get(i, li as usize);
                    if s > mv {
                        g += (s - mv) as f64;
                    }
                }
                g
            }
        }
    }

    fn marginal_gains_batch(&self, candidates: &[ElementId], out: &mut [f64]) {
        debug_assert_eq!(candidates.len(), out.len());
        match &self.mode {
            Mode::Dense(k) => {
                // Register-blocked across candidates: stream max_vec once
                // per 4 contiguous kernel rows (same shape as
                // linalg::dot4 / build_pairwise). Each candidate's f64
                // accumulation runs in ascending-i order exactly like the
                // scalar path, so the results are bit-identical.
                let mv = &self.max_vec;
                let mut c = 0;
                while c + 4 <= candidates.len() {
                    let rows = [
                        k.row(candidates[c]),
                        k.row(candidates[c + 1]),
                        k.row(candidates[c + 2]),
                        k.row(candidates[c + 3]),
                    ];
                    let mut g = [0f64; 4];
                    for (i, &m) in mv.iter().enumerate() {
                        for t in 0..4 {
                            let s = rows[t][i];
                            if s > m {
                                g[t] += (s - m) as f64;
                            }
                        }
                    }
                    out[c..c + 4].copy_from_slice(&g);
                    c += 4;
                }
                for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
                    *o = self.marginal_gain_memoized(e);
                }
            }
            Mode::Rect(k) => {
                // Blocked across candidates so each kernel row is read
                // once per 4 candidates instead of striding down 4 full
                // columns.
                let mut c = 0;
                while c + 4 <= candidates.len() {
                    let es = [
                        candidates[c],
                        candidates[c + 1],
                        candidates[c + 2],
                        candidates[c + 3],
                    ];
                    let mut g = [0f64; 4];
                    for (i, &m) in self.max_vec.iter().enumerate() {
                        let row = k.row(i);
                        for t in 0..4 {
                            let s = row[es[t]];
                            if s > m {
                                g[t] += (s - m) as f64;
                            }
                        }
                    }
                    out[c..c + 4].copy_from_slice(&g);
                    c += 4;
                }
                for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
                    *o = self.marginal_gain_memoized(e);
                }
            }
            Mode::Sparse(k) => {
                // CSR-transpose-style merge: 4 candidates' neighbor lists
                // are walked front-to-front in ascending column order, so
                // `max_vec[i]` is read once per distinct row i the block
                // touches instead of once per (candidate, neighbor) pair.
                // Each candidate still accumulates over *its own* stored
                // neighbors in ascending-column order — exactly the scalar
                // path's order — so results are bit-identical.
                let mv = &self.max_vec;
                let mut c = 0;
                while c + 4 <= candidates.len() {
                    let rows = [
                        k.row(candidates[c]),
                        k.row(candidates[c + 1]),
                        k.row(candidates[c + 2]),
                        k.row(candidates[c + 3]),
                    ];
                    let mut cur = [0usize; 4];
                    let mut g = [0f64; 4];
                    loop {
                        let mut next = u32::MAX;
                        let mut any = false;
                        for t in 0..4 {
                            if cur[t] < rows[t].0.len() {
                                let col = rows[t].0[cur[t]];
                                if !any || col < next {
                                    next = col;
                                }
                                any = true;
                            }
                        }
                        if !any {
                            break;
                        }
                        let m = mv[next as usize];
                        for t in 0..4 {
                            if cur[t] < rows[t].0.len() && rows[t].0[cur[t]] == next {
                                let s = rows[t].1[cur[t]];
                                if s > m {
                                    g[t] += (s - m) as f64;
                                }
                                cur[t] += 1;
                            }
                        }
                    }
                    out[c..c + 4].copy_from_slice(&g);
                    c += 4;
                }
                for (o, &e) in out[c..].iter_mut().zip(&candidates[c..]) {
                    *o = self.marginal_gain_memoized(e);
                }
            }
            Mode::Clustered { clusters, .. } => {
                // Per-cluster grouping (ROADMAP open item): candidates of
                // the same cluster share that cluster's kernel rows and
                // max_vec segment, so group first, then stream the
                // cluster's rows once per 4 same-cluster candidates (same
                // shape as Dense). Ascending-i accumulation per candidate
                // keeps results bit-identical to the scalar path.
                let mut by_cluster: Vec<Vec<usize>> = vec![Vec::new(); clusters.len()];
                for (idx, &e) in candidates.iter().enumerate() {
                    let (ci, _, _) = self.lookup[e];
                    if ci == u32::MAX {
                        out[idx] = 0.0; // not in any cluster: no contribution
                    } else {
                        by_cluster[ci as usize].push(idx);
                    }
                }
                for (ci, members) in by_cluster.iter().enumerate() {
                    if members.is_empty() {
                        continue;
                    }
                    let (_, k) = &clusters[ci];
                    let off = self.lookup[candidates[members[0]]].2 as usize;
                    let mut c = 0;
                    while c + 4 <= members.len() {
                        let lis = [
                            self.lookup[candidates[members[c]]].1 as usize,
                            self.lookup[candidates[members[c + 1]]].1 as usize,
                            self.lookup[candidates[members[c + 2]]].1 as usize,
                            self.lookup[candidates[members[c + 3]]].1 as usize,
                        ];
                        let mut g = [0f64; 4];
                        for i in 0..k.n() {
                            let m = self.max_vec[off + i];
                            let row = k.row(i);
                            for t in 0..4 {
                                let s = row[lis[t]];
                                if s > m {
                                    g[t] += (s - m) as f64;
                                }
                            }
                        }
                        for t in 0..4 {
                            out[members[c + t]] = g[t];
                        }
                        c += 4;
                    }
                    for &idx in &members[c..] {
                        out[idx] = self.marginal_gain_memoized(candidates[idx]);
                    }
                }
            }
        }
    }

    fn update_memoization(&mut self, e: ElementId) {
        match &self.mode {
            Mode::Dense(k) => {
                let row = k.row(e); // symmetric: row e == column e
                for (mv, &s) in self.max_vec.iter_mut().zip(row) {
                    if s > *mv {
                        *mv = s;
                    }
                }
            }
            Mode::Rect(k) => {
                for (i, mv) in self.max_vec.iter_mut().enumerate() {
                    let s = k.get(i, e);
                    if s > *mv {
                        *mv = s;
                    }
                }
            }
            Mode::Sparse(k) => {
                let (cols, vals) = k.row(e);
                for (&i, &s) in cols.iter().zip(vals) {
                    let mv = &mut self.max_vec[i as usize];
                    if s > *mv {
                        *mv = s;
                    }
                }
            }
            Mode::Clustered { clusters, .. } => {
                let (ci, li, off) = self.lookup[e];
                if ci == u32::MAX {
                    return;
                }
                let (_, k) = &clusters[ci as usize];
                for i in 0..k.n() {
                    let mv = &mut self.max_vec[off as usize + i];
                    let s = k.get(i, li as usize);
                    if s > *mv {
                        *mv = s;
                    }
                }
            }
        }
    }

    fn clone_box(&self) -> Box<dyn SetFunction> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "FacilityLocation"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::{kmeans, partition};
    use crate::data::synthetic;
    use crate::kernel::Metric;
    use crate::linalg::Matrix;

    fn dense_fl(n: usize, seed: u64) -> (FacilityLocation, DenseKernel) {
        let data = synthetic::blobs(n, 2, 3, 1.0, seed);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        (FacilityLocation::new(k.clone()), k)
    }

    #[test]
    fn empty_set_zero() {
        let (f, _) = dense_fl(20, 1);
        assert_eq!(f.evaluate(&Subset::empty(20)), 0.0);
    }

    #[test]
    fn full_set_is_row_sum_of_ones() {
        // with euclidean similarity, max over full set includes self (=1)
        let (f, _) = dense_fl(15, 2);
        let full = Subset::from_ids(15, &(0..15).collect::<Vec<_>>());
        assert!((f.evaluate(&full) - 15.0).abs() < 1e-4);
    }

    #[test]
    fn marginal_gain_matches_evaluate_delta() {
        let (f, _) = dense_fl(25, 3);
        let s = Subset::from_ids(25, &[1, 7, 13]);
        for e in [0usize, 5, 20] {
            let delta = f.evaluate(&s.union_with(&[e])) - f.evaluate(&s);
            assert!((f.marginal_gain(&s, e) - delta).abs() < 1e-9);
        }
    }

    #[test]
    fn memoized_matches_stateless() {
        let (mut f, _) = dense_fl(30, 4);
        let mut s = Subset::empty(30);
        f.init_memoization(&s);
        for &add in &[3usize, 17, 8, 25] {
            for e in 0..30 {
                if s.contains(e) {
                    continue;
                }
                let fast = f.marginal_gain_memoized(e);
                let slow = f.marginal_gain(&s, e);
                assert!((fast - slow).abs() < 1e-6, "e={e}: {fast} vs {slow}");
            }
            f.update_memoization(add);
            s.insert(add);
        }
    }

    #[test]
    fn init_memoization_mid_set() {
        let (mut f, _) = dense_fl(20, 5);
        let s = Subset::from_ids(20, &[2, 9]);
        f.init_memoization(&s);
        for e in [0usize, 14] {
            assert!((f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6);
        }
    }

    #[test]
    fn rect_mode_represented_set() {
        // U = 2 points, V = 3 points; FL should sum over U rows only
        let u = Matrix::from_rows(&[&[0.0, 0.0], &[10.0, 0.0]]);
        let v = Matrix::from_rows(&[&[0.0, 1.0], &[10.0, 1.0], &[5.0, 5.0]]);
        let k = RectKernel::from_data(&u, &v, Metric::Euclidean).unwrap();
        let mut f = FacilityLocation::with_represented(k.clone());
        assert_eq!(f.n(), 3);
        let s = Subset::from_ids(3, &[0]);
        let expect = (k.get(0, 0) + k.get(1, 0)) as f64;
        assert!((f.evaluate(&s) - expect).abs() < 1e-6);
        f.init_memoization(&Subset::empty(3));
        assert!((f.marginal_gain_memoized(0) - expect).abs() < 1e-6);
    }

    #[test]
    fn sparse_mode_matches_dense_on_gains_for_neighbors() {
        let data = synthetic::blobs(40, 2, 4, 0.5, 6);
        let sparse = SparseKernel::from_data(&data, Metric::Euclidean, 40).unwrap();
        let dense = DenseKernel::from_data(&data, Metric::Euclidean);
        // with k = n the sparse kernel is exact → functions must agree
        let mut fs = FacilityLocation::sparse(sparse);
        let mut fd = FacilityLocation::new(dense);
        let empty = Subset::empty(40);
        fs.init_memoization(&empty);
        fd.init_memoization(&empty);
        for step in 0..5 {
            let mut best = (0usize, f64::MIN);
            for e in 0..40 {
                let g = fd.marginal_gain_memoized(e);
                if g > best.1 {
                    best = (e, g);
                }
            }
            let gs = fs.marginal_gain_memoized(best.0);
            assert!((gs - best.1).abs() < 1e-5, "step {step}");
            fs.update_memoization(best.0);
            fd.update_memoization(best.0);
        }
    }

    #[test]
    fn clustered_mode_matches_definition() {
        let data = synthetic::blobs(30, 2, 3, 0.4, 7);
        let km = kmeans(&data, 3, 30, 1);
        let parts = partition(&km.labels, 3);
        let clusters: Vec<(Vec<usize>, DenseKernel)> = parts
            .iter()
            .map(|ids| {
                let mut sub = Matrix::zeros(ids.len(), 2);
                for (li, &g) in ids.iter().enumerate() {
                    sub.row_mut(li).copy_from_slice(data.row(g));
                }
                (ids.clone(), DenseKernel::from_data(&sub, Metric::Euclidean))
            })
            .collect();
        let mut f = FacilityLocation::clustered(clusters.clone(), 30);
        let s = Subset::from_ids(30, &[parts[0][0], parts[1][0]]);
        // manual definition: Σ_l Σ_{i∈C_l} max_{j∈A∩C_l} s_ij
        let mut expect = 0f64;
        for (ids, k) in &clusters {
            let local: Vec<usize> = ids
                .iter()
                .enumerate()
                .filter(|(_, g)| s.contains(**g))
                .map(|(l, _)| l)
                .collect();
            for i in 0..k.n() {
                expect += local.iter().map(|&j| k.get(i, j)).fold(0f32, f32::max) as f64;
            }
        }
        assert!((f.evaluate(&s) - expect).abs() < 1e-6);
        // memoized path agrees with stateless
        f.init_memoization(&s);
        for e in 0..30 {
            if s.contains(e) {
                continue;
            }
            assert!(
                (f.marginal_gain_memoized(e) - f.marginal_gain(&s, e)).abs() < 1e-6,
                "e={e}"
            );
        }
    }

    #[test]
    fn diminishing_returns_spot_check() {
        let (f, _) = dense_fl(20, 8);
        let a = Subset::from_ids(20, &[1]);
        let b = Subset::from_ids(20, &[1, 5, 9]);
        for e in [0usize, 3, 12] {
            assert!(f.marginal_gain(&a, e) >= f.marginal_gain(&b, e) - 1e-9);
        }
    }
}

//! NaiveGreedy (paper §5.3.1): the standard greedy algorithm [Nemhauser
//! et al. 1978] — every iteration scans the whole remaining ground set for
//! the element with maximum marginal gain (gain/cost ratio under knapsack
//! budgets, per Sviridenko 2004) and adds it, until the budget is met or
//! the stop rules fire.
//!
//! Ties: the first best element encountered wins (matching the paper's
//! §5.3.1 note on non-unique greedy solutions; our ground-set scan order
//! is ascending id, so unlike Submodlib's unordered sets it IS
//! deterministic).
//!
//! The per-iteration scan gathers the eligible candidates and evaluates
//! their gains through [`super::batch_gains`] (multi-threaded batch path);
//! the argmax then runs serially in ascending-id order accepting only
//! strictly greater keys, so the selection is bit-identical to the old
//! one-element-at-a-time loop.
//!
//! Cancellation polls: once per iteration at the loop top, and again
//! after the batch scan *before the argmax* — a cancel landing mid-scan
//! leaves the gain tail unwritten, and committing a pick from it would
//! be a nondeterministic prefix (see the module docs' contract).

use super::{batch_gains, should_stop, Budget, MaximizeOpts, Selection};
use crate::error::Result;
use crate::functions::traits::SetFunction;
use crate::runtime::cancel;

pub(crate) fn run(
    f: &mut dyn SetFunction,
    budget: &Budget,
    opts: &MaximizeOpts,
) -> Result<Selection> {
    let n = f.n();
    let mut in_set = vec![false; n];
    let mut order = Vec::new();
    let mut value = 0f64;
    let mut spent = 0f64;
    let mut evaluations = 0u64;
    let mut candidates: Vec<usize> = Vec::with_capacity(n);
    let mut gains: Vec<f64> = Vec::with_capacity(n);

    loop {
        cancel::check_current()?;
        let remaining = budget.max_cost - spent;
        candidates.clear();
        candidates
            .extend((0..n).filter(|&e| !in_set[e] && budget.cost(e) <= remaining + 1e-12));
        if candidates.is_empty() {
            break;
        }
        gains.clear();
        gains.resize(candidates.len(), 0.0);
        batch_gains(&*f, &candidates, &mut gains, opts.parallel, opts.threads);
        cancel::check_current()?; // a mid-scan cancel leaves `gains` partial
        evaluations += candidates.len() as u64;
        let mut best: Option<(usize, f64, f64)> = None; // (e, gain, key)
        for (&e, &gain) in candidates.iter().zip(gains.iter()) {
            let key = gain / budget.cost(e);
            if best.map(|(_, _, bk)| key > bk).unwrap_or(true) {
                best = Some((e, gain, key));
            }
        }
        let Some((e, gain, _)) = best else { break };
        if should_stop(gain, opts) {
            break;
        }
        f.update_memoization(e);
        in_set[e] = true;
        spent += budget.cost(e);
        value += gain;
        if opts.verbose {
            eprintln!(
                "[naive {}] pick {e} gain {gain:.6} value {value:.6} cost {spent}",
                order.len()
            );
        }
        order.push((e, gain));
    }
    Ok(Selection { order, value, evaluations })
}

#[cfg(test)]
mod tests {
    use crate::data::synthetic;
    use crate::functions::set_cover::SetCover;
    use crate::functions::traits::{SetFunction, Subset};
    use crate::functions::facility_location::FacilityLocation;
    use crate::kernel::{DenseKernel, Metric};
    use crate::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};

    #[test]
    fn greedy_set_cover_is_optimal_here() {
        // classic instance where greedy finds the optimum
        let f = SetCover::new(
            vec![vec![0, 1, 2], vec![3, 4], vec![0, 3], vec![5]],
            vec![1.0; 6],
        )
        .unwrap();
        let sel = maximize(
            &f,
            Budget::cardinality(3),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert_eq!(sel.ids(), vec![0, 1, 3]);
        assert_eq!(sel.value, 6.0);
    }

    #[test]
    fn stops_on_zero_gain() {
        // after covering everything, gains are 0 → must stop early
        let f = SetCover::new(vec![vec![0], vec![0], vec![0]], vec![1.0]).unwrap();
        let sel = maximize(
            &f,
            Budget::cardinality(3),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert_eq!(sel.order.len(), 1);
    }

    #[test]
    fn no_stop_flags_fills_budget() {
        let f = SetCover::new(vec![vec![0], vec![0], vec![0]], vec![1.0]).unwrap();
        let sel = maximize(
            &f,
            Budget::cardinality(3),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts {
                stop_if_zero_gain: false,
                stop_if_negative_gain: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(sel.order.len(), 3);
    }

    #[test]
    fn knapsack_budget_respected() {
        let data = synthetic::blobs(30, 2, 3, 1.0, 5);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let costs: Vec<f64> = (0..30).map(|i| 1.0 + (i % 3) as f64).collect();
        let budget = Budget::knapsack(6.0, costs.clone()).unwrap();
        let sel = maximize(
            &f,
            budget,
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let total: f64 = sel.ids().iter().map(|&e| costs[e]).sum();
        assert!(total <= 6.0 + 1e-9);
        assert!(!sel.order.is_empty());
    }

    #[test]
    fn gains_weakly_decreasing_for_submodular_f() {
        let data = synthetic::blobs(50, 2, 5, 1.0, 6);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let sel = maximize(
            &f,
            Budget::cardinality(10),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        for w in sel.order.windows(2) {
            assert!(w[0].1 >= w[1].1 - 1e-9, "gains must not increase");
        }
    }

    #[test]
    fn first_pick_maximizes_singleton_value() {
        let data = synthetic::blobs(40, 2, 4, 1.0, 7);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let sel = maximize(
            &f,
            Budget::cardinality(1),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let picked = sel.order[0].0;
        let best = (0..40)
            .map(|e| f.evaluate(&Subset::from_ids(40, &[e])))
            .fold(f64::MIN, f64::max);
        let got = f.evaluate(&Subset::from_ids(40, &[picked]));
        assert!((got - best).abs() < 1e-9);
    }
}

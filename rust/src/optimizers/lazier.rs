//! LazierThanLazyGreedy (paper §5.3.4; Mirzasoleiman et al. 2015):
//! "random sampling with lazy evaluation" — StochasticGreedy's subsampling
//! combined with LazyGreedy's stale upper bounds. Within each iteration's
//! random sample, elements are examined in descending stale-bound order
//! and only re-evaluated until a fresh bound tops the rest — typically a
//! handful of evaluations per pick.
//!
//! Within-sample stale re-evaluations are Minoux-blocked exactly like
//! `super::lazy`: the run of stale entries at the top of the sample heap
//! is drained into one [`super::batch_gains`] call, block sizes doubling
//! 1 → [`LAZY_STALE_BLOCK`] per cascade and resetting every pick. The
//! selection is invariant (see lazy.rs for the argument: a pick only
//! happens on a *fresh* top, and early recomputes replace upper bounds
//! with exact values, never changing the argmax); only the evaluation
//! count can grow, by less than one block per pick —
//! `tests/lazier_parity.rs` pins both against the serial pop-one replica.
//!
//! Cardinality budgets only (inherits StochasticGreedy's sample formula).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::lazy::LAZY_STALE_BLOCK;
use super::stochastic::sample_size;
use super::{batch_gains, should_stop, Budget, MaximizeOpts, Selection};
use crate::error::{Result, SubmodError};
use crate::functions::traits::SetFunction;
use crate::rng::Pcg64;
use crate::runtime::cancel;

struct Entry {
    bound: f64,
    e: usize,
    fresh: bool,
}
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound && self.e == other.e
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // total order even for non-finite bounds (the +∞ never-evaluated
        // sentinel is routine here); see lazy.rs on why
        // partial_cmp().unwrap_or(Equal) corrupts the heap on NaN.
        self.bound
            .total_cmp(&other.bound)
            .then_with(|| other.e.cmp(&self.e))
    }
}

pub(crate) fn run(
    f: &mut dyn SetFunction,
    budget: &Budget,
    opts: &MaximizeOpts,
) -> Result<Selection> {
    let Some(k) = budget.as_count() else {
        return Err(SubmodError::Unsupported(
            "LazierThanLazyGreedy requires a cardinality budget".into(),
        ));
    };
    if !(0.0 < opts.epsilon && opts.epsilon < 1.0) {
        return Err(SubmodError::InvalidParam(format!(
            "epsilon {} outside (0,1)",
            opts.epsilon
        )));
    }
    let n = f.n();
    let k = k.min(n);
    let s = sample_size(n, k, opts.epsilon);
    let mut rng = Pcg64::new(opts.seed);

    // persistent stale upper bounds (∞ = never evaluated)
    let mut upper = vec![f64::INFINITY; n];
    let mut pool: Vec<usize> = (0..n).collect();
    let mut order = Vec::new();
    let mut value = 0f64;
    let mut evaluations = 0u64;
    let mut unseen: Vec<usize> = Vec::with_capacity(s);
    let mut unseen_gains: Vec<f64> = Vec::with_capacity(s);
    let mut seen_before: Vec<bool> = Vec::with_capacity(s);
    // Minoux stale-block scratch (drained ids + recomputed gains)
    let mut stale_ids: Vec<usize> = Vec::with_capacity(LAZY_STALE_BLOCK);
    let mut stale_gains: Vec<f64> = Vec::with_capacity(LAZY_STALE_BLOCK);

    for it in 0..k {
        cancel::check_current()?; // per-iteration poll
        if pool.is_empty() {
            break;
        }
        let take = s.min(pool.len());
        for i in 0..take {
            let j = i + rng.next_below(pool.len() - i);
            pool.swap(i, j);
        }
        // Batch-evaluate the sample members that have never been touched.
        // Behavior-identical to the serial loop: their ∞ sentinel bounds
        // outrank every finite fresh bound, so the serial heap would have
        // popped and evaluated all of them (in ascending-id order, with no
        // memoization updates in between) before accepting any pick —
        // same evaluations, same values, one parallel batch instead.
        unseen.clear();
        seen_before.clear();
        for &e in &pool[..take] {
            let inf = upper[e] == f64::INFINITY;
            seen_before.push(!inf);
            if inf {
                unseen.push(e);
            }
        }
        if !unseen.is_empty() {
            unseen_gains.clear();
            unseen_gains.resize(unseen.len(), 0.0);
            batch_gains(&*f, &unseen, &mut unseen_gains, opts.parallel, opts.threads);
            cancel::check_current()?; // don't install bounds from a partial batch
            evaluations += unseen.len() as u64;
            for (&e, &g) in unseen.iter().zip(unseen_gains.iter()) {
                debug_assert!(!g.is_nan(), "NaN gain for element {e}");
                upper[e] = g;
            }
        }
        // lazy evaluation *within the sample*: just-evaluated members
        // enter fresh, previously-seen ones enter with their stale bound
        let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(take);
        for (i, &e) in pool[..take].iter().enumerate() {
            heap.push(Entry { bound: upper[e], e, fresh: !seen_before[i] });
        }
        let mut picked: Option<(usize, f64)> = None;
        // blocked within-sample drain: block sizes double per cascade and
        // reset on every pick, same schedule as lazy.rs
        let mut block = 1usize;
        while let Some(top) = heap.pop() {
            if top.fresh {
                picked = Some((top.e, top.bound));
                break;
            }
            // drain the run of stale entries at the top of the heap (up
            // to `block`, stopping as soon as a fresh entry surfaces) and
            // recompute the whole run in one batch
            stale_ids.clear();
            stale_ids.push(top.e);
            while stale_ids.len() < block {
                match heap.peek() {
                    Some(next) if !next.fresh => {
                        let next = heap.pop().expect("peeked entry");
                        stale_ids.push(next.e);
                    }
                    _ => break,
                }
            }
            stale_gains.clear();
            stale_gains.resize(stale_ids.len(), 0.0);
            batch_gains(&*f, &stale_ids, &mut stale_gains, opts.parallel, opts.threads);
            cancel::check_current()?; // don't reinsert bounds from a partial batch
            evaluations += stale_ids.len() as u64;
            for (&e, &gain) in stale_ids.iter().zip(stale_gains.iter()) {
                debug_assert!(!gain.is_nan(), "NaN gain for element {e}");
                upper[e] = gain;
                heap.push(Entry { bound: gain, e, fresh: true });
            }
            block = (block * 2).min(LAZY_STALE_BLOCK);
        }
        let Some((e, gain)) = picked else { break };
        if should_stop(gain, opts) {
            break;
        }
        f.update_memoization(e);
        value += gain;
        if opts.verbose {
            eprintln!("[lazier {it}] pick {e} gain {gain:.6} sample {take}");
        }
        order.push((e, gain));
        let pos = pool[..take].iter().position(|&x| x == e).unwrap();
        pool.swap_remove(pos);
    }
    Ok(Selection { order, value, evaluations })
}

#[cfg(test)]
mod tests {
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::kernel::{DenseKernel, Metric};
    use crate::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};

    fn fl(n: usize, seed: u64) -> FacilityLocation {
        let data = synthetic::blobs(n, 2, 8, 2.0, seed);
        FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean))
    }

    #[test]
    fn deterministic_in_seed() {
        let f = fl(90, 31);
        let opts = MaximizeOpts { seed: 3, ..Default::default() };
        let a = maximize(&f, Budget::cardinality(9), OptimizerKind::LazierThanLazyGreedy, &opts)
            .unwrap();
        let b = maximize(&f, Budget::cardinality(9), OptimizerKind::LazierThanLazyGreedy, &opts)
            .unwrap();
        assert_eq!(a.ids(), b.ids());
    }

    #[test]
    fn near_naive_quality() {
        let f = fl(200, 32);
        let naive = maximize(
            &f,
            Budget::cardinality(15),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let lazier = maximize(
            &f,
            Budget::cardinality(15),
            OptimizerKind::LazierThanLazyGreedy,
            &MaximizeOpts { epsilon: 0.01, ..Default::default() },
        )
        .unwrap();
        assert!(lazier.value >= 0.9 * naive.value);
    }

    #[test]
    fn fewer_evaluations_than_stochastic() {
        // the lazy-within-sample trick should cut evaluations vs plain
        // stochastic at the same ε
        let f = fl(400, 33);
        let opts = MaximizeOpts { epsilon: 0.05, ..Default::default() };
        let stoch = maximize(
            &f,
            Budget::cardinality(40),
            OptimizerKind::StochasticGreedy,
            &opts,
        )
        .unwrap();
        let lazier = maximize(
            &f,
            Budget::cardinality(40),
            OptimizerKind::LazierThanLazyGreedy,
            &opts,
        )
        .unwrap();
        assert!(
            lazier.evaluations < stoch.evaluations,
            "lazier {} vs stochastic {}",
            lazier.evaluations,
            stoch.evaluations
        );
    }

    #[test]
    fn budget_sized_output() {
        let f = fl(60, 34);
        let sel = maximize(
            &f,
            Budget::cardinality(12),
            OptimizerKind::LazierThanLazyGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert_eq!(sel.order.len(), 12);
        let ids = sel.ids();
        let set: std::collections::HashSet<_> = ids.iter().collect();
        assert_eq!(set.len(), 12);
    }

    #[test]
    fn knapsack_rejected() {
        let f = fl(20, 35);
        let b = Budget::knapsack(4.0, vec![1.0; 20]).unwrap();
        assert!(maximize(
            &f,
            b,
            OptimizerKind::LazierThanLazyGreedy,
            &MaximizeOpts::default()
        )
        .is_err());
    }
}

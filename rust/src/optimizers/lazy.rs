//! LazyGreedy / Accelerated Greedy (paper §5.3.2; Minoux 1978).
//!
//! Maintains a max-heap of stale upper bounds on each element's marginal
//! gain. Submodularity guarantees gains only shrink as the set grows, so a
//! popped element whose bound was computed this iteration is guaranteed
//! optimal — no full scan. Several times faster than NaiveGreedy (paper
//! Table 2: 3.93 s → 417 ms on the 500-point workload).
//!
//! ## Blocked stale re-evaluation
//!
//! Stale entries are not recomputed one heap pop at a time. When the top
//! of the heap is stale, the run of stale entries below it is drained too
//! (up to the current block size, stopping as soon as a fresh entry tops
//! the heap), their gains are recomputed in a single
//! [`super::batch_gains`] call, and all are reinserted with fresh bounds.
//! Block sizes double per cascade — 1, 2, 4, … up to
//! [`LAZY_STALE_BLOCK`] — resetting after every accept, so the common
//! "top stays top" case performs exactly one recompute (zero waste vs the
//! serial algorithm) while long re-sort cascades stream through the
//! functions' vectorized batch kernels.
//!
//! **The selection is invariant.** An element is only ever accepted when
//! a *fresh* entry tops the heap; its exact key then dominates every
//! remaining stale bound, which by submodularity dominates every true
//! value, and the heap's `(key desc, id asc)` order resolves ties to the
//! lowest id — the same "lowest-id argmax of the true gain" the serial
//! one-pop-at-a-time algorithm accepts. Recomputing extra entries early
//! only replaces upper bounds with exact values; it can change the
//! *evaluation count* (by less than one block per accept) but never the
//! accepted element, its gain, or the final value.
//!
//! Only valid for submodular functions (the paper is explicit); for
//! non-submodular ones (DisparityMin, DisparitySum, and the max-based
//! MI/CG/CMI measures over kernels with *negative* similarities — see
//! `functions::mi::flqmi`) the solution may differ from NaiveGreedy's —
//! callers choose accordingly.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::{batch_gains, should_stop, Budget, MaximizeOpts, Selection};
use crate::error::Result;
use crate::functions::traits::SetFunction;
use crate::runtime::cancel;

/// Heap entry ordered by upper bound (gain/cost key under knapsack).
struct Entry {
    key: f64,
    gain: f64,
    e: usize,
    /// iteration at which `key` was computed; fresh == current iteration
    iter: u64,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.e == other.e
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // total_cmp, NOT partial_cmp().unwrap_or(Equal): a NaN key under
        // the old scheme compared Equal to *everything*, which violates
        // Ord's transitivity and silently corrupts the heap. total_cmp is
        // a total order (NaN sorts above +∞), so even a NaN-producing
        // function (e.g. LogDeterminant on a near-singular kernel) leaves
        // the heap structurally sound. For finite keys the order is
        // unchanged.
        self.key
            .total_cmp(&other.key)
            .then_with(|| other.e.cmp(&self.e)) // deterministic tie-break: lower id first
    }
}

/// Upper bound on the Minoux stale re-evaluation block: at most this many
/// stale heap entries are drained into one `batch_gains` call. Cascades
/// grow geometrically from 1 toward this cap (see the module docs), so
/// the cap only matters for the long re-sort storms of early iterations.
pub const LAZY_STALE_BLOCK: usize = 64;

/// All heap insertions funnel through here: a NaN upper bound means the
/// function produced a poisoned gain and lazy pruning is meaningless —
/// catch it loudly in debug builds (−∞ is legitimate: LogDeterminant
/// yields it for singular minors, and it orders fine under `total_cmp`).
fn push(heap: &mut BinaryHeap<Entry>, entry: Entry) {
    debug_assert!(
        !entry.key.is_nan(),
        "NaN lazy-greedy key for element {} (gain {})",
        entry.e,
        entry.gain
    );
    heap.push(entry);
}

pub(crate) fn run(
    f: &mut dyn SetFunction,
    budget: &Budget,
    opts: &MaximizeOpts,
) -> Result<Selection> {
    let n = f.n();
    let mut evaluations = 0u64;
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(n);
    // iteration 0: seed the heap with exact first-iteration gains, batch
    // evaluated (this full scan is LazyGreedy's only O(n) gain sweep)
    {
        let ids: Vec<usize> = (0..n).collect();
        let mut gains = vec![0f64; n];
        batch_gains(&*f, &ids, &mut gains, opts.parallel, opts.threads);
        cancel::check_current()?; // a mid-seed cancel leaves `gains` partial
        evaluations += n as u64;
        for (e, &gain) in gains.iter().enumerate() {
            push(&mut heap, Entry { key: gain / budget.cost(e), gain, e, iter: 0 });
        }
    }

    let mut order = Vec::new();
    let mut value = 0f64;
    let mut spent = 0f64;
    let mut iter = 0u64;
    let mut skipped: Vec<Entry> = Vec::new(); // over-budget entries, retried next iter
    // Minoux block state: current cap (doubles per cascade, resets on
    // accept) and reusable scratch for the drained ids / recomputed gains
    let mut block = 1usize;
    let mut stale_ids: Vec<usize> = Vec::with_capacity(LAZY_STALE_BLOCK);
    let mut stale_gains: Vec<f64> = Vec::with_capacity(LAZY_STALE_BLOCK);

    while let Some(top) = heap.pop() {
        cancel::check_current()?; // per-iteration poll (see module docs)
        let remaining = budget.max_cost - spent;
        if budget.cost(top.e) > remaining + 1e-12 {
            // cannot afford now; keep for later iterations (smaller budgets
            // never reopen under unit costs, but knapsack costs can)
            skipped.push(top);
            if heap.is_empty() {
                break;
            }
            continue;
        }
        if top.iter == iter {
            // fresh bound → guaranteed best by submodularity
            if should_stop(top.gain, opts) {
                break;
            }
            f.update_memoization(top.e);
            spent += budget.cost(top.e);
            value += top.gain;
            if opts.verbose {
                eprintln!(
                    "[lazy {}] pick {} gain {:.6} value {value:.6} heap {}",
                    order.len(),
                    top.e,
                    top.gain,
                    heap.len()
                );
            }
            order.push((top.e, top.gain));
            iter += 1;
            block = 1;
            // over-budget entries may fit again after... no: spent only grows.
            // Under knapsack, cheaper items may still fit even as the
            // remaining budget shrinks — re-add previously skipped ones
            // whose cost now exceeds remaining is pointless; only re-add
            // ones that still fit.
            let rem = budget.max_cost - spent;
            skipped.retain(|s| {
                if budget.cost(s.e) <= rem + 1e-12 {
                    push(&mut heap, Entry { key: s.key, gain: s.gain, e: s.e, iter: s.iter });
                    false
                } else {
                    true
                }
            });
            if spent + 1e-12 >= budget.max_cost && budget.is_cardinality() {
                break;
            }
        } else {
            // stale → Minoux-blocked re-evaluation: drain the run of stale
            // entries at the top of the heap (affordability-checked exactly
            // as a pop would be), recompute the whole block in one batch,
            // and reinsert with fresh bounds. Stops as soon as a fresh
            // entry surfaces — see the module docs for why the accepted
            // element is invariant under this.
            stale_ids.clear();
            stale_ids.push(top.e);
            while stale_ids.len() < block {
                match heap.peek() {
                    Some(next) if next.iter != iter => {
                        let next = heap.pop().expect("peeked entry");
                        if budget.cost(next.e) > remaining + 1e-12 {
                            skipped.push(next);
                        } else {
                            stale_ids.push(next.e);
                        }
                    }
                    _ => break,
                }
            }
            stale_gains.clear();
            stale_gains.resize(stale_ids.len(), 0.0);
            batch_gains(&*f, &stale_ids, &mut stale_gains, opts.parallel, opts.threads);
            cancel::check_current()?; // don't reinsert bounds from a partial batch
            evaluations += stale_ids.len() as u64;
            for (&e, &gain) in stale_ids.iter().zip(stale_gains.iter()) {
                push(&mut heap, Entry { key: gain / budget.cost(e), gain, e, iter });
            }
            block = (block * 2).min(LAZY_STALE_BLOCK);
        }
    }
    Ok(Selection { order, value, evaluations })
}

#[cfg(test)]
mod tests {
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::functions::graph_cut::GraphCut;
    use crate::functions::log_determinant::LogDeterminant;
    use crate::functions::set_cover::SetCover;
    use crate::functions::traits::SetFunction;
    use crate::kernel::{DenseKernel, Metric};
    use crate::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};

    fn check_matches_naive(f: &dyn SetFunction, k: usize) {
        let a = maximize(
            f,
            Budget::cardinality(k),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let b = maximize(
            f,
            Budget::cardinality(k),
            OptimizerKind::LazyGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert!((a.value - b.value).abs() < 1e-6, "{} vs {}", a.value, b.value);
        assert_eq!(a.ids(), b.ids());
    }

    #[test]
    fn matches_naive_on_fl() {
        let data = synthetic::blobs(70, 2, 5, 1.5, 11);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        check_matches_naive(&f, 10);
    }

    #[test]
    fn matches_naive_on_gc() {
        let data = synthetic::blobs(50, 2, 4, 1.0, 12);
        let f =
            GraphCut::new(DenseKernel::from_data(&data, Metric::Euclidean), 0.4).unwrap();
        check_matches_naive(&f, 8);
    }

    #[test]
    fn matches_naive_on_logdet() {
        let data = synthetic::blobs(30, 3, 3, 1.0, 13);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 0.5 });
        let f = LogDeterminant::with_regularization(k, 0.1).unwrap();
        check_matches_naive(&f, 6);
    }

    #[test]
    fn matches_naive_on_set_cover() {
        let f = SetCover::new(
            vec![vec![0, 1, 2], vec![3, 4], vec![0, 3], vec![5], vec![1, 5]],
            vec![1.0, 2.0, 1.0, 3.0, 1.0, 2.0],
        )
        .unwrap();
        check_matches_naive(&f, 4);
    }

    #[test]
    fn far_fewer_evaluations_than_naive() {
        let data = synthetic::blobs(200, 2, 10, 2.0, 14);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let a = maximize(
            &f,
            Budget::cardinality(20),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let b = maximize(
            &f,
            Budget::cardinality(20),
            OptimizerKind::LazyGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert!(
            (b.evaluations as f64) < 0.5 * a.evaluations as f64,
            "lazy {} vs naive {}",
            b.evaluations,
            a.evaluations
        );
    }

    #[test]
    fn knapsack_respected() {
        let data = synthetic::blobs(40, 2, 4, 1.0, 15);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let costs: Vec<f64> = (0..40).map(|i| 1.0 + (i % 4) as f64 * 0.5).collect();
        let sel = maximize(
            &f,
            Budget::knapsack(5.0, costs.clone()).unwrap(),
            OptimizerKind::LazyGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let total: f64 = sel.ids().iter().map(|&e| costs[e]).sum();
        assert!(total <= 5.0 + 1e-9);
    }
}

//! Submodular Cover (paper Problem 2; Wolsey 1982):
//!
//! ```text
//! min s(X)  subject to  f(X) ≥ c
//! ```
//!
//! Greedy by gain-per-cost until the coverage constraint is met. For
//! integral monotone submodular f the greedy solution is within
//! `H(max_j f(j))` of optimal; the paper presents it as the dual of
//! Problem 1.

use super::Budget;
use crate::error::{Result, SubmodError};
use crate::functions::traits::{SetFunction, Subset};

/// Result of a submodular-cover run.
#[derive(Debug, Clone)]
pub struct CoverResult {
    /// Picked elements in order with their gains.
    pub order: Vec<(usize, f64)>,
    /// Achieved f(X).
    pub value: f64,
    /// Total cost s(X).
    pub cost: f64,
    /// Whether f(X) ≥ c was reached (false = coverage infeasible or
    /// gains exhausted first).
    pub satisfied: bool,
}

/// Greedy submodular cover: grow X by best gain/cost until `f(X) ≥ c`.
/// `costs = None` means unit costs.
pub fn submodular_cover(
    f: &dyn SetFunction,
    coverage: f64,
    costs: Option<Vec<f64>>,
) -> Result<CoverResult> {
    if coverage <= 0.0 {
        return Err(SubmodError::InvalidParam(format!("coverage {coverage} must be > 0")));
    }
    let n = f.n();
    let budget = match costs {
        None => Budget::cardinality(n),
        Some(c) => Budget::knapsack(f64::INFINITY, c)?,
    };
    let mut work = f.clone_box();
    work.init_memoization(&Subset::empty(n));
    let mut in_set = vec![false; n];
    let mut order = Vec::new();
    let mut value = 0f64;
    let mut cost = 0f64;

    while value < coverage {
        let mut best: Option<(usize, f64, f64)> = None;
        for e in 0..n {
            if in_set[e] {
                continue;
            }
            let gain = work.marginal_gain_memoized(e);
            let key = gain / budget.cost(e);
            if best.map(|(_, _, bk)| key > bk).unwrap_or(true) {
                best = Some((e, gain, key));
            }
        }
        let Some((e, gain, _)) = best else { break };
        if gain <= super::ZERO_GAIN_EPS {
            break; // cannot make progress
        }
        work.update_memoization(e);
        in_set[e] = true;
        value += gain;
        cost += budget.cost(e);
        order.push((e, gain));
    }
    Ok(CoverResult { order, value, cost, satisfied: value >= coverage })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::set_cover::SetCover;

    fn sc() -> SetCover {
        SetCover::new(
            vec![vec![0, 1], vec![2, 3], vec![0, 2], vec![4], vec![0, 1, 2, 3, 4]],
            vec![1.0; 5],
        )
        .unwrap()
    }

    #[test]
    fn covers_with_minimum_elements() {
        // element 4 covers everything alone
        let r = submodular_cover(&sc(), 5.0, None).unwrap();
        assert!(r.satisfied);
        assert_eq!(r.order.len(), 1);
        assert_eq!(r.order[0].0, 4);
    }

    #[test]
    fn partial_coverage_stops() {
        // demand more than attainable
        let r = submodular_cover(&sc(), 10.0, None).unwrap();
        assert!(!r.satisfied);
        assert_eq!(r.value, 5.0);
    }

    #[test]
    fn cost_sensitive_choice() {
        // make the all-covering element prohibitively expensive: greedy
        // should assemble coverage from cheap elements instead
        let costs = vec![1.0, 1.0, 1.0, 1.0, 100.0];
        let r = submodular_cover(&sc(), 5.0, Some(costs)).unwrap();
        assert!(r.satisfied);
        assert!(r.cost < 100.0);
        assert!(!r.order.iter().any(|&(e, _)| e == 4));
    }

    #[test]
    fn invalid_coverage_rejected() {
        assert!(submodular_cover(&sc(), 0.0, None).is_err());
        assert!(submodular_cover(&sc(), -1.0, None).is_err());
    }

    #[test]
    fn duality_with_problem1() {
        // the cover solution's cost, used as a Problem-1 budget, recovers
        // at least the same value (paper: Problem 2 is the dual of 1)
        use crate::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
        let f = sc();
        let r = submodular_cover(&f, 4.0, None).unwrap();
        let sel = maximize(
            &f,
            Budget::cardinality(r.order.len()),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert!(sel.value >= r.value - 1e-9);
    }
}

//! StochasticGreedy (paper §5.3.3; Mirzasoleiman et al. 2015, "Lazier
//! than lazy greedy"'s non-lazy half): each iteration samples
//! `s = ⌈(n/k)·ln(1/ε)⌉` elements uniformly at random from the remaining
//! ground set and picks the best of the sample. Linear total running time
//! independent of the budget, (1 − 1/e − ε) guarantee in expectation.
//!
//! Cardinality budgets only (the sample-size formula needs k).
//!
//! The per-iteration sample sweep evaluates gains through
//! [`super::batch_gains`]; the argmax scans the sample in sampled order
//! accepting only strictly greater gains, so selections are bit-identical
//! to the serial loop for any fixed seed.

use super::{batch_gains, should_stop, Budget, MaximizeOpts, Selection};
use crate::error::{Result, SubmodError};
use crate::functions::traits::SetFunction;
use crate::rng::Pcg64;
use crate::runtime::cancel;

/// Sample size for one stochastic-greedy iteration:
/// `⌈(n/k)·ln(1/ε)⌉`, clamped to `[1, n]`. Public so parity suites can
/// replicate the optimizer's exact sampling sequence.
pub fn sample_size(n: usize, k: usize, epsilon: f64) -> usize {
    let s = ((n as f64 / k as f64) * (1.0 / epsilon).ln()).ceil() as usize;
    s.clamp(1, n)
}

pub(crate) fn run(
    f: &mut dyn SetFunction,
    budget: &Budget,
    opts: &MaximizeOpts,
) -> Result<Selection> {
    let Some(k) = budget.as_count() else {
        return Err(SubmodError::Unsupported(
            "StochasticGreedy requires a cardinality budget".into(),
        ));
    };
    if !(0.0 < opts.epsilon && opts.epsilon < 1.0) {
        return Err(SubmodError::InvalidParam(format!(
            "epsilon {} outside (0,1)",
            opts.epsilon
        )));
    }
    let n = f.n();
    let k = k.min(n);
    let s = sample_size(n, k, opts.epsilon);
    let mut rng = Pcg64::new(opts.seed);
    // remaining elements as a swap-removable pool
    let mut pool: Vec<usize> = (0..n).collect();
    let mut order = Vec::new();
    let mut value = 0f64;
    let mut evaluations = 0u64;
    let mut gains: Vec<f64> = Vec::with_capacity(s);

    for it in 0..k {
        cancel::check_current()?; // per-iteration poll
        if pool.is_empty() {
            break;
        }
        let take = s.min(pool.len());
        // sample `take` distinct pool positions via partial Fisher–Yates
        for i in 0..take {
            let j = i + rng.next_below(pool.len() - i);
            pool.swap(i, j);
        }
        gains.clear();
        gains.resize(take, 0.0);
        batch_gains(&*f, &pool[..take], &mut gains, opts.parallel, opts.threads);
        cancel::check_current()?; // a mid-sweep cancel leaves `gains` partial
        evaluations += take as u64;
        let mut best: Option<(usize, usize, f64)> = None; // (pool pos, e, gain)
        for (pos, (&e, &gain)) in pool[..take].iter().zip(gains.iter()).enumerate() {
            if best.map(|(_, _, bg)| gain > bg).unwrap_or(true) {
                best = Some((pos, e, gain));
            }
        }
        let Some((pos, e, gain)) = best else { break };
        if should_stop(gain, opts) {
            break;
        }
        f.update_memoization(e);
        value += gain;
        if opts.verbose {
            eprintln!("[stochastic {it}] pick {e} gain {gain:.6} sample {take}");
        }
        order.push((e, gain));
        pool.swap_remove(pos);
    }
    Ok(Selection { order, value, evaluations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::kernel::{DenseKernel, Metric};
    use crate::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};

    #[test]
    fn sample_size_formula() {
        // n=500, k=100, ε=0.1 → (5)·ln(10) ≈ 11.5 → 12
        assert_eq!(sample_size(500, 100, 0.1), 12);
        assert_eq!(sample_size(10, 10, 0.5), 1);
        assert!(sample_size(100, 1, 1e-9) <= 100);
    }

    #[test]
    fn deterministic_in_seed() {
        let data = synthetic::blobs(80, 2, 4, 1.0, 21);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let opts = MaximizeOpts { seed: 7, ..Default::default() };
        let a = maximize(&f, Budget::cardinality(10), OptimizerKind::StochasticGreedy, &opts)
            .unwrap();
        let b = maximize(&f, Budget::cardinality(10), OptimizerKind::StochasticGreedy, &opts)
            .unwrap();
        assert_eq!(a.ids(), b.ids());
    }

    #[test]
    fn different_seeds_usually_differ() {
        let data = synthetic::blobs(100, 2, 5, 2.0, 22);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let a = maximize(
            &f,
            Budget::cardinality(10),
            OptimizerKind::StochasticGreedy,
            &MaximizeOpts { seed: 1, epsilon: 0.5, ..Default::default() },
        )
        .unwrap();
        let b = maximize(
            &f,
            Budget::cardinality(10),
            OptimizerKind::StochasticGreedy,
            &MaximizeOpts { seed: 2, epsilon: 0.5, ..Default::default() },
        )
        .unwrap();
        assert_ne!(a.ids(), b.ids());
    }

    #[test]
    fn fewer_evaluations_than_naive() {
        let data = synthetic::blobs(300, 2, 10, 2.0, 23);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let naive = maximize(
            &f,
            Budget::cardinality(30),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let stoch = maximize(
            &f,
            Budget::cardinality(30),
            OptimizerKind::StochasticGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert!(stoch.evaluations < naive.evaluations / 4);
    }

    #[test]
    fn knapsack_rejected() {
        let data = synthetic::blobs(20, 2, 2, 1.0, 24);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let b = Budget::knapsack(5.0, vec![1.0; 20]).unwrap();
        assert!(maximize(&f, b, OptimizerKind::StochasticGreedy, &MaximizeOpts::default())
            .is_err());
    }

    #[test]
    fn invalid_epsilon_rejected() {
        let data = synthetic::blobs(20, 2, 2, 1.0, 25);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        for eps in [0.0, 1.0, -0.5] {
            assert!(maximize(
                &f,
                Budget::cardinality(5),
                OptimizerKind::StochasticGreedy,
                &MaximizeOpts { epsilon: eps, ..Default::default() }
            )
            .is_err());
        }
    }
}

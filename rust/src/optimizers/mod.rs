//! The optimizer suite (paper §5.3): NaiveGreedy, LazyGreedy (Minoux's
//! accelerated greedy), StochasticGreedy (Mirzasoleiman et al.), and
//! LazierThanLazyGreedy ("random sampling with lazy evaluation"), plus the
//! Submodular Cover solver for Problem 2 (Wolsey).
//!
//! The de-coupled paradigm (paper §5.1): any [`SetFunction`] is first
//! instantiated, then [`maximize`] is called on it with a [`Budget`], an
//! [`OptimizerKind`] and [`MaximizeOpts`]. The optimizers drive only the
//! memoized interface (`init_memoization` / `marginal_gain_memoized` /
//! `update_memoization`), so every function's Table 3/4 statistics are
//! exercised on the hot path.
//!
//! ## Batched, parallel gain scans
//!
//! Full-scan steps — every NaiveGreedy iteration, StochasticGreedy's
//! per-iteration sample sweep, LazyGreedy's iteration-0 heap seeding plus
//! its Minoux-blocked stale re-evaluation (see [`lazy`]), and
//! LazierThanLazy's first touch of each sampled element — no longer call
//! `marginal_gain_memoized` one element at a time. They collect the
//! candidate ids and hand them to [`SetFunction::marginal_gains_batch`]
//! via [`batch_gains`], which fans fixed-size candidate chunks out over
//! the persistent worker pool (`runtime::pool`; `SetFunction: Sync`
//! makes the shared read-only fan-out safe) — no threads are spawned
//! per call.
//!
//! **Determinism is preserved exactly:** the gains a batch produces are
//! bit-identical to the serial per-element path (the trait contract), and
//! the subsequent argmax is a single serial scan in ascending candidate
//! order where only a *strictly greater* key replaces the incumbent — so
//! ties resolve to the lowest id, within and across chunks, exactly as
//! the old one-at-a-time loop did. `MaximizeOpts::parallel = false`
//! forces the serial per-element path (used by the determinism tests and
//! the bench baseline); selections are identical either way.
//!
//! ## Cooperative cancellation
//!
//! Every optimizer polls the ambient [`cancel`] token at two boundaries:
//! once **per iteration** (before committing another pick) and once
//! **after every [`batch_gains`] scan, before the argmax** — the second
//! poll matters because a cancel that lands mid-scan leaves the tail of
//! the gain buffer unwritten, and an argmax over it would commit a
//! nondeterministic pick via `update_memoization`. [`batch_gains`]
//! itself polls once per [`GAIN_CHUNK`] on *every* path (serial,
//! single-call, pooled), so a fired token bounds the remaining work to
//! one chunk per participant. Cancellation is all-or-nothing:
//! [`maximize`] returns `SubmodError::Cancelled` and no partial
//! [`Selection`] is observable (the memoized state mutated was a
//! private clone). A token that never fires is inert — polls read an
//! atomic flag and change no claim order, so selections are
//! byte-identical with or without `MaximizeOpts::cancel`, at every pool
//! width and on every backend (pinned by `tests/pool_matrix.rs`).
//!
//! [`cancel`]: crate::runtime::cancel

pub mod cover;
pub mod lazier;
pub mod lazy;
pub mod naive;
pub mod stochastic;

use std::sync::Arc;

use crate::coordinator::faults;
use crate::error::{Result, SubmodError};
use crate::functions::traits::{ElementId, SetFunction, Subset};
use crate::runtime::cancel::{self, CancelToken};
use crate::runtime::pool;

pub use cover::submodular_cover;

/// Positive gains below this threshold count as zero for the
/// `stop_if_zero_gain` rule (float noise guard).
pub const ZERO_GAIN_EPS: f64 = 1e-12;

/// Selection budget: cardinality or knapsack (paper Problem 1).
#[derive(Debug, Clone)]
pub struct Budget {
    /// Maximum total cost.
    pub max_cost: f64,
    /// Per-element costs; `None` = unit costs (cardinality constraint).
    pub costs: Option<Arc<Vec<f64>>>,
}

impl Budget {
    /// Cardinality constraint |X| ≤ k.
    pub fn cardinality(k: usize) -> Budget {
        Budget { max_cost: k as f64, costs: None }
    }

    /// Knapsack constraint Σ_{i∈X} c_i ≤ b.
    pub fn knapsack(b: f64, costs: Vec<f64>) -> Result<Budget> {
        if costs.iter().any(|&c| c <= 0.0) {
            return Err(SubmodError::InvalidParam("knapsack costs must be > 0".into()));
        }
        Ok(Budget { max_cost: b, costs: Some(Arc::new(costs)) })
    }

    #[inline]
    pub fn cost(&self, e: ElementId) -> f64 {
        match &self.costs {
            None => 1.0,
            Some(c) => c[e],
        }
    }

    pub fn is_cardinality(&self) -> bool {
        self.costs.is_none()
    }

    /// Budget as an integer element count (cardinality budgets only).
    pub fn as_count(&self) -> Option<usize> {
        self.is_cardinality().then_some(self.max_cost as usize)
    }
}

/// Options shared by all optimizers, mirroring Submodlib's maximize()
/// keyword arguments.
#[derive(Debug, Clone)]
pub struct MaximizeOpts {
    /// Stop when the best available gain is ≤ [`ZERO_GAIN_EPS`].
    pub stop_if_zero_gain: bool,
    /// Stop when the best available gain is negative.
    pub stop_if_negative_gain: bool,
    /// Stochastic/Lazier sample-size parameter ε (sample size
    /// ⌈(n/k)·ln(1/ε)⌉).
    pub epsilon: f64,
    /// RNG seed for the stochastic optimizers.
    pub seed: u64,
    /// Print per-iteration traces.
    pub verbose: bool,
    /// Evaluate full-scan marginal gains via the batched, multi-threaded
    /// path (default). `false` forces the serial per-element loop; the
    /// selection is identical either way (see the module docs), so this
    /// exists for baselining and determinism tests, not correctness.
    pub parallel: bool,
    /// Cap on the number of pool participants a gain scan uses; `None`
    /// (default) means the full resolved width
    /// (`runtime::pool::num_threads()`, i.e. `SUBMODLIB_THREADS` or
    /// `available_parallelism`). Values are clamped to that width — the
    /// pool can narrow but never widen. Selections are bit-identical at
    /// any cap (the pool's indexed-slot determinism rule); this is a
    /// wall-clock knob only.
    pub threads: Option<usize>,
    /// Cooperative cancellation token. [`maximize`] installs it as the
    /// ambient cancel scope for the whole run (seeding scans, kernel
    /// access, every pool fan-out) and returns
    /// `SubmodError::Cancelled` at the next poll boundary once it
    /// fires. `None` (default) inherits whatever ambient scope the
    /// caller already installed (none, for plain library use). An
    /// armed-but-unfired token is inert: selections are byte-identical
    /// to a run without one.
    pub cancel: Option<CancelToken>,
}

impl Default for MaximizeOpts {
    fn default() -> Self {
        MaximizeOpts {
            stop_if_zero_gain: true,
            stop_if_negative_gain: true,
            epsilon: 0.1,
            seed: 1,
            verbose: false,
            parallel: true,
            threads: None,
            cancel: None,
        }
    }
}

/// Result of a greedy maximization.
#[derive(Debug, Clone)]
pub struct Selection {
    /// (element, marginal gain at pick time), in pick order — the
    /// "greedyList" of the paper's sample code.
    pub order: Vec<(ElementId, f64)>,
    /// Final objective value f(X) (= Σ gains, since f(∅) = 0 for every
    /// function in the suite).
    pub value: f64,
    /// Number of marginal-gain evaluations performed (the quantity the
    /// lazy variants reduce; reported by the optimizer benches).
    pub evaluations: u64,
}

impl Selection {
    /// Selected ids only.
    pub fn ids(&self) -> Vec<ElementId> {
        self.order.iter().map(|&(e, _)| e).collect()
    }

    /// As a [`Subset`] over ground size n.
    pub fn subset(&self, n: usize) -> Subset {
        Subset::from_ids(n, &self.ids())
    }
}

/// The four greedy maximizers (paper §5.3.1–§5.3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    NaiveGreedy,
    LazyGreedy,
    StochasticGreedy,
    LazierThanLazyGreedy,
}

impl std::str::FromStr for OptimizerKind {
    type Err = SubmodError;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "naivegreedy" | "naive" => Ok(OptimizerKind::NaiveGreedy),
            "lazygreedy" | "lazy" => Ok(OptimizerKind::LazyGreedy),
            "stochasticgreedy" | "stochastic" => Ok(OptimizerKind::StochasticGreedy),
            "lazierthanlazygreedy" | "lazier" => Ok(OptimizerKind::LazierThanLazyGreedy),
            other => Err(SubmodError::InvalidParam(format!("unknown optimizer {other:?}"))),
        }
    }
}

/// Maximize `f` under `budget` with the chosen optimizer. The function's
/// memoization state is cloned, not mutated — repeated calls on the same
/// instance are independent (matching Submodlib's maximize()).
pub fn maximize(
    f: &dyn SetFunction,
    budget: Budget,
    kind: OptimizerKind,
    opts: &MaximizeOpts,
) -> Result<Selection> {
    if budget.max_cost <= 0.0 {
        return Err(SubmodError::InvalidParam(format!(
            "budget {} must be > 0",
            budget.max_cost
        )));
    }
    if let Some(costs) = &budget.costs {
        if costs.len() != f.n() {
            return Err(SubmodError::Shape(format!(
                "{} costs for ground set of {}",
                costs.len(),
                f.n()
            )));
        }
    }
    let mut work = f.clone_box();
    work.init_memoization(&Subset::empty(f.n()));
    let run = move |work: &mut dyn SetFunction| -> Result<Selection> {
        cancel::check_current()?;
        match kind {
            OptimizerKind::NaiveGreedy => naive::run(work, &budget, opts),
            OptimizerKind::LazyGreedy => lazy::run(work, &budget, opts),
            OptimizerKind::StochasticGreedy => stochastic::run(work, &budget, opts),
            OptimizerKind::LazierThanLazyGreedy => lazier::run(work, &budget, opts),
        }
    };
    match &opts.cancel {
        // install the caller's token as the ambient scope for the whole
        // run; None inherits any scope already installed (coordinator
        // stage-1 workers run under the request's scope)
        Some(token) => cancel::with_scope(Some(token.clone()), || run(work.as_mut())),
        None => run(work.as_mut()),
    }
}

/// Shared stop-rule check: should the loop halt given the best gain found?
///
/// A −∞ gain terminates unconditionally, independent of the configurable
/// stop flags: it marks an element whose addition makes the function
/// undefined (LogDeterminant yields −∞ for candidates that drive the
/// kernel singular), and committing one would desynchronize the reported
/// selection from the function's memoized state — `evaluate()` of the
/// returned ids would no longer equal the accumulated value.
pub(crate) fn should_stop(best_gain: f64, opts: &MaximizeOpts) -> bool {
    best_gain == f64::NEG_INFINITY
        || (opts.stop_if_negative_gain && best_gain < 0.0)
        || (opts.stop_if_zero_gain && best_gain <= ZERO_GAIN_EPS)
}

/// Below this candidate count a gain scan stays on one thread: even a
/// pool dispatch costs more than the saved work (each gain is at most
/// O(n) and usually far less).
pub const PARALLEL_MIN_CANDIDATES: usize = 256;

/// Candidates per claimable chunk of a parallel gain scan. Fixed-size
/// chunks (instead of one even pre-split per thread) let participants
/// that land on cheap candidates claim more chunks — better load balance
/// when `marginal_gains_batch` costs are skewed (e.g. FL sparse rows of
/// very different degree) — while each candidate still writes its own
/// output slot, so the bytes out are identical.
pub const GAIN_CHUNK: usize = 64;

/// Evaluate the memoized gains of `candidates` into `out`, fanning the
/// batch out across the persistent worker pool (`runtime::pool`) when it
/// is large enough. With `parallel = false` this is the plain serial
/// per-element loop. `threads` caps the participant count (`None` = the
/// full pool width).
///
/// Parallelism cannot change results: chunks are claimed off an atomic
/// counter, each element's gain is computed by the same
/// `marginal_gains_batch` code against the same (read-only) memoized
/// state whichever participant claims its chunk, every gain lands in its
/// own pre-split output slot, and the trait contract guarantees batch ==
/// per-element bit-for-bit — the pool's indexed-slot determinism rule.
///
/// Every path — serial, single-call, pooled — walks the scan in
/// [`GAIN_CHUNK`] chunks and polls the ambient cancel token (plus the
/// `GAIN_CHUNK` failpoint, keyed by the scan's candidate count) before
/// each chunk; the sub-batching is invisible in the output because the
/// trait contract makes sub-batches bit-equal to one full batch. A
/// fired token returns early with the *tail of `out` unwritten* —
/// callers must poll `cancel::check_current()` before consuming the
/// gains (every optimizer does, before its argmax).
pub fn batch_gains(
    f: &dyn SetFunction,
    candidates: &[ElementId],
    out: &mut [f64],
    parallel: bool,
    threads: Option<usize>,
) {
    debug_assert_eq!(candidates.len(), out.len());
    let len = candidates.len();
    if !parallel {
        for (ci, out_chunk) in out.chunks_mut(GAIN_CHUNK).enumerate() {
            faults::trip(faults::GAIN_CHUNK, len);
            if cancel::active() {
                return;
            }
            let c0 = ci * GAIN_CHUNK;
            for (o, &e) in out_chunk.iter_mut().zip(&candidates[c0..]) {
                *o = f.marginal_gain_memoized(e);
            }
        }
        return;
    }
    let width = threads
        .map(|t| t.clamp(1, pool::num_threads()))
        .unwrap_or_else(pool::num_threads);
    let chunks = len.div_ceil(GAIN_CHUNK);
    let parts = width.min(chunks);
    if len < PARALLEL_MIN_CANDIDATES || parts < 2 {
        for (ci, out_chunk) in out.chunks_mut(GAIN_CHUNK).enumerate() {
            faults::trip(faults::GAIN_CHUNK, len);
            if cancel::active() {
                return;
            }
            let c0 = ci * GAIN_CHUNK;
            f.marginal_gains_batch(&candidates[c0..c0 + out_chunk.len()], out_chunk);
        }
        return;
    }
    pool::run_indexed(parts, out.chunks_mut(GAIN_CHUNK).collect(), |t, out_chunk| {
        faults::trip(faults::GAIN_CHUNK, len);
        if cancel::active() {
            return;
        }
        let c0 = t * GAIN_CHUNK;
        f.marginal_gains_batch(&candidates[c0..c0 + out_chunk.len()], out_chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::functions::facility_location::FacilityLocation;
    use crate::kernel::{DenseKernel, Metric};

    fn fl(n: usize, seed: u64) -> FacilityLocation {
        let data = synthetic::blobs(n, 2, 4, 1.0, seed);
        FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean))
    }

    #[test]
    fn budget_validation() {
        let f = fl(10, 1);
        assert!(maximize(
            &f,
            Budget::cardinality(0),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default()
        )
        .is_err());
        assert!(Budget::knapsack(3.0, vec![1.0, -2.0]).is_err());
        let b = Budget::knapsack(3.0, vec![1.0; 5]).unwrap(); // wrong len
        assert!(maximize(&f, b, OptimizerKind::NaiveGreedy, &MaximizeOpts::default())
            .is_err());
    }

    #[test]
    fn all_optimizers_return_budget_sized_sets() {
        let f = fl(60, 2);
        for kind in [
            OptimizerKind::NaiveGreedy,
            OptimizerKind::LazyGreedy,
            OptimizerKind::StochasticGreedy,
            OptimizerKind::LazierThanLazyGreedy,
        ] {
            let sel =
                maximize(&f, Budget::cardinality(8), kind, &MaximizeOpts::default())
                    .unwrap();
            assert_eq!(sel.order.len(), 8, "{kind:?}");
            // ids distinct
            let ids = sel.ids();
            let set: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(set.len(), 8);
            // value equals evaluate() of the returned set
            let v = f.evaluate(&sel.subset(60));
            assert!((v - sel.value).abs() < 1e-6, "{kind:?}: {v} vs {}", sel.value);
        }
    }

    #[test]
    fn lazy_matches_naive_exactly() {
        let f = fl(80, 3);
        let a = maximize(
            &f,
            Budget::cardinality(12),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let b = maximize(
            &f,
            Budget::cardinality(12),
            OptimizerKind::LazyGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert_eq!(a.ids(), b.ids());
        assert!((a.value - b.value).abs() < 1e-9);
        assert!(b.evaluations < a.evaluations, "lazy should evaluate less");
    }

    #[test]
    fn stochastic_near_naive_value() {
        let f = fl(100, 4);
        let a = maximize(
            &f,
            Budget::cardinality(10),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let b = maximize(
            &f,
            Budget::cardinality(10),
            OptimizerKind::StochasticGreedy,
            &MaximizeOpts { epsilon: 0.01, ..Default::default() },
        )
        .unwrap();
        assert!(b.value >= 0.9 * a.value, "{} vs {}", b.value, a.value);
    }

    const ALL_KINDS: [OptimizerKind; 4] = [
        OptimizerKind::NaiveGreedy,
        OptimizerKind::LazyGreedy,
        OptimizerKind::StochasticGreedy,
        OptimizerKind::LazierThanLazyGreedy,
    ];

    #[test]
    fn fired_cancel_token_aborts_every_optimizer() {
        use crate::runtime::cancel::CancelReason;
        let f = fl(60, 5);
        for kind in ALL_KINDS {
            let token = CancelToken::new();
            token.fire(CancelReason::Manual);
            let res = maximize(
                &f,
                Budget::cardinality(8),
                kind,
                &MaximizeOpts { cancel: Some(token), ..Default::default() },
            );
            assert!(matches!(res, Err(SubmodError::Cancelled)), "{kind:?}");
        }
        // the shared instance is untouched (the optimizer mutated only
        // its private clone): a clean run afterwards works normally
        let sel = maximize(
            &f,
            Budget::cardinality(8),
            OptimizerKind::NaiveGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        assert_eq!(sel.order.len(), 8);
    }

    #[test]
    fn unfired_cancel_token_is_byte_inert() {
        let f = fl(70, 6);
        for kind in ALL_KINDS {
            let base =
                maximize(&f, Budget::cardinality(9), kind, &MaximizeOpts::default())
                    .unwrap();
            let armed = maximize(
                &f,
                Budget::cardinality(9),
                kind,
                &MaximizeOpts { cancel: Some(CancelToken::new()), ..Default::default() },
            )
            .unwrap();
            assert_eq!(base.ids(), armed.ids(), "{kind:?}");
            assert_eq!(base.value.to_bits(), armed.value.to_bits(), "{kind:?}");
            for (b, a) in base.order.iter().zip(&armed.order) {
                assert_eq!(b.1.to_bits(), a.1.to_bits(), "{kind:?} gain bits");
            }
        }
    }

    #[test]
    fn optimizer_kind_parse() {
        assert_eq!("lazy".parse::<OptimizerKind>().unwrap(), OptimizerKind::LazyGreedy);
        assert_eq!(
            "NaiveGreedy".parse::<OptimizerKind>().unwrap(),
            OptimizerKind::NaiveGreedy
        );
        assert!("fancy".parse::<OptimizerKind>().is_err());
    }
}

//! Command-line interface — a small hand-rolled parser (clap is
//! unavailable in the offline registry) with the same UX:
//!
//! ```text
//! submodlib select   --data points.csv --function fl --budget 10 --optimizer lazy
//! submodlib exp      table2|table5|fig3|fig5|fig7|fig8|fig10|all [--quick]
//! submodlib serve    --items 2000 --requests 20        # streaming demo
//! submodlib runtime  --n 512 --dim 1024                # PJRT vs native kernel build
//! ```

use std::collections::HashMap;

use crate::error::{Result, SubmodError};

/// Parsed command line.
#[derive(Debug)]
pub struct Cli {
    pub config: Option<String>,
    pub command: Command,
}

#[derive(Debug)]
pub enum Command {
    Select {
        data: String,
        function: String,
        budget: usize,
        optimizer: String,
        metric: String,
        param: f64,
        out: Option<String>,
    },
    Exp {
        target: String,
        quick: bool,
    },
    Serve {
        items: usize,
        dim: usize,
        requests: usize,
        budget: usize,
    },
    Runtime {
        n: usize,
        dim: usize,
        artifacts: String,
    },
    /// Problem 2 (Submodular Cover): min-cost subset with f(X) ≥ c·f(V).
    Cover {
        data: String,
        function: String,
        /// coverage as a fraction of f(V)
        fraction: f64,
        metric: String,
    },
    /// Sustained-load harness: seeded multi-tenant chaos traffic against
    /// the coordinator, reported as `bench_loadgen/v1` JSON.
    Loadgen {
        cfg: crate::coordinator::LoadgenConfig,
        out: String,
    },
    /// Run the determinism conformance linter over the repo's sources.
    Lint {
        /// Repo root to scan (defaults to the current directory).
        root: Option<String>,
        /// Print the rule table instead of linting.
        rules: bool,
    },
    Help,
}

pub const USAGE: &str = "\
submodlib — Submodlib (2022) reproduction: submodular optimization engine

USAGE:
  submodlib [--config cfg.json] <COMMAND> [OPTIONS]

COMMANDS:
  select    one-shot subset selection from a CSV feature matrix
              --data <csv> [--function fl|gc|logdet|dsum|dmin|fb]
              [--budget 10] [--optimizer naive|lazy|stochastic|lazier]
              [--metric euclidean|cosine|dot|rbf] [--param 0.4] [--out sel.csv]
  exp       reproduce a paper table/figure (CSV dumps into out_dir)
              <table2|table5|fig3|fig5|fig7|fig8|fig10|all> [--quick]
  serve     streaming-coordinator demo (synthetic stream + selections)
              [--items 2000] [--dim 16] [--requests 10] [--budget 10]
  runtime   PJRT-artifact kernel build vs native, with numerics check
              [--n 512] [--dim 1024] [--artifacts artifacts]
  cover     Problem 2: minimum subset reaching a coverage target
              --data <csv> [--function fl] [--fraction 0.9] [--metric euclidean]
  loadgen   sustained multi-tenant load harness (writes bench_loadgen/v1 JSON)
              [--items 600] [--dim 8] [--tenants 4] [--requests 16] [--budget 8]
              [--max-inflight 2] [--queue-depth 2] [--breaker-threshold 3]
              [--breaker-probe 4] [--deadline-ms 0] [--quorum 1] [--seed 42]
              [--shed-retries 2] [--out BENCH_loadgen.json]
              chaos (needs --features faults): [--panic-prob 0] [--error-prob 0]
              [--delay-prob 0] [--delay-ms 5] [--drain-panic-prob 0]
  lint      determinism conformance linter over rust/src, rust/tests, rust/benches
              [--root <repo-dir>] [--rules]
  help      this text
";

/// Split argv into flags (`--k v` / bare `--flag`) and positionals.
fn split_args(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut pos = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let is_bare = i + 1 >= args.len() || args[i + 1].starts_with("--");
            if is_bare {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (flags, pos)
}

fn get_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| SubmodError::InvalidParam(format!("--{key} {v:?} is not an integer"))),
    }
}

fn get_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| SubmodError::InvalidParam(format!("--{key} {v:?} is not a number"))),
    }
}

impl Cli {
    /// Parse from raw args (everything after the program name).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let (flags, pos) = split_args(args);
        let config = flags.get("config").cloned();
        let cmd = pos.first().map(String::as_str).unwrap_or("help");
        let command = match cmd {
            "select" => Command::Select {
                data: flags
                    .get("data")
                    .cloned()
                    .ok_or_else(|| SubmodError::InvalidParam("select requires --data".into()))?,
                function: flags.get("function").cloned().unwrap_or_else(|| "fl".into()),
                budget: get_usize(&flags, "budget", 10)?,
                optimizer: flags.get("optimizer").cloned().unwrap_or_else(|| "lazy".into()),
                metric: flags.get("metric").cloned().unwrap_or_else(|| "euclidean".into()),
                param: get_f64(&flags, "param", 0.4)?,
                out: flags.get("out").cloned(),
            },
            "exp" => Command::Exp {
                target: pos
                    .get(1)
                    .cloned()
                    .ok_or_else(|| SubmodError::InvalidParam("exp requires a target".into()))?,
                quick: flags.contains_key("quick"),
            },
            "serve" => Command::Serve {
                items: get_usize(&flags, "items", 2000)?,
                dim: get_usize(&flags, "dim", 16)?,
                requests: get_usize(&flags, "requests", 10)?,
                budget: get_usize(&flags, "budget", 10)?,
            },
            "runtime" => Command::Runtime {
                n: get_usize(&flags, "n", 512)?,
                dim: get_usize(&flags, "dim", 1024)?,
                artifacts: flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()),
            },
            "cover" => Command::Cover {
                data: flags
                    .get("data")
                    .cloned()
                    .ok_or_else(|| SubmodError::InvalidParam("cover requires --data".into()))?,
                function: flags.get("function").cloned().unwrap_or_else(|| "fl".into()),
                fraction: get_f64(&flags, "fraction", 0.9)?,
                metric: flags.get("metric").cloned().unwrap_or_else(|| "euclidean".into()),
            },
            "loadgen" => {
                let defaults = crate::coordinator::LoadgenConfig::default();
                // 0 means "disabled" for the optional knobs
                let breaker = get_usize(
                    &flags,
                    "breaker-threshold",
                    defaults.breaker_threshold.unwrap_or(0),
                )?;
                let deadline_ms = get_usize(&flags, "deadline-ms", 0)?;
                let quorum =
                    get_usize(&flags, "quorum", defaults.min_shard_quorum.unwrap_or(0))?;
                Command::Loadgen {
                    cfg: crate::coordinator::LoadgenConfig {
                        items: get_usize(&flags, "items", defaults.items)?,
                        dim: get_usize(&flags, "dim", defaults.dim)?,
                        shard_capacity: get_usize(
                            &flags,
                            "shard-capacity",
                            defaults.shard_capacity,
                        )?,
                        tenants: get_usize(&flags, "tenants", defaults.tenants)?,
                        requests_per_tenant: get_usize(
                            &flags,
                            "requests",
                            defaults.requests_per_tenant,
                        )?,
                        budget: get_usize(&flags, "budget", defaults.budget)?,
                        max_inflight: get_usize(&flags, "max-inflight", defaults.max_inflight)?,
                        admission_queue_depth: get_usize(
                            &flags,
                            "queue-depth",
                            defaults.admission_queue_depth,
                        )?,
                        breaker_threshold: (breaker > 0).then_some(breaker),
                        breaker_probe_after: get_usize(
                            &flags,
                            "breaker-probe",
                            defaults.breaker_probe_after,
                        )?,
                        deadline_ms: (deadline_ms > 0).then_some(deadline_ms as u64),
                        min_shard_quorum: (quorum > 0).then_some(quorum),
                        seed: get_usize(&flags, "seed", defaults.seed as usize)? as u64,
                        shed_retries: get_usize(&flags, "shed-retries", defaults.shed_retries)?,
                        stage1_panic_prob: get_f64(&flags, "panic-prob", 0.0)?,
                        stage1_error_prob: get_f64(&flags, "error-prob", 0.0)?,
                        stage2_delay_prob: get_f64(&flags, "delay-prob", 0.0)?,
                        stage2_delay_ms: get_usize(
                            &flags,
                            "delay-ms",
                            defaults.stage2_delay_ms as usize,
                        )? as u64,
                        drain_panic_prob: get_f64(&flags, "drain-panic-prob", 0.0)?,
                    },
                    out: flags
                        .get("out")
                        .cloned()
                        .unwrap_or_else(|| "BENCH_loadgen.json".into()),
                }
            }
            "lint" => Command::Lint {
                root: flags.get("root").cloned(),
                rules: flags.contains_key("rules"),
            },
            "help" | "--help" | "-h" => Command::Help,
            other => {
                return Err(SubmodError::InvalidParam(format!("unknown command {other:?}")))
            }
        };
        Ok(Cli { config, command })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_select() {
        let c = Cli::parse(&argv("select --data d.csv --budget 7 --optimizer naive")).unwrap();
        match c.command {
            Command::Select { data, budget, optimizer, .. } => {
                assert_eq!(data, "d.csv");
                assert_eq!(budget, 7);
                assert_eq!(optimizer, "naive");
            }
            _ => panic!("wrong command"),
        }
    }

    #[test]
    fn select_requires_data() {
        assert!(Cli::parse(&argv("select --budget 5")).is_err());
    }

    #[test]
    fn parses_exp_with_quick() {
        let c = Cli::parse(&argv("exp table2 --quick")).unwrap();
        match c.command {
            Command::Exp { target, quick } => {
                assert_eq!(target, "table2");
                assert!(quick);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn global_config_flag() {
        let c = Cli::parse(&argv("--config cfg.json serve --items 10")).unwrap();
        assert_eq!(c.config.as_deref(), Some("cfg.json"));
        match c.command {
            Command::Serve { items, .. } => assert_eq!(items, 10),
            _ => panic!(),
        }
    }

    #[test]
    fn bad_numbers_rejected() {
        assert!(Cli::parse(&argv("serve --items ten")).is_err());
        assert!(Cli::parse(&argv("select --data x --param abc")).is_err());
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(Cli::parse(&argv("frobnicate")).is_err());
    }

    #[test]
    fn parses_cover() {
        let c = Cli::parse(&argv("cover --data d.csv --fraction 0.8")).unwrap();
        match c.command {
            Command::Cover { data, fraction, function, .. } => {
                assert_eq!(data, "d.csv");
                assert_eq!(fraction, 0.8);
                assert_eq!(function, "fl");
            }
            _ => panic!(),
        }
        assert!(Cli::parse(&argv("cover --fraction 0.8")).is_err());
    }

    #[test]
    fn parses_lint() {
        let c = Cli::parse(&argv("lint")).unwrap();
        match c.command {
            Command::Lint { root, rules } => {
                assert!(root.is_none());
                assert!(!rules);
            }
            _ => panic!(),
        }
        let c = Cli::parse(&argv("lint --root /tmp/repo --rules")).unwrap();
        match c.command {
            Command::Lint { root, rules } => {
                assert_eq!(root.as_deref(), Some("/tmp/repo"));
                assert!(rules);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn parses_loadgen() {
        let c = Cli::parse(&argv(
            "loadgen --tenants 6 --requests 3 --max-inflight 1 --queue-depth 1 \
             --breaker-threshold 0 --deadline-ms 250 --seed 7 --out lg.json",
        ))
        .unwrap();
        match c.command {
            Command::Loadgen { cfg, out } => {
                assert_eq!(cfg.tenants, 6);
                assert_eq!(cfg.requests_per_tenant, 3);
                assert_eq!(cfg.max_inflight, 1);
                assert_eq!(cfg.admission_queue_depth, 1);
                assert_eq!(cfg.breaker_threshold, None, "0 disables the breaker");
                assert_eq!(cfg.deadline_ms, Some(250));
                assert_eq!(cfg.seed, 7);
                assert_eq!(out, "lg.json");
                // chaos defaults off
                assert_eq!(cfg.stage1_panic_prob, 0.0);
            }
            _ => panic!(),
        }
        // defaults: breaker on, no deadline, default out path
        let c = Cli::parse(&argv("loadgen")).unwrap();
        match c.command {
            Command::Loadgen { cfg, out } => {
                assert!(cfg.breaker_threshold.is_some());
                assert_eq!(cfg.deadline_ms, None);
                assert_eq!(out, "BENCH_loadgen.json");
            }
            _ => panic!(),
        }
        assert!(Cli::parse(&argv("loadgen --tenants six")).is_err());
    }

    #[test]
    fn defaults_to_help() {
        let c = Cli::parse(&[]).unwrap();
        assert!(matches!(c.command, Command::Help));
    }
}

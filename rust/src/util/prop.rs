//! Proptest-style randomized property checking (proptest is unavailable
//! offline). [`check`] runs a property over `iters` generated cases from a
//! seeded [`Pcg64`] and panics with the failing seed + case index on
//! violation — enough to reproduce deterministically.

use crate::rng::Pcg64;

/// Run `prop(case_rng)` for `iters` cases. Each case gets an independent,
/// deterministic RNG stream. On failure, panics with the case number so
/// `Pcg64::new_stream(seed, case)` reproduces it.
pub fn check(name: &str, seed: u64, iters: usize, mut prop: impl FnMut(&mut Pcg64) -> Result<(), String>) {
    for case in 0..iters {
        let mut rng = Pcg64::new_stream(seed, case as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed at case {case} (seed {seed}): {msg}");
        }
    }
}

/// Generators used by the property suites.
pub mod gen {
    use crate::linalg::Matrix;
    use crate::rng::Pcg64;

    /// Random feature matrix, n in [n_lo, n_hi], dim in [d_lo, d_hi].
    pub fn matrix(rng: &mut Pcg64, n_lo: usize, n_hi: usize, d_lo: usize, d_hi: usize) -> Matrix {
        let n = n_lo + rng.next_below(n_hi - n_lo + 1);
        let d = d_lo + rng.next_below(d_hi - d_lo + 1);
        Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32 * 2.0).collect())
            .unwrap()
    }

    /// Random subset ids of size ≤ max_k over [0, n).
    pub fn subset_ids(rng: &mut Pcg64, n: usize, max_k: usize) -> Vec<usize> {
        let k = rng.next_below(max_k.min(n) + 1);
        rng.sample_indices(n, k)
    }

    /// A random element NOT in `ids`.
    pub fn fresh_element(rng: &mut Pcg64, n: usize, ids: &[usize]) -> Option<usize> {
        if ids.len() >= n {
            return None;
        }
        loop {
            let e = rng.next_below(n);
            if !ids.contains(&e) {
                return Some(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u32 parity", 1, 50, |rng| {
            let x = rng.next_u32();
            if (x % 2 == 0) == (x & 1 == 0) {
                Ok(())
            } else {
                Err("parity mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn reports_failures() {
        check("always false", 2, 3, |_| Err("nope".into()));
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::rng::Pcg64::new(3);
        for _ in 0..20 {
            let m = gen::matrix(&mut rng, 2, 10, 1, 5);
            assert!((2..=10).contains(&m.rows()));
            assert!((1..=5).contains(&m.cols()));
            let ids = gen::subset_ids(&mut rng, m.rows(), 4);
            assert!(ids.len() <= 4);
            let set: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(set.len(), ids.len());
            if let Some(e) = gen::fresh_element(&mut rng, m.rows(), &ids) {
                assert!(!ids.contains(&e));
            }
        }
    }
}

//! Minimal JSON: recursive-descent parser + writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); used for `artifacts/manifest.json` and the
//! CLI config file. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Result, SubmodError};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer view of a number. `fract() == 0.0` alone is not enough:
    /// every f64 at or above 2^53 has zero fract, but above 2^53 − 1
    /// distinct integers collapse onto the same float during parsing, so
    /// a "whole" value no longer identifies one integer — those are
    /// rejected instead of silently rounded (as is anything beyond
    /// `usize::MAX`, which would otherwise saturate on 32-bit targets).
    pub fn as_usize(&self) -> Option<usize> {
        const MAX_EXACT_INT: f64 = 9_007_199_254_740_991.0; // 2^53 − 1
        let max = MAX_EXACT_INT.min(usize::MAX as f64);
        self.as_f64()
            .filter(|x| *x >= 0.0 && x.fract() == 0.0 && *x <= max)
            .map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field helpers with descriptive errors.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| SubmodError::InvalidParam(format!("json: missing string {key:?}")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| SubmodError::InvalidParam(format!("json: missing integer {key:?}")))
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> SubmodError {
        SubmodError::InvalidParam(format!("json parse error at byte {}: {msg}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let ch = match cp {
                                // UTF-16 high surrogate: JSON encodes
                                // astral characters as an escaped
                                // surrogate *pair* (RFC 8259 §7) — the
                                // two escapes are one code point, not two
                                0xD800..=0xDBFF => {
                                    let save = self.i;
                                    if self.b[self.i..].starts_with(b"\\u") {
                                        self.i += 2;
                                        let lo = self.hex4()?;
                                        if (0xDC00..=0xDFFF).contains(&lo) {
                                            let c = 0x10000
                                                + ((cp - 0xD800) << 10)
                                                + (lo - 0xDC00);
                                            char::from_u32(c).unwrap_or('\u{fffd}')
                                        } else {
                                            // not a low surrogate: the
                                            // high one is lone; re-parse
                                            // the peeked escape on its own
                                            self.i = save;
                                            '\u{fffd}'
                                        }
                                    } else {
                                        '\u{fffd}' // lone high surrogate
                                    }
                                }
                                0xDC00..=0xDFFF => '\u{fffd}', // lone low surrogate
                                cp => char::from_u32(cp).unwrap_or('\u{fffd}'),
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multibyte utf-8: re-scan from the byte before
                    let start = self.i - 1;
                    let rest = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    /// Consume exactly four hex digits (the payload of a `\u` escape).
    /// Digit check up front: `from_str_radix` alone also accepts a
    /// leading `+`, which JSON does not.
    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("bad \\u"));
        }
        let raw = &self.b[self.i..self.i + 4];
        if !raw.iter().all(|b| b.is_ascii_hexdigit()) {
            return Err(self.err("bad \\u"));
        }
        let hex = std::str::from_utf8(raw).map_err(|_| self.err("bad \\u"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u"))?;
        self.i += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].req_str("b").unwrap(),
            "c"
        );
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", r#"{"a""#, "tru", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn surrogate_pairs_combine() {
        // an escaped UTF-16 surrogate pair is ONE code point
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("😀".into())
        );
        assert_eq!(
            Json::parse(r#""x\ud83d\ude00y""#).unwrap(),
            Json::Str("x😀y".into())
        );
        // raw UTF-8 still passes through unchanged
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        // writer emits raw UTF-8, so the escaped pair round-trips
        let v = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn lone_surrogates_become_replacement_char() {
        // high with nothing after it
        assert_eq!(Json::parse(r#""\ud83d""#).unwrap(), Json::Str("\u{fffd}".into()));
        // high followed by plain text
        assert_eq!(
            Json::parse(r#""\ud83dab""#).unwrap(),
            Json::Str("\u{fffd}ab".into())
        );
        // high followed by a non-surrogate *escape*: the rewind path —
        // lone high becomes U+FFFD, then A is re-parsed on its own
        assert_eq!(
            Json::parse(r#""\ud83d\u0041""#).unwrap(),
            Json::Str("\u{fffd}A".into())
        );
        // invalid hex after a high surrogate still errors (and a '+'
        // sign is not a hex digit)
        assert!(Json::parse(r#""\u+041""#).is_err());
        assert!(Json::parse(r#""\ud83d\uzzzz""#).is_err());
        // two highs in a row: first is lone, second pairs with nothing
        assert_eq!(
            Json::parse(r#""\ud83d\ud83d""#).unwrap(),
            Json::Str("\u{fffd}\u{fffd}".into())
        );
        // low with no preceding high
        assert_eq!(Json::parse(r#""\ude00""#).unwrap(), Json::Str("\u{fffd}".into()));
    }

    #[test]
    fn as_usize_rejects_unrepresentable() {
        // 2^53 − 1 is the largest f64 that still identifies one integer
        assert_eq!(
            Json::Num(9_007_199_254_740_991.0).as_usize(),
            Some(9_007_199_254_740_991)
        );
        // 2^53 parses equal to 2^53 + 1 — ambiguous, so rejected
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_usize(), None);
        assert_eq!(Json::Num(1e300).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(0.5).as_usize(), None);
        assert_eq!(Json::Num(0.0).as_usize(), Some(0));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":{"x":{"kind":"similarity","n":256}},"ok":true,"v":[1,2.5,null]}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 256, "name": "fl", "frac": 0.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 256);
        assert_eq!(v.req_str("name").unwrap(), "fl");
        assert!(v.req_usize("frac").is_err()); // non-integer
        assert!(v.req_str("missing").is_err());
    }
}

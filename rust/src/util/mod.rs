//! Dependency-free utility substrates.
//!
//! The offline build environment carries only the `xla` crate closure, so
//! the pieces a library like this would normally take from crates.io are
//! implemented here from scratch:
//!
//! * [`json`]  — a small recursive-descent JSON parser + writer (replaces
//!   serde_json for the artifact manifest and the config file).
//! * [`mod bench`](self::bench) — a criterion-style timing harness used by every
//!   `rust/benches/*.rs` binary (warmup + N samples, mean/median/stddev).
//! * [`prop`]  — a proptest-style randomized-property helper driven by the
//!   crate's own [`crate::rng::Pcg64`].

pub mod bench;
pub mod json;
pub mod prop;

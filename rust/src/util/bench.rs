//! Criterion-style micro-bench harness (criterion is unavailable in the
//! offline registry). Each `rust/benches/*.rs` binary builds a
//! [`BenchRunner`], registers closures, and gets a mean/median/stddev
//! table plus machine-readable CSV lines on stdout.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn csv_header() -> &'static str {
        "name,samples,mean_s,median_s,stddev_s,min_s,max_s"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{},{},{:.9},{:.9},{:.9},{:.9},{:.9}",
            self.name,
            self.samples,
            self.mean.as_secs_f64(),
            self.median.as_secs_f64(),
            self.stddev.as_secs_f64(),
            self.min.as_secs_f64(),
            self.max.as_secs_f64()
        )
    }
}

/// Harness: `warmup` untimed runs then `samples` timed runs per bench.
pub struct BenchRunner {
    pub warmup: usize,
    pub samples: usize,
    results: Vec<BenchStats>,
}

impl BenchRunner {
    pub fn new(warmup: usize, samples: usize) -> Self {
        BenchRunner { warmup, samples: samples.max(1), results: Vec::new() }
    }

    /// Quick-mode scaling via env var (used by `make bench SAMPLES=..`).
    pub fn from_env() -> Self {
        let samples = std::env::var("BENCH_SAMPLES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(5);
        BenchRunner::new(1, samples)
    }

    /// Time `f` (which should do one full unit of work per call).
    /// A `black_box`-style sink: have `f` return something and it is
    /// consumed here to stop the optimizer deleting the work.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed());
        }
        times.sort();
        let total: Duration = times.iter().sum();
        let mean = total / self.samples as u32;
        let median = median_of_sorted(&times);
        let mean_s = mean.as_secs_f64();
        let var = times
            .iter()
            .map(|t| {
                let d = t.as_secs_f64() - mean_s;
                d * d
            })
            .sum::<f64>()
            / self.samples as f64;
        let stats = BenchStats {
            name: name.to_string(),
            samples: self.samples,
            mean,
            median,
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: times[0],
            max: *times.last().unwrap(),
        };
        eprintln!(
            "  {name:<44} mean {:>10.4?}  median {:>10.4?}  ±{:>9.4?}",
            stats.mean, stats.median, stats.stddev
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Emit the CSV block (stdout) — `cargo bench | tee bench_output.txt`
    /// captures it.
    pub fn finish(self, title: &str) {
        println!("== {title} ==");
        println!("{}", BenchStats::csv_header());
        for r in &self.results {
            println!("{}", r.to_csv());
        }
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

/// Median of an ascending-sorted, non-empty sample list: the mean of the
/// two middle values for even counts. (`times[n/2]` alone is the *upper*
/// middle, which biases the reported median high as sample counts vary —
/// the BENCH_*.json trajectory needs the statistic to mean the same
/// thing at every `BENCH_SAMPLES` setting.)
fn median_of_sorted(times: &[Duration]) -> Duration {
    let n = times.len();
    if n % 2 == 0 {
        (times[n / 2 - 1] + times[n / 2]) / 2
    } else {
        times[n / 2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_stats() {
        let mut b = BenchRunner::new(0, 5);
        let s = b.bench("noop", || 1 + 1);
        assert_eq!(s.samples, 5);
        assert!(s.min <= s.median && s.median <= s.max);
    }

    #[test]
    fn median_even_is_mean_of_middles() {
        let ms = Duration::from_millis;
        assert_eq!(median_of_sorted(&[ms(5)]), ms(5));
        assert_eq!(median_of_sorted(&[ms(1), ms(3)]), ms(2));
        assert_eq!(median_of_sorted(&[ms(1), ms(2), ms(30)]), ms(2));
        // upper-middle alone would report 10 here
        assert_eq!(median_of_sorted(&[ms(1), ms(2), ms(10), ms(20)]), ms(6));
    }

    #[test]
    fn csv_shape() {
        let mut b = BenchRunner::new(0, 3);
        b.bench("x", || std::thread::sleep(Duration::from_micros(10)));
        let csv = b.results()[0].to_csv();
        assert_eq!(csv.split(',').count(), 7);
        assert!(csv.starts_with("x,3,"));
    }
}

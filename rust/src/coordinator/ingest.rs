//! Bounded ingestion: feature rows flow through a `sync_channel` with
//! fixed depth — when the drain lags, producers block (backpressure)
//! instead of ballooning memory. A drain thread moves rows into the
//! [`super::shard::ShardStore`].
//!
//! (The architecture sketch calls for tokio here; the offline registry
//! ships no async runtime, so the coordinator uses std threads + bounded
//! channels, which give the same backpressure semantics for this
//! CPU-bound pipeline.)

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::shard::ShardStore;
use crate::error::{Result, SubmodError};

/// One ingest message: features + reply channel for the assigned id.
pub(crate) struct IngestMsg {
    pub features: Vec<f32>,
    pub reply: SyncSender<Result<usize>>,
}

/// Producer-side handle (cheap to clone; many producers allowed).
#[derive(Clone)]
pub struct IngestHandle {
    tx: SyncSender<IngestMsg>,
    metrics: Arc<Metrics>,
}

impl IngestHandle {
    /// Submit one item; blocks (backpressure) when the queue is full.
    /// Returns the item's global id once stored.
    pub fn ingest(&self, features: Vec<f32>) -> Result<usize> {
        let (reply, rx) = sync_channel(1);
        let msg = IngestMsg { features, reply };
        // try_send first so backpressure events are observable in metrics
        match self.tx.try_send(msg) {
            Ok(()) => {}
            Err(TrySendError::Full(msg)) => {
                self.metrics
                    .backpressure_waits
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.tx
                    .send(msg)
                    .map_err(|_| SubmodError::Coordinator("ingest channel closed".into()))?;
            }
            Err(TrySendError::Disconnected(_)) => {
                return Err(SubmodError::Coordinator("ingest channel closed".into()));
            }
        }
        rx.recv()
            .map_err(|_| SubmodError::Coordinator("ingest drain dropped reply".into()))?
    }
}

/// Spawn the drain thread; returns the producer handle and the join
/// handle (the drain exits when every producer handle is dropped).
pub(crate) fn spawn_drain(
    store: Arc<ShardStore>,
    metrics: Arc<Metrics>,
    depth: usize,
) -> (IngestHandle, std::thread::JoinHandle<()>) {
    let (tx, rx): (SyncSender<IngestMsg>, Receiver<IngestMsg>) =
        sync_channel(depth.max(1));
    let m = metrics.clone();
    let join = std::thread::spawn(move || {
        while let Ok(msg) = rx.recv() {
            let res = store.push(msg.features);
            if res.is_ok() {
                m.items_ingested.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            let _ = msg.reply.send(res);
        }
    });
    (IngestHandle { tx, metrics }, join)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingest_assigns_sequential_ids() {
        let store = Arc::new(ShardStore::new(4));
        let metrics = Arc::new(Metrics::new());
        let (h, _join) = spawn_drain(store.clone(), metrics.clone(), 8);
        for i in 0..6 {
            let id = h.ingest(vec![i as f32, 1.0]).unwrap();
            assert_eq!(id, i);
        }
        assert_eq!(store.len(), 6);
        assert_eq!(metrics.snapshot().items_ingested, 6);
    }

    #[test]
    fn dim_error_propagates() {
        let store = Arc::new(ShardStore::new(4));
        let metrics = Arc::new(Metrics::new());
        let (h, _join) = spawn_drain(store, metrics, 8);
        h.ingest(vec![1.0, 2.0]).unwrap();
        assert!(h.ingest(vec![1.0]).is_err());
    }

    #[test]
    fn concurrent_producers_with_tiny_queue() {
        let store = Arc::new(ShardStore::new(1024));
        let metrics = Arc::new(Metrics::new());
        let (h, _join) = spawn_drain(store.clone(), metrics.clone(), 1);
        let mut threads = Vec::new();
        for t in 0..8 {
            let h = h.clone();
            threads.push(std::thread::spawn(move || {
                for i in 0..16 {
                    h.ingest(vec![(t * 16 + i) as f32]).unwrap();
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(store.len(), 128);
        assert_eq!(metrics.snapshot().items_ingested, 128);
    }
}

//! Bounded ingestion: feature rows flow through a `sync_channel` with
//! fixed depth — when the drain lags, producers block (backpressure)
//! instead of ballooning memory. A supervised drain thread moves rows
//! into the [`super::shard::ShardStore`].
//!
//! ## Fault model (ISSUE 6)
//!
//! The drain is the coordinator's single point of ingest failure, so it
//! is *supervised*: the drain loop runs under `catch_unwind`, and a
//! panic (anywhere in a batch — including the [`super::faults`]
//! `drain_loop` site) restarts the loop with the channel receiver and
//! the `ShardStore` intact, bumping `Metrics::drain_restarts`. Producers
//! never hang on a drain crash:
//!
//! * messages whose replies were in flight when the panic hit see their
//!   reply channel close → a typed `Coordinator` error (the rows in that
//!   batch are dropped, at-most-once; the producer may retry);
//! * messages still queued survive in the channel and are served after
//!   the restart;
//! * if the supervisor itself is gone (process teardown), `ingest`'s
//!   sends and reply receives observe disconnected channels → typed
//!   errors, again never a hang.
//!
//! (The architecture sketch calls for tokio here; the offline registry
//! ships no async runtime, so the coordinator uses std threads + bounded
//! channels, which give the same backpressure semantics for this
//! CPU-bound pipeline.)

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

use crate::coordinator::faults;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::shard::ShardStore;
use crate::error::{Result, SubmodError};

/// One ingest message: an item (features + reply channel for the
/// assigned id), or the shutdown sentinel `Coordinator::shutdown` sends.
pub(crate) enum IngestMsg {
    Item { features: Vec<f32>, reply: SyncSender<Result<usize>> },
    /// Drain everything queued ahead of this sentinel, then exit the
    /// drain loop cleanly (the supervisor treats a clean exit as final).
    Shutdown,
}

/// Producer-side handle (cheap to clone; many producers allowed).
#[derive(Clone)]
pub struct IngestHandle {
    tx: SyncSender<IngestMsg>,
    metrics: Arc<Metrics>,
}

impl IngestHandle {
    /// Submit one item; blocks (backpressure) when the queue is full.
    /// Returns the item's global id once stored. Every failure mode is a
    /// typed error — a crashed or restarting drain can fail an in-flight
    /// item but can never hang the producer.
    pub fn ingest(&self, features: Vec<f32>) -> Result<usize> {
        let (reply, rx) = sync_channel(1);
        let msg = IngestMsg::Item { features, reply };
        // try_send first so backpressure events are observable in metrics
        match self.tx.try_send(msg) {
            Ok(()) => {}
            Err(TrySendError::Full(msg)) => {
                self.metrics
                    .backpressure_waits
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.tx
                    .send(msg)
                    .map_err(|_| SubmodError::Coordinator("ingest channel closed".into()))?;
            }
            Err(TrySendError::Disconnected(_)) => {
                return Err(SubmodError::Coordinator("ingest channel closed".into()));
            }
        }
        rx.recv()
            .map_err(|_| SubmodError::Coordinator("ingest drain dropped reply".into()))?
    }

    /// Queue the shutdown sentinel (best-effort: a drain that already
    /// exited is fine). Items queued ahead of the sentinel are still
    /// stored and replied to; items ingested after it observe the
    /// disconnected channel as a typed error once the drain exits.
    pub(crate) fn request_shutdown(&self) {
        let _ = self.tx.send(IngestMsg::Shutdown);
    }
}

/// Upper bound on one drain batch: enough to amortize the store's write
/// lock under load, small enough that replies stay prompt.
const DRAIN_BATCH: usize = 64;

/// Spawn the supervised drain thread; returns the producer handle and
/// the join handle. The thread exits only when every producer handle is
/// dropped *and* the loop finishes cleanly — a panicking drain loop is
/// restarted in place (receiver and store intact, see module docs).
pub(crate) fn spawn_drain(
    store: Arc<ShardStore>,
    metrics: Arc<Metrics>,
    depth: usize,
) -> (IngestHandle, std::thread::JoinHandle<()>) {
    let (tx, rx): (SyncSender<IngestMsg>, Receiver<IngestMsg>) =
        sync_channel(depth.max(1));
    let m = metrics.clone();
    // lint: allow(thread-spawn) — the drain supervisor must outlive any one pool job
    // (it blocks on a channel for the process lifetime; pool workers may never block)
    let join = std::thread::spawn(move || loop {
        let exited = catch_unwind(AssertUnwindSafe(|| drain_loop(&rx, &store, &m)));
        match exited {
            // channel closed: every producer is gone — clean shutdown
            Ok(()) => break,
            // drain crashed mid-batch: that batch's replies were dropped
            // during unwind (producers see a typed error); restart with
            // the store and any queued messages intact
            Err(_) => {
                m.drain_restarts.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }
    });
    (IngestHandle { tx, metrics }, join)
}

/// The drain proper, opportunistically batched: block for the first
/// message, soak up whatever else is already queued (up to
/// [`DRAIN_BATCH`]) and append the whole run through
/// [`ShardStore::push_batch`] — one write-lock acquisition per batch
/// instead of one per item. Ids stay arrival-ordered (the channel is
/// FIFO and the batch preserves it) and each producer still gets its own
/// per-item reply.
fn drain_loop(rx: &Receiver<IngestMsg>, store: &ShardStore, m: &Metrics) {
    let mut pending: Vec<(Vec<f32>, SyncSender<Result<usize>>)> =
        Vec::with_capacity(DRAIN_BATCH);
    loop {
        // a Shutdown sentinel stops the loop *after* the batch it closes:
        // items queued ahead of it are stored and replied to, honoring
        // the graceful-drain contract
        let mut stop = false;
        match rx.recv() {
            Err(_) => return, // every producer handle dropped
            Ok(IngestMsg::Shutdown) => return,
            Ok(IngestMsg::Item { features, reply }) => pending.push((features, reply)),
        }
        while pending.len() < DRAIN_BATCH {
            match rx.try_recv() {
                Ok(IngestMsg::Item { features, reply }) => pending.push((features, reply)),
                Ok(IngestMsg::Shutdown) => {
                    stop = true;
                    break;
                }
                Err(_) => break,
            }
        }
        // injection site: a Panic here unwinds out of drain_loop and the
        // supervisor restarts it; an Error fails this batch's producers
        // with the typed error and keeps draining
        if let Err(e) = faults::failpoint(faults::DRAIN_LOOP, 0) {
            let text = e.to_string();
            for (_, reply) in pending.drain(..) {
                let _ = reply.send(Err(SubmodError::Coordinator(text.clone())));
            }
            if stop {
                return;
            }
            continue;
        }
        let feats: Vec<Vec<f32>> =
            pending.iter_mut().map(|(features, _)| std::mem::take(features)).collect();
        let results = store.push_batch(feats);
        for ((_, reply), res) in pending.drain(..).zip(results) {
            if res.is_ok() {
                m.items_ingested.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            let _ = reply.send(res);
        }
        if stop {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ingest_assigns_sequential_ids() {
        let store = Arc::new(ShardStore::new(4));
        let metrics = Arc::new(Metrics::new());
        let (h, _join) = spawn_drain(store.clone(), metrics.clone(), 8);
        for i in 0..6 {
            let id = h.ingest(vec![i as f32, 1.0]).unwrap();
            assert_eq!(id, i);
        }
        assert_eq!(store.len(), 6);
        assert_eq!(metrics.snapshot().items_ingested, 6);
    }

    #[test]
    fn dim_error_propagates() {
        let store = Arc::new(ShardStore::new(4));
        let metrics = Arc::new(Metrics::new());
        let (h, _join) = spawn_drain(store, metrics, 8);
        h.ingest(vec![1.0, 2.0]).unwrap();
        assert!(h.ingest(vec![1.0]).is_err());
    }

    #[test]
    fn batched_drain_assigns_unique_ids() {
        // a deep queue lets the drain soak up whole batches; every item
        // must still get a unique, in-range id and land in the store
        let store = Arc::new(ShardStore::new(64));
        let metrics = Arc::new(Metrics::new());
        let (h, _join) = spawn_drain(store.clone(), metrics.clone(), 256);
        let mut threads = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            // lint: allow(thread-spawn) — test models external producer threads, not a compute fan-out
            threads.push(std::thread::spawn(move || {
                (0..32).map(|i| h.ingest(vec![(t * 32 + i) as f32]).unwrap()).collect::<Vec<_>>()
            }));
        }
        let mut ids: Vec<usize> = Vec::new();
        for t in threads {
            ids.extend(t.join().unwrap());
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..128).collect::<Vec<_>>());
        assert_eq!(store.len(), 128);
        assert_eq!(metrics.snapshot().items_ingested, 128);
    }

    #[test]
    fn concurrent_producers_with_tiny_queue() {
        let store = Arc::new(ShardStore::new(1024));
        let metrics = Arc::new(Metrics::new());
        let (h, _join) = spawn_drain(store.clone(), metrics.clone(), 1);
        let mut threads = Vec::new();
        for t in 0..8 {
            let h = h.clone();
            // lint: allow(thread-spawn) — test models external producer threads, not a compute fan-out
            threads.push(std::thread::spawn(move || {
                for i in 0..16 {
                    h.ingest(vec![(t * 16 + i) as f32]).unwrap();
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(store.len(), 128);
        assert_eq!(metrics.snapshot().items_ingested, 128);
    }

    #[test]
    fn shutdown_sentinel_drains_queued_items_then_exits() {
        let store = Arc::new(ShardStore::new(8));
        let metrics = Arc::new(Metrics::new());
        let (h, join) = spawn_drain(store.clone(), metrics.clone(), 8);
        // items ahead of the sentinel are stored and replied to
        for i in 0..3 {
            assert_eq!(h.ingest(vec![i as f32]).unwrap(), i);
        }
        h.request_shutdown();
        join.join().expect("drain exits cleanly on shutdown sentinel");
        assert_eq!(store.len(), 3);
        // the handle is still alive but the drain is gone: ingest after
        // shutdown is a typed error, never a hang
        let err = h.ingest(vec![9.0]).unwrap_err();
        assert!(matches!(err, SubmodError::Coordinator(_)), "{err}");
        // a second sentinel is harmless (best-effort send)
        h.request_shutdown();
        assert_eq!(metrics.snapshot().drain_restarts, 0);
    }

    #[test]
    fn drain_exits_cleanly_when_producers_drop() {
        let store = Arc::new(ShardStore::new(4));
        let metrics = Arc::new(Metrics::new());
        let (h, join) = spawn_drain(store, metrics.clone(), 8);
        h.ingest(vec![1.0]).unwrap();
        drop(h);
        join.join().expect("supervised drain exits cleanly on channel close");
        assert_eq!(metrics.snapshot().drain_restarts, 0);
    }
}

//! Layer-3 coordinator: a fault-tolerant streaming subset-selection
//! pipeline.
//!
//! Submodlib is a library, not a service; its natural data-pipeline
//! deployment (the use cases the paper's §1 motivates — continual data
//! subset selection for training pipelines, streaming summarization) is a
//! long-running selector over an *arriving* ground set. That is what this
//! coordinator provides:
//!
//! * [`ingest`]    — bounded ingestion queue (backpressure) feeding
//!   fixed-capacity feature [`shard`]s, drained by a *supervised* thread
//!   that is restarted in place if it panics;
//! * [`admission`] — the overload gate: bounded in-flight selections +
//!   bounded FIFO admission queue; excess load is shed with a typed
//!   `SubmodError::Overloaded` instead of queueing unboundedly;
//! * [`service`]   — the orchestrator: stage-1 greedy per shard fanned
//!   out over the shared worker pool (behind per-shard circuit
//!   breakers), then a stage-2 greedy merge over the candidate union
//!   (the two-stage scheme of Wei, Iyer & Bilmes 2014, cited by the
//!   paper for exactly this scaling role);
//! * [`metrics`]   — ingest/select counters, fault/recovery/overload
//!   counters, and success + failed latency accounting;
//! * [`faults`]    — deterministic fault injection (failpoints) used by
//!   `tests/fault_injection.rs` to pin every recovery path (no-op unless
//!   the `faults` cargo feature is enabled);
//! * [`loadgen`]   — a seeded multi-tenant closed-loop load generator
//!   that measures the whole stack under sustained chaos traffic
//!   (`benches/loadgen.rs`, `submodlib loadgen`).
//!
//! ## Fault model, in one paragraph
//!
//! Shed → degrade → cancel → error → shutdown. Load beyond
//! `CoordinatorConfig::max_inflight` waits in a bounded FIFO queue;
//! beyond that it is *shed* fast with `SubmodError::Overloaded`. A
//! stage-1 shard evaluation that panics or errors is isolated, retried
//! once, and then dropped; a shard failing `breaker_threshold`
//! consecutive requests is quarantined by a circuit breaker (request-
//! count-based Half-Open probes readmit it). The request still succeeds
//! — marked `degraded`, listing `failed_shards` — as long as
//! `CoordinatorConfig::min_shard_quorum` shards survive (default: all
//! must). Requests carry an optional deadline enforced *preemptively*:
//! the [`watchdog`] fires the request's cancel token when the budget
//! runs out, every compute layer polls it at claim boundaries
//! (`runtime::cancel`), and the request unwinds within one
//! tile/chunk/iteration as `SubmodError::DeadlineExceeded` instead of
//! blocking. The ingest drain is supervised: producers get typed errors
//! (never hangs) across a drain crash, and the drain resumes with the
//! [`ShardStore`] intact. [`Coordinator::shutdown`] closes admission,
//! drains in-flight work and the ingest queue, and returns a final
//! checkpoint ([`Coordinator::shutdown_with_grace`] bounds the drain:
//! selections still running when the grace budget ends are hard-
//! cancelled); the whole ground set snapshots to a versioned binary
//! checkpoint from which a new coordinator serves byte-identical
//! selections. See [`service`] for the full contract.

pub(crate) mod admission;
pub mod faults;
pub mod ingest;
pub mod loadgen;
pub mod metrics;
pub mod service;
pub mod shard;
pub(crate) mod watchdog;

pub use ingest::IngestHandle;
pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use metrics::MetricsSnapshot;
pub use service::{Coordinator, SelectRequest, SelectResponse};
pub use shard::ShardStore;

//! Layer-3 coordinator: a streaming subset-selection pipeline.
//!
//! Submodlib is a library, not a service; its natural data-pipeline
//! deployment (the use cases the paper's §1 motivates — continual data
//! subset selection for training pipelines, streaming summarization) is a
//! long-running selector over an *arriving* ground set. That is what this
//! coordinator provides:
//!
//! * [`ingest`]   — bounded ingestion queue (backpressure) feeding
//!   fixed-capacity feature [`shard`]s;
//! * [`service`]  — the orchestrator: routes selection requests to worker
//!   tasks that run stage-1 greedy per shard in parallel, then merges the
//!   per-shard candidates with a stage-2 greedy over the union (the
//!   two-stage scheme of Wei, Iyer & Bilmes 2014, cited by the paper for
//!   exactly this scaling role);
//! * [`metrics`]  — ingest/select counters and latency accounting.

pub mod ingest;
pub mod metrics;
pub mod service;
pub mod shard;

pub use ingest::IngestHandle;
pub use metrics::MetricsSnapshot;
pub use service::{Coordinator, SelectRequest, SelectResponse};
pub use shard::ShardStore;

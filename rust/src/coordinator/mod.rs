//! Layer-3 coordinator: a fault-tolerant streaming subset-selection
//! pipeline.
//!
//! Submodlib is a library, not a service; its natural data-pipeline
//! deployment (the use cases the paper's §1 motivates — continual data
//! subset selection for training pipelines, streaming summarization) is a
//! long-running selector over an *arriving* ground set. That is what this
//! coordinator provides:
//!
//! * [`ingest`]   — bounded ingestion queue (backpressure) feeding
//!   fixed-capacity feature [`shard`]s, drained by a *supervised* thread
//!   that is restarted in place if it panics;
//! * [`service`]  — the orchestrator: stage-1 greedy per shard fanned out
//!   over the shared worker pool, then a stage-2 greedy merge over the
//!   candidate union (the two-stage scheme of Wei, Iyer & Bilmes 2014,
//!   cited by the paper for exactly this scaling role);
//! * [`metrics`]  — ingest/select counters, fault/recovery counters, and
//!   latency accounting;
//! * [`faults`]   — deterministic fault injection (failpoints) used by
//!   `tests/fault_injection.rs` to pin every recovery path (no-op unless
//!   the `faults` cargo feature is enabled).
//!
//! ## Fault model, in one paragraph
//!
//! A stage-1 shard evaluation that panics or errors is isolated, retried
//! once, and then dropped; the request still succeeds — marked
//! `degraded`, listing `failed_shards` — as long as
//! `CoordinatorConfig::min_shard_quorum` shards survive (default: all
//! must). Requests carry an optional deadline and fail fast with
//! `SubmodError::DeadlineExceeded` instead of blocking. The ingest drain
//! is supervised: producers get typed errors (never hangs) across a
//! drain crash, and the drain resumes with the [`ShardStore`] intact.
//! The whole ground set snapshots to a versioned binary checkpoint from
//! which a new coordinator serves byte-identical selections. See
//! [`service`] for the full contract.

pub mod faults;
pub mod ingest;
pub mod metrics;
pub mod service;
pub mod shard;

pub use ingest::IngestHandle;
pub use metrics::MetricsSnapshot;
pub use service::{Coordinator, SelectRequest, SelectResponse};
pub use shard::ShardStore;

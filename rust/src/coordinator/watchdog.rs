//! Deadline watchdog: the coordinator-rim timer that turns request
//! deadlines into *preemptive* cancellation.
//!
//! Before ISSUE 10, `SelectRequest::deadline` was enforced only at rim
//! checkpoints (between shard claims, before the stage-2 merge) — a
//! request stuck inside one long kernel build or gain scan sailed past
//! its budget. The watchdog closes that gap: `select()` arms the
//! request's [`CancelToken`] here, and when the deadline passes the
//! watchdog *fires* it with [`CancelReason::Deadline`]; every compute
//! layer polls the token at its claim boundaries (see
//! `runtime::cancel`) and unwinds within one tile/chunk/iteration.
//!
//! This module is the only place where wall-clock time meets
//! cancellation, by design: the linter's no-wall-clock rule keeps
//! `Instant` out of every selection path, so deadlines are translated to
//! token fires *here*, at the rim, and the compute layers see only the
//! clockless flag.
//!
//! Mechanics: a `Mutex`+`Condvar` registry of armed `(deadline, token)`
//! pairs, serviced by one lazily-spawned timer thread that
//! `wait_timeout`s until the earliest deadline, fires whatever is due,
//! and **exits when the registry empties** (the next `arm()` respawns
//! it). A coordinator that never sees a deadline therefore never owns a
//! watchdog thread — `tests/pool_threads.rs` keeps pinning that a plain
//! `select()` spawns nothing. Arming returns an RAII [`ArmedDeadline`]
//! guard; dropping it (the request finished in time) disarms the entry.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::runtime::cancel::{CancelReason, CancelToken};

/// The armed-deadline registry plus its on-demand timer thread.
pub(crate) struct DeadlineWatchdog {
    inner: Arc<Inner>,
}

struct Inner {
    state: Mutex<State>,
    cv: Condvar,
}

#[derive(Default)]
struct State {
    next_id: u64,
    /// Armed entries in arming order; the timer scans for the earliest.
    armed: Vec<(u64, Instant, CancelToken)>,
    /// Whether the timer thread is live (it exits when `armed` empties).
    timer_live: bool,
}

/// RAII disarm guard: dropping it removes the entry (whether or not the
/// token already fired) and wakes the timer to recompute its wait.
pub(crate) struct ArmedDeadline {
    inner: Arc<Inner>,
    id: u64,
}

impl DeadlineWatchdog {
    pub fn new() -> DeadlineWatchdog {
        DeadlineWatchdog {
            inner: Arc::new(Inner { state: Mutex::new(State::default()), cv: Condvar::new() }),
        }
    }

    /// Arm `token` to fire with [`CancelReason::Deadline`] once
    /// `deadline` passes. Drop the returned guard to disarm.
    pub fn arm(&self, deadline: Instant, token: CancelToken) -> ArmedDeadline {
        let mut st = self.inner.state.lock().unwrap();
        let id = st.next_id;
        st.next_id += 1;
        st.armed.push((id, deadline, token));
        if st.timer_live {
            // the new entry may be the new earliest: shorten the wait
            self.inner.cv.notify_all();
        } else {
            st.timer_live = true;
            let inner = Arc::clone(&self.inner);
            // lint: allow(thread-spawn) — rim timer thread: parks on a
            // Condvar until the earliest armed deadline and exits when no
            // deadlines remain; never runs on a compute path
            std::thread::Builder::new()
                .name("submodlib-watchdog".into())
                .spawn(move || timer(inner))
                .expect("spawn watchdog timer thread");
        }
        drop(st);
        ArmedDeadline { inner: Arc::clone(&self.inner), id }
    }
}

fn timer(inner: Arc<Inner>) {
    let mut st = inner.state.lock().unwrap();
    loop {
        let now = Instant::now();
        // fire (and retire) everything due; a token races its guard's
        // drop harmlessly — firing is idempotent and first-reason-wins
        st.armed.retain(|(_, deadline, token)| {
            if *deadline <= now {
                token.fire(CancelReason::Deadline);
                false
            } else {
                true
            }
        });
        let Some(earliest) = st.armed.iter().map(|&(_, d, _)| d).min() else {
            // idle: exit — the next arm() respawns the timer
            st.timer_live = false;
            return;
        };
        let wait = earliest.saturating_duration_since(now);
        st = inner.cv.wait_timeout(st, wait).unwrap().0;
    }
}

impl Drop for ArmedDeadline {
    fn drop(&mut self) {
        let mut st = self.inner.state.lock().unwrap();
        st.armed.retain(|&(id, _, _)| id != self.id);
        drop(st);
        // wake the timer so it recomputes (or exits when now idle)
        self.inner.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn wait_fired(token: &CancelToken, budget: Duration) -> bool {
        let t0 = Instant::now();
        while !token.is_fired() {
            if t0.elapsed() > budget {
                return false;
            }
            std::thread::yield_now();
        }
        true
    }

    #[test]
    fn due_deadline_fires_with_deadline_reason() {
        let w = DeadlineWatchdog::new();
        let token = CancelToken::new();
        let _armed = w.arm(Instant::now(), token.clone());
        assert!(wait_fired(&token, Duration::from_secs(10)));
        assert_eq!(token.reason(), Some(CancelReason::Deadline));
    }

    #[test]
    fn dropped_guard_disarms_before_the_deadline() {
        let w = DeadlineWatchdog::new();
        let token = CancelToken::new();
        let armed = w.arm(Instant::now() + Duration::from_millis(80), token.clone());
        drop(armed);
        std::thread::sleep(Duration::from_millis(160));
        assert!(!token.is_fired(), "disarmed deadline must never fire");
    }

    #[test]
    fn timer_respawns_after_going_idle() {
        let w = DeadlineWatchdog::new();
        let a = CancelToken::new();
        let _g1 = w.arm(Instant::now(), a.clone());
        assert!(wait_fired(&a, Duration::from_secs(10)));
        drop(_g1);
        // let the timer drain to idle, then arm again: a fresh timer
        // must pick the new entry up
        std::thread::sleep(Duration::from_millis(20));
        let b = CancelToken::new();
        let _g2 = w.arm(Instant::now(), b.clone());
        assert!(wait_fired(&b, Duration::from_secs(10)));
    }

    #[test]
    fn earlier_arm_shortens_a_live_timer_wait() {
        let w = DeadlineWatchdog::new();
        let far = CancelToken::new();
        let near = CancelToken::new();
        // the timer is parked on a far deadline when a near one arrives
        let _g1 = w.arm(Instant::now() + Duration::from_secs(600), far.clone());
        let _g2 = w.arm(Instant::now(), near.clone());
        assert!(wait_fired(&near, Duration::from_secs(10)));
        assert!(!far.is_fired());
    }
}

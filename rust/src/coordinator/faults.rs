//! Deterministic fault injection (failpoints) for the coordinator.
//!
//! The fault-tolerance layer (panic isolation, retries, quorum
//! degradation, deadlines, drain supervision — see [`super::service`])
//! is only trustworthy if every recovery path is a *reproducible test*.
//! This module provides named injection sites on the coordinator's hot
//! paths; `tests/fault_injection.rs` arms them to force panics, delays,
//! and errors exactly where real faults would occur.
//!
//! ## Sites
//!
//! * [`STAGE1_EVAL`]   — top of a stage-1 per-shard evaluation, keyed by
//!   the shard's `base_id` (so a *specific* shard can be killed
//!   regardless of which pool participant claims it);
//! * [`DRAIN_LOOP`]    — the ingest drain, once per batch, before the
//!   store append (key 0);
//! * [`STAGE2_MERGE`]  — before the stage-2 merge over the candidate
//!   union, keyed by the number of stage-1 candidates (a Delay here
//!   holds a selection in flight past its admission, which is how the
//!   overload tests force saturation deterministically);
//! * [`KERNEL_BUILD`]  — [`super::service::ObjectiveKind`] kernel/
//!   function construction, keyed by the ground-set size being built
//!   (distinguishes per-shard builds from the stage-2 merge build);
//! * [`TILE_CLAIM`]    — inside the `kernel::tile` drivers, once per
//!   tile/wedge claim, keyed by the build's column count `n` (again
//!   distinguishing per-shard builds from the stage-2 merge build);
//!   a *poll-only* site reached through [`trip`];
//! * [`GAIN_CHUNK`]    — inside `optimizers::batch_gains`, once per
//!   `GAIN_CHUNK` chunk, keyed by the scan's candidate count; also
//!   poll-only.
//!
//! The two poll-only sites exist so *mid-kernel-build* and *mid-scan*
//! cancellation are forceable deterministically — no sleeps, no timing
//! asserts: arm them with [`FaultAction::Cancel`] and the ambient
//! `CancelToken` fires on the first matching claim. Which participant's
//! chunk trips first may vary, but the observable outcome never does:
//! the whole operation aborts with `SubmodError::Cancelled` either way
//! (all-or-nothing is the cancellation contract).
//!
//! ## Determinism
//!
//! Count-based triggers ([`Trigger::Times`]) combined with a key filter
//! are deterministic under any thread interleaving: "the shard with
//! `base_id` 0 panics on its first 2 evaluations" does not depend on
//! which worker claims that shard or when. [`Trigger::Prob`] draws from
//! a seeded [`Pcg64`] stream — bit-reproducible wherever the *hit order*
//! at a site is deterministic (single-threaded sites like the drain
//! loop; stochastic-soak tests elsewhere should assert invariants, not
//! exact schedules).
//!
//! ## Cost when disabled
//!
//! Without the `faults` cargo feature the registry and configuration API
//! do not exist and [`failpoint`] is an inlined `Ok(())` — the
//! production hot paths carry no branch, no lock, no atomic.

/// Stage-1 per-shard evaluation (keyed by shard `base_id`).
pub const STAGE1_EVAL: &str = "stage1_eval";
/// Ingest drain loop, once per batch (key 0).
pub const DRAIN_LOOP: &str = "drain_loop";
/// Stage-2 merge entry (keyed by stage-1 candidate count).
pub const STAGE2_MERGE: &str = "stage2_merge";
/// Objective kernel/function construction (keyed by ground-set size).
pub const KERNEL_BUILD: &str = "kernel_build";
/// Tile/wedge claim inside the `kernel::tile` drivers (keyed by the
/// build's column count `n`). Poll-only: reached through [`trip`].
pub const TILE_CLAIM: &str = "tile_claim";
/// Per-chunk claim inside `optimizers::batch_gains` (keyed by the
/// scan's candidate count). Poll-only: reached through [`trip`].
pub const GAIN_CHUNK: &str = "gain_chunk";

/// Check a named injection site. No-op unless the `faults` feature is
/// enabled *and* the site has been armed with [`inject`]. `key`
/// identifies the logical unit hitting the site (shard id, build size);
/// specs may filter on it.
#[cfg(not(feature = "faults"))]
#[inline(always)]
pub fn failpoint(_site: &str, _key: usize) -> crate::error::Result<()> {
    Ok(())
}

/// Poll-only variant of [`failpoint`] for sites inside claim loops that
/// have no `Result` channel ([`TILE_CLAIM`], [`GAIN_CHUNK`]). Armed
/// [`FaultAction::Cancel`] / `Delay` / `Panic` behave as usual; an
/// armed `Error` is escalated to a panic (loud, rather than silently
/// swallowed) — use `Cancel` to abort through the poll-only sites.
#[cfg(not(feature = "faults"))]
#[inline(always)]
pub fn trip(_site: &str, _key: usize) {}

#[cfg(feature = "faults")]
pub use enabled::{
    clear, clear_site, failpoint, hits, inject, trip, FaultAction, FaultSpec, Trigger,
};

#[cfg(feature = "faults")]
mod enabled {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    use std::time::Duration;

    use crate::error::{Result, SubmodError};
    use crate::rng::Pcg64;
    use crate::runtime::cancel::{self, CancelReason};

    /// What an armed site does when its trigger fires.
    #[derive(Debug, Clone, Copy, PartialEq)]
    pub enum FaultAction {
        /// `panic!` at the site (exercises catch_unwind isolation).
        Panic,
        /// Sleep before proceeding (exercises deadlines).
        Delay(Duration),
        /// Return a typed `SubmodError::Coordinator` from the site.
        Error,
        /// Fire the *ambient* `CancelToken` (the one in scope at the
        /// site) with the given reason, then proceed — the operation
        /// aborts at its next cancellation poll. This is how the tests
        /// force a deadline/shutdown cancel mid-kernel-build or
        /// mid-scan without any wall-clock.
        Cancel(CancelReason),
    }

    /// When an armed site fires.
    #[derive(Debug, Clone, Copy)]
    pub enum Trigger {
        /// Fire on the first `n` matching hits, then go quiet.
        Times(u32),
        /// Fire each matching hit with probability `p`, drawn from a
        /// dedicated `Pcg64` seeded with `seed`.
        Prob { p: f64, seed: u64 },
    }

    /// A site's armed behavior.
    #[derive(Debug, Clone, Copy)]
    pub struct FaultSpec {
        pub action: FaultAction,
        /// Only hits whose key matches fire (None = every hit).
        pub key: Option<usize>,
        pub trigger: Trigger,
    }

    struct SiteState {
        spec: FaultSpec,
        /// Matching hits that fired so far (bounds `Trigger::Times`).
        fired: u32,
        /// Every hit observed at the site, matching or not.
        hits: u64,
        rng: Pcg64,
    }

    fn registry() -> &'static Mutex<HashMap<String, SiteState>> {
        static REGISTRY: OnceLock<Mutex<HashMap<String, SiteState>>> = OnceLock::new();
        REGISTRY.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Registry guard that survives a poisoned mutex: an injected panic
    /// can unwind through arbitrary frames, and the harness must keep
    /// working afterwards.
    fn lock() -> std::sync::MutexGuard<'static, HashMap<String, SiteState>> {
        registry().lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Arm `site` with `spec` (replacing any previous arming).
    pub fn inject(site: &str, spec: FaultSpec) {
        let seed = match spec.trigger {
            Trigger::Prob { seed, .. } => seed,
            Trigger::Times(_) => 0,
        };
        lock().insert(
            site.to_string(),
            SiteState { spec, fired: 0, hits: 0, rng: Pcg64::new(seed) },
        );
    }

    /// Disarm one site.
    pub fn clear_site(site: &str) {
        lock().remove(site);
    }

    /// Disarm every site (call between tests).
    pub fn clear() {
        lock().clear();
    }

    /// Hits observed at `site` since it was armed (0 if unarmed).
    pub fn hits(site: &str) -> u64 {
        lock().get(site).map_or(0, |s| s.hits)
    }

    /// See the module docs. The action is *decided* under the registry
    /// lock but *performed* after releasing it, so a panic or delay
    /// never wedges or poisons the registry for other sites.
    pub fn failpoint(site: &str, key: usize) -> Result<()> {
        let action = {
            let mut reg = lock();
            let Some(st) = reg.get_mut(site) else { return Ok(()) };
            st.hits += 1;
            if st.spec.key.is_some_and(|k| k != key) {
                return Ok(());
            }
            let fire = match st.spec.trigger {
                Trigger::Times(n) => st.fired < n,
                Trigger::Prob { p, .. } => st.rng.next_f64() < p,
            };
            if !fire {
                return Ok(());
            }
            st.fired += 1;
            st.spec.action
        };
        match action {
            FaultAction::Panic => panic!("injected fault: panic at {site} (key {key})"),
            FaultAction::Delay(d) => {
                std::thread::sleep(d);
                Ok(())
            }
            FaultAction::Error => Err(SubmodError::Coordinator(format!(
                "injected fault: error at {site} (key {key})"
            ))),
            FaultAction::Cancel(reason) => {
                cancel::fire_current(reason);
                Ok(())
            }
        }
    }

    /// See the stub's docs: [`super::failpoint`] for poll-only sites.
    pub fn trip(site: &str, key: usize) {
        if let Err(e) = failpoint(site, key) {
            panic!("fault action Error at poll-only site {site}: {e} (use Cancel here)");
        }
    }

    // NOTE for test authors: the registry is process-global. Tests in
    // this crate's lib target use synthetic site names (never the real
    // coordinator sites) so they cannot perturb unrelated tests running
    // in parallel; tests/fault_injection.rs serializes on its own mutex.
    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn unarmed_site_is_noop() {
            assert!(failpoint("faults_unit_unarmed", 3).is_ok());
            assert_eq!(hits("faults_unit_unarmed"), 0);
        }

        #[test]
        fn times_trigger_fires_exactly_n() {
            let site = "faults_unit_times";
            inject(
                site,
                FaultSpec { action: FaultAction::Error, key: None, trigger: Trigger::Times(2) },
            );
            assert!(failpoint(site, 0).is_err());
            assert!(failpoint(site, 1).is_err());
            assert!(failpoint(site, 2).is_ok());
            assert!(failpoint(site, 3).is_ok());
            assert_eq!(hits(site), 4);
            clear_site(site);
        }

        #[test]
        fn key_filter_selects_matching_hits_only() {
            let site = "faults_unit_key";
            inject(
                site,
                FaultSpec {
                    action: FaultAction::Error,
                    key: Some(7),
                    trigger: Trigger::Times(u32::MAX),
                },
            );
            assert!(failpoint(site, 0).is_ok());
            assert!(failpoint(site, 7).is_err());
            assert!(failpoint(site, 8).is_ok());
            assert!(failpoint(site, 7).is_err());
            clear_site(site);
        }

        #[test]
        fn prob_trigger_is_seed_deterministic() {
            let site = "faults_unit_prob";
            let run = || -> Vec<bool> {
                inject(
                    site,
                    FaultSpec {
                        action: FaultAction::Error,
                        key: None,
                        trigger: Trigger::Prob { p: 0.5, seed: 42 },
                    },
                );
                let fires = (0..64).map(|i| failpoint(site, i).is_err()).collect();
                clear_site(site);
                fires
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "same seed must give the same fire schedule");
            assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f), "p=0.5 mixes");
        }

        #[test]
        fn cancel_action_fires_the_ambient_token() {
            use crate::runtime::cancel::CancelToken;
            let site = "faults_unit_cancel";
            inject(
                site,
                FaultSpec {
                    action: FaultAction::Cancel(CancelReason::Deadline),
                    key: None,
                    trigger: Trigger::Times(1),
                },
            );
            let token = CancelToken::new();
            cancel::with_scope(Some(token.clone()), || trip(site, 0));
            assert!(token.is_fired(), "Cancel action must fire the ambient token");
            assert_eq!(token.reason(), Some(CancelReason::Deadline));
            // trigger exhausted: the next scope's token stays unfired
            let second = CancelToken::new();
            cancel::with_scope(Some(second.clone()), || trip(site, 0));
            assert!(!second.is_fired());
            // with no ambient scope the action is a harmless no-op
            inject(
                site,
                FaultSpec {
                    action: FaultAction::Cancel(CancelReason::Manual),
                    key: None,
                    trigger: Trigger::Times(1),
                },
            );
            trip(site, 0);
            clear_site(site);
        }

        #[test]
        fn panic_action_does_not_wedge_the_registry() {
            let site = "faults_unit_panic";
            inject(
                site,
                FaultSpec { action: FaultAction::Panic, key: None, trigger: Trigger::Times(1) },
            );
            let caught = std::panic::catch_unwind(|| failpoint(site, 0));
            assert!(caught.is_err(), "armed panic must fire");
            // the registry still works after unwinding through failpoint
            assert!(failpoint(site, 0).is_ok());
            assert_eq!(hits(site), 2);
            clear_site(site);
        }
    }
}

//! The selection service: two-stage distributed greedy over the sharded
//! ground set.
//!
//! Stage 1 (fan-out): each shard runs greedy (the requested function +
//! optimizer) over its own dense kernel, returning
//! `ceil(budget · factor / n_shards)` local candidates. Shards run on a
//! scoped thread pool of `cfg.workers` threads.
//!
//! Stage 2 (merge): the union of candidates forms a reduced ground set; a
//! final greedy over its kernel picks the answer. This is the classic
//! composable two-stage scheme (Wei, Iyer & Bilmes 2014 — cited by the
//! paper for exactly this scaling role; same shape as GreeDi).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::CoordinatorConfig;
use crate::coordinator::ingest::{spawn_drain, IngestHandle};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::shard::{Shard, ShardStore};
use crate::error::{Result, SubmodError};
use crate::functions::disparity_sum::DisparitySum;
use crate::functions::facility_location::FacilityLocation;
use crate::functions::graph_cut::GraphCut;
use crate::functions::log_determinant::LogDeterminant;
use crate::functions::traits::SetFunction;
use crate::kernel::{DenseKernel, Metric};
use crate::linalg::Matrix;
use crate::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};

/// Which objective a selection request optimizes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObjectiveKind {
    FacilityLocation,
    GraphCut { lambda: f64 },
    /// LogDet always uses an RBF kernel internally (positive definite).
    LogDeterminant { reg: f64 },
    DisparitySum,
}

impl ObjectiveKind {
    fn build(&self, data: &Matrix, metric: Metric) -> Result<Box<dyn SetFunction>> {
        Ok(match *self {
            ObjectiveKind::FacilityLocation => {
                Box::new(FacilityLocation::new(DenseKernel::from_data(data, metric)))
            }
            ObjectiveKind::GraphCut { lambda } => {
                Box::new(GraphCut::new(DenseKernel::from_data(data, metric), lambda)?)
            }
            ObjectiveKind::LogDeterminant { reg } => Box::new(
                LogDeterminant::with_regularization(
                    DenseKernel::from_data(data, Metric::Rbf { gamma: 1.0 }),
                    reg,
                )?,
            ),
            ObjectiveKind::DisparitySum => {
                Box::new(DisparitySum::new(DenseKernel::distances_from_data(data)))
            }
        })
    }

    /// DisparitySum is supermodular → lazy bounds are invalid; route it to
    /// NaiveGreedy regardless of the requested optimizer.
    fn effective_optimizer(&self, requested: OptimizerKind) -> OptimizerKind {
        match self {
            ObjectiveKind::DisparitySum => OptimizerKind::NaiveGreedy,
            _ => requested,
        }
    }
}

/// A selection request.
#[derive(Debug, Clone)]
pub struct SelectRequest {
    pub objective: ObjectiveKind,
    pub budget: usize,
    pub optimizer: OptimizerKind,
    pub metric: Metric,
}

impl Default for SelectRequest {
    fn default() -> Self {
        SelectRequest {
            objective: ObjectiveKind::FacilityLocation,
            budget: 10,
            optimizer: OptimizerKind::LazyGreedy,
            metric: Metric::Euclidean,
        }
    }
}

/// A selection response: global ids + objective value + stage accounting.
#[derive(Debug, Clone)]
pub struct SelectResponse {
    pub ids: Vec<usize>,
    pub value: f64,
    pub shards: usize,
    pub stage1_candidates: usize,
    pub elapsed_ms: f64,
}

/// The coordinator.
pub struct Coordinator {
    store: Arc<ShardStore>,
    metrics: Arc<Metrics>,
    ingest: IngestHandle,
    cfg: CoordinatorConfig,
    _drain: std::thread::JoinHandle<()>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        let store = Arc::new(ShardStore::new(cfg.shard_capacity));
        let metrics = Arc::new(Metrics::new());
        let (ingest, drain) = spawn_drain(store.clone(), metrics.clone(), cfg.ingest_depth);
        Coordinator { store, metrics, ingest, cfg, _drain: drain }
    }

    /// Producer handle for streaming items in.
    pub fn ingest_handle(&self) -> IngestHandle {
        self.ingest.clone()
    }

    /// Items currently in the ground set.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Run one two-stage selection over the current ground set.
    pub fn select(&self, req: SelectRequest) -> Result<SelectResponse> {
        let t0 = Instant::now();
        let shards = self.store.snapshot();
        if shards.is_empty() {
            self.metrics
                .selections_failed
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return Err(SubmodError::Coordinator("ground set is empty".into()));
        }
        let n_shards = shards.len();
        let per_shard =
            (((req.budget as f64) * self.cfg.per_shard_factor / n_shards as f64).ceil()
                as usize)
                .max(1);

        // stage 1: fan out per-shard greedy over `workers` threads
        let queue: Mutex<Vec<Shard>> = Mutex::new(shards);
        let results: Mutex<Vec<Result<Vec<usize>>>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..self.cfg.workers.max(1) {
                scope.spawn(|| loop {
                    let shard = {
                        let mut q = queue.lock().unwrap();
                        match q.pop() {
                            Some(s) => s,
                            None => break,
                        }
                    };
                    let r = stage1(&shard, &req, per_shard);
                    results.lock().unwrap().push(r);
                });
            }
        });
        let mut candidates: Vec<usize> = Vec::new();
        for r in results.into_inner().unwrap() {
            candidates.extend(r?);
        }
        candidates.sort_unstable();
        candidates.dedup();
        let stage1_candidates = candidates.len();

        // stage 2: greedy over the candidate union
        let features = self.store.gather(&candidates)?;
        let f = req.objective.build(&features, req.metric)?;
        let budget = req.budget.min(candidates.len());
        let sel = maximize(
            f.as_ref(),
            Budget::cardinality(budget),
            req.objective.effective_optimizer(req.optimizer),
            &MaximizeOpts {
                stop_if_zero_gain: false,
                stop_if_negative_gain: false,
                ..Default::default()
            },
        )?;
        let ids: Vec<usize> = sel.ids().iter().map(|&local| candidates[local]).collect();

        let elapsed = t0.elapsed();
        self.metrics.record_select_latency(elapsed);
        self.metrics
            .selections_served
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(SelectResponse {
            ids,
            value: sel.value,
            shards: n_shards,
            stage1_candidates,
            elapsed_ms: elapsed.as_secs_f64() * 1e3,
        })
    }
}

fn stage1(shard: &Shard, req: &SelectRequest, per_shard: usize) -> Result<Vec<usize>> {
    let data = shard.matrix();
    let f = req.objective.build(&data, req.metric)?;
    let budget = per_shard.min(shard.len());
    // first-pick gains can legitimately be 0 (DisparitySum) — relax stop
    // rules so every shard returns its quota of candidates.
    let opts = MaximizeOpts {
        stop_if_zero_gain: false,
        stop_if_negative_gain: false,
        ..Default::default()
    };
    let sel = maximize(
        f.as_ref(),
        Budget::cardinality(budget),
        req.objective.effective_optimizer(req.optimizer),
        &opts,
    )?;
    Ok(sel.ids().iter().map(|&local| shard.base_id + local).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn seeded_coordinator(n: usize, shard_cap: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            workers: 2,
            shard_capacity: shard_cap,
            ingest_depth: 64,
            per_shard_factor: 2.0,
        };
        let c = Coordinator::new(cfg);
        let data = synthetic::blobs(n, 2, 5, 1.5, 77);
        let h = c.ingest_handle();
        for i in 0..n {
            h.ingest(data.row(i).to_vec()).unwrap();
        }
        c
    }

    #[test]
    fn select_returns_budget_ids() {
        let c = seeded_coordinator(120, 32);
        let resp = c.select(SelectRequest { budget: 10, ..Default::default() }).unwrap();
        assert_eq!(resp.ids.len(), 10);
        assert!(resp.shards >= 4);
        assert!(resp.stage1_candidates >= 10);
        let set: std::collections::HashSet<_> = resp.ids.iter().collect();
        assert_eq!(set.len(), 10);
        assert!(resp.ids.iter().all(|&id| id < 120));
        let m = c.metrics();
        assert_eq!(m.selections_served, 1);
        assert_eq!(m.items_ingested, 120);
    }

    #[test]
    fn two_stage_close_to_flat_greedy() {
        let c = seeded_coordinator(150, 40);
        let resp = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
        // flat single-machine baseline on identical data
        let data = synthetic::blobs(150, 2, 5, 1.5, 77);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let flat = maximize(
            &f,
            Budget::cardinality(8),
            OptimizerKind::LazyGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let subset = crate::functions::traits::Subset::from_ids(150, &resp.ids);
        let coord_value = f.evaluate(&subset);
        assert!(
            coord_value >= 0.85 * flat.value,
            "two-stage {coord_value} vs flat {}",
            flat.value
        );
    }

    #[test]
    fn empty_ground_set_fails_cleanly() {
        let c = Coordinator::new(CoordinatorConfig::default());
        assert!(c.select(SelectRequest::default()).is_err());
        assert_eq!(c.metrics().selections_failed, 1);
    }

    #[test]
    fn other_objectives_work() {
        let c = seeded_coordinator(60, 20);
        for obj in [
            ObjectiveKind::GraphCut { lambda: 0.4 },
            ObjectiveKind::DisparitySum,
            ObjectiveKind::LogDeterminant { reg: 0.1 },
        ] {
            let resp = c
                .select(SelectRequest { objective: obj, budget: 5, ..Default::default() })
                .unwrap();
            assert_eq!(resp.ids.len(), 5, "{obj:?}");
        }
    }

    #[test]
    fn growing_ground_set_between_requests() {
        let c = seeded_coordinator(50, 16);
        let r1 = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
        let h = c.ingest_handle();
        let extra = synthetic::blobs(30, 2, 2, 1.0, 99);
        for i in 0..30 {
            h.ingest(extra.row(i).to_vec()).unwrap();
        }
        let r2 = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
        assert!(r2.shards >= r1.shards);
        assert_eq!(c.len(), 80);
    }
}

//! The selection service: two-stage distributed greedy over the sharded
//! ground set, with fault isolation around every shard.
//!
//! Stage 1 (fan-out): each shard runs greedy (the requested function +
//! optimizer) over its own dense kernel, returning
//! `ceil(budget · factor / n_shards)` local candidates. Shards are
//! claimed off the shared `runtime::pool` as one job (`cfg.workers` caps
//! the participants); per-shard kernel builds and gain scans execute
//! inline inside the job.
//!
//! Stage 2 (merge): the union of candidates forms a reduced ground set;
//! a final greedy over its kernel picks the answer. This is the classic
//! composable two-stage scheme (Wei, Iyer & Bilmes 2014 — cited by the
//! paper for exactly this scaling role; same shape as GreeDi).
//!
//! ## Approximation bound (ROADMAP item 4)
//!
//! For a monotone submodular objective, running greedy independently on
//! a partition of the ground set and then greedy again over the union of
//! the per-block solutions is a constant-factor approximation of the
//! centralized greedy: with `m` blocks and budget `k`, the two-stage
//! value is within `1/min(m, k)` of the optimal subset in the worst
//! case, and Wei, Iyer & Bilmes (2014, "Fast multi-stage submodular
//! maximization") show the practical gap is far smaller when blocks are
//! balanced — which the capacity-bounded [`super::shard::ShardStore`]
//! guarantees. `per_shard_factor` over-provisions each block's quota
//! (`ceil(budget · factor / n_shards)`) so the stage-2 union almost
//! always contains the centralized greedy's picks (the
//! `two_stage_close_to_flat_greedy` test pins ≥ 0.85 of the flat value).
//! Dropping a failed shard removes only that block's candidates: the
//! bound degrades gracefully to the surviving blocks' partition — the
//! formal basis for the quorum policy below, and why a `degraded`
//! response is still a principled answer rather than a best-effort one.
//!
//! ## Fault model (ISSUE 6 + 8 + 10): shed → degrade → cancel → error → shutdown
//!
//! Overload protection wraps the per-request fault tolerance in five
//! layers, ordered from cheapest to most drastic:
//!
//! 1. **Shed** ([`super::admission`]): at most
//!    `CoordinatorConfig::max_inflight` selections run concurrently;
//!    `admission_queue_depth` more wait FIFO. Beyond that — or when a
//!    request's deadline is already spent at admission — the request is
//!    refused immediately with `SubmodError::Overloaded`
//!    (`Metrics::selections_shed`). Admission schedules *when* a
//!    selection runs, never *what* it computes, so admitted selections
//!    are byte-identical to an uncontended run.
//! 2. **Degrade** (quorum + circuit breakers): shards that fail their
//!    retry are dropped; a shard failing `breaker_threshold` consecutive
//!    requests is quarantined ([`super::shard::ShardBreakers`]) and
//!    skipped — counted toward quorum exactly like a dropped shard,
//!    surfaced in `failed_shards` and the `shards_quarantined` gauge —
//!    until a request-count-based Half-Open probe readmits it.
//! 3. **Cancel** (ISSUE 10, [`super::watchdog`] + `runtime::cancel`):
//!    every admitted request evaluates under its own [`CancelToken`],
//!    installed as the ambient cancel scope and propagated by the worker
//!    pool into every participant. When `SelectRequest::deadline` is
//!    set, the watchdog arms the token and fires it the moment the
//!    budget runs out; every compute layer — kernel tiles, the sparse
//!    wavefront, gain-scan chunks, optimizer iterations, pool claim
//!    loops — polls the token at its claim boundaries and unwinds within
//!    one tile/chunk/iteration. The typed `SubmodError::Cancelled`
//!    surfaces as `DeadlineExceeded` when the watchdog fired the token
//!    (`Metrics::selections_cancelled` counts the preemptive unwind);
//!    shard evaluations aborted by a cancel are *not* charged to circuit
//!    breakers or `shard_failures` — the shard did nothing wrong. The
//!    pool, memoized states, and CSR builders are left clean: the next
//!    request on the same coordinator serves byte-identical results.
//! 4. **Error**: quorum misses, deadlines, and stage-2 failures return
//!    typed errors; failed/shed/cancelled request latencies land in a
//!    separate histogram (`failed_latency_p50/p99_us`) so success
//!    percentiles carry no survivorship bias.
//! 5. **Shutdown** ([`Coordinator::shutdown`]): admission closes (typed
//!    `ShuttingDown` for new requests), in-flight selections and the
//!    ingest queue drain, the drain thread joins, and a final checkpoint
//!    blob is returned. [`Coordinator::shutdown_with_grace`] bounds the
//!    drain: selections still in flight when the grace budget ends are
//!    hard-cancelled (reason `Shutdown`) and unwind as
//!    `SubmodError::Cancelled`.
//!
//! ## Fault model (ISSUE 6)
//!
//! The two-stage scheme keeps a partition-greedy approximation story per
//! *surviving* shard, so the service degrades instead of dying:
//!
//! * **What retries:** a stage-1 shard evaluation that panics or errors
//!   is retried once (`Metrics::shard_retries`). Panics are contained by
//!   `catch_unwind` inside the fan-out job — they never unwind into the
//!   worker pool or tear down the request.
//! * **What degrades:** a shard that fails even its retry is dropped
//!   (`Metrics::shard_failures`). If at least
//!   `CoordinatorConfig::min_shard_quorum` shards survive (default: all
//!   must), selection proceeds over the survivors and the response is
//!   marked `degraded` with the dropped shards' base ids in
//!   `failed_shards` (`Metrics::selections_degraded`).
//! * **What errors:** quorum failures return a typed `Coordinator`
//!   error; a request running past `SelectRequest::deadline` — checked
//!   between shard claims and again before stage 2 — returns
//!   `SubmodError::DeadlineExceeded` (`Metrics::deadline_exceeded`)
//!   instead of blocking unboundedly. Stage-2 failures fail the request:
//!   there is no partial answer to degrade to. Every failed request
//!   bumps `Metrics::selections_failed`.
//! * **What recovers:** the ingest drain is supervised (see
//!   [`super::ingest`]), and the whole ground set can be checkpointed
//!   and restored ([`Coordinator::checkpoint`] /
//!   [`Coordinator::from_checkpoint`]); restored selections are
//!   byte-identical to pre-crash ones because selection is a
//!   deterministic function of the stored rows.
//!
//! Every path above is pinned by the deterministic fault-injection suite
//! (`tests/fault_injection.rs`, via [`super::faults`]).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::CoordinatorConfig;
use crate::coordinator::admission::AdmissionGate;
use crate::coordinator::faults;
use crate::coordinator::ingest::{spawn_drain, IngestHandle};
use crate::coordinator::metrics::{Metrics, MetricsSnapshot};
use crate::coordinator::shard::{
    BreakerDecision, BreakerTransition, Shard, ShardBreakers, ShardStore,
};
use crate::coordinator::watchdog::DeadlineWatchdog;
use crate::error::{Result, SubmodError};
use crate::functions::disparity_sum::DisparitySum;
use crate::functions::facility_location::FacilityLocation;
use crate::functions::graph_cut::GraphCut;
use crate::functions::log_determinant::LogDeterminant;
use crate::functions::traits::SetFunction;
use crate::kernel::{DenseKernel, Metric};
use crate::linalg::Matrix;
use crate::optimizers::{maximize, Budget, MaximizeOpts, OptimizerKind};
use crate::runtime::cancel::{self, CancelReason, CancelToken};
use crate::runtime::pool;

/// Which objective a selection request optimizes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObjectiveKind {
    FacilityLocation,
    GraphCut { lambda: f64 },
    /// LogDet requires a positive-definite kernel, so it only accepts
    /// RBF metrics: an explicit `Metric::Rbf` in the request is honored
    /// (gamma included); any other metric is overridden to
    /// `Rbf { gamma: 1.0 }`. See [`SelectRequest::metric`].
    LogDeterminant { reg: f64 },
    DisparitySum,
}

impl ObjectiveKind {
    fn build(&self, data: &Matrix, metric: Metric) -> Result<Box<dyn SetFunction>> {
        // injection site: keyed by the ground-set size being built, so
        // tests can target per-shard builds vs the stage-2 merge build
        faults::failpoint(faults::KERNEL_BUILD, data.rows())?;
        let f: Box<dyn SetFunction> = match *self {
            ObjectiveKind::FacilityLocation => {
                Box::new(FacilityLocation::new(DenseKernel::from_data(data, metric)))
            }
            ObjectiveKind::GraphCut { lambda } => {
                Box::new(GraphCut::new(DenseKernel::from_data(data, metric), lambda)?)
            }
            ObjectiveKind::LogDeterminant { reg } => {
                // LogDet's Cholesky needs a positive-definite kernel:
                // honor an explicit RBF (gamma included), override
                // anything else to RBF γ=1.0 (documented on
                // `SelectRequest::metric`, pinned by
                // `log_determinant_metric_override_is_pinned`)
                let metric = match metric {
                    rbf @ Metric::Rbf { .. } => rbf,
                    _ => Metric::Rbf { gamma: 1.0 },
                };
                Box::new(LogDeterminant::with_regularization(
                    DenseKernel::from_data(data, metric),
                    reg,
                )?)
            }
            ObjectiveKind::DisparitySum => {
                Box::new(DisparitySum::new(DenseKernel::distances_from_data(data)))
            }
        };
        // the tile drivers only *stop* on a fired token (they return
        // `()`): a cancelled build's partial kernel is discarded here, at
        // the nearest Result-returning layer
        cancel::check_current()?;
        Ok(f)
    }

    /// DisparitySum is supermodular → lazy bounds are invalid; route it to
    /// NaiveGreedy regardless of the requested optimizer.
    fn effective_optimizer(&self, requested: OptimizerKind) -> OptimizerKind {
        match self {
            ObjectiveKind::DisparitySum => OptimizerKind::NaiveGreedy,
            _ => requested,
        }
    }
}

/// A selection request.
#[derive(Debug, Clone)]
pub struct SelectRequest {
    pub objective: ObjectiveKind,
    pub budget: usize,
    pub optimizer: OptimizerKind,
    /// Similarity metric for kernel construction. One documented
    /// override: `ObjectiveKind::LogDeterminant` requires a
    /// positive-definite kernel, so it honors `Metric::Rbf` (gamma
    /// included) but silently substitutes `Rbf { gamma: 1.0 }` for any
    /// other metric — the default `Euclidean` therefore still works for
    /// LogDet requests (pinned by
    /// `log_determinant_metric_override_is_pinned`).
    pub metric: Metric,
    /// Wall-clock budget for this request, measured from `select()`
    /// entry — time spent waiting in the admission queue counts. A
    /// deadline already spent at admission sheds the request
    /// (`SubmodError::Overloaded`); one expiring in the queue or during
    /// evaluation fails it with `SubmodError::DeadlineExceeded`.
    /// Enforcement is *preemptive* (ISSUE 10): the [`super::watchdog`]
    /// fires the request's cancel token when the budget runs out, and
    /// every compute layer polls it at claim boundaries — a request
    /// stuck inside one kernel build or gain scan still unwinds within
    /// one tile/chunk/iteration. `None` (default) = no deadline.
    pub deadline: Option<Duration>,
}

impl Default for SelectRequest {
    fn default() -> Self {
        SelectRequest {
            objective: ObjectiveKind::FacilityLocation,
            budget: 10,
            optimizer: OptimizerKind::LazyGreedy,
            metric: Metric::Euclidean,
            deadline: None,
        }
    }
}

/// A selection response: global ids + objective value + stage accounting.
#[derive(Debug, Clone)]
pub struct SelectResponse {
    pub ids: Vec<usize>,
    pub value: f64,
    /// Shards consulted (including any that failed and were dropped).
    pub shards: usize,
    pub stage1_candidates: usize,
    pub elapsed_ms: f64,
    /// True when at least one shard was dropped after its retry and the
    /// answer was computed over the surviving shards only.
    pub degraded: bool,
    /// `base_id`s of the dropped shards (ascending; empty when healthy).
    pub failed_shards: Vec<usize>,
}

/// One shard's stage-1 outcome: candidate ids, or the (stringified)
/// error/panic that survived the retry.
struct ShardOutcome {
    base_id: usize,
    result: std::result::Result<Vec<usize>, String>,
}

/// The coordinator.
pub struct Coordinator {
    store: Arc<ShardStore>,
    metrics: Arc<Metrics>,
    ingest: IngestHandle,
    cfg: CoordinatorConfig,
    admission: AdmissionGate,
    breakers: ShardBreakers,
    /// Fires request cancel tokens when their deadlines pass.
    watchdog: DeadlineWatchdog,
    /// Cancel tokens of admitted, still-running selections — what
    /// [`shutdown_with_grace`](Self::shutdown_with_grace) hard-cancels
    /// when the drain grace budget runs out.
    inflight: Mutex<HashMap<u64, CancelToken>>,
    next_request_id: AtomicU64,
    /// Taken (and joined) exactly once, by [`shutdown`](Self::shutdown).
    drain: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// RAII entry in [`Coordinator::inflight`]; deregisters on drop.
struct InflightGuard<'a> {
    coordinator: &'a Coordinator,
    id: u64,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.coordinator.inflight.lock().unwrap().remove(&self.id);
    }
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        let store = Arc::new(ShardStore::new(cfg.shard_capacity));
        Coordinator::with_store(cfg, store)
    }

    /// Rebuild a coordinator from a [`checkpoint`](Self::checkpoint)
    /// blob: the restored store keeps its checkpointed shard layout and
    /// capacity (new ingest continues from the checkpointed id space);
    /// `cfg.shard_capacity` is ignored in favor of the checkpoint's.
    pub fn from_checkpoint(cfg: CoordinatorConfig, bytes: &[u8]) -> Result<Coordinator> {
        let store = Arc::new(ShardStore::restore(bytes)?);
        Ok(Coordinator::with_store(cfg, store))
    }

    fn with_store(cfg: CoordinatorConfig, store: Arc<ShardStore>) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        let (ingest, drain) = spawn_drain(store.clone(), metrics.clone(), cfg.ingest_depth);
        let admission =
            AdmissionGate::new(cfg.max_inflight, cfg.admission_queue_depth, metrics.clone());
        let breakers = ShardBreakers::new(cfg.breaker_threshold, cfg.breaker_probe_after);
        Coordinator {
            store,
            metrics,
            ingest,
            cfg,
            admission,
            breakers,
            watchdog: DeadlineWatchdog::new(),
            inflight: Mutex::new(HashMap::new()),
            next_request_id: AtomicU64::new(0),
            drain: Mutex::new(Some(drain)),
        }
    }

    /// Serialize the current ground set (see [`ShardStore::checkpoint`]).
    /// Selections over a store restored from this blob are byte-identical
    /// to selections over the live store at checkpoint time.
    pub fn checkpoint(&self) -> Vec<u8> {
        self.store.checkpoint()
    }

    /// Producer handle for streaming items in.
    pub fn ingest_handle(&self) -> IngestHandle {
        self.ingest.clone()
    }

    /// Items currently in the ground set.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Run one two-stage selection over the current ground set, gated by
    /// admission control. See the module docs for the full fault model
    /// (shed → degrade → cancel → error → shutdown).
    pub fn select(&self, req: SelectRequest) -> Result<SelectResponse> {
        // the clock starts at entry: time waiting in the admission queue
        // counts against the request's deadline
        let t0 = Instant::now();
        let token = CancelToken::new();
        let res = self.admission.acquire(t0, req.deadline).and_then(|_permit| {
            // register for shutdown hard-cancel, arm the deadline
            // watchdog (RAII: both deregister when evaluation returns),
            // and evaluate under the token as the ambient cancel scope —
            // the pool propagates it into every participant
            let _inflight = self.track_inflight(&token);
            let _armed =
                req.deadline.map(|d| self.watchdog.arm(t0 + d, token.clone()));
            cancel::with_scope(Some(token.clone()), || self.select_inner(&req, t0))
        });
        // a token the watchdog fired IS the deadline: surface it under
        // the request's contract; shutdown/manual cancels stay Cancelled
        let res = res.map_err(|e| match (e, token.reason()) {
            (SubmodError::Cancelled, Some(CancelReason::Deadline)) => {
                SubmodError::DeadlineExceeded
            }
            (e, _) => e,
        });
        if let Err(e) = &res {
            if matches!(e, SubmodError::DeadlineExceeded) {
                self.metrics.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
            }
            if token.is_fired() {
                // compute was actually unwound mid-flight (as opposed to
                // a deadline caught at a rim checkpoint)
                self.metrics.selections_cancelled.fetch_add(1, Ordering::Relaxed);
            }
            self.metrics.selections_failed.fetch_add(1, Ordering::Relaxed);
            // failed/shed/cancelled latencies go to their own histogram
            // so the success percentiles carry no survivorship bias
            self.metrics.record_failed_latency(t0.elapsed());
        }
        res
    }

    fn track_inflight(&self, token: &CancelToken) -> InflightGuard<'_> {
        let id = self.next_request_id.fetch_add(1, Ordering::Relaxed);
        self.inflight.lock().unwrap().insert(id, token.clone());
        InflightGuard { coordinator: self, id }
    }

    /// Stop serving: close admission (new selections fail with
    /// `SubmodError::ShuttingDown`), wait for in-flight selections to
    /// finish, drain the ingest queue, join the drain thread, and return
    /// a final checkpoint of the ground set. Idempotent — a second call
    /// returns a fresh checkpoint of the (unchanged) store.
    pub fn shutdown(&self) -> Result<Vec<u8>> {
        self.admission.close();
        self.admission.drain();
        self.finish_shutdown()
    }

    /// [`shutdown`](Self::shutdown) with a bounded drain: in-flight
    /// selections get `grace` to finish on their own; whatever is still
    /// running after that is **hard-cancelled** — its cancel token fires
    /// with [`CancelReason::Shutdown`], the compute layers unwind at
    /// their next poll, and the caller sees `SubmodError::Cancelled`
    /// (counted in `Metrics::selections_cancelled`). The drain then
    /// completes unconditionally; everything else matches `shutdown`.
    pub fn shutdown_with_grace(&self, grace: Duration) -> Result<Vec<u8>> {
        self.admission.close();
        if !self.admission.drain_timeout(grace) {
            for token in self.inflight.lock().unwrap().values() {
                token.fire(CancelReason::Shutdown);
            }
            self.admission.drain();
        }
        self.finish_shutdown()
    }

    fn finish_shutdown(&self) -> Result<Vec<u8>> {
        self.ingest.request_shutdown();
        let drain = self.drain.lock().unwrap().take();
        if let Some(join) = drain {
            join.join().map_err(|_| {
                SubmodError::Coordinator("ingest drain panicked during shutdown".into())
            })?;
        }
        Ok(self.store.checkpoint())
    }

    /// Map a breaker state-machine transition onto the metrics surface.
    fn note_breaker(&self, transition: Option<BreakerTransition>) {
        match transition {
            Some(BreakerTransition::Tripped) => {
                self.metrics.breaker_trips.fetch_add(1, Ordering::Relaxed);
                self.metrics.shards_quarantined.fetch_add(1, Ordering::Relaxed);
            }
            Some(BreakerTransition::Probing) => {
                self.metrics.breaker_probes.fetch_add(1, Ordering::Relaxed);
            }
            Some(BreakerTransition::Recovered) => {
                self.metrics.breaker_recoveries.fetch_add(1, Ordering::Relaxed);
                self.metrics.shards_quarantined.fetch_sub(1, Ordering::Relaxed);
            }
            // re-opening keeps the shard quarantined: gauge unchanged
            Some(BreakerTransition::Reopened) | None => {}
        }
    }

    fn select_inner(&self, req: &SelectRequest, t0: Instant) -> Result<SelectResponse> {
        let shards = self.store.snapshot();
        if shards.is_empty() {
            return Err(SubmodError::Coordinator("ground set is empty".into()));
        }
        let n_shards = shards.len();
        let per_shard =
            (((req.budget as f64) * self.cfg.per_shard_factor / n_shards as f64).ceil()
                as usize)
                .max(1);

        // stage 1: fan the shards out over the shared pool as one job.
        // Shards are claimed off an atomic counter and each outcome goes
        // to its own slot (slot index = shard index), so the result is
        // independent of the participant count. Each evaluation runs
        // under catch_unwind with one retry; panics never reach the pool.
        let deadline_hit = AtomicBool::new(false);
        let outcomes: Vec<Mutex<Option<ShardOutcome>>> =
            (0..n_shards).map(|_| Mutex::new(None)).collect();
        pool::run_indexed(self.cfg.workers.max(1), shards, |t, shard: Shard| {
            // a fired cancel token skips remaining shards without
            // charging them (no evaluation, no retry, no breaker record)
            if cancel::active() {
                return;
            }
            // deadline check between shard claims: once the budget is
            // gone, remaining shards are skipped, not evaluated
            if let Some(d) = req.deadline {
                if deadline_hit.load(Ordering::Relaxed) || t0.elapsed() >= d {
                    deadline_hit.store(true, Ordering::Relaxed);
                    return;
                }
            }
            let base_id = shard.base_id;
            // circuit breaker: a quarantined shard is skipped without an
            // evaluation (or retry) — it still counts toward quorum like
            // a dropped shard, but costs nothing per request
            let (decision, opening) = self.breakers.decide(base_id);
            self.note_breaker(opening);
            let result = match decision {
                BreakerDecision::Skip => {
                    Err("circuit breaker open (shard quarantined)".to_string())
                }
                BreakerDecision::Attempt { probe } => {
                    let result = match run_isolated(|| stage1(&shard, req, per_shard)) {
                        Ok(ids) => Ok(ids),
                        // an evaluation aborted by the request's own
                        // cancel token is not a shard fault: leave the
                        // slot empty with no retry and no breaker charge
                        // (a cancelled probe is un-decided so the shard
                        // is re-probed on the next request)
                        Err(_cancelled) if cancel::active() => {
                            if probe {
                                self.breakers.abort_probe(base_id);
                            }
                            return;
                        }
                        Err(_first) => {
                            self.metrics.shard_retries.fetch_add(1, Ordering::Relaxed);
                            match run_isolated(|| stage1(&shard, req, per_shard)) {
                                Ok(ids) => Ok(ids),
                                Err(_cancelled) if cancel::active() => {
                                    if probe {
                                        self.breakers.abort_probe(base_id);
                                    }
                                    return;
                                }
                                Err(e) => {
                                    self.metrics
                                        .shard_failures
                                        .fetch_add(1, Ordering::Relaxed);
                                    Err(e)
                                }
                            }
                        }
                    };
                    // the post-retry outcome feeds the breaker; a probe
                    // outcome decides recovery vs re-quarantine
                    self.note_breaker(self.breakers.record(base_id, probe, result.is_ok()));
                    result
                }
            };
            *outcomes[t].lock().unwrap() = Some(ShardOutcome { base_id, result });
        });
        // a cancel that landed anywhere in the fan-out (or during the
        // admission-to-here window) aborts before the slots are read —
        // cancel-skipped slots are legitimately empty
        cancel::check_current()?;
        if deadline_hit.load(Ordering::Relaxed)
            || req.deadline.is_some_and(|d| t0.elapsed() >= d)
        {
            return Err(SubmodError::DeadlineExceeded);
        }

        // quorum policy: proceed over the survivors iff enough remain
        let mut candidates: Vec<usize> = Vec::new();
        let mut failed_shards: Vec<usize> = Vec::new();
        let mut last_error = String::new();
        for slot in &outcomes {
            let outcome = slot
                .lock()
                .unwrap()
                .take()
                .expect("every shard slot is filled when no deadline or cancel fired");
            match outcome.result {
                Ok(ids) => candidates.extend(ids),
                Err(e) => {
                    failed_shards.push(outcome.base_id);
                    last_error = e;
                }
            }
        }
        let survivors = n_shards - failed_shards.len();
        let quorum = self.cfg.min_shard_quorum.map_or(n_shards, |q| q.clamp(1, n_shards));
        if survivors < quorum {
            return Err(SubmodError::Coordinator(format!(
                "shard quorum not met: {survivors}/{n_shards} shards survived stage 1 \
                 (quorum {quorum}); last shard error: {last_error}"
            )));
        }
        let degraded = !failed_shards.is_empty();
        candidates.sort_unstable();
        candidates.dedup();
        let stage1_candidates = candidates.len();

        // deadline check before the stage-2 merge
        if req.deadline.is_some_and(|d| t0.elapsed() >= d) {
            return Err(SubmodError::DeadlineExceeded);
        }

        // injection site: a Delay here holds the selection in flight
        // (admission permit held) — how the saturation tests force
        // overload deterministically; keyed by the candidate count
        faults::failpoint(faults::STAGE2_MERGE, stage1_candidates)?;

        // stage 2: greedy over the candidate union
        let features = self.store.gather(&candidates)?;
        let f = req.objective.build(&features, req.metric)?;
        let budget = req.budget.min(candidates.len());
        let sel = maximize(
            f.as_ref(),
            Budget::cardinality(budget),
            req.objective.effective_optimizer(req.optimizer),
            &MaximizeOpts {
                stop_if_zero_gain: false,
                stop_if_negative_gain: false,
                // the request token, plumbed explicitly (it is also the
                // ambient scope, but MaximizeOpts is the public contract)
                cancel: cancel::current(),
                ..Default::default()
            },
        )?;
        let ids: Vec<usize> = sel.ids().iter().map(|&local| candidates[local]).collect();

        let elapsed = t0.elapsed();
        self.metrics.record_select_latency(elapsed);
        self.metrics.selections_served.fetch_add(1, Ordering::Relaxed);
        if degraded {
            self.metrics.selections_degraded.fetch_add(1, Ordering::Relaxed);
        }
        Ok(SelectResponse {
            ids,
            value: sel.value,
            shards: n_shards,
            stage1_candidates,
            elapsed_ms: elapsed.as_secs_f64() * 1e3,
            degraded,
            failed_shards,
        })
    }
}

/// Run one shard evaluation with panics contained: a panic or error
/// becomes a stringified failure the fan-out can retry or record, never
/// an unwind into the pool.
fn run_isolated<T>(f: impl FnOnce() -> Result<T>) -> std::result::Result<T, String> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(e)) => Err(e.to_string()),
        Err(payload) => Err(panic_message(&*payload)),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: <non-string payload>".to_string()
    }
}

fn stage1(shard: &Shard, req: &SelectRequest, per_shard: usize) -> Result<Vec<usize>> {
    // injection site: keyed by the shard's base_id so a specific shard
    // can be killed deterministically under any claim order
    faults::failpoint(faults::STAGE1_EVAL, shard.base_id)?;
    let data = shard.matrix();
    let f = req.objective.build(&data, req.metric)?;
    let budget = per_shard.min(shard.len());
    // first-pick gains can legitimately be 0 (DisparitySum) — relax stop
    // rules so every shard returns its quota of candidates.
    let opts = MaximizeOpts {
        stop_if_zero_gain: false,
        stop_if_negative_gain: false,
        // the request token: the pool installed it as this worker's
        // ambient scope; hand it to maximize explicitly as well
        cancel: cancel::current(),
        ..Default::default()
    };
    let sel = maximize(
        f.as_ref(),
        Budget::cardinality(budget),
        req.objective.effective_optimizer(req.optimizer),
        &opts,
    )?;
    Ok(sel.ids().iter().map(|&local| shard.base_id + local).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn seeded_coordinator(n: usize, shard_cap: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            workers: 2,
            shard_capacity: shard_cap,
            ingest_depth: 64,
            per_shard_factor: 2.0,
            min_shard_quorum: None,
            max_inflight: 4,
            admission_queue_depth: 16,
            breaker_threshold: None,
            breaker_probe_after: 4,
        };
        let c = Coordinator::new(cfg);
        let data = synthetic::blobs(n, 2, 5, 1.5, 77);
        let h = c.ingest_handle();
        for i in 0..n {
            h.ingest(data.row(i).to_vec()).unwrap();
        }
        c
    }

    #[test]
    fn select_returns_budget_ids() {
        let c = seeded_coordinator(120, 32);
        let resp = c.select(SelectRequest { budget: 10, ..Default::default() }).unwrap();
        assert_eq!(resp.ids.len(), 10);
        assert!(resp.shards >= 4);
        assert!(resp.stage1_candidates >= 10);
        assert!(!resp.degraded);
        assert!(resp.failed_shards.is_empty());
        let set: std::collections::HashSet<_> = resp.ids.iter().collect();
        assert_eq!(set.len(), 10);
        assert!(resp.ids.iter().all(|&id| id < 120));
        let m = c.metrics();
        assert_eq!(m.selections_served, 1);
        assert_eq!(m.items_ingested, 120);
        assert_eq!(m.selections_degraded, 0);
        assert_eq!(m.shard_failures, 0);
    }

    #[test]
    fn two_stage_close_to_flat_greedy() {
        let c = seeded_coordinator(150, 40);
        let resp = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
        // flat single-machine baseline on identical data
        let data = synthetic::blobs(150, 2, 5, 1.5, 77);
        let f = FacilityLocation::new(DenseKernel::from_data(&data, Metric::Euclidean));
        let flat = maximize(
            &f,
            Budget::cardinality(8),
            OptimizerKind::LazyGreedy,
            &MaximizeOpts::default(),
        )
        .unwrap();
        let subset = crate::functions::traits::Subset::from_ids(150, &resp.ids);
        let coord_value = f.evaluate(&subset);
        assert!(
            coord_value >= 0.85 * flat.value,
            "two-stage {coord_value} vs flat {}",
            flat.value
        );
    }

    #[test]
    fn empty_ground_set_fails_cleanly() {
        let c = Coordinator::new(CoordinatorConfig::default());
        assert!(c.select(SelectRequest::default()).is_err());
        let m = c.metrics();
        assert_eq!(m.selections_failed, 1);
        // the failure's latency lands in the failed histogram, not the
        // success one (survivorship-bias fix, ISSUE 8)
        assert!(m.failed_latency_p99_us > 0);
        assert_eq!(m.latency_p99_us, 0);
    }

    #[test]
    fn other_objectives_work() {
        let c = seeded_coordinator(60, 20);
        for obj in [
            ObjectiveKind::GraphCut { lambda: 0.4 },
            ObjectiveKind::DisparitySum,
            ObjectiveKind::LogDeterminant { reg: 0.1 },
        ] {
            let resp = c
                .select(SelectRequest { objective: obj, budget: 5, ..Default::default() })
                .unwrap();
            assert_eq!(resp.ids.len(), 5, "{obj:?}");
        }
    }

    #[test]
    fn growing_ground_set_between_requests() {
        let c = seeded_coordinator(50, 16);
        let r1 = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
        let h = c.ingest_handle();
        let extra = synthetic::blobs(30, 2, 2, 1.0, 99);
        for i in 0..30 {
            h.ingest(extra.row(i).to_vec()).unwrap();
        }
        let r2 = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
        assert!(r2.shards >= r1.shards);
        assert_eq!(c.len(), 80);
    }

    #[test]
    fn generous_deadline_is_met() {
        let c = seeded_coordinator(80, 20);
        let resp = c
            .select(SelectRequest {
                budget: 5,
                deadline: Some(Duration::from_secs(600)),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(resp.ids.len(), 5);
        assert_eq!(c.metrics().deadline_exceeded, 0);
    }

    #[test]
    fn zero_deadline_is_shed_at_admission() {
        // a deadline already spent on arrival can only expire in the
        // queue, so admission sheds it with `Overloaded` (ISSUE 8)
        // before any shard work happens
        let c = seeded_coordinator(80, 20);
        let err = c
            .select(SelectRequest {
                budget: 5,
                deadline: Some(Duration::ZERO),
                ..Default::default()
            })
            .unwrap_err();
        assert!(matches!(err, SubmodError::Overloaded), "{err}");
        let m = c.metrics();
        assert_eq!(m.selections_shed, 1);
        assert_eq!(m.selections_failed, 1);
        // shed ≠ deadline-exceeded-in-flight, and no shard was charged
        assert_eq!(m.deadline_exceeded, 0);
        assert_eq!(m.shard_failures, 0);
    }

    #[test]
    fn log_determinant_metric_override_is_pinned() {
        // LogDet honors an explicit RBF metric (gamma included) and
        // overrides every other metric to Rbf{gamma: 1.0} — the default
        // Euclidean request must behave exactly like explicit Rbf{1.0}
        let c = seeded_coordinator(60, 20);
        let logdet = ObjectiveKind::LogDeterminant { reg: 0.1 };
        let with_metric = |metric| {
            c.select(SelectRequest { objective: logdet, budget: 5, metric, ..Default::default() })
                .unwrap()
        };
        let euclid = with_metric(Metric::Euclidean);
        let rbf_default = with_metric(Metric::Rbf { gamma: 1.0 });
        assert_eq!(euclid.ids, rbf_default.ids);
        assert_eq!(euclid.value.to_bits(), rbf_default.value.to_bits());
        // and an explicit non-default gamma is actually honored
        let rbf_wide = with_metric(Metric::Rbf { gamma: 0.01 });
        assert_ne!(
            euclid.value.to_bits(),
            rbf_wide.value.to_bits(),
            "explicit gamma must reach the kernel"
        );
    }

    #[test]
    fn shutdown_refuses_new_work_and_returns_checkpoint() {
        let c = seeded_coordinator(60, 20);
        let before = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
        let blob = c.shutdown().unwrap();
        // new selections are refused with the typed shutdown error
        let err = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap_err();
        assert!(matches!(err, SubmodError::ShuttingDown), "{err}");
        // ingest after shutdown is a typed error, never a hang
        assert!(c.ingest_handle().ingest(vec![0.0, 0.0]).is_err());
        // the checkpoint restores to a coordinator serving byte-identical
        // selections
        let r = Coordinator::from_checkpoint(CoordinatorConfig::default(), &blob).unwrap();
        let after = r.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
        assert_eq!(after.ids, before.ids);
        assert_eq!(after.value.to_bits(), before.value.to_bits());
        // shutdown is idempotent
        assert_eq!(c.shutdown().unwrap(), blob);
    }

    #[test]
    fn shutdown_with_grace_is_shutdown_when_nothing_is_inflight() {
        let c = seeded_coordinator(60, 20);
        let before = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
        let blob = c.shutdown_with_grace(Duration::from_millis(50)).unwrap();
        let err = c.select(SelectRequest { budget: 5, ..Default::default() }).unwrap_err();
        assert!(matches!(err, SubmodError::ShuttingDown), "{err}");
        // nothing was in flight, so nothing was hard-cancelled
        assert_eq!(c.metrics().selections_cancelled, 0);
        let r = Coordinator::from_checkpoint(CoordinatorConfig::default(), &blob).unwrap();
        let after = r.select(SelectRequest { budget: 5, ..Default::default() }).unwrap();
        assert_eq!(after.ids, before.ids);
        assert_eq!(after.value.to_bits(), before.value.to_bits());
    }

    #[test]
    fn watchdog_deadline_returns_typed_error_and_leaves_pool_reusable() {
        // a deadline far too small for a real selection: the watchdog
        // fires the token mid-compute (or the rim checks catch it) —
        // either way the contract is a typed DeadlineExceeded and an
        // immediately reusable coordinator
        let c = seeded_coordinator(150, 32);
        let clean = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
        let err = c
            .select(SelectRequest {
                budget: 8,
                deadline: Some(Duration::from_nanos(1)),
                ..Default::default()
            })
            .unwrap_err();
        // a 1 ns deadline may already be spent at admission (shed) —
        // both outcomes are typed, neither is a hang or a panic
        assert!(
            matches!(err, SubmodError::DeadlineExceeded | SubmodError::Overloaded),
            "{err}"
        );
        // the next request is byte-identical to the pre-cancel one
        let again = c.select(SelectRequest { budget: 8, ..Default::default() }).unwrap();
        assert_eq!(again.ids, clean.ids);
        assert_eq!(again.value.to_bits(), clean.value.to_bits());
        assert_eq!(c.metrics().shard_failures, 0, "cancel never charges shards");
    }

    #[test]
    fn checkpoint_restore_preserves_selection() {
        let c = seeded_coordinator(90, 24);
        let before = c.select(SelectRequest { budget: 6, ..Default::default() }).unwrap();
        let blob = c.checkpoint();
        let r = Coordinator::from_checkpoint(CoordinatorConfig::default(), &blob).unwrap();
        assert_eq!(r.len(), 90);
        let after = r.select(SelectRequest { budget: 6, ..Default::default() }).unwrap();
        assert_eq!(after.ids, before.ids);
        assert_eq!(after.value.to_bits(), before.value.to_bits());
    }
}

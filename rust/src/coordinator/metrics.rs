//! Coordinator metrics: lock-free counters plus a fixed-bucket latency
//! histogram (enough for p50/p99 without external crates).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Latency histogram buckets (µs upper bounds), roughly logarithmic.
const BUCKETS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 100_000, 1_000_000, u64::MAX];

/// Shared metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    pub items_ingested: AtomicU64,
    pub selections_served: AtomicU64,
    pub selections_failed: AtomicU64,
    pub backpressure_waits: AtomicU64,
    select_latency: [AtomicU64; 12],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_select_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(BUCKETS_US.len() - 1);
        self.select_latency[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let hist: Vec<u64> =
            self.select_latency.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        MetricsSnapshot {
            items_ingested: self.items_ingested.load(Ordering::Relaxed),
            selections_served: self.selections_served.load(Ordering::Relaxed),
            selections_failed: self.selections_failed.load(Ordering::Relaxed),
            backpressure_waits: self.backpressure_waits.load(Ordering::Relaxed),
            latency_p50_us: percentile(&hist, 0.50),
            latency_p99_us: percentile(&hist, 0.99),
        }
    }
}

fn percentile(hist: &[u64], p: f64) -> u64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * p).ceil() as u64;
    let mut acc = 0;
    for (i, &c) in hist.iter().enumerate() {
        acc += c;
        if acc >= target {
            return BUCKETS_US[i];
        }
    }
    *BUCKETS_US.last().unwrap()
}

/// Point-in-time metrics view.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub items_ingested: u64,
    pub selections_served: u64,
    pub selections_failed: u64,
    pub backpressure_waits: u64,
    /// bucketized upper-bound estimates
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ingested={} served={} failed={} backpressure={} p50≤{}µs p99≤{}µs",
            self.items_ingested,
            self.selections_served,
            self.selections_failed,
            self.backpressure_waits,
            self.latency_p50_us,
            self.latency_p99_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.items_ingested.fetch_add(5, Ordering::Relaxed);
        m.selections_served.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.items_ingested, 5);
        assert_eq!(s.selections_served, 2);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.record_select_latency(Duration::from_micros(80));
        }
        m.record_select_latency(Duration::from_millis(50));
        let s = m.snapshot();
        assert_eq!(s.latency_p50_us, 100); // bucket upper bound
        assert!(s.latency_p99_us >= 80);
    }

    #[test]
    fn empty_histogram_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.latency_p50_us, 0);
        assert_eq!(s.latency_p99_us, 0);
    }

    #[test]
    fn display_mentions_counters() {
        let m = Metrics::new();
        m.items_ingested.fetch_add(3, Ordering::Relaxed);
        assert!(m.snapshot().to_string().contains("ingested=3"));
    }
}

//! Coordinator metrics: lock-free counters plus a fixed-bucket latency
//! histogram (enough for p50/p99 without external crates).
//!
//! Besides throughput accounting, the counters are the observability
//! surface of the fault-tolerance layer (ISSUE 6): every recovery path —
//! shard retry, degraded selection, deadline abort, drain respawn — bumps
//! a dedicated counter so operators (and the fault-injection suite) can
//! distinguish "healthy", "degraded but serving", and "failing".
//!
//! The overload-protection layer (ISSUE 8) adds its own surface:
//! admission accounting (`selections_shed`, `admission_waits`, the
//! `selections_inflight` gauge), circuit-breaker transitions
//! (`breaker_trips` / `breaker_probes` / `breaker_recoveries`, the
//! `shards_quarantined` gauge), and a *separate* failure-latency
//! histogram. Successful and failed requests are recorded apart because
//! folding them together understates tail latency in exactly the runs
//! that matter (survivorship bias: the slow requests are the ones that
//! hit deadlines and fail).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Latency histogram buckets (µs upper bounds), roughly logarithmic.
/// The last bucket is the overflow catch-all: recorded there, but
/// *reported* as [`OVERFLOW_CLAMP_US`] (see [`percentile`]).
const BUCKETS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 100_000, 1_000_000, u64::MAX];

/// Finite stand-in reported for the unbounded overflow bucket: one
/// decade above the last real bound (1 s → 10 s). Reporting the raw
/// `u64::MAX` sentinel made a single slow selection look like a
/// ~584 000-year p99 in dashboards and the bench snapshot.
pub const OVERFLOW_CLAMP_US: u64 = 10_000_000;

/// Shared metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    pub items_ingested: AtomicU64,
    pub selections_served: AtomicU64,
    pub selections_failed: AtomicU64,
    pub backpressure_waits: AtomicU64,
    /// Selections served with at least one shard dropped (quorum met).
    pub selections_degraded: AtomicU64,
    /// Stage-1 shard evaluations that failed even after their retry.
    pub shard_failures: AtomicU64,
    /// Stage-1 shard evaluations retried after a panic or error.
    pub shard_retries: AtomicU64,
    /// Selections aborted because `SelectRequest::deadline` passed.
    pub deadline_exceeded: AtomicU64,
    /// Selections aborted preemptively by a fired cancel token (deadline
    /// watchdog, shutdown hard-cancel, or an injected Cancel fault) —
    /// i.e. compute was actually unwound mid-flight, as opposed to a
    /// deadline caught at a rim checkpoint. Every cancelled request also
    /// counts in `selections_failed`, and its latency lands in the
    /// failed histogram.
    pub selections_cancelled: AtomicU64,
    /// Times the supervised ingest drain was restarted after a panic.
    pub drain_restarts: AtomicU64,
    /// Requests shed at admission (queue full, or deadline already spent
    /// on arrival) with a typed `Overloaded` error.
    pub selections_shed: AtomicU64,
    /// Requests that had to wait in the bounded FIFO admission queue
    /// before acquiring a permit.
    pub admission_waits: AtomicU64,
    /// Gauge: selections currently holding an admission permit.
    pub selections_inflight: AtomicU64,
    /// Gauge: shards currently quarantined by their circuit breaker
    /// (Open or Half-Open).
    pub shards_quarantined: AtomicU64,
    /// Circuit breakers tripped Closed → Open (threshold consecutive
    /// request failures reached).
    pub breaker_trips: AtomicU64,
    /// Half-Open probe evaluations dispatched for quarantined shards.
    pub breaker_probes: AtomicU64,
    /// Breakers closed again after a successful Half-Open probe.
    pub breaker_recoveries: AtomicU64,
    select_latency: [AtomicU64; 12],
    /// Latencies of requests that failed or were shed — kept apart from
    /// `select_latency` so success percentiles don't silently exclude
    /// the slow failures (and vice versa).
    failed_latency: [AtomicU64; 12],
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_select_latency(&self, d: Duration) {
        self.select_latency[bucket_index(d)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record the end-to-end latency of a request that errored (failed,
    /// shed, deadline-exceeded). See the module docs on survivorship
    /// bias — these never mix into the success histogram.
    pub fn record_failed_latency(&self, d: Duration) {
        self.failed_latency[bucket_index(d)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let hist: Vec<u64> =
            self.select_latency.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let failed_hist: Vec<u64> =
            self.failed_latency.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        MetricsSnapshot {
            items_ingested: self.items_ingested.load(Ordering::Relaxed),
            selections_served: self.selections_served.load(Ordering::Relaxed),
            selections_failed: self.selections_failed.load(Ordering::Relaxed),
            backpressure_waits: self.backpressure_waits.load(Ordering::Relaxed),
            selections_degraded: self.selections_degraded.load(Ordering::Relaxed),
            shard_failures: self.shard_failures.load(Ordering::Relaxed),
            shard_retries: self.shard_retries.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
            selections_cancelled: self.selections_cancelled.load(Ordering::Relaxed),
            drain_restarts: self.drain_restarts.load(Ordering::Relaxed),
            selections_shed: self.selections_shed.load(Ordering::Relaxed),
            admission_waits: self.admission_waits.load(Ordering::Relaxed),
            selections_inflight: self.selections_inflight.load(Ordering::Relaxed),
            shards_quarantined: self.shards_quarantined.load(Ordering::Relaxed),
            breaker_trips: self.breaker_trips.load(Ordering::Relaxed),
            breaker_probes: self.breaker_probes.load(Ordering::Relaxed),
            breaker_recoveries: self.breaker_recoveries.load(Ordering::Relaxed),
            latency_p50_us: percentile(&hist, 0.50),
            latency_p99_us: percentile(&hist, 0.99),
            failed_latency_p50_us: percentile(&failed_hist, 0.50),
            failed_latency_p99_us: percentile(&failed_hist, 0.99),
        }
    }
}

fn bucket_index(d: Duration) -> usize {
    let us = d.as_micros() as u64;
    BUCKETS_US.iter().position(|&b| us <= b).unwrap_or(BUCKETS_US.len() - 1)
}

fn percentile(hist: &[u64], p: f64) -> u64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = (total as f64 * p).ceil() as u64;
    let mut acc = 0;
    for (i, &c) in hist.iter().enumerate() {
        acc += c;
        if acc >= target {
            // the overflow bucket's `u64::MAX` bound is a sentinel, not a
            // latency — report the finite clamp instead
            return BUCKETS_US[i].min(OVERFLOW_CLAMP_US);
        }
    }
    OVERFLOW_CLAMP_US
}

/// Point-in-time metrics view.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub items_ingested: u64,
    pub selections_served: u64,
    pub selections_failed: u64,
    pub backpressure_waits: u64,
    pub selections_degraded: u64,
    pub shard_failures: u64,
    pub shard_retries: u64,
    pub deadline_exceeded: u64,
    /// Selections unwound mid-compute by a fired cancel token (see
    /// `Metrics::selections_cancelled`).
    pub selections_cancelled: u64,
    pub drain_restarts: u64,
    pub selections_shed: u64,
    pub admission_waits: u64,
    pub selections_inflight: u64,
    pub shards_quarantined: u64,
    pub breaker_trips: u64,
    pub breaker_probes: u64,
    pub breaker_recoveries: u64,
    /// bucketized upper-bound estimates (overflow clamped to
    /// [`OVERFLOW_CLAMP_US`])
    pub latency_p50_us: u64,
    pub latency_p99_us: u64,
    /// percentiles over *unsuccessful* requests only (failed, shed,
    /// deadline-exceeded) — 0 when every request succeeded
    pub failed_latency_p50_us: u64,
    pub failed_latency_p99_us: u64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ingested={} served={} failed={} degraded={} backpressure={} \
             shard_failures={} shard_retries={} deadline_exceeded={} \
             cancelled={} drain_restarts={} shed={} admission_waits={} \
             inflight={} quarantined={} breaker_trips={} breaker_probes={} \
             breaker_recoveries={} p50≤{}µs p99≤{}µs failed_p50≤{}µs \
             failed_p99≤{}µs",
            self.items_ingested,
            self.selections_served,
            self.selections_failed,
            self.selections_degraded,
            self.backpressure_waits,
            self.shard_failures,
            self.shard_retries,
            self.deadline_exceeded,
            self.selections_cancelled,
            self.drain_restarts,
            self.selections_shed,
            self.admission_waits,
            self.selections_inflight,
            self.shards_quarantined,
            self.breaker_trips,
            self.breaker_probes,
            self.breaker_recoveries,
            self.latency_p50_us,
            self.latency_p99_us,
            self.failed_latency_p50_us,
            self.failed_latency_p99_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.items_ingested.fetch_add(5, Ordering::Relaxed);
        m.selections_served.fetch_add(2, Ordering::Relaxed);
        m.shard_retries.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.items_ingested, 5);
        assert_eq!(s.selections_served, 2);
        assert_eq!(s.shard_retries, 1);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for _ in 0..99 {
            m.record_select_latency(Duration::from_micros(80));
        }
        m.record_select_latency(Duration::from_millis(50));
        let s = m.snapshot();
        assert_eq!(s.latency_p50_us, 100); // bucket upper bound
        assert!(s.latency_p99_us >= 80);
    }

    #[test]
    fn overflow_bucket_reports_finite_clamp() {
        // regression (ISSUE 6 satellite): a latency past the last finite
        // bound (1 s) lands in the overflow bucket, whose `u64::MAX`
        // sentinel used to be reported verbatim as the percentile
        let m = Metrics::new();
        m.record_select_latency(Duration::from_secs(5));
        let s = m.snapshot();
        assert_eq!(s.latency_p50_us, OVERFLOW_CLAMP_US);
        assert_eq!(s.latency_p99_us, OVERFLOW_CLAMP_US);
        // mixed: the median stays in a real bucket, p99 is clamped
        for _ in 0..98 {
            m.record_select_latency(Duration::from_micros(40));
        }
        m.record_select_latency(Duration::from_secs(2));
        let s = m.snapshot();
        assert_eq!(s.latency_p50_us, 50);
        assert_eq!(s.latency_p99_us, OVERFLOW_CLAMP_US);
        assert!(s.latency_p99_us < u64::MAX);
    }

    #[test]
    fn empty_histogram_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.latency_p50_us, 0);
        assert_eq!(s.latency_p99_us, 0);
    }

    #[test]
    fn display_mentions_counters() {
        let m = Metrics::new();
        m.items_ingested.fetch_add(3, Ordering::Relaxed);
        m.drain_restarts.fetch_add(1, Ordering::Relaxed);
        m.selections_shed.fetch_add(2, Ordering::Relaxed);
        m.shards_quarantined.fetch_add(1, Ordering::Relaxed);
        let text = m.snapshot().to_string();
        assert!(text.contains("ingested=3"));
        assert!(text.contains("drain_restarts=1"));
        assert!(text.contains("shed=2"));
        assert!(text.contains("quarantined=1"));
    }

    #[test]
    fn cancelled_counter_snapshots_and_displays() {
        // regression (ISSUE 10 satellite): preemptive cancels get their
        // own counter, visible in the snapshot and the Display line, and
        // cancelled latencies land in the *failed* histogram
        let m = Metrics::new();
        m.selections_cancelled.fetch_add(2, Ordering::Relaxed);
        m.record_failed_latency(Duration::from_millis(3));
        let s = m.snapshot();
        assert_eq!(s.selections_cancelled, 2);
        assert!(s.failed_latency_p99_us > 0);
        assert_eq!(s.latency_p99_us, 0, "cancels never pollute success latencies");
        assert!(s.to_string().contains("cancelled=2"));
    }

    #[test]
    fn failed_latency_is_a_separate_histogram() {
        // regression (ISSUE 8 satellite, survivorship bias): failed/shed
        // request latencies must populate their own percentiles without
        // leaking into the success histogram — and slow failures must be
        // visible even when every success was fast
        let m = Metrics::new();
        for _ in 0..10 {
            m.record_select_latency(Duration::from_micros(80));
        }
        m.record_failed_latency(Duration::from_millis(40));
        let s = m.snapshot();
        assert_eq!(s.latency_p50_us, 100, "success p50 unaffected by failures");
        assert_eq!(s.latency_p99_us, 100, "success p99 unaffected by failures");
        assert_eq!(s.failed_latency_p50_us, 100_000);
        assert_eq!(s.failed_latency_p99_us, 100_000);
        // and the failure histogram alone stays empty-safe
        let empty = Metrics::new().snapshot();
        assert_eq!(empty.failed_latency_p50_us, 0);
        assert_eq!(empty.failed_latency_p99_us, 0);
    }
}

//! Sharded feature store: the coordinator's ground set, grown by ingest.
//!
//! Items get globally unique ids in arrival order; shards are closed at
//! `capacity` items so stage-1 selection cost per shard stays bounded
//! (dense kernels are O(shard²)).
//!
//! Rows live in one flat row-major buffer per shard (not `Vec<Vec<f32>>`):
//! `Shard::matrix()` and `ShardStore::gather` copy contiguous slices
//! instead of chasing one heap allocation per row, and `push_batch`
//! appends a whole batch under a single write-lock acquisition.
//!
//! The store is also the coordinator's recovery unit:
//! [`ShardStore::checkpoint`] serializes the full state (shard layout
//! included) to a versioned length-prefixed binary blob, and
//! [`ShardStore::restore`] rebuilds an identical store from it. Because
//! selection is a deterministic function of the stored rows, a restored
//! store serves byte-identical selections to the original (pinned by
//! `tests/fault_injection.rs`).

use std::collections::BTreeMap;
use std::sync::{Mutex, RwLock};

use crate::error::{Result, SubmodError};
use crate::linalg::Matrix;

/// One closed or open shard of features, as a flat row-major buffer.
#[derive(Debug, Clone)]
pub struct Shard {
    /// global id of this shard's first item
    pub base_id: usize,
    len: usize,
    dim: usize,
    data: Vec<f32>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Features of local row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Features as a matrix (one contiguous copy of the flat buffer).
    pub fn matrix(&self) -> Matrix {
        Matrix::from_vec(self.len, self.dim, self.data.clone())
            .expect("shard buffer is len×dim by construction")
    }
}

/// Lock-protected store state: one lock guards dim, shards, and the item
/// count together, so a batch append is a single acquisition and there is
/// no multi-lock ordering to get wrong.
#[derive(Debug)]
struct Inner {
    dim: Option<usize>,
    shards: Vec<Shard>,
    total: usize,
}

impl Inner {
    fn push_one(&mut self, capacity: usize, features: &[f32]) -> Result<usize> {
        match self.dim {
            None => self.dim = Some(features.len()),
            Some(d) if d != features.len() => {
                return Err(SubmodError::Shape(format!(
                    "feature dim {} vs store dim {d}",
                    features.len()
                )))
            }
            _ => {}
        }
        let id = self.total;
        let needs_new_shard = match self.shards.last() {
            None => true,
            Some(s) => s.len >= capacity,
        };
        if needs_new_shard {
            self.shards.push(Shard {
                base_id: id,
                len: 0,
                dim: features.len(),
                data: Vec::new(),
            });
        }
        let shard = self.shards.last_mut().unwrap();
        shard.data.extend_from_slice(features);
        shard.len += 1;
        self.total += 1;
        Ok(id)
    }
}

/// Thread-safe sharded store.
#[derive(Debug)]
pub struct ShardStore {
    capacity: usize,
    inner: RwLock<Inner>,
}

impl ShardStore {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ShardStore {
            capacity,
            inner: RwLock::new(Inner { dim: None, shards: Vec::new(), total: 0 }),
        }
    }

    /// Append one item; returns its global id. Fails on dim mismatch.
    pub fn push(&self, features: Vec<f32>) -> Result<usize> {
        self.inner.write().unwrap().push_one(self.capacity, &features)
    }

    /// Append many items under one write-lock acquisition (the ingest
    /// drain's batch path). Per-item results: a dim-mismatched item is
    /// rejected without poisoning the rest of the batch, matching the
    /// one-at-a-time semantics exactly.
    pub fn push_batch(&self, items: Vec<Vec<f32>>) -> Vec<Result<usize>> {
        let mut inner = self.inner.write().unwrap();
        items.iter().map(|features| inner.push_one(self.capacity, features)).collect()
    }

    /// Total items ingested.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all non-empty shards.
    pub fn snapshot(&self) -> Vec<Shard> {
        self.inner
            .read()
            .unwrap()
            .shards
            .iter()
            .filter(|s| !s.is_empty())
            .cloned()
            .collect()
    }

    /// Serialize the full store — shard layout, ids, features — to a
    /// versioned binary blob (all integers u64 little-endian, feature
    /// rows as raw f32 LE, so the round trip is bit-exact).
    ///
    /// Layout: magic `SMCK`, version u32, capacity, dim flag + dim,
    /// total, shard count, then per shard `base_id, len, dim,
    /// value-count, values`.
    pub fn checkpoint(&self) -> Vec<u8> {
        let inner = self.inner.read().unwrap();
        let mut out = Vec::new();
        out.extend_from_slice(CHECKPOINT_MAGIC);
        out.extend_from_slice(&CHECKPOINT_VERSION.to_le_bytes());
        put_u64(&mut out, self.capacity as u64);
        out.push(inner.dim.is_some() as u8);
        put_u64(&mut out, inner.dim.unwrap_or(0) as u64);
        put_u64(&mut out, inner.total as u64);
        put_u64(&mut out, inner.shards.len() as u64);
        for s in &inner.shards {
            put_u64(&mut out, s.base_id as u64);
            put_u64(&mut out, s.len as u64);
            put_u64(&mut out, s.dim as u64);
            put_u64(&mut out, s.data.len() as u64);
            for v in &s.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Rebuild a store from a [`checkpoint`](Self::checkpoint) blob.
    /// Validates magic, version, and structural invariants (shard
    /// buffer sizes, contiguous id ranges, total) so a truncated or
    /// corrupted blob is rejected instead of serving wrong rows.
    pub fn restore(bytes: &[u8]) -> Result<ShardStore> {
        let mut r = Reader { b: bytes, i: 0 };
        let magic = r.take(4)?;
        if magic != CHECKPOINT_MAGIC {
            return Err(corrupt("bad magic"));
        }
        let version = u32::from_le_bytes(r.take(4)?.try_into().unwrap());
        if version != CHECKPOINT_VERSION {
            return Err(corrupt(&format!(
                "unsupported checkpoint version {version} (supported: {CHECKPOINT_VERSION})"
            )));
        }
        let capacity = r.u64()? as usize;
        if capacity == 0 {
            return Err(corrupt("capacity 0"));
        }
        let has_dim = r.take(1)?[0] != 0;
        let dim_raw = r.u64()? as usize;
        let dim = has_dim.then_some(dim_raw);
        let total = r.u64()? as usize;
        let n_shards = r.u64()? as usize;
        let mut shards = Vec::new();
        let mut expect_base = 0usize;
        for _ in 0..n_shards {
            let base_id = r.u64()? as usize;
            let len = r.u64()? as usize;
            let sdim = r.u64()? as usize;
            let count = r.u64()? as usize;
            if count != len.checked_mul(sdim).ok_or_else(|| corrupt("shard size overflow"))? {
                return Err(corrupt("shard buffer size mismatch"));
            }
            if base_id != expect_base {
                return Err(corrupt("non-contiguous shard id ranges"));
            }
            if Some(sdim) != dim && len > 0 {
                return Err(corrupt("shard dim disagrees with store dim"));
            }
            let byte_len =
                count.checked_mul(4).ok_or_else(|| corrupt("shard size overflow"))?;
            let raw = r.take(byte_len)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            expect_base += len;
            shards.push(Shard { base_id, len, dim: sdim, data });
        }
        if expect_base != total {
            return Err(corrupt("total disagrees with shard lengths"));
        }
        if r.i != bytes.len() {
            return Err(corrupt("trailing bytes"));
        }
        Ok(ShardStore {
            capacity,
            inner: RwLock::new(Inner { dim, shards, total }),
        })
    }

    /// Fetch features for a set of global ids (stage-2 merge).
    pub fn gather(&self, ids: &[usize]) -> Result<Matrix> {
        let inner = self.inner.read().unwrap();
        let d = inner.dim.unwrap_or(0);
        let mut m = Matrix::zeros(ids.len(), d);
        for (row, &id) in ids.iter().enumerate() {
            let shard = inner
                .shards
                .iter()
                .rev()
                .find(|s| s.base_id <= id)
                .ok_or(SubmodError::OutOfGroundSet { id, n: inner.total })?;
            let local = id - shard.base_id;
            if local >= shard.len() {
                return Err(SubmodError::OutOfGroundSet { id, n: inner.total });
            }
            m.row_mut(row).copy_from_slice(shard.row(local));
        }
        Ok(m)
    }
}

/// What the breaker tells the fan-out to do with a shard this request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum BreakerDecision {
    /// Evaluate the shard. `probe: true` marks the single Half-Open
    /// probe whose outcome decides Close vs re-Open.
    Attempt { probe: bool },
    /// Shard is quarantined (Open or mid-probe): skip without
    /// evaluating. Counts toward quorum exactly like a dropped shard.
    Skip,
}

/// State-machine transitions, surfaced so the service layer can map them
/// onto metrics (`breaker_trips` / `breaker_probes` / `breaker_recoveries`
/// and the `shards_quarantined` gauge) without the breaker knowing about
/// `Metrics`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum BreakerTransition {
    /// Closed → Open: `threshold` consecutive request failures.
    Tripped,
    /// Open → Half-Open: this request carries the probe evaluation.
    Probing,
    /// Half-Open → Closed: the probe succeeded, shard back in service.
    Recovered,
    /// Half-Open → Open: the probe failed, quarantine continues.
    Reopened,
}

#[derive(Debug, Clone, Copy)]
enum BreakerState {
    /// In service; counts consecutive request-level failures.
    Closed { consec: usize },
    /// Quarantined; counts requests seen since opening (request-count
    /// based, not wall-clock — breaker behavior stays deterministic).
    Open { seen: usize },
    /// A probe evaluation is in flight for this request.
    HalfOpen,
}

/// Per-shard circuit breakers, keyed by shard `base_id`.
///
/// A shard whose stage-1 evaluation fails (after the retry) on
/// `threshold` *consecutive requests* trips Open and is skipped — it
/// still counts toward the quorum like a dropped shard, but the
/// coordinator stops burning an evaluation + retry on it every request.
/// After `probe_after` subsequent requests the breaker goes Half-Open:
/// the next request evaluates the shard once as a probe, and that single
/// outcome decides Closed (recovered) vs Open again. All bookkeeping is
/// request-count based so breaker behavior is a deterministic function
/// of the request/outcome sequence (no wall-clock, no sleeps in tests).
///
/// `decide` is called per shard at the start of a request, `record` with
/// the shard's final outcome (post-retry); both are cheap and run under
/// one mutex, outside the evaluation itself.
#[derive(Debug)]
pub(crate) struct ShardBreakers {
    /// `None` disables breaking entirely (every decision is Attempt).
    threshold: Option<usize>,
    probe_after: usize,
    states: Mutex<BTreeMap<usize, BreakerState>>,
}

impl ShardBreakers {
    pub fn new(threshold: Option<usize>, probe_after: usize) -> Self {
        ShardBreakers {
            threshold,
            probe_after: probe_after.max(1),
            states: Mutex::new(BTreeMap::new()),
        }
    }

    /// Decide whether this request should evaluate shard `base_id`, and
    /// report any transition the decision itself caused (Open →
    /// Half-Open happens here, on the request that carries the probe).
    pub fn decide(&self, base_id: usize) -> (BreakerDecision, Option<BreakerTransition>) {
        if self.threshold.is_none() {
            return (BreakerDecision::Attempt { probe: false }, None);
        }
        let mut states = self.states.lock().unwrap();
        let st = states.entry(base_id).or_insert(BreakerState::Closed { consec: 0 });
        match *st {
            BreakerState::Closed { .. } => (BreakerDecision::Attempt { probe: false }, None),
            BreakerState::Open { seen } => {
                let seen = seen + 1;
                if seen >= self.probe_after {
                    *st = BreakerState::HalfOpen;
                    (BreakerDecision::Attempt { probe: true }, Some(BreakerTransition::Probing))
                } else {
                    *st = BreakerState::Open { seen };
                    (BreakerDecision::Skip, None)
                }
            }
            BreakerState::HalfOpen => (BreakerDecision::Skip, None),
        }
    }

    /// Record the final (post-retry) outcome of an evaluated shard.
    /// `probe` must be the flag `decide` returned for this request.
    pub fn record(
        &self,
        base_id: usize,
        probe: bool,
        success: bool,
    ) -> Option<BreakerTransition> {
        let threshold = self.threshold?;
        let mut states = self.states.lock().unwrap();
        let st = states.entry(base_id).or_insert(BreakerState::Closed { consec: 0 });
        if probe {
            return if success {
                *st = BreakerState::Closed { consec: 0 };
                Some(BreakerTransition::Recovered)
            } else {
                *st = BreakerState::Open { seen: 0 };
                Some(BreakerTransition::Reopened)
            };
        }
        match (*st, success) {
            (BreakerState::Closed { .. }, true) => {
                *st = BreakerState::Closed { consec: 0 };
                None
            }
            (BreakerState::Closed { consec }, false) => {
                let consec = consec + 1;
                if consec >= threshold {
                    *st = BreakerState::Open { seen: 0 };
                    Some(BreakerTransition::Tripped)
                } else {
                    *st = BreakerState::Closed { consec };
                    None
                }
            }
            // Skipped shards never call record; a non-probe outcome for
            // an Open/HalfOpen shard cannot happen in the service flow,
            // but tolerate it without state damage.
            _ => None,
        }
    }

    /// Un-decide a Half-Open probe whose evaluation was aborted by a
    /// request cancel before producing an outcome: without this the
    /// shard would be stuck Half-Open (permanently skipped). It returns
    /// to Open, primed so the very next request carries a fresh probe.
    /// No transition is reported — the gauge never moved.
    pub fn abort_probe(&self, base_id: usize) {
        if self.threshold.is_none() {
            return;
        }
        let mut states = self.states.lock().unwrap();
        if let Some(st) = states.get_mut(&base_id) {
            if matches!(st, BreakerState::HalfOpen) {
                *st = BreakerState::Open { seen: self.probe_after };
            }
        }
    }

    /// Number of shards currently quarantined (Open or Half-Open).
    #[cfg(test)]
    pub fn quarantined(&self) -> usize {
        self.states
            .lock()
            .unwrap()
            .values()
            .filter(|s| !matches!(s, BreakerState::Closed { .. }))
            .count()
    }
}

const CHECKPOINT_MAGIC: &[u8; 4] = b"SMCK";
const CHECKPOINT_VERSION: u32 = 1;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn corrupt(why: &str) -> SubmodError {
    SubmodError::Coordinator(format!("corrupt checkpoint: {why}"))
}

/// Bounds-checked cursor over a checkpoint blob.
struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.i.checked_add(n).filter(|&e| e <= self.b.len());
        match end {
            Some(e) => {
                let s = &self.b[self.i..e];
                self.i = e;
                Ok(s)
            }
            None => Err(corrupt("truncated")),
        }
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_sequential_and_shards_split() {
        let store = ShardStore::new(3);
        for i in 0..8 {
            assert_eq!(store.push(vec![i as f32, 0.0]).unwrap(), i);
        }
        let shards = store.snapshot();
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].len(), 3);
        assert_eq!(shards[2].len(), 2);
        assert_eq!(shards[1].base_id, 3);
        assert_eq!(store.len(), 8);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let store = ShardStore::new(4);
        store.push(vec![1.0, 2.0]).unwrap();
        assert!(store.push(vec![1.0]).is_err());
    }

    #[test]
    fn gather_returns_right_rows() {
        let store = ShardStore::new(2);
        for i in 0..5 {
            store.push(vec![i as f32, (i * i) as f32]).unwrap();
        }
        let m = store.gather(&[4, 0, 3]).unwrap();
        assert_eq!(m.row(0), &[4.0, 16.0]);
        assert_eq!(m.row(1), &[0.0, 0.0]);
        assert_eq!(m.row(2), &[3.0, 9.0]);
        assert!(store.gather(&[99]).is_err());
    }

    #[test]
    fn shard_matrix() {
        let store = ShardStore::new(10);
        store.push(vec![1.0, 2.0]).unwrap();
        store.push(vec![3.0, 4.0]).unwrap();
        let m = store.snapshot()[0].matrix();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.get(1, 1), 4.0);
    }

    #[test]
    fn push_batch_matches_one_at_a_time_semantics() {
        let store = ShardStore::new(3);
        let results = store.push_batch(vec![
            vec![0.0, 1.0],
            vec![2.0, 3.0],
            vec![9.9], // dim mismatch: rejected, rest of batch unaffected
            vec![4.0, 5.0],
            vec![6.0, 7.0],
        ]);
        assert_eq!(results[0].as_ref().unwrap(), &0);
        assert_eq!(results[1].as_ref().unwrap(), &1);
        assert!(results[2].is_err());
        assert_eq!(results[3].as_ref().unwrap(), &2);
        assert_eq!(results[4].as_ref().unwrap(), &3);
        assert_eq!(store.len(), 4);
        // shard split happens mid-batch exactly as with push()
        let shards = store.snapshot();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].len(), 3);
        assert_eq!(shards[1].base_id, 3);
        let m = store.gather(&[3, 0]).unwrap();
        assert_eq!(m.row(0), &[6.0, 7.0]);
        assert_eq!(m.row(1), &[0.0, 1.0]);
    }

    #[test]
    fn checkpoint_round_trip_is_bit_exact() {
        let store = ShardStore::new(3);
        for i in 0..8 {
            // exercise non-trivial f32 bit patterns, including subnormals
            store.push(vec![i as f32 * 0.1, f32::MIN_POSITIVE * (i + 1) as f32]).unwrap();
        }
        let blob = store.checkpoint();
        let back = ShardStore::restore(&blob).unwrap();
        assert_eq!(back.len(), 8);
        let (a, b) = (store.snapshot(), back.snapshot());
        assert_eq!(a.len(), b.len());
        for (sa, sb) in a.iter().zip(&b) {
            assert_eq!(sa.base_id, sb.base_id);
            assert_eq!(sa.len(), sb.len());
            for i in 0..sa.len() {
                let (ra, rb) = (sa.row(i), sb.row(i));
                assert_eq!(ra.len(), rb.len());
                for (x, y) in ra.iter().zip(rb) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        }
        // restored store keeps ingesting with the checkpointed capacity
        assert_eq!(back.push(vec![9.0, 9.0]).unwrap(), 8);
        assert_eq!(back.snapshot().len(), 3);
        // a second checkpoint of an unchanged store is byte-identical
        assert_eq!(store.checkpoint(), blob);
    }

    #[test]
    fn empty_store_round_trips() {
        let store = ShardStore::new(5);
        let back = ShardStore::restore(&store.checkpoint()).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.push(vec![1.0]).unwrap(), 0);
    }

    #[test]
    fn corrupt_checkpoints_rejected() {
        let store = ShardStore::new(3);
        for i in 0..5 {
            store.push(vec![i as f32]).unwrap();
        }
        let blob = store.checkpoint();
        // truncation at every prefix length must error, never panic
        for cut in 0..blob.len() {
            assert!(ShardStore::restore(&blob[..cut]).is_err(), "cut {cut}");
        }
        // trailing garbage
        let mut long = blob.clone();
        long.push(0);
        assert!(ShardStore::restore(&long).is_err());
        // bad magic
        let mut bad = blob.clone();
        bad[0] ^= 0xff;
        assert!(ShardStore::restore(&bad).is_err());
        // unsupported version
        let mut vers = blob.clone();
        vers[4] = 0xfe;
        assert!(ShardStore::restore(&vers).is_err());
        // corrupted shard length breaks the structural invariants
        let mut len_broken = blob;
        let shard_table = 4 + 4 + 8 + 1 + 8 + 8 + 8; // header up to first shard
        len_broken[shard_table + 8] ^= 1; // first shard's len
        assert!(ShardStore::restore(&len_broken).is_err());
    }

    #[test]
    fn breaker_disabled_always_attempts() {
        let b = ShardBreakers::new(None, 4);
        for _ in 0..10 {
            assert_eq!(b.decide(0), (BreakerDecision::Attempt { probe: false }, None));
            assert_eq!(b.record(0, false, false), None);
        }
        assert_eq!(b.quarantined(), 0);
    }

    #[test]
    fn breaker_full_lifecycle_is_request_count_based() {
        let b = ShardBreakers::new(Some(2), 2);
        // two consecutive failures trip the breaker
        assert_eq!(b.decide(32), (BreakerDecision::Attempt { probe: false }, None));
        assert_eq!(b.record(32, false, false), None);
        assert_eq!(b.decide(32), (BreakerDecision::Attempt { probe: false }, None));
        assert_eq!(b.record(32, false, false), Some(BreakerTransition::Tripped));
        assert_eq!(b.quarantined(), 1);
        // next request: skipped (1 of probe_after=2 seen)
        assert_eq!(b.decide(32), (BreakerDecision::Skip, None));
        // second request since opening: half-open, carries the probe
        assert_eq!(
            b.decide(32),
            (BreakerDecision::Attempt { probe: true }, Some(BreakerTransition::Probing))
        );
        // failed probe re-opens and restarts the request count
        assert_eq!(b.record(32, true, false), Some(BreakerTransition::Reopened));
        assert_eq!(b.decide(32), (BreakerDecision::Skip, None));
        assert_eq!(
            b.decide(32),
            (BreakerDecision::Attempt { probe: true }, Some(BreakerTransition::Probing))
        );
        // successful probe closes the breaker; shard is back in service
        assert_eq!(b.record(32, true, true), Some(BreakerTransition::Recovered));
        assert_eq!(b.quarantined(), 0);
        assert_eq!(b.decide(32), (BreakerDecision::Attempt { probe: false }, None));
    }

    #[test]
    fn breaker_success_resets_consecutive_failures() {
        let b = ShardBreakers::new(Some(3), 4);
        b.record(0, false, false);
        b.record(0, false, false);
        b.record(0, false, true); // success wipes the streak
        b.record(0, false, false);
        assert_eq!(b.record(0, false, false), None); // only 2 consecutive
        assert_eq!(b.record(0, false, false), Some(BreakerTransition::Tripped));
    }

    #[test]
    fn breakers_are_independent_per_shard() {
        let b = ShardBreakers::new(Some(1), 8);
        assert_eq!(b.record(0, false, false), Some(BreakerTransition::Tripped));
        // shard 64 unaffected
        assert_eq!(b.decide(64), (BreakerDecision::Attempt { probe: false }, None));
        assert_eq!(b.decide(0), (BreakerDecision::Skip, None));
        assert_eq!(b.quarantined(), 1);
    }

    #[test]
    fn shard_rows_view_flat_buffer() {
        let store = ShardStore::new(8);
        store.push(vec![1.0, 2.0, 3.0]).unwrap();
        store.push(vec![4.0, 5.0, 6.0]).unwrap();
        let shard = &store.snapshot()[0];
        assert_eq!(shard.dim(), 3);
        assert_eq!(shard.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(shard.row(1), &[4.0, 5.0, 6.0]);
    }
}

//! Sharded feature store: the coordinator's ground set, grown by ingest.
//!
//! Items get globally unique ids in arrival order; shards are closed at
//! `capacity` items so stage-1 selection cost per shard stays bounded
//! (dense kernels are O(shard²)).

use std::sync::RwLock;

use crate::linalg::Matrix;

/// One closed or open shard of features.
#[derive(Debug, Clone)]
pub struct Shard {
    /// global id of this shard's first item
    pub base_id: usize,
    /// row-major features
    pub rows: Vec<Vec<f32>>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Features as a matrix.
    pub fn matrix(&self) -> Matrix {
        let n = self.rows.len();
        let d = self.rows.first().map(|r| r.len()).unwrap_or(0);
        let mut m = Matrix::zeros(n, d);
        for (i, r) in self.rows.iter().enumerate() {
            m.row_mut(i).copy_from_slice(r);
        }
        m
    }
}

/// Thread-safe sharded store.
#[derive(Debug)]
pub struct ShardStore {
    capacity: usize,
    dim: RwLock<Option<usize>>,
    shards: RwLock<Vec<Shard>>,
    total: RwLock<usize>,
}

impl ShardStore {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ShardStore {
            capacity,
            dim: RwLock::new(None),
            shards: RwLock::new(vec![Shard { base_id: 0, rows: Vec::new() }]),
            total: RwLock::new(0),
        }
    }

    /// Append one item; returns its global id. Fails on dim mismatch.
    pub fn push(&self, features: Vec<f32>) -> crate::error::Result<usize> {
        let mut dim = self.dim.write().unwrap();
        match *dim {
            None => *dim = Some(features.len()),
            Some(d) if d != features.len() => {
                return Err(crate::error::SubmodError::Shape(format!(
                    "feature dim {} vs store dim {d}",
                    features.len()
                )))
            }
            _ => {}
        }
        drop(dim);
        let mut shards = self.shards.write().unwrap();
        let mut total = self.total.write().unwrap();
        let id = *total;
        if shards.last().unwrap().len() >= self.capacity {
            shards.push(Shard { base_id: id, rows: Vec::new() });
        }
        shards.last_mut().unwrap().rows.push(features);
        *total += 1;
        Ok(id)
    }

    /// Total items ingested.
    pub fn len(&self) -> usize {
        *self.total.read().unwrap()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all non-empty shards.
    pub fn snapshot(&self) -> Vec<Shard> {
        self.shards.read().unwrap().iter().filter(|s| !s.is_empty()).cloned().collect()
    }

    /// Fetch features for a set of global ids (stage-2 merge).
    pub fn gather(&self, ids: &[usize]) -> crate::error::Result<Matrix> {
        let shards = self.shards.read().unwrap();
        let d = self.dim.read().unwrap().unwrap_or(0);
        let mut m = Matrix::zeros(ids.len(), d);
        for (row, &id) in ids.iter().enumerate() {
            let shard = shards
                .iter()
                .rev()
                .find(|s| s.base_id <= id)
                .ok_or(crate::error::SubmodError::OutOfGroundSet { id, n: self.len() })?;
            let local = id - shard.base_id;
            if local >= shard.len() {
                return Err(crate::error::SubmodError::OutOfGroundSet { id, n: self.len() });
            }
            m.row_mut(row).copy_from_slice(&shard.rows[local]);
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_sequential_and_shards_split() {
        let store = ShardStore::new(3);
        for i in 0..8 {
            assert_eq!(store.push(vec![i as f32, 0.0]).unwrap(), i);
        }
        let shards = store.snapshot();
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].len(), 3);
        assert_eq!(shards[2].len(), 2);
        assert_eq!(shards[1].base_id, 3);
        assert_eq!(store.len(), 8);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let store = ShardStore::new(4);
        store.push(vec![1.0, 2.0]).unwrap();
        assert!(store.push(vec![1.0]).is_err());
    }

    #[test]
    fn gather_returns_right_rows() {
        let store = ShardStore::new(2);
        for i in 0..5 {
            store.push(vec![i as f32, (i * i) as f32]).unwrap();
        }
        let m = store.gather(&[4, 0, 3]).unwrap();
        assert_eq!(m.row(0), &[4.0, 16.0]);
        assert_eq!(m.row(1), &[0.0, 0.0]);
        assert_eq!(m.row(2), &[3.0, 9.0]);
        assert!(store.gather(&[99]).is_err());
    }

    #[test]
    fn shard_matrix() {
        let store = ShardStore::new(10);
        store.push(vec![1.0, 2.0]).unwrap();
        store.push(vec![3.0, 4.0]).unwrap();
        let m = store.snapshot()[0].matrix();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.get(1, 1), 4.0);
    }
}

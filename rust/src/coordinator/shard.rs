//! Sharded feature store: the coordinator's ground set, grown by ingest.
//!
//! Items get globally unique ids in arrival order; shards are closed at
//! `capacity` items so stage-1 selection cost per shard stays bounded
//! (dense kernels are O(shard²)).
//!
//! Rows live in one flat row-major buffer per shard (not `Vec<Vec<f32>>`):
//! `Shard::matrix()` and `ShardStore::gather` copy contiguous slices
//! instead of chasing one heap allocation per row, and `push_batch`
//! appends a whole batch under a single write-lock acquisition.

use std::sync::RwLock;

use crate::error::{Result, SubmodError};
use crate::linalg::Matrix;

/// One closed or open shard of features, as a flat row-major buffer.
#[derive(Debug, Clone)]
pub struct Shard {
    /// global id of this shard's first item
    pub base_id: usize,
    len: usize,
    dim: usize,
    data: Vec<f32>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Feature dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Features of local row `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Features as a matrix (one contiguous copy of the flat buffer).
    pub fn matrix(&self) -> Matrix {
        Matrix::from_vec(self.len, self.dim, self.data.clone())
            .expect("shard buffer is len×dim by construction")
    }
}

/// Lock-protected store state: one lock guards dim, shards, and the item
/// count together, so a batch append is a single acquisition and there is
/// no multi-lock ordering to get wrong.
#[derive(Debug)]
struct Inner {
    dim: Option<usize>,
    shards: Vec<Shard>,
    total: usize,
}

impl Inner {
    fn push_one(&mut self, capacity: usize, features: &[f32]) -> Result<usize> {
        match self.dim {
            None => self.dim = Some(features.len()),
            Some(d) if d != features.len() => {
                return Err(SubmodError::Shape(format!(
                    "feature dim {} vs store dim {d}",
                    features.len()
                )))
            }
            _ => {}
        }
        let id = self.total;
        let needs_new_shard = match self.shards.last() {
            None => true,
            Some(s) => s.len >= capacity,
        };
        if needs_new_shard {
            self.shards.push(Shard {
                base_id: id,
                len: 0,
                dim: features.len(),
                data: Vec::new(),
            });
        }
        let shard = self.shards.last_mut().unwrap();
        shard.data.extend_from_slice(features);
        shard.len += 1;
        self.total += 1;
        Ok(id)
    }
}

/// Thread-safe sharded store.
#[derive(Debug)]
pub struct ShardStore {
    capacity: usize,
    inner: RwLock<Inner>,
}

impl ShardStore {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ShardStore {
            capacity,
            inner: RwLock::new(Inner { dim: None, shards: Vec::new(), total: 0 }),
        }
    }

    /// Append one item; returns its global id. Fails on dim mismatch.
    pub fn push(&self, features: Vec<f32>) -> Result<usize> {
        self.inner.write().unwrap().push_one(self.capacity, &features)
    }

    /// Append many items under one write-lock acquisition (the ingest
    /// drain's batch path). Per-item results: a dim-mismatched item is
    /// rejected without poisoning the rest of the batch, matching the
    /// one-at-a-time semantics exactly.
    pub fn push_batch(&self, items: Vec<Vec<f32>>) -> Vec<Result<usize>> {
        let mut inner = self.inner.write().unwrap();
        items.iter().map(|features| inner.push_one(self.capacity, features)).collect()
    }

    /// Total items ingested.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all non-empty shards.
    pub fn snapshot(&self) -> Vec<Shard> {
        self.inner
            .read()
            .unwrap()
            .shards
            .iter()
            .filter(|s| !s.is_empty())
            .cloned()
            .collect()
    }

    /// Fetch features for a set of global ids (stage-2 merge).
    pub fn gather(&self, ids: &[usize]) -> Result<Matrix> {
        let inner = self.inner.read().unwrap();
        let d = inner.dim.unwrap_or(0);
        let mut m = Matrix::zeros(ids.len(), d);
        for (row, &id) in ids.iter().enumerate() {
            let shard = inner
                .shards
                .iter()
                .rev()
                .find(|s| s.base_id <= id)
                .ok_or(SubmodError::OutOfGroundSet { id, n: inner.total })?;
            let local = id - shard.base_id;
            if local >= shard.len() {
                return Err(SubmodError::OutOfGroundSet { id, n: inner.total });
            }
            m.row_mut(row).copy_from_slice(shard.row(local));
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_sequential_and_shards_split() {
        let store = ShardStore::new(3);
        for i in 0..8 {
            assert_eq!(store.push(vec![i as f32, 0.0]).unwrap(), i);
        }
        let shards = store.snapshot();
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].len(), 3);
        assert_eq!(shards[2].len(), 2);
        assert_eq!(shards[1].base_id, 3);
        assert_eq!(store.len(), 8);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let store = ShardStore::new(4);
        store.push(vec![1.0, 2.0]).unwrap();
        assert!(store.push(vec![1.0]).is_err());
    }

    #[test]
    fn gather_returns_right_rows() {
        let store = ShardStore::new(2);
        for i in 0..5 {
            store.push(vec![i as f32, (i * i) as f32]).unwrap();
        }
        let m = store.gather(&[4, 0, 3]).unwrap();
        assert_eq!(m.row(0), &[4.0, 16.0]);
        assert_eq!(m.row(1), &[0.0, 0.0]);
        assert_eq!(m.row(2), &[3.0, 9.0]);
        assert!(store.gather(&[99]).is_err());
    }

    #[test]
    fn shard_matrix() {
        let store = ShardStore::new(10);
        store.push(vec![1.0, 2.0]).unwrap();
        store.push(vec![3.0, 4.0]).unwrap();
        let m = store.snapshot()[0].matrix();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.get(1, 1), 4.0);
    }

    #[test]
    fn push_batch_matches_one_at_a_time_semantics() {
        let store = ShardStore::new(3);
        let results = store.push_batch(vec![
            vec![0.0, 1.0],
            vec![2.0, 3.0],
            vec![9.9], // dim mismatch: rejected, rest of batch unaffected
            vec![4.0, 5.0],
            vec![6.0, 7.0],
        ]);
        assert_eq!(results[0].as_ref().unwrap(), &0);
        assert_eq!(results[1].as_ref().unwrap(), &1);
        assert!(results[2].is_err());
        assert_eq!(results[3].as_ref().unwrap(), &2);
        assert_eq!(results[4].as_ref().unwrap(), &3);
        assert_eq!(store.len(), 4);
        // shard split happens mid-batch exactly as with push()
        let shards = store.snapshot();
        assert_eq!(shards.len(), 2);
        assert_eq!(shards[0].len(), 3);
        assert_eq!(shards[1].base_id, 3);
        let m = store.gather(&[3, 0]).unwrap();
        assert_eq!(m.row(0), &[6.0, 7.0]);
        assert_eq!(m.row(1), &[0.0, 1.0]);
    }

    #[test]
    fn shard_rows_view_flat_buffer() {
        let store = ShardStore::new(8);
        store.push(vec![1.0, 2.0, 3.0]).unwrap();
        store.push(vec![4.0, 5.0, 6.0]).unwrap();
        let shard = &store.snapshot()[0];
        assert_eq!(shard.dim(), 3);
        assert_eq!(shard.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(shard.row(1), &[4.0, 5.0, 6.0]);
    }
}

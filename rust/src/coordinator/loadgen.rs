//! Sustained-load harness (ISSUE 8): a seeded, deterministic,
//! multi-tenant closed-loop driver for the coordinator.
//!
//! PR 6 pinned each fault-recovery path with a unit-style failpoint
//! test; this module measures the whole shed → degrade → cancel →
//! error → shutdown stack under *sustained* chaos traffic. `run` builds a
//! coordinator, streams a synthetic ground set in, then drives
//! `tenants × requests_per_tenant` selections from closed-loop tenant
//! threads (each tenant issues its next request only after the previous
//! one resolves — the load level is the concurrency, not a wall-clock
//! rate, so runs are schedule-robust). Chaos rides the existing
//! [`super::faults`] registry: seeded `Trigger::Prob` specs on the
//! stage-1, kernel-build, stage-2, and drain-loop sites give a
//! configurable panic/error/delay mix that replays identically for a
//! given seed.
//!
//! Outcomes are tallied per closed-loop accounting — every issued
//! request resolves as served, shed, deadline-exceeded, cancelled, or
//! failed (deadlines enforced *preemptively* by the watchdog since
//! ISSUE 10; `deadline_ms` is how the chaos smoke arms tight per-request
//! budgets against the whole compute stack) — and
//! the final [`LoadgenReport`] merges the tally with the coordinator's
//! own metrics snapshot (shed/degraded/breaker/drain counters, success
//! *and* failed latency percentiles) plus the shutdown checkpoint size.
//! `benches/loadgen.rs` serializes it as `BENCH_loadgen.json` (schema
//! `bench_loadgen/v1`); the `submodlib loadgen` CLI subcommand prints it.
//!
//! Chaos probabilities require the `faults` cargo feature: without it a
//! nonzero probability is a typed `InvalidParam` (never a silent no-op
//! pretending chaos ran).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::config::CoordinatorConfig;
use crate::coordinator::service::{Coordinator, SelectRequest};
use crate::coordinator::MetricsSnapshot;
use crate::data::synthetic;
use crate::error::{Result, SubmodError};
use crate::rng::Pcg64;
use crate::util::json::Json;

/// Everything a loadgen run is parameterized by. Defaults give a small
/// but non-trivial run (4 tenants over 2 permits, breakers armed).
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Ground-set size streamed in before the tenants start.
    pub items: usize,
    pub dim: usize,
    pub shard_capacity: usize,
    /// Closed-loop tenant threads issuing selections concurrently.
    pub tenants: usize,
    pub requests_per_tenant: usize,
    pub budget: usize,
    pub max_inflight: usize,
    pub admission_queue_depth: usize,
    pub breaker_threshold: Option<usize>,
    pub breaker_probe_after: usize,
    /// Per-request deadline (`None` = no deadline).
    pub deadline_ms: Option<u64>,
    pub min_shard_quorum: Option<usize>,
    /// Seeds tenant request streams and every chaos trigger.
    pub seed: u64,
    /// Shed retries per request: a tenant retries an `Overloaded`
    /// response up to this many times (yielding between attempts)
    /// before tallying it as shed.
    pub shed_retries: usize,
    /// Chaos mix (all require the `faults` feature when nonzero).
    pub stage1_panic_prob: f64,
    pub stage1_error_prob: f64,
    pub stage2_delay_prob: f64,
    pub stage2_delay_ms: u64,
    pub drain_panic_prob: f64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            items: 600,
            dim: 8,
            shard_capacity: 64,
            tenants: 4,
            requests_per_tenant: 16,
            budget: 8,
            max_inflight: 2,
            admission_queue_depth: 2,
            breaker_threshold: Some(3),
            breaker_probe_after: 4,
            deadline_ms: None,
            min_shard_quorum: Some(1),
            seed: 42,
            shed_retries: 2,
            stage1_panic_prob: 0.0,
            stage1_error_prob: 0.0,
            stage2_delay_prob: 0.0,
            stage2_delay_ms: 5,
            drain_panic_prob: 0.0,
        }
    }
}

impl LoadgenConfig {
    fn has_chaos(&self) -> bool {
        self.stage1_panic_prob > 0.0
            || self.stage1_error_prob > 0.0
            || self.stage2_delay_prob > 0.0
            || self.drain_panic_prob > 0.0
    }

    fn validate(&self) -> Result<()> {
        let positive = [
            ("items", self.items),
            ("tenants", self.tenants),
            ("requests_per_tenant", self.requests_per_tenant),
            ("budget", self.budget),
        ];
        for (name, v) in positive {
            if v == 0 {
                return Err(SubmodError::InvalidParam(format!("loadgen {name} must be > 0")));
            }
        }
        for (name, p) in [
            ("stage1_panic_prob", self.stage1_panic_prob),
            ("stage1_error_prob", self.stage1_error_prob),
            ("stage2_delay_prob", self.stage2_delay_prob),
            ("drain_panic_prob", self.drain_panic_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(SubmodError::InvalidParam(format!(
                    "loadgen {name} must be in [0, 1], got {p}"
                )));
            }
        }
        if self.has_chaos() && !cfg!(feature = "faults") {
            return Err(SubmodError::InvalidParam(
                "loadgen chaos probabilities require the `faults` cargo feature \
                 (rebuild with --features faults)"
                    .into(),
            ));
        }
        Ok(())
    }
}

/// What a run measured. `to_json` is the `bench_loadgen/v1` document.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub wall_s: f64,
    /// Resolved requests (any outcome) per wall-clock second.
    pub throughput_rps: f64,
    pub requests_total: u64,
    pub served: u64,
    pub degraded: u64,
    pub shed: u64,
    pub deadline_exceeded: u64,
    /// Requests that resolved as `SubmodError::Cancelled` — a cancel
    /// token fired for a reason other than a deadline (deadline fires
    /// surface as `deadline_exceeded`). Distinct from `failed_other`
    /// so preemptive cancels are never lumped in with real failures.
    pub cancelled: u64,
    pub failed_other: u64,
    /// Tenant-level retries of `Overloaded` responses.
    pub shed_retries: u64,
    /// Ingest submissions retried after a drain crash failed them.
    pub ingest_retries: u64,
    pub checkpoint_bytes: usize,
    /// Final coordinator metrics (latency percentiles, breaker
    /// transitions, drain restarts, ...).
    pub metrics: MetricsSnapshot,
}

impl LoadgenReport {
    /// Serialize as the `bench_loadgen/v1` schema.
    pub fn to_json(&self, cfg: &LoadgenConfig) -> Json {
        let m = &self.metrics;
        obj(vec![
            ("schema", Json::Str("bench_loadgen/v1".into())),
            ("threads", Json::Num(crate::runtime::pool::num_threads() as f64)),
            (
                "workload",
                obj(vec![
                    ("items", num(cfg.items as u64)),
                    ("dim", num(cfg.dim as u64)),
                    ("shard_capacity", num(cfg.shard_capacity as u64)),
                    ("tenants", num(cfg.tenants as u64)),
                    ("requests_per_tenant", num(cfg.requests_per_tenant as u64)),
                    ("budget", num(cfg.budget as u64)),
                    ("max_inflight", num(cfg.max_inflight as u64)),
                    ("admission_queue_depth", num(cfg.admission_queue_depth as u64)),
                    ("breaker_threshold", num(cfg.breaker_threshold.unwrap_or(0) as u64)),
                    ("breaker_probe_after", num(cfg.breaker_probe_after as u64)),
                    ("deadline_ms", num(cfg.deadline_ms.unwrap_or(0))),
                    ("seed", num(cfg.seed)),
                    ("stage1_panic_prob", Json::Num(cfg.stage1_panic_prob)),
                    ("stage1_error_prob", Json::Num(cfg.stage1_error_prob)),
                    ("stage2_delay_prob", Json::Num(cfg.stage2_delay_prob)),
                    ("stage2_delay_ms", num(cfg.stage2_delay_ms)),
                    ("drain_panic_prob", Json::Num(cfg.drain_panic_prob)),
                ]),
            ),
            (
                "throughput",
                obj(vec![
                    ("wall_s", Json::Num(self.wall_s)),
                    ("requests_per_s", Json::Num(self.throughput_rps)),
                ]),
            ),
            (
                "select_latency",
                obj(vec![
                    ("p50_us", num(m.latency_p50_us)),
                    ("p99_us", num(m.latency_p99_us)),
                    ("failed_p50_us", num(m.failed_latency_p50_us)),
                    ("failed_p99_us", num(m.failed_latency_p99_us)),
                ]),
            ),
            (
                "outcomes",
                obj(vec![
                    ("requests_total", num(self.requests_total)),
                    ("served", num(self.served)),
                    ("degraded", num(self.degraded)),
                    ("shed", num(self.shed)),
                    ("deadline_exceeded", num(self.deadline_exceeded)),
                    ("cancelled", num(self.cancelled)),
                    ("failed_other", num(self.failed_other)),
                    ("shed_retries", num(self.shed_retries)),
                    ("ingest_retries", num(self.ingest_retries)),
                ]),
            ),
            (
                "coordinator",
                obj(vec![
                    ("selections_served", num(m.selections_served)),
                    ("selections_failed", num(m.selections_failed)),
                    ("selections_degraded", num(m.selections_degraded)),
                    ("selections_shed", num(m.selections_shed)),
                    ("admission_waits", num(m.admission_waits)),
                    ("deadline_exceeded", num(m.deadline_exceeded)),
                    ("selections_cancelled", num(m.selections_cancelled)),
                    ("shard_retries", num(m.shard_retries)),
                    ("shard_failures", num(m.shard_failures)),
                    ("breaker_trips", num(m.breaker_trips)),
                    ("breaker_probes", num(m.breaker_probes)),
                    ("breaker_recoveries", num(m.breaker_recoveries)),
                    ("shards_quarantined", num(m.shards_quarantined)),
                    ("drain_restarts", num(m.drain_restarts)),
                    ("backpressure_waits", num(m.backpressure_waits)),
                    ("checkpoint_bytes", num(self.checkpoint_bytes as u64)),
                ]),
            ),
        ])
    }
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Per-run tallies, bumped by the tenant threads.
#[derive(Default)]
struct Tally {
    served: AtomicU64,
    degraded: AtomicU64,
    shed: AtomicU64,
    deadline: AtomicU64,
    cancelled: AtomicU64,
    failed: AtomicU64,
    shed_retries: AtomicU64,
}

/// Run the harness: build → ingest (chaos may crash the drain; failed
/// submissions are retried) → closed-loop tenant phase → clear chaos →
/// graceful shutdown → report.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    cfg.validate()?;
    let coordinator = Coordinator::new(CoordinatorConfig {
        workers: crate::runtime::pool::num_threads(),
        shard_capacity: cfg.shard_capacity,
        ingest_depth: 64,
        per_shard_factor: 2.0,
        min_shard_quorum: cfg.min_shard_quorum,
        max_inflight: cfg.max_inflight,
        admission_queue_depth: cfg.admission_queue_depth,
        breaker_threshold: cfg.breaker_threshold,
        breaker_probe_after: cfg.breaker_probe_after,
    });

    arm_chaos(cfg);
    // always disarm, even if ingest or a tenant errors out below
    struct ChaosGuard;
    impl Drop for ChaosGuard {
        fn drop(&mut self) {
            clear_chaos();
        }
    }
    let _guard = ChaosGuard;

    // ingest phase: an armed drain_loop panic fails whole batches with
    // typed errors (rows dropped before the store append), so a bounded
    // per-item retry loop makes seeding converge and counts the cost
    let data = synthetic::blobs(cfg.items, cfg.dim, 8, 2.0, cfg.seed);
    let handle = coordinator.ingest_handle();
    let mut ingest_retries = 0u64;
    for i in 0..cfg.items {
        let row = data.row(i).to_vec();
        let mut attempts = 0usize;
        loop {
            match handle.ingest(row.clone()) {
                Ok(_) => break,
                Err(_) if attempts < 50 => {
                    attempts += 1;
                    ingest_retries += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    let tally = Tally::default();
    let t_start = Instant::now();
    // lint: allow(thread-spawn) — loadgen tenants model independent external
    // clients of the service; they must contend on admission concurrently,
    // which pool jobs (one claimed work item per worker) cannot express
    std::thread::scope(|scope| {
        for tenant in 0..cfg.tenants {
            let coordinator = &coordinator;
            let tally = &tally;
            scope.spawn(move || {
                let mut rng = Pcg64::new_stream(cfg.seed, tenant as u64);
                for _ in 0..cfg.requests_per_tenant {
                    // per-tenant budget jitter keeps request costs mixed
                    let budget = 1 + rng.next_below(cfg.budget);
                    let req = SelectRequest {
                        budget,
                        deadline: cfg.deadline_ms.map(Duration::from_millis),
                        ..Default::default()
                    };
                    let mut outcome = coordinator.select(req.clone());
                    let mut retries = 0usize;
                    while matches!(outcome, Err(SubmodError::Overloaded))
                        && retries < cfg.shed_retries
                    {
                        retries += 1;
                        tally.shed_retries.fetch_add(1, Ordering::Relaxed);
                        std::thread::yield_now();
                        outcome = coordinator.select(req.clone());
                    }
                    match outcome {
                        Ok(resp) => {
                            tally.served.fetch_add(1, Ordering::Relaxed);
                            if resp.degraded {
                                tally.degraded.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        Err(SubmodError::Overloaded) => {
                            tally.shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(SubmodError::DeadlineExceeded) => {
                            tally.deadline.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(SubmodError::Cancelled) => {
                            tally.cancelled.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            tally.failed.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
    });
    let wall_s = t_start.elapsed().as_secs_f64();

    // disarm before shutdown so the drain's final batch can't be killed
    drop(_guard);
    let checkpoint = coordinator.shutdown()?;

    let requests_total = (cfg.tenants * cfg.requests_per_tenant) as u64;
    let served = tally.served.load(Ordering::Relaxed);
    let shed = tally.shed.load(Ordering::Relaxed);
    let deadline_exceeded = tally.deadline.load(Ordering::Relaxed);
    let cancelled = tally.cancelled.load(Ordering::Relaxed);
    let failed_other = tally.failed.load(Ordering::Relaxed);
    debug_assert_eq!(
        served + shed + deadline_exceeded + cancelled + failed_other,
        requests_total
    );
    Ok(LoadgenReport {
        wall_s,
        throughput_rps: if wall_s > 0.0 { requests_total as f64 / wall_s } else { 0.0 },
        requests_total,
        served,
        degraded: tally.degraded.load(Ordering::Relaxed),
        shed,
        deadline_exceeded,
        cancelled,
        failed_other,
        shed_retries: tally.shed_retries.load(Ordering::Relaxed),
        ingest_retries,
        checkpoint_bytes: checkpoint.len(),
        metrics: coordinator.metrics(),
    })
}

#[cfg(feature = "faults")]
fn arm_chaos(cfg: &LoadgenConfig) {
    use crate::coordinator::faults::{self, FaultAction, FaultSpec, Trigger};
    let mut arm = |site: &str, action: FaultAction, p: f64, stream: u64| {
        if p > 0.0 {
            faults::inject(
                site,
                FaultSpec {
                    action,
                    key: None,
                    trigger: Trigger::Prob { p, seed: cfg.seed ^ stream },
                },
            );
        }
    };
    arm(faults::STAGE1_EVAL, FaultAction::Panic, cfg.stage1_panic_prob, 0x51);
    arm(faults::KERNEL_BUILD, FaultAction::Error, cfg.stage1_error_prob, 0x52);
    arm(
        faults::STAGE2_MERGE,
        FaultAction::Delay(Duration::from_millis(cfg.stage2_delay_ms)),
        cfg.stage2_delay_prob,
        0x53,
    );
    arm(faults::DRAIN_LOOP, FaultAction::Panic, cfg.drain_panic_prob, 0x54);
}

#[cfg(not(feature = "faults"))]
fn arm_chaos(_cfg: &LoadgenConfig) {}

#[cfg(feature = "faults")]
fn clear_chaos() {
    crate::coordinator::faults::clear();
}

#[cfg(not(feature = "faults"))]
fn clear_chaos() {}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: chaos-armed loadgen runs live in `benches/loadgen.rs` and
    // `tests/fault_injection.rs` (own processes / serialized): the
    // failpoint registry is process-global and these lib tests run in
    // parallel with the coordinator's own unit tests.

    fn small() -> LoadgenConfig {
        LoadgenConfig {
            items: 120,
            dim: 4,
            shard_capacity: 32,
            tenants: 3,
            requests_per_tenant: 4,
            budget: 5,
            ..Default::default()
        }
    }

    #[test]
    fn clean_run_accounts_for_every_request() {
        let cfg = small();
        let report = run(&cfg).unwrap();
        assert_eq!(report.requests_total, 12);
        assert_eq!(
            report.served
                + report.shed
                + report.deadline_exceeded
                + report.cancelled
                + report.failed_other,
            12
        );
        // no chaos, no deadlines, generous queue: everything is
        // eventually served, nothing is cancelled
        assert_eq!(report.served + report.shed, 12);
        assert_eq!(report.cancelled, 0);
        assert_eq!(report.metrics.selections_cancelled, 0);
        assert_eq!(report.metrics.items_ingested, 120);
        assert!(report.throughput_rps > 0.0);
        assert_eq!(report.metrics.drain_restarts, 0);
    }

    #[test]
    fn report_serializes_the_v1_schema() {
        let cfg = small();
        let report = run(&cfg).unwrap();
        let json = report.to_json(&cfg);
        let text = json.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("schema").and_then(Json::as_str), Some("bench_loadgen/v1"));
        let outcomes = back.get("outcomes").expect("outcomes object");
        assert_eq!(outcomes.get("requests_total").and_then(Json::as_usize), Some(12));
        assert!(back.get("select_latency").is_some());
        assert!(back.get("coordinator").is_some());
        assert!(back.get("throughput").is_some());
    }

    #[test]
    fn invalid_configs_are_typed_errors() {
        for broken in [
            LoadgenConfig { tenants: 0, ..small() },
            LoadgenConfig { items: 0, ..small() },
            LoadgenConfig { stage1_panic_prob: 1.5, ..small() },
            LoadgenConfig { drain_panic_prob: -0.1, ..small() },
        ] {
            assert!(matches!(run(&broken), Err(SubmodError::InvalidParam(_))), "{broken:?}");
        }
    }

    #[cfg(not(feature = "faults"))]
    #[test]
    fn chaos_without_faults_feature_is_rejected() {
        let cfg = LoadgenConfig { stage1_panic_prob: 0.1, ..small() };
        let err = run(&cfg).unwrap_err();
        assert!(matches!(err, SubmodError::InvalidParam(_)), "{err}");
    }
}

//! Admission control (ISSUE 8): a FIFO permit gate bounding how many
//! selections the coordinator evaluates concurrently.
//!
//! Without a gate, N tenants calling `select()` simultaneously all pile
//! onto the worker pool's submission lock with unbounded queueing — the
//! classic overload failure of a served system. The gate gives the
//! coordinator an explicit capacity contract:
//!
//! * at most `max_inflight` selections hold a permit at once;
//! * at most `admission_queue_depth` further requests wait, FIFO-fair
//!   (tickets in a `VecDeque`; the head waiter takes the next permit);
//! * everything beyond that is **shed** immediately with a typed
//!   [`SubmodError::Overloaded`] — overload produces fast typed errors,
//!   never an unbounded queue;
//! * a request whose deadline is already spent on arrival is shed
//!   without queueing (it could only expire in line); a request whose
//!   deadline expires *while queued* leaves the queue with the honest
//!   [`SubmodError::DeadlineExceeded`];
//! * after [`AdmissionGate::close`] every acquire — queued or new —
//!   fails with [`SubmodError::ShuttingDown`], and
//!   [`AdmissionGate::drain`] blocks until the last permit is returned
//!   (the graceful-shutdown path).
//!
//! The gate is deliberately passive: a `Mutex` + `Condvar` on the
//! callers' own threads, no helper threads (the pool-thread watcher test
//! pins that `select()` spawns nothing). It schedules *when* a selection
//! runs, never *what* it computes — admitted selections stay
//! byte-identical to an uncontended run (pinned by
//! `tests/coordinator_e2e.rs` and the saturation fault test). Wall-clock
//! reads here are legal: the coordinator rim is outside the linter's
//! no-wall-clock selection paths.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Metrics;
use crate::error::{Result, SubmodError};

/// The permit gate. One per [`super::Coordinator`].
pub(crate) struct AdmissionGate {
    max_inflight: usize,
    queue_depth: usize,
    metrics: Arc<Metrics>,
    state: Mutex<GateState>,
    cv: Condvar,
}

struct GateState {
    in_flight: usize,
    closed: bool,
    next_ticket: u64,
    /// Waiting tickets in arrival order; the front ticket is next.
    queue: VecDeque<u64>,
}

/// RAII permit: dropping it releases the slot and wakes the queue head.
pub(crate) struct Permit<'a> {
    gate: &'a AdmissionGate,
}

impl AdmissionGate {
    pub fn new(max_inflight: usize, queue_depth: usize, metrics: Arc<Metrics>) -> Self {
        AdmissionGate {
            max_inflight: max_inflight.max(1),
            queue_depth,
            metrics,
            state: Mutex::new(GateState {
                in_flight: 0,
                closed: false,
                next_ticket: 0,
                queue: VecDeque::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Acquire a permit for a request that entered `select()` at `t0`
    /// with an optional deadline. See the module docs for the shed /
    /// wait / deadline / shutdown contract.
    pub fn acquire(&self, t0: Instant, deadline: Option<Duration>) -> Result<Permit<'_>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(SubmodError::ShuttingDown);
        }
        // a deadline spent before admission can only expire in line: shed
        if let Some(d) = deadline {
            if t0.elapsed() >= d {
                return self.shed();
            }
        }
        // fast path — but only when nobody is queued, so a newcomer can
        // never overtake the FIFO queue
        if st.in_flight < self.max_inflight && st.queue.is_empty() {
            return Ok(self.admit(&mut st));
        }
        if st.queue.len() >= self.queue_depth {
            return self.shed();
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.queue.push_back(ticket);
        self.metrics.admission_waits.fetch_add(1, Ordering::Relaxed);
        loop {
            if st.closed {
                Self::leave_queue(&mut st, ticket);
                self.cv.notify_all();
                return Err(SubmodError::ShuttingDown);
            }
            if st.queue.front() == Some(&ticket) && st.in_flight < self.max_inflight {
                st.queue.pop_front();
                let permit = self.admit(&mut st);
                // a freed permit may admit more than one head in a row
                self.cv.notify_all();
                return Ok(permit);
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(d) => {
                    let elapsed = t0.elapsed();
                    if elapsed >= d {
                        Self::leave_queue(&mut st, ticket);
                        self.cv.notify_all();
                        return Err(SubmodError::DeadlineExceeded);
                    }
                    st = self.cv.wait_timeout(st, d - elapsed).unwrap().0;
                }
            }
        }
    }

    /// Stop admitting: new and queued requests fail with `ShuttingDown`.
    /// Idempotent.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Block until every admitted selection has returned its permit and
    /// the queue has emptied out (call after [`close`](Self::close)).
    pub fn drain(&self) {
        let mut st = self.state.lock().unwrap();
        while st.in_flight > 0 || !st.queue.is_empty() {
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Like [`drain`](Self::drain) but bounded: wait at most `grace` for
    /// the gate to empty. Returns `true` if it drained in time, `false`
    /// if selections were still in flight when the grace budget ran out
    /// (the graceful-shutdown caller then hard-cancels them and drains
    /// unconditionally).
    pub fn drain_timeout(&self, grace: Duration) -> bool {
        let t0 = Instant::now();
        let mut st = self.state.lock().unwrap();
        while st.in_flight > 0 || !st.queue.is_empty() {
            let elapsed = t0.elapsed();
            if elapsed >= grace {
                return false;
            }
            st = self.cv.wait_timeout(st, grace - elapsed).unwrap().0;
        }
        true
    }

    fn admit(&self, st: &mut GateState) -> Permit<'_> {
        st.in_flight += 1;
        self.metrics.selections_inflight.fetch_add(1, Ordering::Relaxed);
        Permit { gate: self }
    }

    fn shed(&self) -> Result<Permit<'_>> {
        self.metrics.selections_shed.fetch_add(1, Ordering::Relaxed);
        Err(SubmodError::Overloaded)
    }

    fn leave_queue(st: &mut GateState, ticket: u64) {
        if let Some(pos) = st.queue.iter().position(|&t| t == ticket) {
            st.queue.remove(pos);
        }
    }
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        let mut st = self.gate.state.lock().unwrap();
        st.in_flight -= 1;
        drop(st);
        self.gate.metrics.selections_inflight.fetch_sub(1, Ordering::Relaxed);
        self.gate.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(max: usize, depth: usize) -> (AdmissionGate, Arc<Metrics>) {
        let m = Arc::new(Metrics::new());
        (AdmissionGate::new(max, depth, m.clone()), m)
    }

    #[test]
    fn fast_path_admits_and_releases() {
        let (g, m) = gate(2, 4);
        let t0 = Instant::now();
        let a = g.acquire(t0, None).unwrap();
        let b = g.acquire(t0, None).unwrap();
        assert_eq!(m.selections_inflight.load(Ordering::Relaxed), 2);
        drop(a);
        drop(b);
        assert_eq!(m.selections_inflight.load(Ordering::Relaxed), 0);
        assert_eq!(m.selections_shed.load(Ordering::Relaxed), 0);
        assert_eq!(m.admission_waits.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn queue_full_sheds_with_typed_overloaded() {
        // depth 0: as soon as every permit is held, requests shed
        let (g, m) = gate(1, 0);
        let t0 = Instant::now();
        let _held = g.acquire(t0, None).unwrap();
        let err = g.acquire(t0, None).unwrap_err();
        assert!(matches!(err, SubmodError::Overloaded), "{err}");
        assert_eq!(m.selections_shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn spent_deadline_sheds_before_queueing() {
        let (g, m) = gate(4, 4);
        // permits are free, but a zero deadline is already spent at
        // admission time — shed, not admitted, not queued
        let err = g.acquire(Instant::now(), Some(Duration::ZERO)).unwrap_err();
        assert!(matches!(err, SubmodError::Overloaded), "{err}");
        assert_eq!(m.selections_shed.load(Ordering::Relaxed), 1);
        assert_eq!(m.admission_waits.load(Ordering::Relaxed), 0);
        assert_eq!(m.selections_inflight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn closed_gate_rejects_with_shutting_down() {
        let (g, m) = gate(2, 2);
        g.close();
        let err = g.acquire(Instant::now(), None).unwrap_err();
        assert!(matches!(err, SubmodError::ShuttingDown), "{err}");
        // shutdown refusals are not sheds
        assert_eq!(m.selections_shed.load(Ordering::Relaxed), 0);
        g.drain(); // empty gate: returns immediately
    }

    #[test]
    fn drain_timeout_reports_stuck_inflight_then_drains() {
        let (g, _m) = gate(1, 0);
        let held = g.acquire(Instant::now(), None).unwrap();
        g.close();
        // a held permit outlives a tiny grace budget → not drained
        assert!(!g.drain_timeout(Duration::from_millis(5)));
        drop(held);
        assert!(g.drain_timeout(Duration::from_secs(5)));
    }

    #[test]
    fn queued_waiter_admitted_fifo_when_permit_frees() {
        let (g, m) = gate(1, 2);
        let t0 = Instant::now();
        let held = g.acquire(t0, None).unwrap();
        // lint: allow(thread-spawn) — test models external callers blocking on admission
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| g.acquire(Instant::now(), None).map(|_p| ()));
            // wait until the waiter is queued, then free the permit
            while m.admission_waits.load(Ordering::Relaxed) == 0 {
                std::thread::yield_now();
            }
            drop(held);
            waiter.join().unwrap().unwrap();
        });
        assert_eq!(m.admission_waits.load(Ordering::Relaxed), 1);
        assert_eq!(m.selections_inflight.load(Ordering::Relaxed), 0);
        assert_eq!(m.selections_shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn close_flushes_queued_waiters() {
        let (g, m) = gate(1, 2);
        let t0 = Instant::now();
        let held = g.acquire(t0, None).unwrap();
        // lint: allow(thread-spawn) — test models external callers blocking on admission
        std::thread::scope(|scope| {
            let waiter = scope.spawn(|| g.acquire(Instant::now(), None).map(|_p| ()));
            while m.admission_waits.load(Ordering::Relaxed) == 0 {
                std::thread::yield_now();
            }
            g.close();
            let err = waiter.join().unwrap().unwrap_err();
            assert!(matches!(err, SubmodError::ShuttingDown), "{err}");
            drop(held);
            g.drain();
        });
        assert_eq!(m.selections_inflight.load(Ordering::Relaxed), 0);
    }
}

//! Streaming tiled kernel construction — the layer-0 substrate under
//! every similarity-kernel build (ISSUE 3; paper Table 5 names kernel
//! creation as the dominant O(n²·d) cost, and §8's sparse mode exists to
//! escape the O(n²) *memory* wall).
//!
//! All construction paths are built on the same tile machinery:
//!
//! * [`build_pairwise`] — direct-write tiles for the dense / rectangular
//!   kernels: the output matrix is split into disjoint row-block slices,
//!   worker threads claim tiles off an atomic counter and fill them in
//!   place (no intermediate buffer, bit-identical to the pre-tile
//!   builder). The symmetric (`a == b` by reference identity) case
//!   computes only the upper triangle over *triangle-area-balanced* tiles
//!   and mirrors the lower triangle in a second, parallel per-block pass.
//! * [`stream_tiles`] — memory-bounded streaming for rectangular (`a × b`)
//!   consumers that never want a full materialization: each worker owns
//!   one reusable `TILE_ROWS × n` buffer, fills it a row-block at a time
//!   with the same register-blocked math, and hands the finished tile to
//!   a caller-supplied callback *inside the worker thread*.
//! * [`stream_symmetric_tiles`] — the symmetric streaming specialization
//!   (the sparse kNN build): only upper-triangle wedge tiles
//!   ([`TriTile`], row i holding columns `[i, n)`) are computed, over the
//!   same triangle-area-balanced row ranges as the dense direct-write
//!   path, so every unordered pair is computed exactly once — the 2×
//!   dot-product saving the dense symmetric path keeps. Consumers see
//!   each (i, j) value once and deliver it to both row i's and row j's
//!   reduction, so `s_ij == s_ji` holds by construction.
//!
//! All drivers execute on the persistent worker pool
//! (`runtime::pool`) — tiles are claimed off an atomic counter and each
//! writes to its own pre-split slot or packed buffer (the indexed-slot
//! determinism rule the pool documents), so outputs are bit-identical
//! at every pool width and no per-call threads are ever spawned.
//!
//! ## Cooperative cancellation
//!
//! Every driver polls the ambient `runtime::cancel` token **once per
//! tile claim** (direct tiles, streamed full-width tiles, and the
//! symmetric wavefront's wedges alike; the `TILE_CLAIM` failpoint,
//! keyed by the build's column count `n`, sits on the same boundary).
//! A fired token makes workers stop claiming, so an in-flight build
//! finishes within one tile per participant — but the drivers return
//! `()`, not `Result`: a cancelled build's output buffer is *partial*,
//! and the nearest Result-returning caller (`maximize`, the
//! coordinator's `ObjectiveKind::build`) must poll
//! `cancel::check_current()` and discard it. A token that never fires
//! changes nothing — polls read an atomic flag, claim order and row
//! arithmetic are untouched, so built kernels are byte-identical with
//! or without a token, at every pool width and on every backend.
//!
//! ## Peak-memory model
//!
//! With `t = runtime::pool::num_threads()` participants, feature
//! dimension `d`, and 4-byte floats:
//!
//! * direct dense build: `4·n²` output + `8·n` squared norms + the
//!   backend's SoA operand copy (`SoaPoints::padded_bytes(n, d)`) when
//!   the active backend wants one — the output is the floor, nothing
//!   transient scales with n² ([`dense_peak_bytes`]);
//! * symmetric streaming sparse build: `4·t·(TILE_ROWS·n/2 + n)` packed
//!   per-worker wedge buffers (a tile's area is capped near half a
//!   full-width tile, no matter how deep into the triangle's taper it
//!   sits) + `8·n·k` CSR output (the top-k accumulators build in place)
//!   + `8·n` per-row cursors + `4·n` squared norms + the same optional
//!   SoA copy ([`sparse_peak_bytes`]) — O(t·n + n·d) instead of O(n²),
//!   which is what lets sparse mode scale past the dense memory wall
//!   (apricot, Schreiber et al. 2019, makes the same argument).
//!
//! ## Compute backends
//!
//! The inner loop — one gram row finalized through the metric — is not
//! hard-wired: every driver dispatches through the process-wide
//! [`backend::InnerKernel`] selected once per process from
//! `SUBMODLIB_BACKEND` or CPU auto-detection (see `kernel::backend`).
//! Each build constructs one [`PointView`] of the candidate operand —
//! adding the 64-byte-aligned SoA transpose iff the backend asks for
//! it — and hands every output row to `InnerKernel::fill_row`.
//!
//! Determinism is pinned *per backend* (tests/backend_parity.rs):
//!
//! * the `scalar` backend reproduces the pre-backend register-blocked
//!   op order (8/4/1 blocks anchored at `j0`) byte for byte — it
//!   anchors the CSR/bench contract. That is why the symmetric paths
//!   here still anchor row i at `j0 = i`: under `scalar` the sparse
//!   build's stored values stay bit-identical to the dense kernel of
//!   the same data, while full-width [`stream_tiles`] rows (anchored
//!   at column 0) can differ from those by an ulp — which is why the
//!   sparse build does not use them;
//! * the SIMD backends (`wide`, `avx2`) compute each column as a
//!   position-independent per-column reduction chain, so under them
//!   *all* paths — full-width, wedge, rect — agree bitwise;
//! * within every backend, outputs are bit-identical at every pool
//!   width and tile schedule (the indexed-slot rule below); across
//!   backends, agreement is ULP-bounded parity, not bit-equality.
//!
//! Exactly one backend runs per process, so every driver-vs-driver
//! bit-equality in the tests below holds unconditionally.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::backend;
use super::metric::Metric;
use crate::coordinator::faults;
use crate::data::points::{PointView, SoaPoints};
use crate::linalg::Matrix;
use crate::runtime::{cancel, pool};

/// Rows per streamed tile. Chosen so a worker's buffer stays a few
/// hundred KB for typical n (64 rows × n cols × 4 bytes): large enough
/// to amortize scheduling, small enough that `threads · TILE_ROWS · n`
/// stays far from O(n²).
pub const TILE_ROWS: usize = 64;

/// One finished similarity tile: rows `[row_start, row_start + rows)` of
/// the full kernel against *all* `cols` columns, row-major in `data`.
/// Borrowed from the worker's reusable buffer — valid only for the
/// duration of the consumer callback.
pub struct Tile<'a> {
    /// Global index of the first row in this tile.
    pub row_start: usize,
    /// Number of rows in this tile.
    pub rows: usize,
    /// Number of columns (always the full ground-set width).
    pub cols: usize,
    /// Row-major `rows × cols` similarity values.
    pub data: &'a [f32],
}

/// Squared norms via the active backend's (shared) norm pass — the
/// finalization inputs every backend agrees on bitwise.
fn sq_norms(m: &Matrix) -> Vec<f32> {
    backend::active().sq_norms(m)
}

/// Stream full-width row tiles of the `a × b` similarity matrix through
/// `consume`, never materializing more than one `TILE_ROWS × n` buffer
/// per worker thread. Tiles are claimed dynamically off an atomic
/// counter; `consume` runs *inside* the worker that computed the tile,
/// so per-tile reductions (e.g. the sparse top-k) parallelize for free.
/// Tile arrival order is unspecified, but the partition is part of the
/// contract: tile t covers rows `[t·TILE_ROWS, (t+1)·TILE_ROWS).min(m)`,
/// so consumers may key per-tile state on `row_start / TILE_ROWS`.
///
/// Every row is computed over the full column range (`j0 = 0`), so row
/// contents are bit-identical to the rectangular [`build_pairwise`] path
/// on the same inputs. For self-similarity (`a == b`) consumers that can
/// reduce with an order-independent accumulator, prefer
/// [`stream_symmetric_tiles`], which computes each unordered pair once
/// instead of twice.
pub fn stream_tiles<F>(a: &Matrix, b: &Matrix, metric: Metric, distances: bool, consume: &F)
where
    F: Fn(Tile<'_>) + Sync,
{
    let m = a.rows();
    let n = b.rows();
    // nothing to stream when either side is empty (mirrors the empty
    // matrix build_pairwise returns; also keeps the documented
    // chunks_exact(t.cols) consumer pattern panic-free)
    if m == 0 || n == 0 {
        return;
    }
    let sq_a = sq_norms(a);
    // reuse the norms when streaming a self-similarity (a == b) build
    let sq_b_own = if std::ptr::eq(a, b) { None } else { Some(sq_norms(b)) };
    let sq_b: &[f32] = sq_b_own.as_deref().unwrap_or(&sq_a);

    let kernel = backend::active();
    let bview = PointView::new(b, kernel.wants_soa());

    let tile_rows = TILE_ROWS.min(m);
    let tile_count = m.div_ceil(TILE_ROWS);
    let threads = pool::num_threads().min(tile_count).max(1);
    let next = AtomicUsize::new(0);
    let (sq_a, sq_b, bview) = (&sq_a, sq_b, &bview);
    pool::run(threads, &|_worker| {
        let mut buf = vec![0f32; tile_rows * n];
        loop {
            // per-tile cancellation poll (+ forceable failpoint)
            faults::trip(faults::TILE_CLAIM, n);
            if cancel::active() {
                break;
            }
            let t = next.fetch_add(1, Ordering::Relaxed);
            if t >= tile_count {
                break;
            }
            let r0 = t * TILE_ROWS;
            let r1 = (r0 + TILE_ROWS).min(m);
            let rows = r1 - r0;
            let data = &mut buf[..rows * n];
            for (bi, i) in (r0..r1).enumerate() {
                kernel.fill_row(
                    a.row(i),
                    sq_a[i],
                    bview,
                    sq_b,
                    0,
                    metric,
                    distances,
                    &mut data[bi * n..(bi + 1) * n],
                );
            }
            consume(Tile { row_start: r0, rows, cols: n, data });
        }
    });
}

/// One finished upper-triangle wedge tile from
/// [`stream_symmetric_tiles`]: rows `[row_start, row_start + rows)` of a
/// symmetric `cols × cols` kernel, where row i carries only its
/// diagonal-and-right columns `[i, cols)`, packed back-to-back in the
/// worker's reusable buffer. Borrowed — valid only for the duration of
/// the consumer callback.
pub struct TriTile<'a> {
    /// Global index of the first row in this tile.
    pub row_start: usize,
    /// Number of rows in this tile.
    pub rows: usize,
    /// Full kernel width (the ground-set size n).
    pub cols: usize,
    data: &'a [f32],
}

impl<'a> TriTile<'a> {
    /// Columns `[row_start + bi, cols)` of tile row `bi` — entry 0 is the
    /// diagonal `(i, i)`, entry `off` is column `i + off`.
    #[inline]
    pub fn row(&self, bi: usize) -> &'a [f32] {
        debug_assert!(bi < self.rows);
        let w = self.cols - self.row_start; // width of the tile's first row
        // rows shrink by one column each: offset of row bi is
        // sum_{t<bi} (w - t) = bi·(2w − bi + 1)/2
        let off = bi * (2 * w - bi + 1) / 2;
        &self.data[off..off + (w - bi)]
    }
}

/// Upper-triangle streaming driver for symmetric (self-similarity)
/// builds: only tiles with `j ≥ i` are computed — each unordered pair
/// exactly once, halving the O(n²·d) dot work of full-width streaming —
/// and handed to `consume` inside the computing worker as packed
/// [`TriTile`] wedges. Row ranges are triangle-area-balanced (the same
/// scheme as the dense direct-write path), with per-tile area capped
/// near `TILE_ROWS·n/2` so a worker's reusable buffer stays O(TILE_ROWS·n)
/// however deep into the triangle's taper its tiles sit.
///
/// Row i of a wedge is computed with block phases anchored at `j0 = i`,
/// exactly like [`build_pairwise`]'s symmetric case — the values are
/// bit-identical to the dense symmetric kernel of the same data.
///
/// Tile arrival order is unspecified: consumers needing deterministic
/// output must reduce through an order-independent accumulator (see
/// `SparseKernel::from_data`, which keeps per-row top-k sets maximal
/// under a strict total order).
pub fn stream_symmetric_tiles<F>(a: &Matrix, metric: Metric, distances: bool, consume: &F)
where
    F: Fn(TriTile<'_>) + Sync,
{
    let n = a.rows();
    if n == 0 {
        return;
    }
    let sq = sq_norms(a);
    let kernel = backend::active();
    let aview = PointView::new(a, kernel.wants_soa());
    let bounds = triangle_bounds_by_area(n, sym_tile_area_target(n));
    let max_area =
        bounds.iter().map(|&(r0, r1)| wedge_area(n, r0, r1)).max().unwrap_or(0);
    let threads = pool::num_threads().min(bounds.len()).max(1);
    let next = AtomicUsize::new(0);
    let (sq, bounds, aview) = (&sq, &bounds, &aview);
    pool::run(threads, &|_worker| {
        let mut buf = vec![0f32; max_area];
        loop {
            // per-wedge cancellation poll (+ forceable failpoint)
            faults::trip(faults::TILE_CLAIM, n);
            if cancel::active() {
                break;
            }
            let t = next.fetch_add(1, Ordering::Relaxed);
            if t >= bounds.len() {
                break;
            }
            let (r0, r1) = bounds[t];
            let mut off = 0usize;
            for i in r0..r1 {
                let len = n - i;
                kernel.fill_row(
                    a.row(i),
                    sq[i],
                    aview,
                    sq,
                    i,
                    metric,
                    distances,
                    &mut buf[off..off + len],
                );
                off += len;
            }
            consume(TriTile { row_start: r0, rows: r1 - r0, cols: n, data: &buf[..off] });
        }
    });
}

/// Packed area of the wedge covering rows `[r0, r1)` of an n-wide upper
/// triangle (row i carries n − i entries, diagonal included).
fn wedge_area(n: usize, r0: usize, r1: usize) -> usize {
    let w = n - r0;
    let rows = r1 - r0;
    rows * (2 * w - rows + 1) / 2
}

/// Per-tile area target for [`stream_symmetric_tiles`]: half a
/// full-width `TILE_ROWS × n` tile, so the streamed-wedge granularity
/// (and per-worker buffer) matches the full-width driver's at half the
/// total work.
fn sym_tile_area_target(n: usize) -> u64 {
    ((TILE_ROWS as u64) * (n as u64) / 2).max(1)
}

/// Direct-write tile driver: `bounds` are row ranges partitioning the
/// output; the output slice is pre-split into one disjoint sub-slice per
/// tile, workers claim tile indices off an atomic counter and call
/// `fill` once per row of their tile. Safe shared-nothing parallelism —
/// each tile's `&mut` slice is handed out exactly once.
fn run_direct<F>(bounds: &[(usize, usize)], out: &mut [f32], n: usize, fill: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let mut slots: Vec<&mut [f32]> = Vec::with_capacity(bounds.len());
    let mut rest = out;
    for &(r0, r1) in bounds {
        let (tile, tail) = rest.split_at_mut((r1 - r0) * n);
        slots.push(tile);
        rest = tail;
    }
    pool::run_indexed(pool::num_threads(), slots, |t, tile| {
        // per-tile cancellation poll (+ forceable failpoint); run_indexed
        // additionally polls before every claim
        faults::trip(faults::TILE_CLAIM, n);
        if cancel::active() {
            return;
        }
        let (r0, r1) = bounds[t];
        for (bi, i) in (r0..r1).enumerate() {
            fill(i, &mut tile[bi * n..(bi + 1) * n]);
        }
    });
}

/// Row ranges with roughly equal upper-triangle workloads (row i carries
/// n − i entries), split into ~`parts` tiles so dynamic scheduling can
/// balance the remainder.
fn triangle_bounds(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let total = (n as u64) * (n as u64 + 1) / 2;
    triangle_bounds_by_area(n, total.div_ceil(parts.max(1) as u64).max(1))
}

/// Row ranges whose upper-triangle areas each reach `target` (the last
/// range may fall short; any range overshoots by less than one row's
/// width). Shared by [`triangle_bounds`] (target from a part count) and
/// [`stream_symmetric_tiles`] (absolute target, bounding worker buffers).
fn triangle_bounds_by_area(n: usize, target: u64) -> Vec<(usize, usize)> {
    let mut bounds = Vec::new();
    let mut row = 0usize;
    while row < n {
        let start = row;
        let mut acc = 0u64;
        while row < n && acc < target {
            acc += (n - row) as u64;
            row += 1;
        }
        bounds.push((start, row));
    }
    bounds
}

/// Shared blocked + threaded pairwise builder (the direct-write tile
/// path). `distances=true` emits the raw euclidean distance instead of
/// the metric similarity.
///
/// When `a` and `b` are the *same* matrix (detected by reference
/// identity, which is how `DenseKernel::from_data` calls it), every
/// supported metric is symmetric in its inputs, so only the upper
/// triangle (j ≥ i) is computed — the lower triangle is mirrored by a
/// parallel per-block pass. That halves the O(n²·d) dot-product work,
/// the dominant cost of Table 5's kernel construction.
pub(crate) fn build_pairwise(a: &Matrix, b: &Matrix, metric: Metric, distances: bool) -> Matrix {
    if std::ptr::eq(a, b) {
        return build_symmetric(a, metric, distances);
    }
    let m = a.rows();
    let n = b.rows();
    let mut out = Matrix::zeros(m, n);
    if m == 0 || n == 0 {
        return out;
    }
    let sq_a = sq_norms(a);
    let sq_b = sq_norms(b);
    let kernel = backend::active();
    let bview = PointView::new(b, kernel.wants_soa());
    let bounds: Vec<(usize, usize)> = (0..m.div_ceil(TILE_ROWS))
        .map(|t| (t * TILE_ROWS, ((t + 1) * TILE_ROWS).min(m)))
        .collect();
    run_direct(&bounds, out.as_mut_slice(), n, |i, orow| {
        kernel.fill_row(a.row(i), sq_a[i], &bview, &sq_b, 0, metric, distances, orow)
    });
    out
}

/// Symmetric specialization: upper-triangle-only tiles (balanced by
/// triangle area), then a parallel per-block mirror of the lower
/// triangle. The mirror copies bits, so `s_ij == s_ji` exactly.
fn build_symmetric(a: &Matrix, metric: Metric, distances: bool) -> Matrix {
    let n = a.rows();
    let mut out = Matrix::zeros(n, n);
    if n == 0 {
        return out;
    }
    let sq = sq_norms(a);
    let kernel = backend::active();
    let aview = PointView::new(a, kernel.wants_soa());
    // ~4 tiles per worker: coarse enough to amortize scheduling, fine
    // enough that dynamic claiming evens out the triangle's taper
    let bounds = triangle_bounds(n, pool::num_threads() * 4);
    run_direct(&bounds, out.as_mut_slice(), n, |i, orow| {
        kernel.fill_row(a.row(i), sq[i], &aview, &sq, i, metric, distances, &mut orow[i..])
    });
    mirror_lower(out.as_mut_slice(), n);
    out
}

/// Parallel mirror of the strict lower triangle from the (finished)
/// strict upper triangle. Safe disjointness by construction: each row is
/// split at its diagonal into a writable strict-lower part and a shared
/// diagonal-and-above part, so writers and readers never alias. Work is
/// balanced by lower-triangle area (row i carries i copies).
fn mirror_lower(out: &mut [f32], n: usize) {
    let threads = pool::num_threads();
    let total = (n as u64) * (n as u64 - 1) / 2;
    let target = total.div_ceil(threads as u64).max(1);
    let mut uppers: Vec<&[f32]> = Vec::with_capacity(n);
    // (first row, strict-lower slices) per claimable chunk
    let mut chunks: Vec<(usize, Vec<&mut [f32]>)> = Vec::with_capacity(threads + 1);
    let mut rest = out;
    let mut cur: Vec<&mut [f32]> = Vec::new();
    let mut cur_start = 0usize;
    let mut acc = 0u64;
    for i in 0..n {
        let (row, tail) = rest.split_at_mut(n);
        rest = tail;
        let (lo, up) = row.split_at_mut(i);
        cur.push(lo);
        uppers.push(up);
        acc += i as u64;
        if acc >= target && i + 1 < n {
            chunks.push((cur_start, std::mem::take(&mut cur)));
            cur_start = i + 1;
            acc = 0;
        }
    }
    if !cur.is_empty() {
        chunks.push((cur_start, cur));
    }
    let uppers = &uppers;
    pool::run_indexed(threads, chunks, |_t, (start, rows)| {
        for (bi, lo) in rows.into_iter().enumerate() {
            let i = start + bi;
            for (j, slot) in lo.iter_mut().enumerate() {
                // (i, j) mirrors (j, i); uppers[j] starts at col j
                *slot = uppers[j][i - j];
            }
        }
    });
}

/// SoA operand bytes the active backend adds to a build of `n` points
/// in `d` dimensions: the padded transpose when the backend wants one
/// ([`PointView::new`]), zero for the scalar backend. The model is
/// pinned to the actual allocation by the `data::points` unit tests
/// (`heap_bytes == padded_bytes`).
fn soa_operand_bytes(n: usize, d: usize) -> usize {
    if backend::active().wants_soa() && n > 0 && d > 0 {
        SoaPoints::padded_bytes(n, d)
    } else {
        0
    }
}

/// Peak heap bytes of the direct dense build at ground-set size `n`,
/// feature dimension `d`: the n×n output, the two squared-norm vectors,
/// and the backend's SoA operand copy (if it wants one). Nothing
/// transient scales with n².
pub fn dense_peak_bytes(n: usize, d: usize) -> usize {
    4 * n * n + 8 * n + soa_operand_bytes(n, d)
}

/// Peak heap bytes of the symmetric streaming sparse (kNN, `k`
/// neighbors) build at ground-set size `n`, feature dimension `d`:
/// packed per-worker wedge buffers, the CSR output (the top-k
/// accumulators build in place — no separate scratch), per-row cursors,
/// the squared norms, and the backend's SoA operand copy —
/// O(threads·n + n·k + n·d), never O(n²).
pub fn sparse_peak_bytes(n: usize, k: usize, d: usize) -> usize {
    let total = n * (n + 1) / 2;
    let target = sym_tile_area_target(n) as usize;
    // the greedy area walk closes a wedge within one row of the target,
    // and never spawns more workers than there are wedges
    let tiles = total.div_ceil(target).max(1);
    let t = pool::num_threads().min(tiles).max(1);
    let wedge = (target + n).min(total.max(1));
    4 * t * wedge // packed per-worker wedge buffers
        + 8 * n * k // CSR columns + values (accumulators build in place)
        + 8 * n // per-row fill/worst cursors
        + 4 * n // squared norms
        + soa_operand_bytes(n, d) // backend SoA transpose (if any)
}

#[cfg(test)]
mod tests {
    use std::sync::Mutex;

    use super::*;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect())
            .unwrap()
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full tile-pipeline builds; prohibitive under the interpreter
    fn symmetric_build_matches_rect_path() {
        // same math as the two-argument (rectangular) builder
        let data = rand_data(33, 6, 8);
        let copy = data.clone();
        let sym = build_pairwise(&data, &data, Metric::Rbf { gamma: 0.7 }, false);
        let rect = build_pairwise(&data, &copy, Metric::Rbf { gamma: 0.7 }, false);
        for i in 0..33 {
            for j in 0..33 {
                assert!((sym.get(i, j) - rect.get(i, j)).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full tile-pipeline builds; prohibitive under the interpreter
    fn streamed_tiles_reassemble_to_rect_build() {
        // stream_tiles computes full rows (j0 = 0), so reassembling its
        // tiles must reproduce the rectangular direct build bit-for-bit —
        // including across the TILE_ROWS boundary (n > 2·TILE_ROWS)
        let a = rand_data(2 * TILE_ROWS + 21, 5, 9);
        let b = rand_data(37, 5, 10);
        for metric in [Metric::Euclidean, Metric::Cosine, Metric::Dot, Metric::Rbf { gamma: 0.4 }]
        {
            let direct = build_pairwise(&a, &b, metric, false);
            let n = b.rows();
            let assembled = Mutex::new(vec![0f32; a.rows() * n]);
            stream_tiles(&a, &b, metric, false, &|t: Tile<'_>| {
                let mut out = assembled.lock().unwrap();
                out[t.row_start * n..t.row_start * n + t.rows * n].copy_from_slice(t.data);
            });
            let assembled = assembled.into_inner().unwrap();
            for (i, (got, want)) in
                assembled.iter().zip(direct.as_slice().iter()).enumerate()
            {
                assert_eq!(got.to_bits(), want.to_bits(), "{metric:?} flat index {i}");
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full tile-pipeline builds; prohibitive under the interpreter
    fn streamed_self_similarity_reuses_norms() {
        // a == b by reference: norms computed once, rows still full-width
        let data = rand_data(50, 4, 11);
        let copy = data.clone();
        let reference = build_pairwise(&data, &copy, Metric::Euclidean, false);
        let seen = Mutex::new(vec![false; 50]);
        stream_tiles(&data, &data, Metric::Euclidean, false, &|t: Tile<'_>| {
            let mut seen = seen.lock().unwrap();
            for (bi, row) in t.data.chunks_exact(t.cols).enumerate() {
                let i = t.row_start + bi;
                seen[i] = true;
                for (j, v) in row.iter().enumerate() {
                    assert_eq!(v.to_bits(), reference.get(i, j).to_bits(), "({i},{j})");
                }
            }
        });
        assert!(seen.into_inner().unwrap().iter().all(|&s| s), "missing rows");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full tile-pipeline builds; prohibitive under the interpreter
    fn symmetric_stream_covers_upper_triangle_once_bit_equal() {
        // every (i, j≥i) pair delivered exactly once, bit-identical to
        // the dense symmetric build (same j0 = i block-phase anchoring);
        // n spans several area-balanced wedges
        let data = rand_data(3 * TILE_ROWS + 11, 6, 13);
        let n = data.rows();
        let metric = Metric::Rbf { gamma: 0.5 };
        let reference = build_pairwise(&data, &data, metric, false);
        let seen = Mutex::new(vec![0u8; n * n]);
        stream_symmetric_tiles(&data, metric, false, &|t: TriTile<'_>| {
            let mut seen = seen.lock().unwrap();
            for bi in 0..t.rows {
                let i = t.row_start + bi;
                let row = t.row(bi);
                assert_eq!(row.len(), n - i, "row {i} width");
                for (off, v) in row.iter().enumerate() {
                    let j = i + off;
                    assert_eq!(v.to_bits(), reference.get(i, j).to_bits(), "({i},{j})");
                    seen[i * n + j] += 1;
                }
            }
        });
        let seen = seen.into_inner().unwrap();
        for i in 0..n {
            for j in 0..n {
                assert_eq!(seen[i * n + j], u8::from(j >= i), "coverage ({i},{j})");
            }
        }
    }

    #[test]
    fn symmetric_stream_wedge_areas_bounded() {
        // the packed buffer bound the driver allocates must hold for the
        // bounds it actually uses: area ≤ target + (one row's width − 1)
        for n in [1usize, 63, 64, 65, 300, 1000] {
            let target = sym_tile_area_target(n);
            let bounds = triangle_bounds_by_area(n, target);
            assert_eq!(bounds.first().unwrap().0, 0);
            assert_eq!(bounds.last().unwrap().1, n);
            for w in bounds.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap for n={n}");
            }
            for &(r0, r1) in &bounds {
                assert!(
                    (wedge_area(n, r0, r1) as u64) < target + (n - r0) as u64,
                    "oversized wedge [{r0},{r1}) for n={n}"
                );
            }
        }
    }

    #[test]
    fn triangle_bounds_cover_all_rows() {
        for n in [1usize, 2, 7, 64, 257] {
            for parts in [1usize, 3, 8, 40] {
                let bounds = triangle_bounds(n, parts);
                assert_eq!(bounds.first().unwrap().0, 0);
                assert_eq!(bounds.last().unwrap().1, n);
                for w in bounds.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap in bounds for n={n}");
                }
                for &(s, e) in &bounds {
                    assert!(s < e, "empty tile for n={n} parts={parts}");
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // full tile-pipeline builds; prohibitive under the interpreter
    fn distances_path_streams_identically() {
        let data = rand_data(70, 3, 12);
        let copy = data.clone();
        let reference = build_pairwise(&data, &copy, Metric::Euclidean, true);
        stream_tiles(&data, &copy, Metric::Euclidean, true, &|t: Tile<'_>| {
            for (bi, row) in t.data.chunks_exact(t.cols).enumerate() {
                let i = t.row_start + bi;
                for (j, v) in row.iter().enumerate() {
                    assert_eq!(v.to_bits(), reference.get(i, j).to_bits(), "({i},{j})");
                }
            }
        });
    }

    #[test]
    fn peak_models_are_monotone() {
        assert!(dense_peak_bytes(2000, 128) > dense_peak_bytes(500, 128));
        assert!(sparse_peak_bytes(2000, 32, 128) > sparse_peak_bytes(500, 32, 128));
        // the streaming model must beat dense materialization at scale
        assert!(sparse_peak_bytes(100_000, 32, 128) < dense_peak_bytes(100_000, 128));
    }

    #[test]
    fn peak_models_account_for_soa_padding() {
        // the SoA term is exactly the padded transpose the drivers
        // allocate for SoA backends — and exactly zero for scalar
        let (n, d) = (500usize, 128usize);
        let base_dense = 4 * n * n + 8 * n;
        let extra = dense_peak_bytes(n, d) - base_dense;
        if backend::active().wants_soa() {
            assert_eq!(extra, SoaPoints::padded_bytes(n, d));
        } else {
            assert_eq!(extra, 0);
        }
        // the same term, and only it, shows up in the sparse model
        assert_eq!(
            sparse_peak_bytes(n, 32, d) - sparse_peak_bytes(n, 32, 0),
            extra
        );
    }
}

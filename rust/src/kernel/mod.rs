//! Similarity / distance kernels — the data substrate every
//! similarity-based set function consumes (paper §8 "usage patterns").
//!
//! * [`metric::Metric`] — euclidean (`1/(1+d)`), cosine, dot, RBF.
//! * [`dense::DenseKernel`] — N×N dense kernel (paper mode `"dense"`),
//!   built natively (threaded, gram-based) or via the PJRT artifact path
//!   (`runtime::tiled`).
//! * [`sparse::SparseKernel`] — k-nearest-neighbor CSR kernel (paper mode
//!   `"sparse"`): similarity beyond `num_neighbors` treated as zero.
//! * [`rect::RectKernel`] — rectangular kernels (represented set × ground
//!   set, query × ground, private × ground) for the generic-U functions
//!   and the MI / CG / CMI instantiations.
//! * [`tile`] — the streaming tiled construction pipeline all three
//!   builders run on: direct-write row-block tiles for dense/rect,
//!   memory-bounded streamed tiles (per-worker buffers + in-worker
//!   consumers) for rectangular workloads, and symmetric upper-triangle
//!   wedge streaming (each pair computed once) for sparse. See its docs
//!   for the peak-memory model.
//! * [`backend`] — the runtime-dispatched SIMD inner kernels (scalar /
//!   wide / avx2) every tile driver computes through; selected once per
//!   process via `SUBMODLIB_BACKEND` or CPU auto-detection. (Distinct
//!   from [`builder::KernelBackend`], which picks the *construction
//!   path* — native tiles vs the PJRT artifact route.)
//! * [`builder`] — construction-path dispatching helpers.

pub mod backend;
pub mod builder;
pub mod dense;
pub mod metric;
pub mod rect;
pub mod sparse;
pub mod tile;

pub use builder::{build_dense, KernelBackend};
pub use dense::DenseKernel;
pub use metric::Metric;
pub use rect::RectKernel;
pub use sparse::SparseKernel;

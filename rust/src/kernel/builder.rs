//! Backend-dispatching kernel construction (paper §8 usage patterns):
//! the user can have the kernel built "in C++" (here: natively in Rust,
//! threaded) or through the compiled L1/L2 artifact stack (PJRT).

use std::sync::Arc;

use super::dense::DenseKernel;
use super::metric::Metric;
use crate::error::Result;
use crate::linalg::Matrix;
use crate::runtime::{tiled, Engine};

/// Which engine computes the O(n²·d) kernel build.
#[derive(Clone)]
pub enum KernelBackend {
    /// Blocked + threaded Rust (default; always available).
    Native,
    /// AOT Pallas→HLO artifacts executed via PJRT.
    Pjrt(Arc<Engine>),
}

impl std::fmt::Debug for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelBackend::Native => write!(f, "Native"),
            KernelBackend::Pjrt(_) => write!(f, "Pjrt"),
        }
    }
}

/// Build a dense similarity kernel with the selected backend.
pub fn build_dense(data: &Matrix, metric: Metric, backend: &KernelBackend) -> Result<DenseKernel> {
    match backend {
        KernelBackend::Native => Ok(DenseKernel::from_data(data, metric)),
        KernelBackend::Pjrt(engine) => {
            let mat = tiled::build_dense_kernel(engine, data, metric)?;
            DenseKernel::from_matrix(mat)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_backend_builds() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[1.0, 0.0], &[0.0, 1.0]]);
        let k = build_dense(&data, Metric::Euclidean, &KernelBackend::Native).unwrap();
        assert_eq!(k.n(), 3);
        assert!((k.get(0, 0) - 1.0).abs() < 1e-6);
    }
}

//! Rectangular kernels: similarities between two *different* sets.
//!
//! Used by (paper §2.1.1, §3): the generic represented-set U ≠ V variants
//! of FacilityLocation / GraphCut, and every query (Q × V) / private
//! (P × V) kernel in the MI / CG / CMI instantiations. FLQMI in particular
//! only ever needs a Q × V kernel (paper §3.5), which is what makes it
//! cheap.
//!
//! Builds run on the direct-write tile pipeline (`super::tile`) through
//! the process-wide compute backend (`super::backend`), anchored at
//! `j0 = 0` — the rectangular rows are full-width.

use super::metric::Metric;
use super::tile::build_pairwise;
use crate::error::{Result, SubmodError};
use crate::linalg::Matrix;

/// Dense rows × cols similarity kernel between set R (rows) and set C
/// (cols).
#[derive(Debug, Clone)]
pub struct RectKernel {
    mat: Matrix,
}

impl RectKernel {
    /// Build from two feature matrices: `rows_data` (set R) × `cols_data`
    /// (set C).
    pub fn from_data(rows_data: &Matrix, cols_data: &Matrix, metric: Metric) -> Result<Self> {
        if rows_data.cols() != cols_data.cols() {
            return Err(SubmodError::Shape(format!(
                "feature dims {} vs {}",
                rows_data.cols(),
                cols_data.cols()
            )));
        }
        Ok(RectKernel { mat: build_pairwise(rows_data, cols_data, metric, false) })
    }

    /// Wrap a precomputed kernel.
    pub fn from_matrix(mat: Matrix) -> Self {
        RectKernel { mat }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.mat.rows()
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.mat.cols()
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.mat.get(i, j)
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        self.mat.row(i)
    }

    /// Transposed copy (Q×V → V×Q), needed by FLQMI's second term.
    pub fn transpose(&self) -> RectKernel {
        RectKernel { mat: self.mat.transpose() }
    }

    pub fn matrix(&self) -> &Matrix {
        &self.mat
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rect_matches_direct() {
        let a = Matrix::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]);
        let b = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0], &[2.0, 2.0]]);
        let k = RectKernel::from_data(&a, &b, Metric::Euclidean).unwrap();
        assert_eq!(k.rows(), 2);
        assert_eq!(k.cols(), 3);
        for i in 0..2 {
            for j in 0..3 {
                let direct = Metric::Euclidean.similarity(a.row(i), b.row(j));
                assert!((k.get(i, j) - direct).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn dim_mismatch_rejected() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 4);
        assert!(RectKernel::from_data(&a, &b, Metric::Dot).is_err());
    }

    #[test]
    fn transpose_swaps() {
        let a = Matrix::from_rows(&[&[1.0], &[2.0]]);
        let b = Matrix::from_rows(&[&[3.0], &[4.0], &[5.0]]);
        let k = RectKernel::from_data(&a, &b, Metric::Dot).unwrap();
        let t = k.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.cols(), 2);
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(k.get(i, j), t.get(j, i));
            }
        }
    }
}

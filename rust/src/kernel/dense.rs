//! Dense N×N similarity kernel (paper mode `"dense"`).
//!
//! Construction is the O(n²·d) hot-spot of Table 5; the native path runs
//! on the direct-write tile pipeline (`super::tile`): gram expansion (one
//! blocked X·Xᵀ + an O(n²) metric transform) over row-block tiles claimed
//! dynamically by the persistent worker pool, with the inner gram math
//! dispatched through the process-wide compute backend
//! (`super::backend`: scalar / wide / avx2). The PJRT path
//! (`runtime::tiled::build_dense_kernel`) runs the same math through the
//! AOT-compiled Pallas artifact.

use super::metric::Metric;
use super::tile::build_pairwise;
use crate::error::{Result, SubmodError};
use crate::linalg::Matrix;

/// Dense similarity kernel over a ground set of `n` items.
#[derive(Debug, Clone)]
pub struct DenseKernel {
    mat: Matrix,
}

impl DenseKernel {
    /// Build from a feature matrix (rows = items), threaded tile path.
    pub fn from_data(data: &Matrix, metric: Metric) -> Self {
        let mat = build_pairwise(data, data, metric, false);
        DenseKernel { mat }
    }

    /// Build a euclidean *distance* matrix (for the disparity functions).
    pub fn distances_from_data(data: &Matrix) -> Self {
        let mat = build_pairwise(data, data, Metric::Euclidean, true);
        DenseKernel { mat }
    }

    /// Wrap a precomputed square kernel ("create kernel in Python" mode).
    pub fn from_matrix(mat: Matrix) -> Result<Self> {
        if mat.rows() != mat.cols() {
            return Err(SubmodError::Shape(format!(
                "dense kernel must be square, got {}x{}",
                mat.rows(),
                mat.cols()
            )));
        }
        Ok(DenseKernel { mat })
    }

    /// Ground-set size.
    #[inline]
    pub fn n(&self) -> usize {
        self.mat.rows()
    }

    /// Similarity s_ij.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.mat.get(i, j)
    }

    /// Row i as a contiguous slice (all similarities of item i).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        self.mat.row(i)
    }

    /// Underlying matrix (tests, LogDet factorizations).
    pub fn matrix(&self) -> &Matrix {
        &self.mat
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect())
            .unwrap()
    }

    #[test]
    fn matches_direct_pairwise() {
        let data = rand_data(23, 7, 1);
        for metric in [Metric::Euclidean, Metric::Cosine, Metric::Dot, Metric::Rbf { gamma: 0.3 }] {
            let k = DenseKernel::from_data(&data, metric);
            for i in (0..23).step_by(5) {
                for j in (0..23).step_by(3) {
                    let direct = metric.similarity(data.row(i), data.row(j));
                    assert!(
                        (k.get(i, j) - direct).abs() < 1e-4,
                        "{metric:?} ({i},{j}): {} vs {direct}",
                        k.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn symmetric_and_unit_diagonal() {
        let data = rand_data(17, 5, 2);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        for i in 0..17 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-5);
            for j in 0..17 {
                assert!((k.get(i, j) - k.get(j, i)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn distances_kernel() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[3.0, 4.0], &[6.0, 8.0]]);
        let d = DenseKernel::distances_from_data(&data);
        assert!((d.get(0, 1) - 5.0).abs() < 1e-5);
        assert!((d.get(0, 2) - 10.0).abs() < 1e-5);
        assert!(d.get(1, 1).abs() < 1e-5);
    }

    #[test]
    fn from_matrix_rejects_rect() {
        assert!(DenseKernel::from_matrix(Matrix::zeros(3, 4)).is_err());
        assert!(DenseKernel::from_matrix(Matrix::zeros(4, 4)).is_ok());
    }

    #[test]
    fn symmetric_build_mirrors_exactly() {
        // the symmetric path computes the upper triangle and mirrors it
        // (in parallel, per block), so s_ij == s_ji bitwise — for
        // similarities and distances alike
        let data = rand_data(61, 9, 7);
        for k in [
            DenseKernel::from_data(&data, Metric::Cosine),
            DenseKernel::distances_from_data(&data),
        ] {
            for i in 0..61 {
                for j in 0..61 {
                    assert_eq!(k.get(i, j).to_bits(), k.get(j, i).to_bits(), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn threaded_build_matches_single_row_math_large() {
        // Exercise the multi-tile scheduling path (n > TILE_ROWS).
        let data = rand_data(97, 16, 3);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 1.0 });
        for &(i, j) in &[(0, 96), (50, 51), (96, 0), (13, 77)] {
            let direct = Metric::Rbf { gamma: 1.0 }.similarity(data.row(i), data.row(j));
            assert!((k.get(i, j) - direct).abs() < 1e-4);
        }
    }
}

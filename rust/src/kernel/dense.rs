//! Dense N×N similarity kernel (paper mode `"dense"`).
//!
//! Construction is the O(n²·d) hot-spot of Table 5; the native path uses
//! the gram expansion (one blocked X·Xᵀ + an O(n²) metric transform)
//! parallelized across row blocks with scoped threads. The PJRT path
//! (`runtime::tiled::build_dense_kernel`) runs the same math through the
//! AOT-compiled Pallas artifact.

use super::metric::Metric;
use crate::error::{Result, SubmodError};
use crate::linalg::{self, Matrix};

/// Dense similarity kernel over a ground set of `n` items.
#[derive(Debug, Clone)]
pub struct DenseKernel {
    mat: Matrix,
}

impl DenseKernel {
    /// Build from a feature matrix (rows = items), threaded gram path.
    pub fn from_data(data: &Matrix, metric: Metric) -> Self {
        let mat = build_pairwise(data, data, metric, false);
        DenseKernel { mat }
    }

    /// Build a euclidean *distance* matrix (for the disparity functions).
    pub fn distances_from_data(data: &Matrix) -> Self {
        let mat = build_pairwise(data, data, Metric::Euclidean, true);
        DenseKernel { mat }
    }

    /// Wrap a precomputed square kernel ("create kernel in Python" mode).
    pub fn from_matrix(mat: Matrix) -> Result<Self> {
        if mat.rows() != mat.cols() {
            return Err(SubmodError::Shape(format!(
                "dense kernel must be square, got {}x{}",
                mat.rows(),
                mat.cols()
            )));
        }
        Ok(DenseKernel { mat })
    }

    /// Ground-set size.
    #[inline]
    pub fn n(&self) -> usize {
        self.mat.rows()
    }

    /// Similarity s_ij.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.mat.get(i, j)
    }

    /// Row i as a contiguous slice (all similarities of item i).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        self.mat.row(i)
    }

    /// Underlying matrix (tests, LogDet factorizations).
    pub fn matrix(&self) -> &Matrix {
        &self.mat
    }
}

/// Shared blocked + threaded pairwise builder. `distances=true` emits the
/// raw euclidean distance instead of the metric similarity.
///
/// When `a` and `b` are the *same* matrix (detected by reference
/// identity, which is how [`DenseKernel::from_data`] and the sparse
/// builder call it), every supported metric is symmetric in its inputs,
/// so only the upper triangle (j ≥ i) is computed — the lower triangle is
/// mirrored afterwards. That halves the O(n²·d) dot-product work, the
/// dominant cost of Table 5's kernel construction.
pub(crate) fn build_pairwise(a: &Matrix, b: &Matrix, metric: Metric, distances: bool) -> Matrix {
    let m = a.rows();
    let n = b.rows();
    if std::ptr::eq(a, b) {
        return build_symmetric(a, metric, distances);
    }
    let mut out = Matrix::zeros(m, n);
    let sq_a: Vec<f32> = (0..m).map(|i| linalg::dot(a.row(i), a.row(i))).collect();
    let sq_b: Vec<f32> = (0..n).map(|j| linalg::dot(b.row(j), b.row(j))).collect();

    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let chunk = m.div_ceil(threads).max(1);
    let out_slice = out.as_mut_slice();

    std::thread::scope(|scope| {
        let mut rest = out_slice;
        let mut start = 0usize;
        while start < m {
            let rows_here = chunk.min(m - start);
            let (this, tail) = rest.split_at_mut(rows_here * n);
            rest = tail;
            let (sq_a, sq_b) = (&sq_a, &sq_b);
            scope.spawn(move || {
                for (bi, i) in (start..start + rows_here).enumerate() {
                    let arow = a.row(i);
                    let orow = &mut this[bi * n..(bi + 1) * n];
                    // register-blocked: 8 then 4 B rows per pass over
                    // arow (§Perf iterations 1–2 — EXPERIMENTS.md)
                    let mut j = 0;
                    while j + 8 <= n {
                        let g = linalg::dot8(
                            arow,
                            [
                                b.row(j),
                                b.row(j + 1),
                                b.row(j + 2),
                                b.row(j + 3),
                                b.row(j + 4),
                                b.row(j + 5),
                                b.row(j + 6),
                                b.row(j + 7),
                            ],
                        );
                        for t in 0..8 {
                            orow[j + t] = if distances {
                                (sq_a[i] + sq_b[j + t] - 2.0 * g[t]).max(0.0).sqrt()
                            } else {
                                metric.from_gram(g[t], sq_a[i], sq_b[j + t])
                            };
                        }
                        j += 8;
                    }
                    while j + 4 <= n {
                        let g = linalg::dot4(
                            arow,
                            b.row(j),
                            b.row(j + 1),
                            b.row(j + 2),
                            b.row(j + 3),
                        );
                        for t in 0..4 {
                            orow[j + t] = if distances {
                                (sq_a[i] + sq_b[j + t] - 2.0 * g[t]).max(0.0).sqrt()
                            } else {
                                metric.from_gram(g[t], sq_a[i], sq_b[j + t])
                            };
                        }
                        j += 4;
                    }
                    for (jj, o) in orow.iter_mut().enumerate().skip(j) {
                        let g = linalg::dot(arow, b.row(jj));
                        *o = if distances {
                            (sq_a[i] + sq_b[jj] - 2.0 * g).max(0.0).sqrt()
                        } else {
                            metric.from_gram(g, sq_a[i], sq_b[jj])
                        };
                    }
                }
            });
            start += rows_here;
        }
    });
    out
}

/// Symmetric specialization of [`build_pairwise`]: upper triangle only,
/// then mirror. Thread chunks are balanced by *triangle area* (row i
/// carries n−i entries), not by row count, so early rows don't serialize
/// the build.
fn build_symmetric(a: &Matrix, metric: Metric, distances: bool) -> Matrix {
    let n = a.rows();
    let mut out = Matrix::zeros(n, n);
    let sq: Vec<f32> = (0..n).map(|i| linalg::dot(a.row(i), a.row(i))).collect();

    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    // row ranges with roughly equal Σ(n−i) workloads
    let total: u64 = (n as u64) * (n as u64 + 1) / 2;
    let target = total.div_ceil(threads as u64).max(1);
    let mut bounds: Vec<(usize, usize)> = Vec::with_capacity(threads);
    let mut row = 0usize;
    while row < n {
        let mut acc = 0u64;
        let start = row;
        while row < n && acc < target {
            acc += (n - row) as u64;
            row += 1;
        }
        bounds.push((start, row));
    }

    let out_slice = out.as_mut_slice();
    std::thread::scope(|scope| {
        let mut rest = out_slice;
        for &(start, end) in &bounds {
            let (this, tail) = rest.split_at_mut((end - start) * n);
            rest = tail;
            let sq = &sq;
            scope.spawn(move || {
                for (bi, i) in (start..end).enumerate() {
                    let arow = a.row(i);
                    let orow = &mut this[bi * n..(bi + 1) * n];
                    // same register blocking as the rectangular path,
                    // starting at the diagonal
                    let mut j = i;
                    while j + 8 <= n {
                        let g = linalg::dot8(
                            arow,
                            [
                                a.row(j),
                                a.row(j + 1),
                                a.row(j + 2),
                                a.row(j + 3),
                                a.row(j + 4),
                                a.row(j + 5),
                                a.row(j + 6),
                                a.row(j + 7),
                            ],
                        );
                        for t in 0..8 {
                            orow[j + t] = if distances {
                                (sq[i] + sq[j + t] - 2.0 * g[t]).max(0.0).sqrt()
                            } else {
                                metric.from_gram(g[t], sq[i], sq[j + t])
                            };
                        }
                        j += 8;
                    }
                    while j + 4 <= n {
                        let g = linalg::dot4(
                            arow,
                            a.row(j),
                            a.row(j + 1),
                            a.row(j + 2),
                            a.row(j + 3),
                        );
                        for t in 0..4 {
                            orow[j + t] = if distances {
                                (sq[i] + sq[j + t] - 2.0 * g[t]).max(0.0).sqrt()
                            } else {
                                metric.from_gram(g[t], sq[i], sq[j + t])
                            };
                        }
                        j += 4;
                    }
                    for jj in j..n {
                        let g = linalg::dot(arow, a.row(jj));
                        orow[jj] = if distances {
                            (sq[i] + sq[jj] - 2.0 * g).max(0.0).sqrt()
                        } else {
                            metric.from_gram(g, sq[i], sq[jj])
                        };
                    }
                }
            });
        }
    });
    // mirror the lower triangle (exact symmetry by construction)
    let s = out.as_mut_slice();
    for i in 1..n {
        for j in 0..i {
            s[i * n + j] = s[j * n + i];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect())
            .unwrap()
    }

    #[test]
    fn matches_direct_pairwise() {
        let data = rand_data(23, 7, 1);
        for metric in [Metric::Euclidean, Metric::Cosine, Metric::Dot, Metric::Rbf { gamma: 0.3 }] {
            let k = DenseKernel::from_data(&data, metric);
            for i in (0..23).step_by(5) {
                for j in (0..23).step_by(3) {
                    let direct = metric.similarity(data.row(i), data.row(j));
                    assert!(
                        (k.get(i, j) - direct).abs() < 1e-4,
                        "{metric:?} ({i},{j}): {} vs {direct}",
                        k.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn symmetric_and_unit_diagonal() {
        let data = rand_data(17, 5, 2);
        let k = DenseKernel::from_data(&data, Metric::Euclidean);
        for i in 0..17 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-5);
            for j in 0..17 {
                assert!((k.get(i, j) - k.get(j, i)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn distances_kernel() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[3.0, 4.0], &[6.0, 8.0]]);
        let d = DenseKernel::distances_from_data(&data);
        assert!((d.get(0, 1) - 5.0).abs() < 1e-5);
        assert!((d.get(0, 2) - 10.0).abs() < 1e-5);
        assert!(d.get(1, 1).abs() < 1e-5);
    }

    #[test]
    fn from_matrix_rejects_rect() {
        assert!(DenseKernel::from_matrix(Matrix::zeros(3, 4)).is_err());
        assert!(DenseKernel::from_matrix(Matrix::zeros(4, 4)).is_ok());
    }

    #[test]
    fn symmetric_build_mirrors_exactly() {
        // the symmetric path computes the upper triangle and mirrors it,
        // so s_ij == s_ji bitwise — for similarities and distances alike
        let data = rand_data(61, 9, 7);
        for k in [
            DenseKernel::from_data(&data, Metric::Cosine),
            DenseKernel::distances_from_data(&data),
        ] {
            for i in 0..61 {
                for j in 0..61 {
                    assert_eq!(k.get(i, j).to_bits(), k.get(j, i).to_bits(), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn symmetric_build_matches_rect_path() {
        // same math as the two-argument (rectangular) builder
        let data = rand_data(33, 6, 8);
        let copy = data.clone();
        let sym = build_pairwise(&data, &data, Metric::Rbf { gamma: 0.7 }, false);
        let rect = build_pairwise(&data, &copy, Metric::Rbf { gamma: 0.7 }, false);
        for i in 0..33 {
            for j in 0..33 {
                assert!((sym.get(i, j) - rect.get(i, j)).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    fn threaded_build_matches_single_row_math_large() {
        // Exercise the multi-chunk threading path (n > typical core count).
        let data = rand_data(97, 16, 3);
        let k = DenseKernel::from_data(&data, Metric::Rbf { gamma: 1.0 });
        for &(i, j) in &[(0, 96), (50, 51), (96, 0), (13, 77)] {
            let direct = Metric::Rbf { gamma: 1.0 }.similarity(data.row(i), data.row(j));
            assert!((k.get(i, j) - direct).abs() < 1e-4);
        }
    }
}

//! The AVX2+FMA backend: `std::arch` f32x8 intrinsics over the SoA view.
//!
//! Op-order spec (the golden replica in tests/backend_parity.rs pins
//! exactly this): the gram entry for column `j` is the sequential
//! *fused* multiply-add chain over features,
//!
//! ```text
//! g_j = fma(a_{d-1}, b_{j,d-1}, … fma(a_1, b_{j,1}, fma(a_0, b_{j,0}, 0)))
//! ```
//!
//! — one rounding per step (`vfmadd231ps` per lane). The main loop runs
//! 32 such chains at once (four f32x8 accumulators over 64-byte SoA
//! groups), the 8-wide loop one vector, and sub-vector tails fall back
//! to scalar `f32::mul_add` — which is the *same* correctly-rounded
//! fused operation, so every path produces identical bits. As with the
//! `wide` backend, per-column chains are independent of lane and block
//! position: `j0` anchors, tile schedules, pool widths and the
//! row-major fallback cannot change results.
//!
//! # Safety architecture
//!
//! This is the only module outside `runtime::pool` permitted to contain
//! `unsafe` (conformance linter, `unsafe-confined` whitelist), and the
//! linter additionally requires a `SAFETY:` justification on every
//! line that mentions it. The obligations are narrow:
//!
//! * **ISA availability** — [`Avx2`] instances are only reachable
//!   through `backend::avx2()`, which gates construction behind
//!   `is_x86_feature_detected!("avx2")` && `("fma")`, discharging the
//!   `#[target_feature]` precondition once per process.
//! * **Pointer bounds** — all loads/stores go through `loadu`/`storeu`
//!   (no alignment obligation; SoA alignment is purely a perf win) at
//!   offsets the drivers keep inside the padded SoA rows / the output
//!   slice, re-checked here with `debug_assert!` before each block.

// Intrinsic calls are `unsafe fn` on older toolchains but plain safe
// fns inside target_feature contexts on newer ones; the explicit
// `unsafe {}` blocks below (required by `deny(unsafe_op_in_unsafe_fn)`
// on the older compilers) would otherwise warn as redundant there.
#![allow(unused_unsafe)]

use std::arch::x86_64::{
    _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps,
};

use super::InnerKernel;
use crate::data::points::{PointView, SoaPoints};
use crate::kernel::metric::Metric;

/// Lanes per AVX2 vector.
const LANES: usize = 8;
/// Vectors per main-loop block (4 × 8 lanes = 32 columns).
const GROUPS: usize = 4;

/// The x86_64 intrinsics backend (`name() == "avx2"`). The private
/// field makes [`AVX2`] the only instance, so the type is unreachable
/// except through `backend::avx2()`'s CPU feature detection — that
/// gate is what discharges the `target_feature` obligation in the safe
/// `fill_row` below.
pub struct Avx2 {
    _private: (),
}

/// The singleton `backend::avx2()` hands out after detection succeeds.
pub(super) static AVX2: Avx2 = Avx2 { _private: () };

impl InnerKernel for Avx2 {
    fn name(&self) -> &'static str {
        "avx2"
    }

    fn wants_soa(&self) -> bool {
        true
    }

    fn fill_row(
        &self,
        arow: &[f32],
        sq_ai: f32,
        b: &PointView<'_>,
        sq_b: &[f32],
        j0: usize,
        metric: Metric,
        distances: bool,
        orow: &mut [f32],
    ) {
        // SAFETY: `Avx2` is only handed out by `backend::avx2()` after
        // `is_x86_feature_detected!` confirmed avx2+fma, so the
        // target_feature precondition of `fill_row_avx2` holds.
        unsafe { fill_row_avx2(arow, sq_ai, b, sq_b, j0, metric, distances, orow) }
    }
}

/// One gram entry via the scalar fused chain — `f32::mul_add` performs
/// the identical correctly-rounded operation as one `vfmadd` lane, so
/// tails and the row-major fallback match the vector loops bit for bit.
#[inline]
fn gram1_fused(arow: &[f32], brow: &[f32]) -> f32 {
    debug_assert_eq!(arow.len(), brow.len());
    let mut s = 0f32;
    for (&x, &y) in arow.iter().zip(brow.iter()) {
        s = x.mul_add(y, s);
    }
    s
}

/// Tail variant of [`gram1_fused`] reading the SoA view.
#[inline]
fn gram1_fused_soa(arow: &[f32], soa: &SoaPoints, j: usize) -> f32 {
    let mut s = 0f32;
    for (f, &x) in arow.iter().enumerate() {
        s = x.mul_add(soa.feature(f)[j], s);
    }
    s
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
// SAFETY: callers must ensure this CPU supports avx2 and fma (checked
// once at backend construction, see `backend::avx2()`).
unsafe fn fill_row_avx2(
    arow: &[f32],
    sq_ai: f32,
    b: &PointView<'_>,
    sq_b: &[f32],
    j0: usize,
    metric: Metric,
    distances: bool,
    orow: &mut [f32],
) {
    let n = b.rows();
    debug_assert_eq!(orow.len(), n - j0);
    let soa = match b.soa() {
        Some(soa) => soa,
        None => {
            // Row-major fallback (driver supplied no SoA view): scalar
            // fused chains — identical bits to the vector loops.
            let m = b.mat();
            for jj in j0..n {
                let g = [gram1_fused(arow, m.row(jj))];
                metric.finalize_block(
                    distances,
                    sq_ai,
                    &sq_b[jj..jj + 1],
                    &g,
                    &mut orow[jj - j0..jj - j0 + 1],
                );
            }
            return;
        }
    };
    debug_assert_eq!(arow.len(), soa.dim());
    let mut gram = [0f32; GROUPS * LANES];
    let mut j = j0;
    while j + GROUPS * LANES <= n {
        // SAFETY: j + 32 <= n <= stride of every padded feature row, so
        // all loads in `gram32` stay in-bounds; avx2+fma hold here.
        unsafe { gram32(arow, soa, j, &mut gram) };
        metric.finalize_block(
            distances,
            sq_ai,
            &sq_b[j..j + GROUPS * LANES],
            &gram,
            &mut orow[j - j0..j - j0 + GROUPS * LANES],
        );
        j += GROUPS * LANES;
    }
    while j + LANES <= n {
        // SAFETY: j + 8 <= n <= feature-row stride, so the loads in
        // `gram8` stay in-bounds; avx2+fma hold here.
        unsafe { gram8(arow, soa, j, &mut gram[..LANES]) };
        metric.finalize_block(
            distances,
            sq_ai,
            &sq_b[j..j + LANES],
            &gram[..LANES],
            &mut orow[j - j0..j - j0 + LANES],
        );
        j += LANES;
    }
    for jj in j..n {
        let g = [gram1_fused_soa(arow, soa, jj)];
        metric.finalize_block(
            distances,
            sq_ai,
            &sq_b[jj..jj + 1],
            &g,
            &mut orow[jj - j0..jj - j0 + 1],
        );
    }
}

/// 32 fused gram chains: four f32x8 accumulators swept over the SoA
/// feature rows, written to `out[..32]`.
#[inline]
#[target_feature(enable = "avx2,fma")]
// SAFETY: callers must ensure avx2+fma are available and that
// `j + 32 <= soa.stride()` so every load below is in-bounds.
unsafe fn gram32(arow: &[f32], soa: &SoaPoints, j: usize, out: &mut [f32]) {
    debug_assert!(out.len() >= GROUPS * LANES);
    // SAFETY: value-only intrinsics; avx2 is enabled for this fn.
    let (mut a0, mut a1, mut a2, mut a3) = unsafe {
        (
            _mm256_setzero_ps(),
            _mm256_setzero_ps(),
            _mm256_setzero_ps(),
            _mm256_setzero_ps(),
        )
    };
    for (f, &x) in arow.iter().enumerate() {
        let col = soa.feature(f);
        debug_assert!(j + GROUPS * LANES <= col.len());
        let p = col.as_ptr();
        // SAFETY: j + 32 <= col.len() (caller contract, re-asserted
        // above), so the four 8-float loads read inside `col`; fma is
        // enabled for this fn.
        unsafe {
            let xv = _mm256_set1_ps(x);
            a0 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p.add(j)), a0);
            a1 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p.add(j + LANES)), a1);
            a2 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p.add(j + 2 * LANES)), a2);
            a3 = _mm256_fmadd_ps(xv, _mm256_loadu_ps(p.add(j + 3 * LANES)), a3);
        }
    }
    let o = out.as_mut_ptr();
    // SAFETY: out.len() >= 32 (asserted above), so the four 8-float
    // stores cover exactly out[..32].
    unsafe {
        _mm256_storeu_ps(o, a0);
        _mm256_storeu_ps(o.add(LANES), a1);
        _mm256_storeu_ps(o.add(2 * LANES), a2);
        _mm256_storeu_ps(o.add(3 * LANES), a3);
    }
}

/// 8 fused gram chains: one f32x8 accumulator, written to `out[..8]`.
#[inline]
#[target_feature(enable = "avx2,fma")]
// SAFETY: callers must ensure avx2+fma are available and that
// `j + 8 <= soa.stride()` so every load below is in-bounds.
unsafe fn gram8(arow: &[f32], soa: &SoaPoints, j: usize, out: &mut [f32]) {
    debug_assert!(out.len() >= LANES);
    // SAFETY: value-only intrinsic; avx2 is enabled for this fn.
    let mut acc = unsafe { _mm256_setzero_ps() };
    for (f, &x) in arow.iter().enumerate() {
        let col = soa.feature(f);
        debug_assert!(j + LANES <= col.len());
        // SAFETY: j + 8 <= col.len() (caller contract, re-asserted
        // above), so the 8-float load reads inside `col`; fma is
        // enabled for this fn.
        unsafe {
            acc = _mm256_fmadd_ps(_mm256_set1_ps(x), _mm256_loadu_ps(col.as_ptr().add(j)), acc);
        }
    }
    // SAFETY: out.len() >= 8 (asserted above), so the 8-float store
    // covers exactly out[..8].
    unsafe { _mm256_storeu_ps(out.as_mut_ptr(), acc) };
}

//! The portable 8-lane backend: safe Rust the compiler auto-vectorizes.
//!
//! Op-order spec (the golden replica in tests/backend_parity.rs pins
//! exactly this): the gram entry for column `j` is the sequential
//! multiply-then-add chain over features,
//!
//! ```text
//! g_j = (((0 + a_0·b_{j,0}) + a_1·b_{j,1}) + … + a_{d-1}·b_{j,d-1})
//! ```
//!
//! with one rounding per multiply and one per add (never fused — Rust
//! only emits FMA contraction when asked). The vectorized main loop
//! computes eight such chains side by side from the SoA feature rows;
//! because each column's chain is independent of its lane and block
//! position, tails, `j0` anchors and the row-major fallback (used when
//! the driver supplied no SoA view) all produce identical bits. That
//! position-independence is what makes this backend bit-stable across
//! pool widths and tile schedules.
//!
//! The inner loop is written as a fixed-size accumulator array updated
//! lane-by-lane — the canonical shape LLVM turns into vector FMA-free
//! mul+add on any target with 128/256-bit registers (NEON, SSE2, AVX),
//! while staying 100% safe, deterministic scalar semantics.

use super::InnerKernel;
use crate::data::points::{PointView, SoaPoints};
use crate::kernel::metric::Metric;

/// Lanes per vectorized group.
const LANES: usize = 8;

/// The always-available portable SIMD backend (`name() == "wide"`).
pub struct Wide;

/// One gram entry from the row-major operand: the sequential
/// multiply-then-add chain over features.
#[inline]
fn gram1_row(arow: &[f32], brow: &[f32]) -> f32 {
    debug_assert_eq!(arow.len(), brow.len());
    let mut s = 0f32;
    for (&x, &y) in arow.iter().zip(brow.iter()) {
        s += x * y;
    }
    s
}

/// One gram entry from the SoA operand — same chain, same bits, just a
/// strided walk (used only for sub-vector tails).
#[inline]
fn gram1_soa(arow: &[f32], soa: &SoaPoints, j: usize) -> f32 {
    let mut s = 0f32;
    for (f, &x) in arow.iter().enumerate() {
        s += x * soa.feature(f)[j];
    }
    s
}

impl InnerKernel for Wide {
    fn name(&self) -> &'static str {
        "wide"
    }

    fn wants_soa(&self) -> bool {
        true
    }

    fn fill_row(
        &self,
        arow: &[f32],
        sq_ai: f32,
        b: &PointView<'_>,
        sq_b: &[f32],
        j0: usize,
        metric: Metric,
        distances: bool,
        orow: &mut [f32],
    ) {
        let n = b.rows();
        debug_assert_eq!(orow.len(), n - j0);
        let soa = match b.soa() {
            Some(soa) => soa,
            None => {
                // Row-major fallback: per-column chains, identical bits.
                let m = b.mat();
                for jj in j0..n {
                    let g = [gram1_row(arow, m.row(jj))];
                    metric.finalize_block(
                        distances,
                        sq_ai,
                        &sq_b[jj..jj + 1],
                        &g,
                        &mut orow[jj - j0..jj - j0 + 1],
                    );
                }
                return;
            }
        };
        debug_assert_eq!(arow.len(), soa.dim());
        let mut j = j0;
        while j + LANES <= n {
            let mut acc = [0f32; LANES];
            for (f, &x) in arow.iter().enumerate() {
                let col = &soa.feature(f)[j..j + LANES];
                for l in 0..LANES {
                    acc[l] += x * col[l];
                }
            }
            metric.finalize_block(
                distances,
                sq_ai,
                &sq_b[j..j + LANES],
                &acc,
                &mut orow[j - j0..j - j0 + LANES],
            );
            j += LANES;
        }
        for jj in j..n {
            let g = [gram1_soa(arow, soa, jj)];
            metric.finalize_block(
                distances,
                sq_ai,
                &sq_b[jj..jj + 1],
                &g,
                &mut orow[jj - j0..jj - j0 + 1],
            );
        }
    }
}

//! The reference backend: the pre-refactor register-blocked scalar
//! kernels, verbatim.
//!
//! One gram row is produced in `j0`-anchored phases — 8-wide blocks
//! through [`linalg::dot8`], then a 4-wide block through
//! [`linalg::dot4`], then a scalar tail through [`linalg::dot`] — the
//! exact op order `tile::fill_row` used before the backend layer
//! existed. That makes this backend the anchor of the repo's
//! determinism contracts: CSR goldens, bench baselines and the
//! paper-behavior suites were all recorded against these bits, and
//! `SUBMODLIB_BACKEND=scalar` must keep reproducing them byte for byte
//! (pinned by tests/backend_parity.rs against an in-test replica of the
//! old code).
//!
//! Because the phase boundaries are anchored at `j0`, this is the one
//! backend whose bits *do* depend on where a block starts — which is
//! why the symmetric and rect drivers must keep anchoring row `i` at
//! `j0 = i` and `j0 = 0` respectively (see `kernel::tile` docs).

use super::InnerKernel;
use crate::data::points::PointView;
use crate::kernel::metric::Metric;
use crate::linalg;

/// The always-available reference backend (`name() == "scalar"`).
pub struct Scalar;

impl InnerKernel for Scalar {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn wants_soa(&self) -> bool {
        false
    }

    fn fill_row(
        &self,
        arow: &[f32],
        sq_ai: f32,
        b: &PointView<'_>,
        sq_b: &[f32],
        j0: usize,
        metric: Metric,
        distances: bool,
        orow: &mut [f32],
    ) {
        let m = b.mat();
        let n = m.rows();
        debug_assert_eq!(orow.len(), n - j0);
        let mut j = j0;
        while j + 8 <= n {
            let g = linalg::dot8(
                arow,
                [
                    m.row(j),
                    m.row(j + 1),
                    m.row(j + 2),
                    m.row(j + 3),
                    m.row(j + 4),
                    m.row(j + 5),
                    m.row(j + 6),
                    m.row(j + 7),
                ],
            );
            metric.finalize_block(
                distances,
                sq_ai,
                &sq_b[j..j + 8],
                &g,
                &mut orow[j - j0..j - j0 + 8],
            );
            j += 8;
        }
        while j + 4 <= n {
            let g = linalg::dot4(arow, m.row(j), m.row(j + 1), m.row(j + 2), m.row(j + 3));
            metric.finalize_block(
                distances,
                sq_ai,
                &sq_b[j..j + 4],
                &g,
                &mut orow[j - j0..j - j0 + 4],
            );
            j += 4;
        }
        for jj in j..n {
            let g = [linalg::dot(arow, m.row(jj))];
            metric.finalize_block(
                distances,
                sq_ai,
                &sq_b[jj..jj + 1],
                &g,
                &mut orow[jj - j0..jj - j0 + 1],
            );
        }
    }
}

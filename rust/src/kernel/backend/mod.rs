//! Runtime-dispatched SIMD compute backends (ISSUE 9, ROADMAP item 2).
//!
//! Every flop in the library funnels through one inner kernel: given a
//! query point and a block of candidate points, produce one *gram row*
//! (dot products) and finalize it into similarities or distances. This
//! module turns that kernel into a pluggable trait, [`InnerKernel`],
//! with three implementations:
//!
//! * [`scalar`] — the pre-backend register-blocked path
//!   (`linalg::dot8`/`dot4`/`dot`), safe Rust, runs everywhere. This is
//!   the **reference backend**: it anchors the CSR contract and the
//!   bench baseline, and `SUBMODLIB_BACKEND=scalar` reproduces the
//!   pre-refactor kernels byte for byte (tests/backend_parity.rs).
//! * [`wide`] — a portable 8-lane backend in safe Rust: structure-of-
//!   arrays loads with a fixed-width accumulator array the compiler
//!   auto-vectorizes. The non-x86 auto-detect fallback.
//! * [`avx2`] (x86_64 only) — `std::arch` intrinsics, f32x8 FMA over
//!   the SoA view, dispatched only after `is_x86_feature_detected!`
//!   confirms `avx2` **and** `fma`. The only module outside
//!   `runtime::pool` allowed to contain `unsafe` (enforced by the
//!   conformance linter's `unsafe-confined` whitelist).
//!
//! # Selection
//!
//! The backend is selected **once per process** ([`active`]): the
//! `SUBMODLIB_BACKEND` env var (`scalar` | `wide` | `avx2`) wins;
//! otherwise auto-detect picks `avx2` when the CPU supports it and
//! `wide` elsewhere. Requesting `avx2` on a CPU without it is a hard
//! error, not a silent fallback — reproducibility scripts must not lie
//! about what ran.
//!
//! # Determinism contract (per-backend bit-pinning)
//!
//! The old promise — every build bit-identical to one scalar op order —
//! becomes a *per-backend* promise:
//!
//! * each backend is a pure function of its inputs: same data, same
//!   backend ⇒ same bits, at any pool width and any tile schedule.
//!   For the SIMD backends this holds because their per-column
//!   reduction chain (sequential over features) is independent of the
//!   column's position in a block, so tile boundaries, `j0` anchors and
//!   SoA-vs-row-major layout cannot change results. The scalar backend
//!   keeps its `j0`-anchored 8/4/1 block phases instead — that exact
//!   op order is the pre-refactor contract.
//! * squared norms ([`InnerKernel::sq_norms`]) and metric finalization
//!   (`Metric::finalize_block`) are deliberately **shared** (provided
//!   methods over `linalg::dot`), so backends can only disagree through
//!   gram rounding — which the ULP parity sweep bounds against scalar.
//! * cross-backend agreement is *parity*, not equality: ≤ 4 ULP on
//!   well-conditioned rows, analytic-interval containment otherwise
//!   (tests/backend_parity.rs). Non-finite classification is pinned
//!   *per backend* (to its golden replica), not across backends: a
//!   fused chain computing `fma(x, y, +∞)` yields +∞ where the unfused
//!   chain's overflowed product makes ∞ − ∞ = NaN.

use std::sync::OnceLock;

use crate::data::points::PointView;
use crate::kernel::metric::Metric;
use crate::linalg::{self, Matrix};

#[cfg(target_arch = "x86_64")]
pub mod avx2;
pub mod scalar;
pub mod wide;

/// Env var naming the backend to use (`scalar` | `wide` | `avx2`).
/// Unset ⇒ auto-detect. Read once, at first kernel build.
pub const BACKEND_ENV: &str = "SUBMODLIB_BACKEND";

/// One inner compute kernel: gram row + metric finalization over a
/// block of candidate points, plus the (shared) squared-norm pass.
///
/// Implementations must be pure functions of their arguments — no
/// clocks, no global state — so kernel builds stay deterministic at
/// every pool width. Each implementation's exact op order is pinned by
/// a golden replica in tests/backend_parity.rs.
pub trait InnerKernel: Sync {
    /// Stable identifier (`"scalar"`, `"wide"`, `"avx2"`) — recorded in
    /// bench snapshots and accepted by [`BACKEND_ENV`].
    fn name(&self) -> &'static str;

    /// Whether the tile drivers should hand this backend an SoA
    /// transpose of the candidate set ([`PointView::new`]). Layout
    /// only: results are identical either way.
    fn wants_soa(&self) -> bool;

    /// Fill one gram row, finalized through `metric` (or raw euclidean
    /// distances when `distances`): `orow[j - j0] = f(⟨arow, b_j⟩)` for
    /// `j ∈ [j0, b.rows())`. `sq_b` is indexed by absolute `j`;
    /// `orow.len()` must equal `b.rows() - j0`.
    #[allow(clippy::too_many_arguments)]
    fn fill_row(
        &self,
        arow: &[f32],
        sq_ai: f32,
        b: &PointView<'_>,
        sq_b: &[f32],
        j0: usize,
        metric: Metric,
        distances: bool,
        orow: &mut [f32],
    );

    /// Squared norm of every row. Provided, and deliberately identical
    /// across backends: finalization inputs (cosine denominators, rbf
    /// exponents) must not vary per backend, so the parity story stays
    /// confined to gram rounding.
    fn sq_norms(&self, m: &Matrix) -> Vec<f32> {
        (0..m.rows()).map(|i| linalg::dot(m.row(i), m.row(i))).collect()
    }
}

static SCALAR: scalar::Scalar = scalar::Scalar;
static WIDE: wide::Wide = wide::Wide;

static ACTIVE: OnceLock<&'static dyn InnerKernel> = OnceLock::new();

/// The reference scalar backend (always available).
pub fn scalar() -> &'static dyn InnerKernel {
    &SCALAR
}

/// The portable 8-lane backend (always available).
pub fn wide() -> &'static dyn InnerKernel {
    &WIDE
}

/// The AVX2+FMA backend, iff this CPU supports it.
#[cfg(target_arch = "x86_64")]
pub fn avx2() -> Option<&'static dyn InnerKernel> {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        Some(&avx2::AVX2)
    } else {
        None
    }
}

/// The AVX2+FMA backend, iff this CPU supports it.
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2() -> Option<&'static dyn InnerKernel> {
    None
}

/// Every backend runnable on this host, scalar first. The bench harness
/// sweeps this list so one run records all locally comparable kernels.
pub fn available() -> Vec<&'static dyn InnerKernel> {
    let mut out: Vec<&'static dyn InnerKernel> = vec![scalar(), wide()];
    if let Some(k) = avx2() {
        out.push(k);
    }
    out
}

/// Look up a backend by its [`InnerKernel::name`]. `None` when the name
/// is unknown *or* the backend cannot run on this CPU.
pub fn by_name(name: &str) -> Option<&'static dyn InnerKernel> {
    match name {
        "scalar" => Some(scalar()),
        "wide" => Some(wide()),
        "avx2" => avx2(),
        _ => None,
    }
}

/// Selection logic behind [`active`], split out so unit tests can
/// exercise it without mutating process environment.
fn resolve(spec: Option<&str>) -> &'static dyn InnerKernel {
    match spec {
        None => avx2().unwrap_or_else(wide),
        Some(name) => match by_name(name) {
            Some(k) => k,
            None => panic!(
                "{BACKEND_ENV}={name:?} is not available on this host \
                 (valid: scalar, wide{})",
                if cfg!(target_arch = "x86_64") { ", avx2 (CPU permitting)" } else { "" }
            ),
        },
    }
}

/// The process-wide backend: `SUBMODLIB_BACKEND` if set, else
/// auto-detect (avx2 where supported, wide elsewhere). Resolved once —
/// every kernel build in the process uses the same backend, so
/// mixed-backend artifacts cannot exist.
pub fn active() -> &'static dyn InnerKernel {
    *ACTIVE.get_or_init(|| {
        let spec = std::env::var(BACKEND_ENV).ok();
        resolve(spec.as_deref())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_round_trips_every_available_backend() {
        for k in available() {
            let again = by_name(k.name()).expect("available backend must resolve by name");
            assert_eq!(again.name(), k.name());
        }
    }

    #[test]
    fn scalar_and_wide_are_always_available() {
        let names: Vec<&str> = available().iter().map(|k| k.name()).collect();
        assert!(names.contains(&"scalar"));
        assert!(names.contains(&"wide"));
        assert_eq!(names[0], "scalar", "scalar is the reference and leads the list");
    }

    #[test]
    fn explicit_resolution_honours_the_request() {
        assert_eq!(resolve(Some("scalar")).name(), "scalar");
        assert_eq!(resolve(Some("wide")).name(), "wide");
        if avx2().is_some() {
            assert_eq!(resolve(Some("avx2")).name(), "avx2");
        }
    }

    #[test]
    fn auto_detection_prefers_simd() {
        let picked = resolve(None).name();
        match avx2() {
            Some(_) => assert_eq!(picked, "avx2"),
            None => assert_eq!(picked, "wide"),
        }
    }

    #[test]
    #[should_panic(expected = "is not available")]
    fn unknown_backend_name_is_a_hard_error() {
        resolve(Some("neon"));
    }

    #[test]
    fn active_is_one_of_the_available_backends() {
        let name = active().name();
        assert!(
            available().iter().any(|k| k.name() == name),
            "active backend {name:?} must be runnable here"
        );
    }

    #[test]
    fn sq_norms_are_backend_independent() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::new(11);
        let m = Matrix::from_vec(13, 5, (0..65).map(|_| rng.next_gaussian() as f32).collect())
            .unwrap();
        let reference: Vec<u32> =
            scalar().sq_norms(&m).into_iter().map(f32::to_bits).collect();
        for k in available() {
            let got: Vec<u32> = k.sq_norms(&m).into_iter().map(f32::to_bits).collect();
            assert_eq!(got, reference, "sq_norms must be shared verbatim ({})", k.name());
        }
    }
}

//! Similarity metrics, matching `python/compile/kernels/ref.py` exactly so
//! the native and PJRT kernel-construction paths are interchangeable.

use crate::linalg;

const EPS: f32 = 1e-12;

/// Similarity metric between feature vectors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Metric {
    /// `1 / (1 + ||x − y||)` — Submodlib's euclidean-similarity convention.
    Euclidean,
    /// Cosine similarity.
    Cosine,
    /// Raw inner product.
    Dot,
    /// `exp(−γ ||x − y||²)`.
    Rbf { gamma: f32 },
}

impl Metric {
    /// Artifact-name tag (must match aot.py's entry naming).
    pub fn tag(&self) -> &'static str {
        match self {
            Metric::Euclidean => "euclidean",
            Metric::Cosine => "cosine",
            Metric::Dot => "dot",
            Metric::Rbf { .. } => "rbf",
        }
    }

    /// Direct pairwise similarity.
    pub fn similarity(&self, a: &[f32], b: &[f32]) -> f32 {
        match *self {
            Metric::Dot => linalg::dot(a, b),
            Metric::Cosine => {
                let na = linalg::norm(a);
                let nb = linalg::norm(b);
                linalg::dot(a, b) / (na * nb).max(EPS)
            }
            Metric::Euclidean => 1.0 / (1.0 + linalg::sq_dist(a, b).max(0.0).sqrt()),
            Metric::Rbf { gamma } => (-gamma * linalg::sq_dist(a, b).max(0.0)).exp(),
        }
    }

    /// Transform a gram entry `g = <x_i, y_j>` into a similarity, given the
    /// squared norms of the two vectors (the gram-expansion fast path used
    /// by the blocked builders; mirrors model.similarity_block).
    #[inline]
    pub fn from_gram(&self, g: f32, sq_ni: f32, sq_nj: f32) -> f32 {
        match *self {
            Metric::Dot => g,
            Metric::Cosine => g / (sq_ni.sqrt() * sq_nj.sqrt()).max(EPS),
            Metric::Euclidean => {
                let d2 = (sq_ni + sq_nj - 2.0 * g).max(0.0);
                1.0 / (1.0 + d2.sqrt())
            }
            Metric::Rbf { gamma } => {
                let d2 = (sq_ni + sq_nj - 2.0 * g).max(0.0);
                (-gamma * d2).exp()
            }
        }
    }

    /// Euclidean distance (for the disparity functions, which work with
    /// distances rather than similarities).
    pub fn distance(a: &[f32], b: &[f32]) -> f32 {
        linalg::sq_dist(a, b).max(0.0).sqrt()
    }

    /// Finalize a block of gram entries into `out`: similarities via
    /// [`from_gram`](Self::from_gram), or raw euclidean distances when
    /// `distances` (the disparity-function path:
    /// `sqrt(max(sq_ai + sq_bj − 2g, 0))`).
    ///
    /// This is the **shared** finalization stage of the compute-backend
    /// contract (`kernel::backend`): every backend must funnel its gram
    /// bits through this exact element expression, so backends can only
    /// differ in gram rounding — never in how a gram value becomes a
    /// similarity. `gram`, `sq_bj` and `out` are indexed identically.
    #[inline]
    pub fn finalize_block(
        &self,
        distances: bool,
        sq_ai: f32,
        sq_bj: &[f32],
        gram: &[f32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(gram.len(), out.len());
        debug_assert_eq!(sq_bj.len(), out.len());
        for t in 0..out.len() {
            out[t] = if distances {
                (sq_ai + sq_bj[t] - 2.0 * gram[t]).max(0.0).sqrt()
            } else {
                self.from_gram(gram[t], sq_ai, sq_bj[t])
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn euclidean_self_is_one() {
        let a = [1.0f32, 2.0, 3.0];
        assert!((Metric::Euclidean.similarity(&a, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_range() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        let c = [-1.0f32, 0.0];
        assert!(Metric::Cosine.similarity(&a, &b).abs() < 1e-6);
        assert!((Metric::Cosine.similarity(&a, &c) + 1.0).abs() < 1e-6);
        assert!((Metric::Cosine.similarity(&a, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rbf_decays() {
        let a = [0.0f32; 4];
        let b = [1.0f32; 4];
        let m = Metric::Rbf { gamma: 1.0 };
        assert!((m.similarity(&a, &a) - 1.0).abs() < 1e-6);
        assert!((m.similarity(&a, &b) - (-4.0f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn from_gram_matches_direct() {
        let a = [0.3f32, -1.2, 0.7, 2.0];
        let b = [1.1f32, 0.4, -0.5, 0.9];
        let g = crate::linalg::dot(&a, &b);
        let (na, nb) = (crate::linalg::dot(&a, &a), crate::linalg::dot(&b, &b));
        for m in [
            Metric::Euclidean,
            Metric::Cosine,
            Metric::Dot,
            Metric::Rbf { gamma: 0.5 },
        ] {
            let direct = m.similarity(&a, &b);
            let via = m.from_gram(g, na, nb);
            assert!((direct - via).abs() < 1e-5, "{m:?}: {direct} vs {via}");
        }
    }

    #[test]
    fn distance_basic() {
        assert!((Metric::distance(&[0.0, 0.0], &[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}

//! Sparse k-nearest-neighbor kernel (paper mode `"sparse"`, §8):
//! similarity with points beyond `num_neighbors` is treated as zero.
//! Stored CSR; rows sorted by column id for O(log k) lookup.
//!
//! As in Submodlib (following Wei, Iyer, Bilmes 2014 "Fast multi-stage
//! submodular maximization", cited in paper §2.1.1), this trades accuracy
//! for memory/time on large ground sets.
//!
//! Construction streams through the symmetric wavefront of the tile
//! pipeline (`tile::stream_symmetric_tiles`): only upper-triangle wedge
//! tiles are computed — each unordered pair exactly once, the same 2×
//! dot-product saving the dense symmetric path keeps — and every
//! computed (i, j) value is delivered to *both* row i's and row j's
//! top-k accumulator, so `s_ij == s_ji` holds bitwise by construction.
//! Every stored value is bit-identical to the dense kernel built from
//! the same data *within whichever compute backend is active*
//! (`kernel::backend`): the scalar backend needs the wedge's `j0 = i`
//! block-phase anchoring to match the dense symmetric path, while the
//! SIMD backends are position-independent and match everywhere. The
//! scalar backend anchors the CSR golden contract. Peak memory is
//! O(threads·TILE_ROWS·n + n·k + n·d) — see `tile::sparse_peak_bytes`.
//!
//! ## CSR contract: tie-stable top-k
//!
//! Tile arrival order is unspecified, so per-row selection must not
//! depend on it. Each row keeps the k entries *maximal under the strict
//! total order `(value desc via total_cmp, column asc)`* — strict
//! because a row never sees the same column twice — which makes the
//! surviving set unique regardless of delivery order (and therefore
//! bit-identical across thread counts and to a serial
//! materialize-upper-triangle-then-select reference). Survivors are
//! stored sorted by column id. `total_cmp` also pins non-finite values:
//! −∞ loses to every finite value, +∞ wins, and a NaN similarity ranks
//! above +∞ (positive NaN) or below −∞ (negative NaN) — an upstream
//! data bug surfaces deterministically in the neighbor list instead of
//! scrambling the selection.

use std::sync::Mutex;

use super::metric::Metric;
use super::tile::{self, Tile, TriTile};
use crate::error::{Result, SubmodError};
use crate::linalg::Matrix;

/// CSR kNN similarity kernel.
#[derive(Debug, Clone)]
pub struct SparseKernel {
    n: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f32>,
}

/// Rows per accumulator lock. One lock covers the same row span as a
/// full-width tile; workers batch a whole wedge's deliveries per lock
/// acquisition, so lock traffic is O(tiles · n / SHARD_ROWS).
const SHARD_ROWS: usize = tile::TILE_ROWS;

/// Debug-only contention statistics for the wavefront's shard locks
/// (delivery waits vs. acquisitions), grounding the ROADMAP "per-worker
/// partial accumulators" open item in data before anyone builds it. In
/// release builds the counters are compiled out of the hot path
/// entirely ([`stats`](shard_contention::stats) returns `None`); in
/// debug builds `deliver_wedge` counts every lock acquisition and every
/// acquisition that had to wait (`try_lock` would have blocked).
/// Surfaced two ways: the debug-only contention test prints the tallies
/// on every tier-1 `cargo test` run (the practical data source, since
/// tier-1 is a debug build), and the bench harness's `pool` section
/// records them (`null` there in practice — benches are release
/// builds). Resettable for targeted measurements. Counters are
/// process-global and cumulative — concurrent builds add into the same
/// tallies.
pub mod shard_contention {
    use std::sync::atomic::{AtomicU64, Ordering};

    static ACQUISITIONS: AtomicU64 = AtomicU64::new(0);
    static WAITS: AtomicU64 = AtomicU64::new(0);

    #[cfg(debug_assertions)]
    pub(super) fn record(waited: bool) {
        ACQUISITIONS.fetch_add(1, Ordering::Relaxed);
        if waited {
            WAITS.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Zero both counters (e.g. right before a measured build).
    pub fn reset() {
        ACQUISITIONS.store(0, Ordering::Relaxed);
        WAITS.store(0, Ordering::Relaxed);
    }

    /// `(acquisitions, waits)` since the last [`reset`], or `None` in
    /// release builds where the instrumentation is compiled out.
    pub fn stats() -> Option<(u64, u64)> {
        if cfg!(debug_assertions) {
            Some((ACQUISITIONS.load(Ordering::Relaxed), WAITS.load(Ordering::Relaxed)))
        } else {
            None
        }
    }
}

/// `(value desc via total_cmp, column asc)` — the CSR contract's strict
/// total order (see module docs). `a` beats `b` iff it must be kept in
/// preference to it.
#[inline]
fn better(val: f32, col: u32, than_val: f32, than_col: u32) -> bool {
    match val.total_cmp(&than_val) {
        std::cmp::Ordering::Greater => true,
        std::cmp::Ordering::Less => false,
        std::cmp::Ordering::Equal => col < than_col,
    }
}

/// One lock's worth of per-row bounded top-k accumulators, building
/// directly in the CSR output slices (a contiguous row range of the
/// kernel, `k` slots per row). Keeping the k best of a stream
/// under a strict total order is order-independent: the kept set after
/// any prefix is exactly the k maximal entries seen, whatever the
/// arrival order — which is what makes the parallel build deterministic.
struct RowShard<'a> {
    cols: &'a mut [u32],
    vals: &'a mut [f32],
    /// Slots filled so far, per row.
    fill: Vec<u32>,
    /// Index (within the row's k slots) of the current worst survivor —
    /// meaningful only once the row is full.
    worst: Vec<u32>,
}

impl<'a> RowShard<'a> {
    fn new(cols: &'a mut [u32], vals: &'a mut [f32], rows: usize) -> RowShard<'a> {
        RowShard { cols, vals, fill: vec![0; rows], worst: vec![0; rows] }
    }

    /// Offer `(col, val)` to local row `r`'s top-k.
    #[inline]
    fn push(&mut self, r: usize, col: u32, val: f32, k: usize) {
        let base = r * k;
        let fill = self.fill[r] as usize;
        if fill < k {
            self.cols[base + fill] = col;
            self.vals[base + fill] = val;
            self.fill[r] = (fill + 1) as u32;
            if fill + 1 == k {
                self.worst[r] = self.scan_worst(base, k);
            }
        } else {
            let w = base + self.worst[r] as usize;
            if better(val, col, self.vals[w], self.cols[w]) {
                self.vals[w] = val;
                self.cols[w] = col;
                self.worst[r] = self.scan_worst(base, k);
            }
        }
    }

    /// Index of the minimal entry among a full row's k slots.
    fn scan_worst(&self, base: usize, k: usize) -> u32 {
        let mut w = 0usize;
        for t in 1..k {
            if better(
                self.vals[base + w],
                self.cols[base + w],
                self.vals[base + t],
                self.cols[base + t],
            ) {
                w = t;
            }
        }
        w as u32
    }
}

impl SparseKernel {
    /// Build from a feature matrix keeping the `k` most similar neighbors
    /// per row (the row's own diagonal entry always counts as one of them,
    /// matching Submodlib's `num_neighbors` semantics).
    ///
    /// Symmetric wavefront build: streams upper-triangle wedge tiles
    /// (each (i, j) pair computed exactly once) and delivers every value
    /// to both endpoints' accumulators, which keep their k maximal
    /// entries under the tie-stable total order of the CSR contract (see
    /// module docs) directly in the preallocated CSR arrays — no n×n
    /// materialization, no reassembly sort beyond the final per-row
    /// order-by-column. Output is bit-identical across thread counts.
    pub fn from_data(data: &Matrix, metric: Metric, k: usize) -> Result<Self> {
        let n = data.rows();
        if k == 0 || k > n {
            return Err(SubmodError::InvalidParam(format!(
                "num_neighbors {k} for ground set of {n}"
            )));
        }
        let mut col_idx = vec![0u32; n * k];
        let mut vals = vec![0f32; n * k];
        {
            // sharded row-range accumulators over disjoint CSR slices
            let shard_count = n.div_ceil(SHARD_ROWS);
            let mut shards: Vec<Mutex<RowShard<'_>>> = Vec::with_capacity(shard_count);
            let mut rest_c = col_idx.as_mut_slice();
            let mut rest_v = vals.as_mut_slice();
            for s in 0..shard_count {
                let rows = SHARD_ROWS.min(n - s * SHARD_ROWS);
                let (c, tail_c) = rest_c.split_at_mut(rows * k);
                let (v, tail_v) = rest_v.split_at_mut(rows * k);
                shards.push(Mutex::new(RowShard::new(c, v, rows)));
                rest_c = tail_c;
                rest_v = tail_v;
            }
            tile::stream_symmetric_tiles(data, metric, false, &|t: TriTile<'_>| {
                deliver_wedge(&t, &shards, k)
            });
            // every row saw all n columns (n ≥ k), so every accumulator
            // is full; finish by sorting survivors into column order
            // (the CSR lookup contract)
            let mut scratch: Vec<(u32, f32)> = Vec::with_capacity(k);
            for shard in shards {
                let mut sh = shard.into_inner().unwrap();
                debug_assert!(sh.fill.iter().all(|&f| f as usize == k));
                for r in 0..sh.fill.len() {
                    let base = r * k;
                    scratch.clear();
                    scratch.extend(
                        sh.cols[base..base + k]
                            .iter()
                            .copied()
                            .zip(sh.vals[base..base + k].iter().copied()),
                    );
                    scratch.sort_unstable_by_key(|e| e.0);
                    for (t, &(c, v)) in scratch.iter().enumerate() {
                        sh.cols[base + t] = c;
                        sh.vals[base + t] = v;
                    }
                }
            }
        }
        let row_ptr = (0..=n).map(|i| i * k).collect();
        Ok(SparseKernel { n, row_ptr, col_idx, vals })
    }

    /// Full-width streaming build — the pre-wavefront algorithm, kept as
    /// the measurable baseline [`Self::from_data`]'s ~2× is benchmarked
    /// against (`benches/optimizers.rs`). Each row is computed
    /// independently over all n columns through `tile::stream_tiles`, so
    /// it does twice the dot work, and its values are anchored at column
    /// 0 — they can differ from the symmetric build's by an ulp. The
    /// top-k order is the same CSR contract.
    pub fn from_data_full_width(data: &Matrix, metric: Metric, k: usize) -> Result<Self> {
        let n = data.rows();
        if k == 0 || k > n {
            return Err(SubmodError::InvalidParam(format!(
                "num_neighbors {k} for ground set of {n}"
            )));
        }
        let mut col_idx = vec![0u32; n * k];
        let mut vals = vec![0f32; n * k];
        // per-tile output slices, indexed by row_start / TILE_ROWS (the
        // tile partition is part of stream_tiles' contract)
        let tile_count = n.div_ceil(tile::TILE_ROWS);
        let mut slots: Vec<Option<(&mut [u32], &mut [f32])>> =
            Vec::with_capacity(tile_count);
        {
            let mut rest_c = col_idx.as_mut_slice();
            let mut rest_v = vals.as_mut_slice();
            for t in 0..tile_count {
                let rows = tile::TILE_ROWS.min(n - t * tile::TILE_ROWS);
                let (c, tail_c) = rest_c.split_at_mut(rows * k);
                let (v, tail_v) = rest_v.split_at_mut(rows * k);
                slots.push(Some((c, v)));
                rest_c = tail_c;
                rest_v = tail_v;
            }
        }
        let slots = Mutex::new(slots);
        // reusable top-k scratch, recycled across tiles (at most one live
        // per worker)
        let scratch_pool: Mutex<Vec<Vec<(u32, f32)>>> = Mutex::new(Vec::new());
        tile::stream_tiles(data, data, metric, false, &|t: Tile<'_>| {
            let (cols_out, vals_out) = {
                let mut guard = slots.lock().unwrap();
                guard[t.row_start / tile::TILE_ROWS]
                    .take()
                    .expect("each tile is delivered exactly once")
            };
            let mut scratch =
                scratch_pool.lock().unwrap().pop().unwrap_or_default();
            for (bi, row) in t.data.chunks_exact(t.cols).enumerate() {
                select_row_topk(
                    row,
                    k,
                    &mut scratch,
                    &mut cols_out[bi * k..(bi + 1) * k],
                    &mut vals_out[bi * k..(bi + 1) * k],
                );
            }
            scratch_pool.lock().unwrap().push(scratch);
        });
        // the slot table borrows col_idx/vals; release it before moving them
        drop(slots);
        let row_ptr = (0..=n).map(|i| i * k).collect();
        Ok(SparseKernel { n, row_ptr, col_idx, vals })
    }

    /// Build from precomputed dense rows (the materialize-then-select
    /// reference the streaming builds are tested against, and the direct
    /// path for callers that already hold a dense kernel). Same top-k
    /// order as the streaming builds, so feeding it the *symmetric*
    /// dense kernel's rows reproduces [`Self::from_data`] bit-for-bit.
    pub(crate) fn from_dense_rows<'a, F>(n: usize, k: usize, row: F) -> Self
    where
        F: Fn(usize) -> &'a [f32],
    {
        let mut col_idx = vec![0u32; n * k];
        let mut vals = vec![0f32; n * k];
        let mut scratch: Vec<(u32, f32)> = Vec::with_capacity(n);
        for i in 0..n {
            select_row_topk(
                row(i),
                k,
                &mut scratch,
                &mut col_idx[i * k..(i + 1) * k],
                &mut vals[i * k..(i + 1) * k],
            );
        }
        let row_ptr = (0..=n).map(|i| i * k).collect();
        SparseKernel { n, row_ptr, col_idx, vals }
    }

    /// Ground-set size.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored neighbors per row.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Similarity s_ij — zero when j is outside i's neighbor list.
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(pos) => vals[pos],
            Err(_) => 0.0,
        }
    }

    /// Row i as parallel (columns, values) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.vals[s..e])
    }
}

/// Deliver one upper-triangle wedge to every accumulator shard it
/// touches: value (i, j) goes to row i (as column j) *and* to row j (as
/// column i) — the same f32 both times, which is what makes the kernel
/// symmetric by construction. Shards are visited one at a time (never
/// nested, so no lock-order concerns), with all of a wedge's pushes into
/// a shard batched under one acquisition.
fn deliver_wedge(t: &TriTile<'_>, shards: &[Mutex<RowShard<'_>>], k: usize) {
    let n = t.cols;
    let r0 = t.row_start;
    for (s, shard) in shards.iter().enumerate().skip(r0 / SHARD_ROWS) {
        let c0 = s * SHARD_ROWS;
        let c1 = (c0 + SHARD_ROWS).min(n);
        // debug builds tally acquisitions and would-block waits (see
        // `shard_contention`); release builds take the lock directly so
        // the hot path is unchanged
        #[cfg(debug_assertions)]
        let mut guard = match shard.try_lock() {
            Ok(g) => {
                shard_contention::record(false);
                g
            }
            Err(std::sync::TryLockError::WouldBlock) => {
                shard_contention::record(true);
                shard.lock().unwrap()
            }
            Err(std::sync::TryLockError::Poisoned(e)) => panic!("shard lock poisoned: {e}"),
        };
        #[cfg(not(debug_assertions))]
        let mut guard = shard.lock().unwrap();
        // rows at or past this shard's end contribute nothing to it:
        // their columns all sit at j ≥ i ≥ c1
        for bi in 0..t.rows.min(c1 - r0) {
            let i = r0 + bi;
            let row = t.row(bi); // columns [i, n)
            if i >= c0 {
                // row side: all of row i's wedge lands in its own shard
                for (off, &v) in row.iter().enumerate() {
                    guard.push(i - c0, (i + off) as u32, v, k);
                }
            }
            // column side: s_ji == s_ij for this shard's rows j > i
            for j in (i + 1).max(c0)..c1 {
                guard.push(j - c0, i as u32, row[j - i], k);
            }
        }
    }
}

/// Select the k largest entries of `row` under the CSR contract's order
/// (`value desc via total_cmp`, ties by ascending column) and write them
/// to `cols_out`/`vals_out` (length exactly `k`) sorted by column id.
/// Single source of truth for the materialize-then-select semantics: the
/// full-width build and the dense-rows reference both call this, and the
/// wavefront build's accumulators keep the identical set.
fn select_row_topk(
    row: &[f32],
    k: usize,
    scratch: &mut Vec<(u32, f32)>,
    cols_out: &mut [u32],
    vals_out: &mut [f32],
) {
    debug_assert_eq!(cols_out.len(), k);
    debug_assert_eq!(vals_out.len(), k);
    scratch.clear();
    scratch.extend(row.iter().enumerate().map(|(j, &s)| (j as u32, s)));
    // Partial select of the k maximal entries. The comparator is the
    // CSR contract's strict total order — total_cmp then column id — so
    // the selected set is unique even under heavy value ties and
    // non-finite similarities (see module docs for the NaN semantics).
    scratch.select_nth_unstable_by(k - 1, |a, b| {
        b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0))
    });
    let top = &mut scratch[..k];
    top.sort_unstable_by_key(|e| e.0);
    for (t, &(j, s)) in top.iter().enumerate() {
        cols_out[t] = j;
        vals_out[t] = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect())
            .unwrap()
    }

    #[test]
    fn keeps_k_per_row() {
        let data = rand_data(20, 4, 1);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 5).unwrap();
        assert_eq!(k.nnz(), 20 * 5);
        for i in 0..20 {
            let (cols, _) = k.row(i);
            assert_eq!(cols.len(), 5);
        }
    }

    #[test]
    fn self_neighbor_retained() {
        // With euclidean similarity the diagonal is the max (=1), so it
        // must always be among the top-k.
        let data = rand_data(15, 3, 2);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 3).unwrap();
        for i in 0..15 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-5, "row {i} missing diagonal");
        }
    }

    #[test]
    fn topk_values_match_dense() {
        let data = rand_data(12, 4, 3);
        let dense = crate::kernel::DenseKernel::from_data(&data, Metric::Euclidean);
        let sparse = SparseKernel::from_data(&data, Metric::Euclidean, 4).unwrap();
        for i in 0..12 {
            let mut drow: Vec<(usize, f32)> =
                dense.row(i).iter().cloned().enumerate().collect();
            drow.sort_by(|a, b| b.1.total_cmp(&a.1));
            let expect: std::collections::HashSet<usize> =
                drow[..4].iter().map(|e| e.0).collect();
            let (cols, vals) = sparse.row(i);
            for (c, v) in cols.iter().zip(vals) {
                assert!(expect.contains(&(*c as usize)) || {
                    // ties at the cut boundary are acceptable either way
                    (drow[3].1 - v).abs() < 1e-6
                });
                assert!((dense.get(i, *c as usize) - v).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy build matrix; Miri covers the small suites below
    fn stored_pairs_symmetric_and_bit_equal_to_dense() {
        // the headline wavefront guarantees: every stored value is the
        // dense symmetric kernel's value bit-for-bit, and wherever both
        // endpoints keep the pair, the two stored values are identical
        let data = rand_data(90, 5, 7);
        for metric in
            [Metric::Euclidean, Metric::Cosine, Metric::Dot, Metric::Rbf { gamma: 0.8 }]
        {
            let dense = crate::kernel::DenseKernel::from_data(&data, metric);
            let sparse = SparseKernel::from_data(&data, metric, 6).unwrap();
            for i in 0..90 {
                let (cols, vals) = sparse.row(i);
                for (c, v) in cols.iter().zip(vals) {
                    let j = *c as usize;
                    assert_eq!(
                        v.to_bits(),
                        dense.get(i, j).to_bits(),
                        "{metric:?} ({i},{j}) vs dense"
                    );
                    let (jcols, jvals) = sparse.row(j);
                    if let Ok(pos) = jcols.binary_search(&(i as u32)) {
                        assert_eq!(
                            v.to_bits(),
                            jvals[pos].to_bits(),
                            "{metric:?} ({i},{j}) vs mirror"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn absent_entries_zero() {
        let data = rand_data(30, 4, 4);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 2).unwrap();
        let mut zeros = 0;
        for i in 0..30 {
            for j in 0..30 {
                if k.get(i, j) == 0.0 {
                    zeros += 1;
                }
            }
        }
        assert_eq!(zeros, 30 * 30 - k.nnz());
    }

    #[test]
    #[cfg_attr(miri, ignore)] // multi-wedge n > 2·TILE_ROWS is interpreter-prohibitive
    fn wavefront_matches_dense_rows_reference() {
        // the wavefront accumulators keep the k maximal entries of
        // exactly the rows the dense *symmetric* build materializes, so
        // feeding those rows to the serial dense-rows select must
        // reproduce the CSR bit-for-bit (n > TILE_ROWS exercises
        // multi-wedge scheduling; repeated builds pin order independence
        // across schedules)
        let data = rand_data(2 * tile::TILE_ROWS + 9, 6, 6);
        let n = data.rows();
        let dense = crate::kernel::DenseKernel::from_data(&data, Metric::Cosine);
        for k in [1usize, 3, 16, n] {
            let streamed = SparseKernel::from_data(&data, Metric::Cosine, k).unwrap();
            let again = SparseKernel::from_data(&data, Metric::Cosine, k).unwrap();
            let reference = SparseKernel::from_dense_rows(n, k, |i| dense.row(i));
            let bits =
                |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(streamed.row_ptr, reference.row_ptr, "k={k}");
            assert_eq!(streamed.col_idx, reference.col_idx, "k={k}");
            assert_eq!(bits(&streamed.vals), bits(&reference.vals), "k={k}");
            assert_eq!(streamed.col_idx, again.col_idx, "k={k} rebuild");
            assert_eq!(bits(&streamed.vals), bits(&again.vals), "k={k} rebuild");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)] // two full n=80 builds; covered natively by tier-1
    fn full_width_build_close_to_wavefront() {
        // the baseline build selects from column-0-anchored rows, which
        // may differ from the symmetric values by ulps — so neighbor
        // sets may legally differ only at sub-ulp ties; compare the
        // rank-ordered survivor values instead of exact membership
        let data = rand_data(80, 5, 8);
        let sym = SparseKernel::from_data(&data, Metric::Euclidean, 5).unwrap();
        let full = SparseKernel::from_data_full_width(&data, Metric::Euclidean, 5).unwrap();
        assert_eq!(sym.nnz(), full.nnz());
        for i in 0..80 {
            let mut svals = sym.row(i).1.to_vec();
            let mut fvals = full.row(i).1.to_vec();
            svals.sort_by(|a, b| b.total_cmp(a));
            fvals.sort_by(|a, b| b.total_cmp(a));
            for (a, b) in svals.iter().zip(&fvals) {
                assert!((a - b).abs() < 1e-5, "row {i}: {a} vs {b}");
            }
            // the diagonal (maximum under euclidean similarity) always
            // survives both builds
            assert!((sym.get(i, i) - 1.0).abs() < 1e-5);
            assert!((full.get(i, i) - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn topk_total_order_handles_nonfinite_rows() {
        // −∞ (a legal f32, e.g. from a degenerate log-space similarity)
        // must lose to every finite value under total_cmp; exact value
        // ties resolve by ascending column id (the CSR contract), so
        // even all-tied rows have a deterministic survivor set.
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, f32::NEG_INFINITY, 0.5, 0.75],
            vec![0.25, 0.25, 0.25, 0.25],
            vec![f32::NEG_INFINITY, f32::NEG_INFINITY, 2.0, 1.0],
            vec![0.0, -0.0, 3.0, -1.0],
        ];
        let k = SparseKernel::from_dense_rows(4, 2, |i| rows[i].as_slice());
        assert_eq!(k.nnz(), 8);
        let survivors = |i: usize| -> Vec<u32> { k.row(i).0.to_vec() };
        assert_eq!(survivors(0), vec![0, 3]); // 1.0 and 0.75
        assert_eq!(survivors(1), vec![0, 1]); // all tied: lowest columns win
        assert_eq!(survivors(2), vec![2, 3]); // the two finite entries
        assert_eq!(survivors(3), vec![0, 2]); // 3.0 and +0.0 (beats −0.0)
    }

    #[test]
    fn shard_accumulator_is_order_independent() {
        // feed the same entries to a RowShard in opposite orders: the
        // kept set must match (the tentpole's core invariant, isolated)
        let entries: Vec<(u32, f32)> = vec![
            (0, 0.5),
            (1, 0.5),
            (2, -1.0),
            (3, f32::NEG_INFINITY),
            (4, 2.0),
            (5, 0.5),
            (6, 0.25),
            (7, 2.0),
        ];
        let k = 3;
        let run = |order: &[(u32, f32)]| -> (Vec<u32>, Vec<f32>) {
            let mut cols = vec![0u32; k];
            let mut vals = vec![0f32; k];
            let mut shard = RowShard::new(&mut cols, &mut vals, 1);
            for &(c, v) in order {
                shard.push(0, c, v, k);
            }
            let mut pairs: Vec<(u32, f32)> =
                cols.iter().copied().zip(vals.iter().copied()).collect();
            pairs.sort_unstable_by_key(|e| e.0);
            (pairs.iter().map(|e| e.0).collect(), pairs.iter().map(|e| e.1).collect())
        };
        let fwd = run(&entries);
        let rev = run(&entries.iter().rev().copied().collect::<Vec<_>>());
        assert_eq!(fwd, rev);
        // 2.0@4, 2.0@7, then the 0.5 tie resolves to the lowest column
        assert_eq!(fwd.0, vec![0, 4, 7]);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // n = 3·TILE_ROWS build exists only to drive the lock counters
    fn contention_counters_surface_in_debug_builds() {
        // enough rows for several wedges and shards, so locks are taken
        let data = rand_data(3 * tile::TILE_ROWS, 4, 21);
        shard_contention::reset();
        let _ = SparseKernel::from_data(&data, Metric::Euclidean, 8).unwrap();
        match shard_contention::stats() {
            Some((acq, waits)) => {
                assert!(acq > 0, "debug builds must count shard-lock acquisitions");
                // tier-1 (`cargo test`) runs in debug, so this line is
                // where the ROADMAP open item's data actually surfaces —
                // `cargo bench` is release and reports null
                eprintln!(
                    "shard contention (n={}, k=8): {acq} acquisitions, {waits} waits",
                    data.rows()
                );
            }
            None => assert!(
                !cfg!(debug_assertions),
                "stats() may only be None in release builds"
            ),
        }
    }

    #[test]
    fn row_shard_replacement_updates_worst_slot() {
        // the claim/replace path in isolation: once a row is full, each
        // winning push must evict exactly the current worst survivor and
        // re-aim the worst pointer (Miri-clean: no pool, no tiles)
        let k = 2;
        let mut cols = vec![0u32; k];
        let mut vals = vec![0f32; k];
        let mut shard = RowShard::new(&mut cols, &mut vals, 1);
        shard.push(0, 0, 1.0, k);
        shard.push(0, 1, 2.0, k); // full; worst = 1.0@0
        shard.push(0, 2, 0.5, k); // loses to the worst — no change
        shard.push(0, 3, 3.0, k); // evicts 1.0@0; worst = 2.0@1
        shard.push(0, 4, 2.0, k); // ties 2.0@1 on value, higher column — loses
        shard.push(0, 5, 2.5, k); // evicts 2.0@1
        let mut pairs: Vec<(u32, f32)> =
            cols.iter().copied().zip(vals.iter().copied()).collect();
        pairs.sort_unstable_by_key(|e| e.0);
        assert_eq!(pairs, [(3, 3.0), (5, 2.5)]);
    }

    #[test]
    fn row_shard_agrees_with_select_row_topk() {
        // the streaming accumulator and materialize-then-select are two
        // implementations of one contract: identical survivors (bitwise),
        // including ties, ±∞, and NaN, whatever the arrival order
        let n = if cfg!(miri) { 12 } else { 64 };
        let mut rng = Pcg64::new(11);
        for k in [1usize, 2, 5] {
            let mut row: Vec<f32> =
                (0..n).map(|_| rng.next_below(8) as f32 * 0.25).collect();
            row[1] = f32::NEG_INFINITY;
            row[2] = f32::INFINITY;
            row[3] = f32::NAN;
            let mut scratch = Vec::new();
            let mut ref_cols = vec![0u32; k];
            let mut ref_vals = vec![0f32; k];
            select_row_topk(&row, k, &mut scratch, &mut ref_cols, &mut ref_vals);
            // feed the accumulator in a rotated order
            let mut cols = vec![0u32; k];
            let mut vals = vec![0f32; k];
            let mut shard = RowShard::new(&mut cols, &mut vals, 1);
            for off in 0..n {
                let j = (off + n / 3) % n;
                shard.push(0, j as u32, row[j], k);
            }
            let mut pairs: Vec<(u32, f32)> =
                cols.iter().copied().zip(vals.iter().copied()).collect();
            pairs.sort_unstable_by_key(|e| e.0);
            let got_cols: Vec<u32> = pairs.iter().map(|e| e.0).collect();
            let got_bits: Vec<u32> = pairs.iter().map(|e| e.1.to_bits()).collect();
            let ref_bits: Vec<u32> = ref_vals.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got_cols, ref_cols, "k={k}");
            assert_eq!(got_bits, ref_bits, "k={k}");
        }
    }

    #[test]
    fn invalid_k_rejected() {
        let data = rand_data(5, 2, 5);
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 0).is_err());
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 6).is_err());
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 5).is_ok());
        assert!(SparseKernel::from_data_full_width(&data, Metric::Euclidean, 0).is_err());
        assert!(SparseKernel::from_data_full_width(&data, Metric::Euclidean, 6).is_err());
    }
}

//! Sparse k-nearest-neighbor kernel (paper mode `"sparse"`, §8):
//! similarity with points beyond `num_neighbors` is treated as zero.
//! Stored CSR; rows sorted by column id for O(log k) lookup.
//!
//! As in Submodlib (following Wei, Iyer, Bilmes 2014 "Fast multi-stage
//! submodular maximization", cited in paper §2.1.1), this trades accuracy
//! for memory/time on large ground sets.
//!
//! Construction streams through the tile pipeline (`super::tile`): each
//! worker computes a `TILE_ROWS × n` similarity tile into its own
//! reusable buffer and reduces every row to its top-k *inside the worker*
//! before the next tile overwrites the buffer. Peak memory is
//! O(threads·TILE_ROWS·n + n·k) — the n×n matrix the old
//! materialize-then-select build allocated never exists, and the top-k
//! selection parallelizes for free (see `tile::sparse_peak_bytes` for
//! the full model).

use std::sync::Mutex;

use super::metric::Metric;
use super::tile::{self, Tile};
use crate::error::{Result, SubmodError};
use crate::linalg::Matrix;

/// CSR kNN similarity kernel.
#[derive(Debug, Clone)]
pub struct SparseKernel {
    n: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f32>,
}

impl SparseKernel {
    /// Build from a feature matrix keeping the `k` most similar neighbors
    /// per row (the row's own diagonal entry always counts as one of them,
    /// matching Submodlib's `num_neighbors` semantics).
    ///
    /// Streaming tiled build: never materializes the n×n matrix. Rows are
    /// computed full-width (so the per-row selection sees exactly the
    /// values a materialize-then-select build over the rectangular tile
    /// path would see) and reduced to top-k inside the worker thread.
    /// Every row lands at a fixed CSR offset (exactly `k` entries per
    /// row), so the output is preallocated once and pre-split into one
    /// disjoint slice pair per tile — workers write their rows in place,
    /// with no per-tile buffers, reassembly sort, or second copy.
    pub fn from_data(data: &Matrix, metric: Metric, k: usize) -> Result<Self> {
        let n = data.rows();
        if k == 0 || k > n {
            return Err(SubmodError::InvalidParam(format!(
                "num_neighbors {k} for ground set of {n}"
            )));
        }
        let mut col_idx = vec![0u32; n * k];
        let mut vals = vec![0f32; n * k];
        // per-tile output slices, indexed by row_start / TILE_ROWS (the
        // tile partition is part of stream_tiles' contract)
        let tile_count = n.div_ceil(tile::TILE_ROWS);
        let mut slots: Vec<Option<(&mut [u32], &mut [f32])>> =
            Vec::with_capacity(tile_count);
        {
            let mut rest_c = col_idx.as_mut_slice();
            let mut rest_v = vals.as_mut_slice();
            for t in 0..tile_count {
                let rows = tile::TILE_ROWS.min(n - t * tile::TILE_ROWS);
                let (c, tail_c) = rest_c.split_at_mut(rows * k);
                let (v, tail_v) = rest_v.split_at_mut(rows * k);
                slots.push(Some((c, v)));
                rest_c = tail_c;
                rest_v = tail_v;
            }
        }
        let slots = Mutex::new(slots);
        // reusable top-k scratch, recycled across tiles (at most one live
        // per worker — the 8·t·n term of tile::sparse_peak_bytes)
        let scratch_pool: Mutex<Vec<Vec<(u32, f32)>>> = Mutex::new(Vec::new());
        tile::stream_tiles(data, data, metric, false, &|t: Tile<'_>| {
            let (cols_out, vals_out) = {
                let mut guard = slots.lock().unwrap();
                guard[t.row_start / tile::TILE_ROWS]
                    .take()
                    .expect("each tile is delivered exactly once")
            };
            let mut scratch =
                scratch_pool.lock().unwrap().pop().unwrap_or_default();
            for (bi, row) in t.data.chunks_exact(t.cols).enumerate() {
                select_row_topk(
                    t.row_start + bi,
                    row,
                    k,
                    &mut scratch,
                    &mut cols_out[bi * k..(bi + 1) * k],
                    &mut vals_out[bi * k..(bi + 1) * k],
                );
            }
            scratch_pool.lock().unwrap().push(scratch);
        });
        // the slot table borrows col_idx/vals; release it before moving them
        drop(slots);
        let row_ptr = (0..=n).map(|i| i * k).collect();
        Ok(SparseKernel { n, row_ptr, col_idx, vals })
    }

    /// Build from precomputed dense rows (the materialize-then-select
    /// reference the streaming build is tested against, and the direct
    /// path for callers that already hold a dense kernel).
    pub(crate) fn from_dense_rows<'a, F>(n: usize, k: usize, row: F) -> Self
    where
        F: Fn(usize) -> &'a [f32],
    {
        let mut col_idx = vec![0u32; n * k];
        let mut vals = vec![0f32; n * k];
        let mut scratch: Vec<(u32, f32)> = Vec::with_capacity(n);
        for i in 0..n {
            select_row_topk(
                i,
                row(i),
                k,
                &mut scratch,
                &mut col_idx[i * k..(i + 1) * k],
                &mut vals[i * k..(i + 1) * k],
            );
        }
        let row_ptr = (0..=n).map(|i| i * k).collect();
        SparseKernel { n, row_ptr, col_idx, vals }
    }

    /// Ground-set size.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored neighbors per row.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Similarity s_ij — zero when j is outside i's neighbor list.
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(pos) => vals[pos],
            Err(_) => 0.0,
        }
    }

    /// Row i as parallel (columns, values) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.vals[s..e])
    }
}

/// Select the k largest entries of `row` (by similarity) and write them
/// to `cols_out`/`vals_out` (length exactly `k`) sorted by column id.
/// Single source of truth for the top-k semantics: the streaming build
/// and the dense-rows reference both call this, so their survivors agree
/// even on exact ties.
fn select_row_topk(
    i: usize,
    row: &[f32],
    k: usize,
    scratch: &mut Vec<(u32, f32)>,
    cols_out: &mut [u32],
    vals_out: &mut [f32],
) {
    debug_assert_eq!(cols_out.len(), k);
    debug_assert_eq!(vals_out.len(), k);
    scratch.clear();
    scratch.extend(row.iter().enumerate().map(|(j, &s)| {
        // a NaN similarity would make "the k most similar
        // neighbors" meaningless — catch it at the source rather
        // than letting it scramble the selection downstream
        debug_assert!(!s.is_nan(), "NaN similarity in kernel row {i}, col {j}");
        (j as u32, s)
    }));
    // Partial select of the k largest by similarity. total_cmp,
    // NOT partial_cmp().unwrap_or(Equal): under the old comparator
    // a NaN compared Equal to *everything*, breaking the strict
    // weak ordering select_nth_unstable_by relies on and silently
    // scrambling which neighbors survive. total_cmp is a total
    // order (NaN sorts above +∞, i.e. first in this descending
    // select), so even a release build with NaNs keeps the
    // selection well-defined; finite-only rows are unchanged.
    scratch.select_nth_unstable_by(k - 1, |a, b| b.1.total_cmp(&a.1));
    let top = &mut scratch[..k];
    top.sort_unstable_by_key(|e| e.0);
    for (t, &(j, s)) in top.iter().enumerate() {
        cols_out[t] = j;
        vals_out[t] = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect())
            .unwrap()
    }

    #[test]
    fn keeps_k_per_row() {
        let data = rand_data(20, 4, 1);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 5).unwrap();
        assert_eq!(k.nnz(), 20 * 5);
        for i in 0..20 {
            let (cols, _) = k.row(i);
            assert_eq!(cols.len(), 5);
        }
    }

    #[test]
    fn self_neighbor_retained() {
        // With euclidean similarity the diagonal is the max (=1), so it
        // must always be among the top-k.
        let data = rand_data(15, 3, 2);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 3).unwrap();
        for i in 0..15 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-5, "row {i} missing diagonal");
        }
    }

    #[test]
    fn topk_values_match_dense() {
        let data = rand_data(12, 4, 3);
        let dense = crate::kernel::DenseKernel::from_data(&data, Metric::Euclidean);
        let sparse = SparseKernel::from_data(&data, Metric::Euclidean, 4).unwrap();
        for i in 0..12 {
            let mut drow: Vec<(usize, f32)> =
                dense.row(i).iter().cloned().enumerate().collect();
            // total_cmp: same NaN-total comparator class as the builder —
            // the old partial_cmp().unwrap() panicked outright on NaN
            drow.sort_by(|a, b| b.1.total_cmp(&a.1));
            let expect: std::collections::HashSet<usize> =
                drow[..4].iter().map(|e| e.0).collect();
            let (cols, vals) = sparse.row(i);
            for (c, v) in cols.iter().zip(vals) {
                assert!(expect.contains(&(*c as usize)) || {
                    // ties at the cut boundary are acceptable either way
                    (drow[3].1 - v).abs() < 1e-6
                });
                assert!((dense.get(i, *c as usize) - v).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn absent_entries_zero() {
        let data = rand_data(30, 4, 4);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 2).unwrap();
        let mut zeros = 0;
        for i in 0..30 {
            for j in 0..30 {
                if k.get(i, j) == 0.0 {
                    zeros += 1;
                }
            }
        }
        assert_eq!(zeros, 30 * 30 - k.nnz());
    }

    #[test]
    fn streaming_matches_dense_rows_reference() {
        // the streaming build reduces the same full-width rows the
        // rectangular tile path produces, through the same select —
        // survivors and values must agree with materialize-then-select
        // exactly (n > TILE_ROWS exercises multi-tile scheduling)
        let data = rand_data(2 * tile::TILE_ROWS + 9, 6, 6);
        let n = data.rows();
        let copy = data.clone();
        let dense = crate::kernel::RectKernel::from_data(&data, &copy, Metric::Cosine).unwrap();
        for k in [1usize, 3, 16, n] {
            let streamed = SparseKernel::from_data(&data, Metric::Cosine, k).unwrap();
            let reference = SparseKernel::from_dense_rows(n, k, |i| dense.row(i));
            assert_eq!(streamed.row_ptr, reference.row_ptr, "k={k}");
            assert_eq!(streamed.col_idx, reference.col_idx, "k={k}");
            let bits =
                |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&streamed.vals), bits(&reference.vals), "k={k}");
        }
    }

    #[test]
    fn topk_total_order_handles_nonfinite_rows() {
        // −∞ (a legal f32, e.g. from a degenerate log-space similarity)
        // must lose to every finite value under total_cmp, and equal
        // values must still yield exactly k survivors.
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, f32::NEG_INFINITY, 0.5, 0.75],
            vec![0.25, 0.25, 0.25, 0.25],
            vec![f32::NEG_INFINITY, f32::NEG_INFINITY, 2.0, 1.0],
            vec![0.0, -0.0, 3.0, -1.0],
        ];
        let k = SparseKernel::from_dense_rows(4, 2, |i| rows[i].as_slice());
        assert_eq!(k.nnz(), 8);
        let survivors = |i: usize| -> Vec<u32> { k.row(i).0.to_vec() };
        assert_eq!(survivors(0), vec![0, 3]); // 1.0 and 0.75
        assert_eq!(survivors(1).len(), 2); // all tied: any 2, but exactly 2
        assert_eq!(survivors(2), vec![2, 3]); // the two finite entries
        assert_eq!(survivors(3), vec![0, 2]); // 3.0 and +0.0 (beats −0.0)
    }

    #[test]
    fn invalid_k_rejected() {
        let data = rand_data(5, 2, 5);
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 0).is_err());
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 6).is_err());
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 5).is_ok());
    }
}

//! Sparse k-nearest-neighbor kernel (paper mode `"sparse"`, §8):
//! similarity with points beyond `num_neighbors` is treated as zero.
//! Stored CSR; rows sorted by column id for O(log k) lookup.
//!
//! As in Submodlib (following Wei, Iyer, Bilmes 2014 "Fast multi-stage
//! submodular maximization", cited in paper §2.1.1), this trades accuracy
//! for memory/time on large ground sets.

use super::dense::build_pairwise;
use super::metric::Metric;
use crate::error::{Result, SubmodError};
use crate::linalg::Matrix;

/// CSR kNN similarity kernel.
#[derive(Debug, Clone)]
pub struct SparseKernel {
    n: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<u32>,
    vals: Vec<f32>,
}

impl SparseKernel {
    /// Build from a feature matrix keeping the `k` most similar neighbors
    /// per row (the row's own diagonal entry always counts as one of them,
    /// matching Submodlib's `num_neighbors` semantics).
    pub fn from_data(data: &Matrix, metric: Metric, k: usize) -> Result<Self> {
        let n = data.rows();
        if k == 0 || k > n {
            return Err(SubmodError::InvalidParam(format!(
                "num_neighbors {k} for ground set of {n}"
            )));
        }
        // Dense pass, then top-k per row. For n where dense is infeasible
        // the coordinator shards first (coordinator::shard), so the dense
        // intermediate here is bounded by shard size.
        let dense = build_pairwise(data, data, metric, false);
        Ok(Self::from_dense_rows(n, k, |i| dense.row(i)))
    }

    /// Build from precomputed dense rows (used by tests and the shard path).
    pub(crate) fn from_dense_rows<'a, F>(n: usize, k: usize, row: F) -> Self
    where
        F: Fn(usize) -> &'a [f32],
    {
        let mut row_ptr = Vec::with_capacity(n + 1);
        let mut col_idx = Vec::with_capacity(n * k);
        let mut vals = Vec::with_capacity(n * k);
        row_ptr.push(0);
        let mut scratch: Vec<(u32, f32)> = Vec::with_capacity(n);
        for i in 0..n {
            scratch.clear();
            scratch.extend(row(i).iter().enumerate().map(|(j, &s)| {
                // a NaN similarity would make "the k most similar
                // neighbors" meaningless — catch it at the source rather
                // than letting it scramble the selection downstream
                debug_assert!(!s.is_nan(), "NaN similarity in kernel row {i}, col {j}");
                (j as u32, s)
            }));
            // Partial select of the k largest by similarity. total_cmp,
            // NOT partial_cmp().unwrap_or(Equal): under the old comparator
            // a NaN compared Equal to *everything*, breaking the strict
            // weak ordering select_nth_unstable_by relies on and silently
            // scrambling which neighbors survive. total_cmp is a total
            // order (NaN sorts above +∞, i.e. first in this descending
            // select), so even a release build with NaNs keeps the
            // selection well-defined; finite-only rows are unchanged.
            scratch.select_nth_unstable_by(k - 1, |a, b| b.1.total_cmp(&a.1));
            let mut top: Vec<(u32, f32)> = scratch[..k].to_vec();
            top.sort_unstable_by_key(|e| e.0);
            for (j, s) in top {
                col_idx.push(j);
                vals.push(s);
            }
            row_ptr.push(col_idx.len());
        }
        SparseKernel { n, row_ptr, col_idx, vals }
    }

    /// Ground-set size.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored neighbors per row.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Similarity s_ij — zero when j is outside i's neighbor list.
    pub fn get(&self, i: usize, j: usize) -> f32 {
        let (cols, vals) = self.row(i);
        match cols.binary_search(&(j as u32)) {
            Ok(pos) => vals[pos],
            Err(_) => 0.0,
        }
    }

    /// Row i as parallel (columns, values) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (s, e) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[s..e], &self.vals[s..e])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_data(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect())
            .unwrap()
    }

    #[test]
    fn keeps_k_per_row() {
        let data = rand_data(20, 4, 1);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 5).unwrap();
        assert_eq!(k.nnz(), 20 * 5);
        for i in 0..20 {
            let (cols, _) = k.row(i);
            assert_eq!(cols.len(), 5);
        }
    }

    #[test]
    fn self_neighbor_retained() {
        // With euclidean similarity the diagonal is the max (=1), so it
        // must always be among the top-k.
        let data = rand_data(15, 3, 2);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 3).unwrap();
        for i in 0..15 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-5, "row {i} missing diagonal");
        }
    }

    #[test]
    fn topk_values_match_dense() {
        let data = rand_data(12, 4, 3);
        let dense = crate::kernel::DenseKernel::from_data(&data, Metric::Euclidean);
        let sparse = SparseKernel::from_data(&data, Metric::Euclidean, 4).unwrap();
        for i in 0..12 {
            let mut drow: Vec<(usize, f32)> =
                dense.row(i).iter().cloned().enumerate().collect();
            // total_cmp: same NaN-total comparator class as the builder —
            // the old partial_cmp().unwrap() panicked outright on NaN
            drow.sort_by(|a, b| b.1.total_cmp(&a.1));
            let expect: std::collections::HashSet<usize> =
                drow[..4].iter().map(|e| e.0).collect();
            let (cols, vals) = sparse.row(i);
            for (c, v) in cols.iter().zip(vals) {
                assert!(expect.contains(&(*c as usize)) || {
                    // ties at the cut boundary are acceptable either way
                    (drow[3].1 - v).abs() < 1e-6
                });
                assert!((dense.get(i, *c as usize) - v).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn absent_entries_zero() {
        let data = rand_data(30, 4, 4);
        let k = SparseKernel::from_data(&data, Metric::Euclidean, 2).unwrap();
        let mut zeros = 0;
        for i in 0..30 {
            for j in 0..30 {
                if k.get(i, j) == 0.0 {
                    zeros += 1;
                }
            }
        }
        assert_eq!(zeros, 30 * 30 - k.nnz());
    }

    #[test]
    fn topk_total_order_handles_nonfinite_rows() {
        // −∞ (a legal f32, e.g. from a degenerate log-space similarity)
        // must lose to every finite value under total_cmp, and equal
        // values must still yield exactly k survivors.
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, f32::NEG_INFINITY, 0.5, 0.75],
            vec![0.25, 0.25, 0.25, 0.25],
            vec![f32::NEG_INFINITY, f32::NEG_INFINITY, 2.0, 1.0],
            vec![0.0, -0.0, 3.0, -1.0],
        ];
        let k = SparseKernel::from_dense_rows(4, 2, |i| rows[i].as_slice());
        assert_eq!(k.nnz(), 8);
        let survivors = |i: usize| -> Vec<u32> { k.row(i).0.to_vec() };
        assert_eq!(survivors(0), vec![0, 3]); // 1.0 and 0.75
        assert_eq!(survivors(1).len(), 2); // all tied: any 2, but exactly 2
        assert_eq!(survivors(2), vec![2, 3]); // the two finite entries
        assert_eq!(survivors(3), vec![0, 2]); // 3.0 and +0.0 (beats −0.0)
    }

    #[test]
    fn invalid_k_rejected() {
        let data = rand_data(5, 2, 5);
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 0).is_err());
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 6).is_err());
        assert!(SparseKernel::from_data(&data, Metric::Euclidean, 5).is_ok());
    }
}

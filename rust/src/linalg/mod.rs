//! Dense linear-algebra substrate built from scratch.
//!
//! Submodlib's LogDeterminant family needs incremental Cholesky machinery
//! (the "Fast Greedy MAP Inference" of Chen et al. 2018 the paper cites in
//! §5.2.1); the kernel builders need blocked matrix products. Everything
//! here is row-major `f32`/`f64`, no external BLAS.
//!
//! [`dot`], [`dot4`] and [`dot8`] are the *scalar compute backend's*
//! pinned inner kernels (`kernel::backend::scalar`): their exact op
//! orders are the pre-backend determinism contract, reproduced bitwise
//! under `SUBMODLIB_BACKEND=scalar` and replicated as the golden
//! reference in tests/backend_parity.rs. Change them and every scalar
//! golden in the repo moves — don't.

pub mod cholesky;
pub mod matrix;

pub use cholesky::{Cholesky, IncrementalLogDet};
pub use matrix::Matrix;

/// Dot product with 4-way unrolling (the compiler auto-vectorizes this
/// shape reliably; see EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// Four simultaneous dot products of `a` against rows `b0..b3`
/// (register blocking: `a` is loaded once per lane instead of four
/// times — the §Perf kernel-build iteration, EXPERIMENTS.md).
#[inline]
pub fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    debug_assert!(b0.len() == a.len() && b1.len() == a.len());
    debug_assert!(b2.len() == a.len() && b3.len() == a.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..a.len() {
        let x = a[i];
        s0 += x * b0[i];
        s1 += x * b1[i];
        s2 += x * b2[i];
        s3 += x * b3[i];
    }
    [s0, s1, s2, s3]
}

/// Eight simultaneous dot products (see [`dot4`]; §Perf iteration 2).
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn dot8(
    a: &[f32],
    b: [&[f32]; 8],
) -> [f32; 8] {
    let mut s = [0f32; 8];
    for i in 0..a.len() {
        let x = a[i];
        for t in 0..8 {
            s[t] += x * b[t][i];
        }
    }
    s
}

/// Squared euclidean distance, fused single pass.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// L2 norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-4);
    }

    #[test]
    fn dot_empty() {
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn sq_dist_symmetric_and_zero_on_self() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 6.0, 8.0];
        assert_eq!(sq_dist(&a, &a), 0.0);
        assert!((sq_dist(&a, &b) - sq_dist(&b, &a)).abs() < 1e-6);
        assert!((sq_dist(&a, &b) - (9.0 + 16.0 + 25.0)).abs() < 1e-5);
    }

    #[test]
    fn norm_unit() {
        assert!((norm(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}

//! Row-major dense matrix with the handful of operations the library
//! needs: blocked products for kernel construction, transpose, row views,
//! and small-matrix utilities for tests.

use crate::error::{Result, SubmodError};

/// Row-major `f32` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(SubmodError::Shape(format!(
                "buffer of {} for {}x{}",
                data.len(),
                rows,
                cols
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from nested slices (tests / small literals).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Contiguous row view.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row view.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Whole backing buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Whole backing buffer, mutable.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// `self · otherᵀ`, cache-blocked. This is the native fallback for the
    /// gram stage of kernel construction (the runtime path uses the Pallas
    /// HLO artifact instead — see `runtime::tiled`).
    pub fn matmul_nt(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.cols {
            return Err(SubmodError::Shape(format!(
                "matmul_nt: inner dims {} vs {}",
                self.cols, other.cols
            )));
        }
        let m = self.rows;
        let n = other.rows;
        let mut out = Matrix::zeros(m, n);
        const BI: usize = 32;
        const BJ: usize = 32;
        for ib in (0..m).step_by(BI) {
            let ie = (ib + BI).min(m);
            for jb in (0..n).step_by(BJ) {
                let je = (jb + BJ).min(n);
                for i in ib..ie {
                    let a = self.row(i);
                    let orow = &mut out.data[i * n..(i + 1) * n];
                    for j in jb..je {
                        orow[j] = super::dot(a, other.row(j));
                    }
                }
            }
        }
        Ok(out)
    }

    /// Extract the principal submatrix indexed by `idx` (for LogDet tests).
    pub fn principal_submatrix(&self, idx: &[usize]) -> Matrix {
        let k = idx.len();
        let mut out = Matrix::zeros(k, k);
        for (a, &i) in idx.iter().enumerate() {
            for (b, &j) in idx.iter().enumerate() {
                out.data[a * k + b] = self.get(i, j);
            }
        }
        out
    }

    /// Frobenius-norm distance to another matrix (test helper).
    pub fn frob_dist(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_shape() {
        assert!(Matrix::from_vec(2, 2, vec![0.0; 3]).is_err());
        assert!(Matrix::from_vec(2, 2, vec![0.0; 4]).is_ok());
    }

    #[test]
    fn eye_diag() {
        let m = Matrix::eye(3);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m.get(i, j), if i == j { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn matmul_nt_small() {
        // A (2x3) · B (2x3)^T = (2x2)
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let b = Matrix::from_rows(&[&[1.0, 0.0, 1.0], &[0.0, 1.0, 0.0]]);
        let c = a.matmul_nt(&b).unwrap();
        assert_eq!(c.get(0, 0), 4.0);
        assert_eq!(c.get(0, 1), 2.0);
        assert_eq!(c.get(1, 0), 10.0);
        assert_eq!(c.get(1, 1), 5.0);
    }

    #[test]
    fn matmul_nt_blocked_matches_naive_large() {
        let mut rng = crate::rng::Pcg64::new(17);
        let m = 70;
        let k = 45;
        let n = 53;
        let a = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.next_f32()).collect()).unwrap();
        let b = Matrix::from_vec(n, k, (0..n * k).map(|_| rng.next_f32()).collect()).unwrap();
        let c = a.matmul_nt(&b).unwrap();
        for i in (0..m).step_by(13) {
            for j in (0..n).step_by(11) {
                let naive: f32 = (0..k).map(|t| a.get(i, t) * b.get(j, t)).sum();
                assert!((c.get(i, j) - naive).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn matmul_nt_shape_mismatch() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 4);
        assert!(a.matmul_nt(&b).is_err());
    }

    #[test]
    fn principal_submatrix_picks() {
        let m = Matrix::from_rows(&[
            &[1.0, 2.0, 3.0],
            &[4.0, 5.0, 6.0],
            &[7.0, 8.0, 9.0],
        ]);
        let s = m.principal_submatrix(&[0, 2]);
        assert_eq!(s.get(0, 0), 1.0);
        assert_eq!(s.get(0, 1), 3.0);
        assert_eq!(s.get(1, 0), 7.0);
        assert_eq!(s.get(1, 1), 9.0);
    }
}

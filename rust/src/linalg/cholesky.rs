//! Cholesky machinery for the LogDeterminant family.
//!
//! Two pieces:
//!
//! * [`Cholesky`] — batch factorization of an SPD matrix, with `log_det`
//!   and linear solves. Used by tests and by the LogDet MI/CG closed forms.
//! * [`IncrementalLogDet`] — the *Fast Greedy MAP Inference* structure
//!   (Chen, Zhang, Zhou 2018 — paper §5.2.1 "Log Determinant:
//!   implementation leverages Fast Greedy MAP Inference"): maintains the
//!   Cholesky factor of `K_A` as elements are appended, so the marginal
//!   log-det gain of a candidate is one forward substitution,
//!   O(|A|²), instead of refactorizing, O(|A|³).
//!
//! All accumulation is in `f64`: chained updates on `f32` lose the
//! SPD-ness of small pivots long before |A| reaches realistic budgets.

use super::matrix::Matrix;
use crate::error::{Result, SubmodError};

/// Batch Cholesky factor (lower triangular, row-major packed).
#[derive(Debug, Clone)]
pub struct Cholesky {
    n: usize,
    /// Packed lower triangle: row i occupies i+1 entries.
    l: Vec<f64>,
}

impl Cholesky {
    /// Factor an SPD matrix. Fails on non-positive pivots.
    pub fn factor(a: &Matrix) -> Result<Cholesky> {
        if a.rows() != a.cols() {
            return Err(SubmodError::Shape(format!(
                "cholesky of {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        let n = a.rows();
        let mut l = vec![0f64; n * (n + 1) / 2];
        let idx = |i: usize, j: usize| i * (i + 1) / 2 + j;
        for i in 0..n {
            for j in 0..=i {
                let mut s = a.get(i, j) as f64;
                for k in 0..j {
                    s -= l[idx(i, k)] * l[idx(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(SubmodError::InvalidParam(format!(
                            "matrix not positive definite at pivot {i} (s={s})"
                        )));
                    }
                    l[idx(i, j)] = s.sqrt();
                } else {
                    l[idx(i, j)] = s / l[idx(j, j)];
                }
            }
        }
        Ok(Cholesky { n, l })
    }

    #[inline]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.l[i * (i + 1) / 2 + j]
    }

    /// log det(A) = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.n).map(|i| self.at(i, i).ln()).sum::<f64>() * 2.0
    }

    /// Solve A x = b via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        // L y = b
        let mut y = vec![0f64; n];
        for i in 0..n {
            let mut s = b[i];
            for j in 0..i {
                s -= self.at(i, j) * y[j];
            }
            y[i] = s / self.at(i, i);
        }
        // Lᵀ x = y
        let mut x = vec![0f64; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for j in i + 1..n {
                s -= self.at(j, i) * x[j];
            }
            x[i] = s / self.at(i, i);
        }
        x
    }
}

/// Incremental Cholesky for greedy log-det maximization.
///
/// Maintains `L` (packed lower triangle) for the currently selected set in
/// insertion order. `gain(col, diag)` returns the marginal gain
/// `log det(K_{A∪j}) − log det(K_A) = ln(diag − ‖c‖²)` where `L c = col`;
/// `push` commits the candidate by appending row `[cᵀ, √(diag − ‖c‖²)]`.
#[derive(Debug, Clone, Default)]
pub struct IncrementalLogDet {
    /// Packed rows of L.
    l: Vec<f64>,
    k: usize,
}

impl IncrementalLogDet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of committed elements.
    pub fn len(&self) -> usize {
        self.k
    }

    pub fn is_empty(&self) -> bool {
        self.k == 0
    }

    #[inline]
    fn at(&self, i: usize, j: usize) -> f64 {
        self.l[i * (i + 1) / 2 + j]
    }

    /// Forward-substitute `L c = col` for a candidate's cross-similarity
    /// column (in insertion order), returning (c, residual = diag − ‖c‖²).
    fn forward(&self, col: &[f32], diag: f32) -> (Vec<f64>, f64) {
        debug_assert_eq!(col.len(), self.k);
        let mut c = vec![0f64; self.k];
        let mut sq = 0f64;
        for i in 0..self.k {
            let mut s = col[i] as f64;
            for j in 0..i {
                s -= self.at(i, j) * c[j];
            }
            let ci = s / self.at(i, i);
            c[i] = ci;
            sq += ci * ci;
        }
        (c, diag as f64 - sq)
    }

    /// Marginal gain `ln(diag − ‖c‖²)` of adding a candidate whose
    /// similarity to the committed elements (insertion order) is `col` and
    /// self-similarity is `diag`. Returns −∞ when the update would lose
    /// positive-definiteness (kernel numerically singular) — the greedy
    /// loop then treats the candidate as worthless, matching Submodlib.
    pub fn gain(&self, col: &[f32], diag: f32) -> f64 {
        let (_, res) = self.forward(col, diag);
        if res <= 0.0 {
            f64::NEG_INFINITY
        } else {
            res.ln()
        }
    }

    /// Batch variant of [`gain`](IncrementalLogDet::gain): the marginal
    /// gains of `cols.len()` candidates against the *same* factor, blocked
    /// 4 wide so each packed row of `L` is read once per 4 candidates
    /// instead of once per candidate. Every candidate's forward
    /// substitution runs in exactly the scalar order (`j` ascending inside
    /// `i` ascending), so results are bit-identical to per-candidate
    /// `gain` calls — the `marginal_gains_batch` determinism contract.
    pub fn gains_batch(&self, cols: &[Vec<f32>], diags: &[f32], out: &mut [f64]) {
        debug_assert_eq!(cols.len(), diags.len());
        debug_assert_eq!(cols.len(), out.len());
        let k = self.k;
        let mut b = 0;
        // scratch: c[t * k + i] is candidate t's forward-substituted column
        let mut c = vec![0f64; 4 * k];
        while b + 4 <= cols.len() {
            let mut sq = [0f64; 4];
            for i in 0..k {
                let base = i * (i + 1) / 2;
                for t in 0..4 {
                    let mut s = cols[b + t][i] as f64;
                    for j in 0..i {
                        s -= self.l[base + j] * c[t * k + j];
                    }
                    let ci = s / self.l[base + i];
                    c[t * k + i] = ci;
                    sq[t] += ci * ci;
                }
            }
            for t in 0..4 {
                let res = diags[b + t] as f64 - sq[t];
                out[b + t] = if res <= 0.0 { f64::NEG_INFINITY } else { res.ln() };
            }
            b += 4;
        }
        for t in b..cols.len() {
            out[t] = self.gain(&cols[t], diags[t]);
        }
    }

    /// Commit a candidate (same arguments as `gain`).
    pub fn push(&mut self, col: &[f32], diag: f32) -> Result<()> {
        let (c, res) = self.forward(col, diag);
        if res <= 0.0 {
            return Err(SubmodError::InvalidParam(
                "incremental cholesky lost positive definiteness".into(),
            ));
        }
        self.l.extend_from_slice(&c);
        self.l.push(res.sqrt());
        self.k += 1;
        Ok(())
    }

    /// Current log det(K_A).
    pub fn log_det(&self) -> f64 {
        (0..self.k).map(|i| self.at(i, i).ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd3() -> Matrix {
        // A = B Bᵀ + I for B random-ish → SPD.
        Matrix::from_rows(&[
            &[4.0, 2.0, 0.6],
            &[2.0, 5.0, 1.0],
            &[0.6, 1.0, 3.0],
        ])
    }

    #[test]
    fn factor_identity() {
        let c = Cholesky::factor(&Matrix::eye(4)).unwrap();
        assert!(c.log_det().abs() < 1e-12);
    }

    #[test]
    fn logdet_matches_known() {
        // det of diag(2, 3) = 6
        let m = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, 3.0]]);
        let c = Cholesky::factor(&m).unwrap();
        assert!((c.log_det() - 6f64.ln()).abs() < 1e-6);
    }

    #[test]
    fn non_spd_rejected() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig −1
        assert!(Cholesky::factor(&m).is_err());
        assert!(Cholesky::factor(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn solve_roundtrip() {
        let a = spd3();
        let c = Cholesky::factor(&a).unwrap();
        let b = [1.0, 2.0, 3.0];
        let x = c.solve(&b);
        // A x ≈ b
        for i in 0..3 {
            let ax: f64 = (0..3).map(|j| a.get(i, j) as f64 * x[j]).sum();
            assert!((ax - b[i]).abs() < 1e-6, "row {i}: {ax} vs {}", b[i]);
        }
    }

    #[test]
    fn incremental_matches_batch() {
        let a = spd3();
        let mut inc = IncrementalLogDet::new();
        // add 0, then 1, then 2; after each, logdet must match batch factor
        let order = [0usize, 1, 2];
        for (step, &j) in order.iter().enumerate() {
            let col: Vec<f32> = order[..step].iter().map(|&i| a.get(j, i)).collect();
            let g = inc.gain(&col, a.get(j, j));
            let before = inc.log_det();
            inc.push(&col, a.get(j, j)).unwrap();
            let after = inc.log_det();
            assert!((after - before - g).abs() < 1e-9);
            let idx: Vec<usize> = order[..=step].to_vec();
            let batch = Cholesky::factor(&a.principal_submatrix(&idx)).unwrap().log_det();
            assert!((after - batch).abs() < 1e-6, "step {step}: {after} vs {batch}");
        }
    }

    #[test]
    fn gains_batch_bitwise_matches_scalar() {
        // 6 candidates against a 3-element factor: exercises the 4-wide
        // block and the scalar remainder, including a singular candidate
        let a = spd3();
        let mut inc = IncrementalLogDet::new();
        for (step, j) in [0usize, 1, 2].into_iter().enumerate() {
            let col: Vec<f32> = (0..step).map(|i| a.get(j, i)).collect();
            inc.push(&col, a.get(j, j)).unwrap();
        }
        let dup: Vec<f32> = (0..3).map(|i| a.get(1, i)).collect(); // duplicate of row 1
        let cols: Vec<Vec<f32>> = vec![
            vec![1.0, 0.5, 0.2],
            vec![0.0, 0.0, 0.0],
            dup.clone(),
            vec![2.0, 1.0, 0.6],
            vec![0.3, 0.9, 0.1],
            dup,
        ];
        let diags = [5.0f32, 2.0, a.get(1, 1), 6.0, 4.0, a.get(1, 1)];
        let mut out = vec![0f64; 6];
        inc.gains_batch(&cols, &diags, &mut out);
        for t in 0..6 {
            let scalar = inc.gain(&cols[t], diags[t]);
            assert_eq!(out[t].to_bits(), scalar.to_bits(), "candidate {t}");
        }
        assert_eq!(out[2], f64::NEG_INFINITY);
    }

    #[test]
    fn gain_neg_infinity_on_duplicate() {
        // adding a duplicate row makes the kernel singular → gain −∞
        let mut inc = IncrementalLogDet::new();
        inc.push(&[], 1.0).unwrap();
        let g = inc.gain(&[1.0], 1.0); // identical element, similarity 1
        assert_eq!(g, f64::NEG_INFINITY);
        assert!(inc.push(&[1.0], 1.0).is_err());
    }

    #[test]
    fn empty_logdet_zero() {
        let inc = IncrementalLogDet::new();
        assert_eq!(inc.log_det(), 0.0);
        assert!(inc.is_empty());
    }
}

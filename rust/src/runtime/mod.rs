//! Runtime substrate: the persistent worker pool every parallel layer
//! runs on, plus the PJRT accelerator path.
//!
//! * [`pool`] — lazily-initialized persistent worker pool (std-only).
//!   All native parallel sections (`kernel::tile` drivers,
//!   `optimizers::batch_gains`, the sparse wavefront consumer) publish
//!   scoped jobs here instead of spawning threads per call; see its
//!   module docs for the `SUBMODLIB_THREADS` contract and the
//!   indexed-slot determinism rule.
//! * [`cancel`] — cooperative cancellation tokens (shared atomic flag,
//!   no wall-clock) polled at claim boundaries by every compute layer;
//!   the pool propagates the submitter's ambient token into worker
//!   invocations.
//! * [`client::Engine`] — PJRT CPU client + compiled-executable registry,
//!   keyed by the entries in `artifacts/manifest.json` (loads the
//!   AOT-compiled HLO artifacts produced by `make artifacts`; Python is
//!   never involved at run time).
//! * [`tiled`] — padding/tiling drivers that stitch fixed-shape artifact
//!   invocations into arbitrary-shape kernel builds.
//!
//! Interchange format for artifacts is HLO *text* (see aot.py's
//! docstring for why serialized protos don't work against
//! xla_extension 0.5.1).

pub mod cancel;
pub mod client;
pub mod pool;
pub mod tiled;

pub use client::{Engine, Manifest};

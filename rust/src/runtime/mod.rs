//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `make artifacts` (python/compile/aot.py) and executes them from the
//! Rust hot path. Python is never involved at run time.
//!
//! * [`client::Engine`] — PJRT CPU client + compiled-executable registry,
//!   keyed by the entries in `artifacts/manifest.json`.
//! * [`tiled`] — padding/tiling drivers that stitch fixed-shape artifact
//!   invocations into arbitrary-shape kernel builds.
//!
//! Interchange format is HLO *text* (see aot.py's docstring for why
//! serialized protos don't work against xla_extension 0.5.1).

pub mod client;
pub mod tiled;

pub use client::{Engine, Manifest};

//! Persistent worker-pool runtime — the one thread pool under every
//! parallel layer (ISSUE 5).
//!
//! Before this module, each parallel section (the tile drivers in
//! `kernel::tile`, `optimizers::batch_gains`, the sparse wavefront
//! consumer) spawned and joined its own OS threads via
//! `std::thread::scope`. A greedy run with k accepts plus Minoux
//! cascades crossed those sections thousands of times, so thread
//! spawn/join dominated wall-clock at the paper's Table 2 sizes. Here
//! the workers are spawned **once**, lazily, and then park on a condvar
//! between jobs; a parallel section publishes a job and wakes them —
//! dispatch is a mutex acquisition plus a condvar broadcast, not one
//! `clone(2)` per participant.
//!
//! ## The indexed-slot determinism rule
//!
//! A job is a `&(dyn Fn(usize) + Sync)` invoked once per participant
//! with a distinct participant index in `0..parts`. Every caller in this
//! crate follows the same discipline the tile drivers established:
//!
//! * work items are **claimed off an atomic counter**, not pre-assigned
//!   to participants, so load balance never depends on the width; and
//! * each work item writes its results to **its own pre-split output
//!   slot** (a disjoint `&mut` slice or an order-independent
//!   accumulator), never to a shared append buffer.
//!
//! Under that discipline the bytes produced are a pure function of the
//! input — identical whichever participant computes which item, and
//! therefore identical across pool widths 1 / 2 / default (pinned by
//! `tests/pool_matrix.rs`). New callers must keep both halves of the
//! rule; a participant-indexed output (e.g. per-worker append lists
//! concatenated in participant order) would break width independence.
//!
//! ## `SUBMODLIB_THREADS` contract
//!
//! The pool width is resolved **once**, at first use, from the
//! `SUBMODLIB_THREADS` environment variable (a positive integer; unset,
//! empty, or unparsable values fall back to
//! `available_parallelism()`), and never changes for the life of the
//! process. Width w means w participants: the submitting thread always
//! participates, so the pool spawns w − 1 detached workers; w = 1 runs
//! every job inline with no worker threads at all. Per-call narrowing
//! (never widening) is available via [`with_thread_limit`] (scoped,
//! thread-local — safe under concurrent tests) or
//! `MaximizeOpts::threads`; results are unaffected by any of these
//! knobs, only wall-clock is.
//!
//! Concurrent submitters (e.g. coordinator shard workers that each call
//! `maximize`) serialize on a submission lock: one job runs at a time,
//! which is also what keeps the machine from oversubscribing. The lock
//! is not re-entrant, so a [`run`] issued from *inside* a job never
//! submits — an `IN_JOB` thread-local degrades it to inline serial
//! execution (result-identical by the indexed-slot rule) instead of
//! deadlocking. Most callers should reach for [`run_indexed`], which
//! packages the claim-off-a-counter / own-slot discipline once instead
//! of each call site hand-rolling it.
//!
//! ## Cooperative cancellation
//!
//! The pool is cancellation-transparent: [`run`] captures the
//! submitter's ambient [`cancel`] scope at submission and re-installs
//! it inside every worker invocation, so a job polls the same
//! `CancelToken` on each participant. [`run_indexed`] polls the token
//! before **every item claim** — a fired token means workers stop
//! claiming and the job completes normally with the remaining items
//! untouched; the Result-returning caller above then unwinds with
//! `SubmodError::Cancelled` (see `runtime::cancel`). The generation
//! protocol always runs to completion, so a cancelled pool is
//! immediately reusable, and a token that never fires changes nothing:
//! polls read a flag and claims stay in the same deterministic order,
//! so outputs are byte-identical with or without a token, at any width.
//!
//! [`cancel`]: crate::runtime::cancel

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::runtime::cancel;

/// A published job: one invocation per participant, with the
/// participant's index. See the module docs for the determinism rule.
type JobRef<'a> = &'a (dyn Fn(usize) + Sync + 'a);

/// Lifetime-erased job pointer handed to the workers. Safety: the
/// submitter does not return from [`Pool::run_scoped`] until every
/// participant has finished executing the job, so the erased borrow is
/// live for every dereference.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize) + Sync + 'static));

// SAFETY: the pointer is only dereferenced while the submitting thread
// keeps the underlying closure alive — `run_scoped` blocks until every
// participant finishes (see `Job`) — and the closure is `Sync`, so
// invoking it concurrently from worker threads is sound.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per published job; workers use it to tell a fresh
    /// job from a spurious wakeup.
    generation: u64,
    job: Option<Job>,
    /// Worker slots not yet claimed for the current generation.
    unclaimed: usize,
    /// Next participant index to hand to a claiming worker.
    next_slot: usize,
    /// Workers currently executing the current job.
    running: usize,
    /// First worker panic of the current job — its original payload,
    /// re-raised on the submitter so diagnostics don't depend on which
    /// participant a panic landed on.
    panic_payload: Option<Box<dyn std::any::Any + Send>>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work: Condvar,
    /// The submitter parks here until `unclaimed == 0 && running == 0`.
    done: Condvar,
}

/// The process-wide pool. Workers are detached (`std::thread::spawn`)
/// and live until process exit — there is intentionally no shutdown:
/// parked workers cost one blocked OS thread each and nothing else.
pub struct Pool {
    shared: Arc<Shared>,
    /// Spawned worker count (resolved width − 1).
    size: usize,
    /// Serializes submitters; held for the whole duration of a job.
    submit: Mutex<()>,
}

thread_local! {
    /// Scoped per-thread width cap set by [`with_thread_limit`].
    static THREAD_LIMIT: Cell<Option<usize>> = const { Cell::new(None) };
    /// True while this thread is executing a pool job (worker threads,
    /// and the submitter during its own participant slot). A nested
    /// [`run`] from such a context would self-deadlock on the
    /// non-reentrant submission lock, so `run` checks this flag and
    /// degrades to inline serial execution instead — identical results
    /// by the indexed-slot rule, and it fails *safe* if a future caller
    /// ever parallelizes inside a job.
    static IN_JOB: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with [`IN_JOB`] set, restoring the previous value even on
/// panic (the panic is still propagated by the caller).
fn with_in_job<T>(f: impl FnOnce() -> T) -> T {
    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            IN_JOB.with(|c| c.set(self.0));
        }
    }
    let prev = IN_JOB.with(|c| c.replace(true));
    let _restore = Restore(prev);
    f()
}

/// Pool width resolved once per process: `SUBMODLIB_THREADS` if set to a
/// positive integer, else `available_parallelism()` (1 if unknown).
pub fn configured_width() -> usize {
    static WIDTH: OnceLock<usize> = OnceLock::new();
    *WIDTH.get_or_init(|| {
        std::env::var("SUBMODLIB_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
            })
    })
}

/// Effective parallel width for the calling thread: [`configured_width`]
/// capped by any enclosing [`with_thread_limit`]. This is the single
/// source of truth every parallel section sizes itself with (the
/// `available_parallelism` copies it replaced are gone).
pub fn num_threads() -> usize {
    let configured = configured_width();
    THREAD_LIMIT.with(|l| match l.get() {
        Some(limit) => limit.clamp(1, configured),
        None => configured,
    })
}

/// Run `f` with this thread's parallel sections capped at `limit`
/// participants (clamped to `[1, configured_width()]` — the pool can
/// narrow but never widen). Thread-local and re-entrant: the previous
/// cap is restored on exit, even on panic. Results are identical at any
/// width (the indexed-slot rule); this exists for determinism tests and
/// baselining.
pub fn with_thread_limit<T>(limit: usize, f: impl FnOnce() -> T) -> T {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_LIMIT.with(|l| l.set(self.0));
        }
    }
    let prev = THREAD_LIMIT.with(|l| l.replace(Some(limit.max(1))));
    let _restore = Restore(prev);
    f()
}

/// Number of detached worker threads the pool owns (resolved width − 1;
/// forces lazy initialization). Exposed so tests can pin "no threads
/// beyond the pool" and the bench snapshot can record the topology.
pub fn worker_count() -> usize {
    global().size
}

/// Execute `job` once per participant with indices `0..parts`, where
/// `parts` is capped at [`num_threads`] (and, transitively, at the pool
/// width). The submitting thread participates (it takes the highest
/// index); `parts <= 1` runs inline without touching the pool, and so
/// does a `run` issued from *inside* a pool job (nested submission
/// would self-deadlock on the submission lock; inline execution is
/// result-identical by the indexed-slot rule). Returns only after every
/// invocation has finished, so `job` may borrow from the caller's
/// stack. Panics inside `job` are propagated to the caller.
pub fn run(parts: usize, job: JobRef<'_>) {
    // fired ambient token: don't start work that would only be thrown
    // away — the Result-returning caller unwinds with `Cancelled`.
    // (An unfired or absent token takes this branch never, so clean
    // runs are untouched.)
    if cancel::active() {
        return;
    }
    let parts = parts.clamp(1, num_threads());
    if parts == 1 || IN_JOB.with(|c| c.get()) {
        job(0);
        return;
    }
    global().run_scoped(parts, job);
}

/// The claim-and-run shape every indexed-slot caller shares: each entry
/// of `items` is claimed exactly once off an atomic counter by whichever
/// participant gets there first and handed to `work` together with its
/// index — so results never depend on the participant count, only on
/// the (deterministic) item order. `parts` is additionally capped at
/// the item count. This is the single implementation of the discipline
/// `kernel::tile`'s direct drivers and `optimizers::batch_gains` run on;
/// keep new fan-outs on it rather than hand-rolling the claim loop.
pub fn run_indexed<T, F>(parts: usize, items: Vec<T>, work: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    let count = items.len();
    if count == 0 {
        return;
    }
    let slots: Mutex<Vec<Option<T>>> = Mutex::new(items.into_iter().map(Some).collect());
    let next = AtomicUsize::new(0);
    run(parts.min(count), &|_worker| loop {
        // poll per item claim: a fired token stops this participant
        // from claiming further work (already-claimed items finish)
        if cancel::active() {
            break;
        }
        let t = next.fetch_add(1, Ordering::Relaxed);
        if t >= count {
            break;
        }
        let item = {
            let mut guard = slots.lock().unwrap();
            guard[t].take().expect("each item is claimed exactly once")
        };
        work(t, item);
    });
}

fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(Pool::spawn)
}

impl Pool {
    /// Spawn the process pool: `configured_width() − 1` parked workers.
    fn spawn() -> Pool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                generation: 0,
                job: None,
                unclaimed: 0,
                next_slot: 0,
                running: 0,
                panic_payload: None,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let want = configured_width().saturating_sub(1);
        let mut size = 0;
        for i in 0..want {
            let sh = Arc::clone(&shared);
            let spawned = std::thread::Builder::new()
                .name(format!("submodlib-pool-{i}"))
                .spawn(move || worker_loop(&sh));
            // a failed spawn just narrows the pool; jobs still complete
            // because slots are claimed, not pre-assigned
            if spawned.is_ok() {
                size += 1;
            }
        }
        Pool { shared, size, submit: Mutex::new(()) }
    }

    fn run_scoped(&self, parts: usize, job: JobRef<'_>) {
        // the caller is one participant; workers take the rest
        let worker_parts = parts.min(self.size + 1) - 1;
        if worker_parts == 0 {
            job(0);
            return;
        }
        let serial = self.submit.lock().unwrap();
        // propagate the submitter's ambient cancel scope into worker
        // invocations: every participant polls the same token (workers
        // have no ambient scope of their own)
        let token = cancel::current();
        let scoped = move |slot: usize| cancel::with_scope(token.clone(), || job(slot));
        let scoped_ref: JobRef<'_> = &scoped;
        // SAFETY: lifetime erasure only — the transmute does not change
        // the fat reference's layout, and this function does not return
        // until `unclaimed` and `running` have both drained to 0 (the
        // `done` wait below), so the erased borrow outlives every
        // dereference a worker performs.
        let erased = Job(unsafe {
            std::mem::transmute::<JobRef<'_>, JobRef<'static>>(scoped_ref) as *const _
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.generation = st.generation.wrapping_add(1);
            st.job = Some(erased);
            st.unclaimed = worker_parts;
            st.next_slot = 0;
            st.panic_payload = None;
            self.shared.work.notify_all();
        }
        // participate with the highest index while the workers run
        // 0..worker_parts (IN_JOB turns any nested `run` inline)
        let caller = catch_unwind(AssertUnwindSafe(|| with_in_job(|| job(worker_parts))));
        let worker_panic = {
            let mut st = self.shared.state.lock().unwrap();
            while st.unclaimed != 0 || st.running != 0 {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
            st.panic_payload.take()
        };
        drop(serial);
        // the caller's own panic wins; otherwise re-raise the first
        // worker panic with its original payload, so diagnostics are
        // the same whichever participant a panic landed on
        if let Err(p) = caller {
            resume_unwind(p);
        }
        if let Some(p) = worker_panic {
            resume_unwind(p);
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let (job, slot) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.generation != seen {
                    if st.unclaimed > 0 {
                        break;
                    }
                    // this generation's slots are all claimed; remember
                    // it so the next wakeup waits for a fresh one
                    seen = st.generation;
                }
                st = shared.work.wait(st).unwrap();
            }
            seen = st.generation;
            st.unclaimed -= 1;
            let slot = st.next_slot;
            st.next_slot += 1;
            st.running += 1;
            (st.job.expect("job published with unclaimed slots"), slot)
        };
        // catch panics so `running` always reaches 0 and the submitter
        // can re-raise instead of deadlocking on `done`; IN_JOB turns
        // any nested `run` issued by the job inline.
        // SAFETY: the submitter keeps the closure behind `job.0` alive —
        // it cannot return from `run_scoped` before this worker drops
        // `running` back to 0 — and the closure is `Sync`, so calling it
        // from this thread is sound.
        let call = || unsafe { (*job.0)(slot) };
        let result = catch_unwind(AssertUnwindSafe(|| with_in_job(call)));
        let mut st = shared.state.lock().unwrap();
        if let Err(p) = result {
            // keep the first payload; later ones are dropped
            if st.panic_payload.is_none() {
                st.panic_payload = Some(p);
            }
        }
        st.running -= 1;
        if st.unclaimed == 0 && st.running == 0 {
            shared.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_participant_index_runs_exactly_once() {
        for parts in [1usize, 2, 3, 8, 64] {
            // effective participants: the requested parts, capped by the
            // width and by the workers actually spawned (+ the caller)
            let expected = parts.clamp(1, num_threads()).min(worker_count() + 1);
            let hits: Vec<AtomicUsize> =
                (0..num_threads().max(parts)).map(|_| AtomicUsize::new(0)).collect();
            run(parts, &|w| {
                hits[w].fetch_add(1, Ordering::Relaxed);
            });
            for (w, h) in hits.iter().enumerate() {
                let want = usize::from(w < expected);
                assert_eq!(h.load(Ordering::Relaxed), want, "slot {w} of {parts}");
            }
        }
    }

    #[test]
    fn nested_run_from_inside_a_job_executes_inline() {
        // a job that itself calls run must not deadlock on the
        // submission lock — IN_JOB degrades the nested call to one
        // inline slot (result-identical by the indexed-slot rule)
        let outer = AtomicUsize::new(0);
        let inner = AtomicUsize::new(0);
        run(num_threads(), &|_w| {
            outer.fetch_add(1, Ordering::Relaxed);
            run(num_threads(), &|iw| {
                assert_eq!(iw, 0, "nested run must collapse to a single inline slot");
                inner.fetch_add(1, Ordering::Relaxed);
            });
        });
        let o = outer.load(Ordering::Relaxed);
        assert!(o >= 1);
        assert_eq!(inner.load(Ordering::Relaxed), o, "one inline nested run per slot");
    }

    /// Item/round counts shrink under Miri (the interpreter is ~100×
    /// slower); the claim/slot/panic paths exercised are identical.
    const N_ITEMS: usize = if cfg!(miri) { 37 } else { 131 };
    const N_CLAIMS: usize = if cfg!(miri) { 33 } else { 257 };
    const N_ROUNDS: usize = if cfg!(miri) { 8 } else { 200 };
    const N_SUBMITTERS: usize = if cfg!(miri) { 2 } else { 4 };
    const N_JOBS_EACH: usize = if cfg!(miri) { 4 } else { 50 };

    #[test]
    fn run_indexed_claims_every_item_exactly_once() {
        for limit in [1usize, 2, 16] {
            with_thread_limit(limit, || {
                let items: Vec<usize> = (0..N_ITEMS).collect();
                let out: Vec<AtomicUsize> =
                    (0..items.len()).map(|_| AtomicUsize::new(usize::MAX)).collect();
                run_indexed(num_threads(), items, |t, item| {
                    assert_eq!(t, item, "index must match the item's position");
                    out[t].store(item * 3, Ordering::Relaxed);
                });
                for (t, o) in out.iter().enumerate() {
                    assert_eq!(o.load(Ordering::Relaxed), t * 3, "limit {limit}");
                }
                // empty input is a no-op, not a panic
                run_indexed(num_threads(), Vec::<usize>::new(), |_t, _item| {
                    panic!("no items to run")
                });
            });
        }
    }

    #[test]
    fn atomic_claiming_covers_all_items_at_any_width() {
        // the canonical caller shape: items claimed off a counter, each
        // writing its own slot — complete and exclusive at every width
        for limit in [1usize, 2, 16] {
            with_thread_limit(limit, || {
                let next = AtomicUsize::new(0);
                let out: Vec<AtomicUsize> =
                    (0..N_CLAIMS).map(|_| AtomicUsize::new(usize::MAX)).collect();
                run(num_threads(), &|_w| loop {
                    let t = next.fetch_add(1, Ordering::Relaxed);
                    if t >= out.len() {
                        break;
                    }
                    out[t].store(t * t, Ordering::Relaxed);
                });
                for (t, o) in out.iter().enumerate() {
                    assert_eq!(o.load(Ordering::Relaxed), t * t, "limit {limit}");
                }
            });
        }
    }

    #[test]
    fn sequential_jobs_reuse_the_pool() {
        // many back-to-back jobs through the same workers; a stuck
        // generation handoff would hang this test
        let total = AtomicUsize::new(0);
        for _ in 0..N_ROUNDS {
            run(num_threads(), &|_w| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert!(total.load(Ordering::Relaxed) >= N_ROUNDS);
    }

    #[test]
    fn concurrent_submitters_serialize_safely() {
        // coordinator-style: several non-pool threads each submitting
        // jobs; the submission lock must keep them isolated
        let sum = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..N_SUBMITTERS {
                scope.spawn(|| {
                    for _ in 0..N_JOBS_EACH {
                        let local = AtomicUsize::new(0);
                        run(2, &|w| {
                            local.fetch_add(w + 1, Ordering::Relaxed);
                        });
                        sum.fetch_add(local.load(Ordering::Relaxed), Ordering::Relaxed);
                    }
                });
            }
        });
        // each job adds 1(+2 when a second participant exists); with
        // width 1 the job degenerates to slot 0 only — either way > 0
        assert!(sum.load(Ordering::Relaxed) >= N_SUBMITTERS * N_JOBS_EACH);
    }

    #[test]
    fn thread_limit_is_scoped_and_restored() {
        let base = num_threads();
        with_thread_limit(1, || {
            assert_eq!(num_threads(), 1);
            with_thread_limit(usize::MAX, || {
                // cannot widen past the configured width
                assert_eq!(num_threads(), configured_width());
            });
            assert_eq!(num_threads(), 1);
        });
        assert_eq!(num_threads(), base);
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        if worker_count() == 0 {
            return; // no workers to panic
        }
        let hit = catch_unwind(AssertUnwindSafe(|| {
            run(2, &|w| {
                if w == 0 {
                    panic!("boom in worker");
                }
            });
        }));
        let payload = hit.expect_err("worker panic must reach the submitter");
        // the ORIGINAL payload is re-raised, not a generic wrapper, so
        // diagnostics don't depend on which participant panicked
        assert_eq!(
            payload.downcast_ref::<&str>().copied(),
            Some("boom in worker")
        );
        // and the pool must still work afterwards
        let ok = AtomicUsize::new(0);
        run(2, &|_w| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert!(ok.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn run_indexed_panic_propagates_and_pool_recovers() {
        let hit = catch_unwind(AssertUnwindSafe(|| {
            run_indexed(num_threads(), (0..16).collect::<Vec<usize>>(), |_t, item| {
                if item == 7 {
                    panic!("boom in item 7");
                }
            });
        }));
        let payload = hit.expect_err("panic inside run_indexed must reach the caller");
        // original payload, whichever participant claimed item 7
        assert_eq!(payload.downcast_ref::<&str>().copied(), Some("boom in item 7"));
        // every slot of the next job still runs: no stuck generation,
        // no leaked claim counter
        let done = AtomicUsize::new(0);
        run_indexed(num_threads(), (0..8).collect::<Vec<usize>>(), |_t, _item| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn run_indexed_results_are_width_independent() {
        // the indexed-slot rule, end to end: bytes out are a pure
        // function of the items, whatever the pool width
        let compute = |limit: usize| {
            with_thread_limit(limit, || {
                let n = if cfg!(miri) { 24 } else { 96 };
                let out: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                run_indexed(num_threads(), (0..n).collect::<Vec<usize>>(), |t, item| {
                    out[t].store(item * item + 1, Ordering::Relaxed);
                });
                out.into_iter().map(AtomicUsize::into_inner).collect::<Vec<usize>>()
            })
        };
        let w1 = compute(1);
        let w2 = compute(2);
        let wmax = compute(usize::MAX);
        assert_eq!(w1, w2, "width 1 vs 2");
        assert_eq!(w1, wmax, "width 1 vs max");
    }

    #[test]
    fn submitter_cancel_scope_reaches_every_participant() {
        use crate::runtime::cancel::{self, CancelToken};
        let token = CancelToken::new();
        let seen = AtomicUsize::new(0);
        cancel::with_scope(Some(token.clone()), || {
            run(num_threads(), &|_w| {
                let ambient = cancel::current().expect("ambient token inside job");
                assert!(ambient.same_as(&token), "worker sees the submitter's token");
                seen.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert!(seen.load(Ordering::Relaxed) >= 1);
        // workers' own scope is restored after the job
        assert!(cancel::current().is_none());
    }

    #[test]
    fn fired_token_stops_claims_and_pool_stays_reusable() {
        use crate::runtime::cancel::{self, CancelReason, CancelToken};
        let token = CancelToken::new();
        token.fire(CancelReason::Manual);
        let touched = AtomicUsize::new(0);
        cancel::with_scope(Some(token), || {
            run_indexed(num_threads(), (0..N_ITEMS).collect::<Vec<usize>>(), |_t, _item| {
                touched.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(touched.load(Ordering::Relaxed), 0, "pre-fired token: no item claimed");
        // the generation protocol completed; the next (clean) job runs fully
        let done = AtomicUsize::new(0);
        run_indexed(num_threads(), (0..N_ITEMS).collect::<Vec<usize>>(), |_t, _item| {
            done.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(done.load(Ordering::Relaxed), N_ITEMS);
    }

    #[test]
    fn unfired_token_is_inert_for_run_indexed() {
        use crate::runtime::cancel::{self, CancelToken};
        let compute = |token: Option<CancelToken>| {
            cancel::with_scope(token, || {
                let out: Vec<AtomicUsize> =
                    (0..N_ITEMS).map(|_| AtomicUsize::new(0)).collect();
                run_indexed(num_threads(), (0..N_ITEMS).collect::<Vec<usize>>(), |t, item| {
                    out[t].store(item * 7 + 1, Ordering::Relaxed);
                });
                out.into_iter().map(AtomicUsize::into_inner).collect::<Vec<usize>>()
            })
        };
        assert_eq!(compute(None), compute(Some(CancelToken::new())));
    }

    #[test]
    fn pool_width_matches_configuration() {
        // workers ≤ width − 1 (the caller is the remaining participant);
        // equality is the normal case but a failed worker spawn only
        // narrows the pool, by design
        assert!(worker_count() < configured_width());
    }
}

//! PJRT CPU client wrapper + artifact registry.
//!
//! Pattern follows /opt/xla-example/src/bin/load_hlo.rs:
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`, unwrapping the 1-tuple the AOT path
//! produces (`return_tuple=True`). The manifest is parsed with the
//! crate's own JSON substrate (util::json).

use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

use crate::error::{Result, SubmodError};
use crate::util::json::Json;

/// Tile geometry block of `manifest.json` (shared with aot.py).
#[derive(Debug, Clone)]
pub struct TileGeometry {
    pub tm: usize,
    pub tn: usize,
    pub d: usize,
    pub gn: usize,
    pub gc: usize,
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub kind: String,
    pub file: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub tile: TileGeometry,
    pub entries: HashMap<String, ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let tile = v
            .get("tile")
            .ok_or_else(|| SubmodError::Runtime("manifest: missing tile".into()))?;
        let tile = TileGeometry {
            tm: tile.req_usize("tm")?,
            tn: tile.req_usize("tn")?,
            d: tile.req_usize("d")?,
            gn: tile.req_usize("gn")?,
            gc: tile.req_usize("gc")?,
        };
        let mut entries = HashMap::new();
        let obj = v
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| SubmodError::Runtime("manifest: missing entries".into()))?;
        for (name, e) in obj {
            entries.insert(
                name.clone(),
                ManifestEntry {
                    kind: e.req_str("kind")?.to_string(),
                    file: e.req_str("file")?.to_string(),
                },
            );
        }
        Ok(Manifest { tile, entries })
    }
}

#[cfg(feature = "pjrt")]
fn rt<E: std::fmt::Display>(what: &'static str) -> impl Fn(E) -> SubmodError {
    move |e| SubmodError::Runtime(format!("{what}: {e}"))
}

/// PJRT engine: one compiled executable per artifact, compile-once cache.
///
/// Real implementation requires the `pjrt` cargo feature *and* an `xla`
/// dependency added to Cargo.toml (the crate is not vendorable in the
/// offline environment — see the manifest's comments). Without the
/// feature, the stub below keeps every call site compiling: `load`
/// returns a `Runtime` error and the tile entry points are unreachable.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: std::sync::Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create the CPU client and parse the manifest. Executables compile
    /// lazily on first use and are cached for the process lifetime.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(rt("pjrt cpu client"))?;
        Ok(Engine { client, manifest, dir, exes: std::sync::Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.entries.get(name).ok_or_else(|| {
            SubmodError::Runtime(format!("artifact {name} not in manifest"))
        })?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| SubmodError::Runtime("non-utf8 path".into()))?,
        )
        .map_err(rt("parse hlo text"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp).map_err(rt("compile"))?);
        self.exes.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a 2-input → 1-output (tupled) artifact with f32 buffers.
    fn run2(
        &self,
        name: &str,
        a: (&[f32], &[usize]),
        b: (&[f32], &[usize]),
    ) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let to_lit = |buf: &[f32], shape: &[usize]| -> Result<xla::Literal> {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(buf).reshape(&dims).map_err(rt("reshape literal"))
        };
        let la = to_lit(a.0, a.1)?;
        let lb = to_lit(b.0, b.1)?;
        let result = exe.execute::<xla::Literal>(&[la, lb]).map_err(rt("execute"))?[0][0]
            .to_literal_sync()
            .map_err(rt("to_literal"))?;
        let out = result.to_tuple1().map_err(rt("untuple"))?;
        out.to_vec::<f32>().map_err(rt("literal to vec"))
    }

    /// Run a similarity tile: x (TM×D), y (TN×D) → (TM×TN) row-major.
    pub fn similarity_tile(&self, metric_tag: &str, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let t = &self.manifest.tile;
        if x.len() != t.tm * t.d || y.len() != t.tn * t.d {
            return Err(SubmodError::Shape(format!(
                "similarity tile buffers {}/{} vs {}x{}/{}x{}",
                x.len(),
                y.len(),
                t.tm,
                t.d,
                t.tn,
                t.d
            )));
        }
        let name = format!("similarity_{}_{}x{}x{}", metric_tag, t.tm, t.tn, t.d);
        self.run2(&name, (x, &[t.tm, t.d]), (y, &[t.tn, t.d]))
    }

    /// Run the FL-gains artifact: s (GN×GC), max_vec (GN,) → gains (GC,).
    pub fn fl_gains(&self, s: &[f32], max_vec: &[f32]) -> Result<Vec<f32>> {
        let t = &self.manifest.tile;
        if s.len() != t.gn * t.gc || max_vec.len() != t.gn {
            return Err(SubmodError::Shape(format!(
                "fl_gains buffers {}/{} vs {}x{}/{}",
                s.len(),
                max_vec.len(),
                t.gn,
                t.gc,
                t.gn
            )));
        }
        let name = format!("fl_gains_{}x{}", t.gn, t.gc);
        self.run2(&name, (s, &[t.gn, t.gc]), (max_vec, &[t.gn]))
    }
}

#[cfg(feature = "pjrt")]
impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("dir", &self.dir)
            .field("entries", &self.manifest.entries.len())
            .finish()
    }
}

/// Stub engine (no `pjrt` feature): same public surface, but `load`
/// fails after validating the manifest, so the native kernel paths stay
/// the only ones reachable. `runtime_pjrt.rs` tests already skip when
/// artifacts are absent; `submodlib runtime` reports the load error.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Parses the manifest (surface-checking the artifacts dir), then
    /// reports that no PJRT client can be created in this build.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let _manifest = Manifest::load(artifacts_dir.as_ref())?;
        Err(SubmodError::Runtime(
            "PJRT runtime unavailable: built without the `pjrt` cargo feature \
             (the `xla` crate is not present in this environment; see Cargo.toml)"
                .into(),
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable (pjrt feature disabled)".to_string()
    }

    pub fn similarity_tile(
        &self,
        _metric_tag: &str,
        _x: &[f32],
        _y: &[f32],
    ) -> Result<Vec<f32>> {
        Err(Self::unavailable())
    }

    pub fn fl_gains(&self, _s: &[f32], _max_vec: &[f32]) -> Result<Vec<f32>> {
        Err(Self::unavailable())
    }

    fn unavailable() -> SubmodError {
        SubmodError::Runtime("PJRT runtime unavailable (pjrt feature disabled)".into())
    }
}

#[cfg(not(feature = "pjrt"))]
impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("entries", &self.manifest.entries.len())
            .field("stub", &true)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
            "tile": {"tm": 256, "tn": 256, "d": 1024, "gn": 1024, "gc": 256},
            "entries": {
                "similarity_euclidean_256x256x1024": {
                    "kind": "similarity", "metric": "euclidean",
                    "tm": 256, "tn": 256, "d": 1024,
                    "file": "similarity_euclidean_256x256x1024.hlo.txt"
                }
            }
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.tile.tm, 256);
        assert_eq!(m.entries.len(), 1);
        assert_eq!(
            m.entries["similarity_euclidean_256x256x1024"].kind,
            "similarity"
        );
    }

    #[test]
    fn bad_manifest_rejected() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"tile": {"tm": 1}}"#).is_err());
    }
}

//! Tiling drivers: stitch fixed-shape artifact invocations into
//! arbitrary-shape kernel builds.
//!
//! The AOT artifacts are compiled at one tile geometry (manifest `tile`):
//! similarity tiles of (TM×D)·(TN×D) and FL-gain blocks of (GN×GC). Real
//! ground sets are any size, so we zero-pad features up to D, pad item
//! counts up to tile multiples, loop tile pairs, and copy out only the
//! valid region. Zero-padding the *feature* axis is exact for every metric
//! (dot, norms and distances are unchanged by appended zeros); padded
//! *items* produce garbage rows/cols that are simply never copied out.
//!
//! These drivers are the device-side counterpart of the native compute
//! backends (`kernel::backend`): on the CPU path one `InnerKernel` call
//! fills one output row; here one artifact invocation fills one tile.
//! The trait boundary is the seam a future PJRT-backed `InnerKernel`
//! plugs into — one tile = one device launch — at which point backend
//! selection covers devices, not just CPU ISAs.

use super::client::Engine;
use crate::error::{Result, SubmodError};
use crate::kernel::metric::Metric;
use crate::linalg::Matrix;

/// Pad `data` (n×d) into a (rows_padded × d_padded) row-major buffer.
fn pad_features(data: &Matrix, rows_padded: usize, d_padded: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows_padded * d_padded];
    for i in 0..data.rows() {
        out[i * d_padded..i * d_padded + data.cols()].copy_from_slice(data.row(i));
    }
    out
}

/// Build a dense similarity kernel through the PJRT artifact path.
///
/// Functionally identical to `DenseKernel::from_data` (native); exists so
/// the whole L1→L2→L3 stack is exercised end-to-end and so the headline
/// kernel build can run on a real accelerator when one is present.
pub fn build_dense_kernel(engine: &Engine, data: &Matrix, metric: Metric) -> Result<Matrix> {
    build_rect_kernel(engine, data, data, metric)
}

/// Build a rectangular similarity kernel (rows set × cols set) via PJRT.
pub fn build_rect_kernel(
    engine: &Engine,
    rows_data: &Matrix,
    cols_data: &Matrix,
    metric: Metric,
) -> Result<Matrix> {
    if rows_data.cols() != cols_data.cols() {
        return Err(SubmodError::Shape(format!(
            "feature dims {} vs {}",
            rows_data.cols(),
            cols_data.cols()
        )));
    }
    let t = engine.manifest().tile.clone();
    if rows_data.cols() > t.d {
        return Err(SubmodError::Unsupported(format!(
            "feature dim {} exceeds compiled tile depth {}; recompile artifacts",
            rows_data.cols(),
            t.d
        )));
    }
    let (m, n) = (rows_data.rows(), cols_data.rows());
    let mp = m.div_ceil(t.tm) * t.tm;
    let np = n.div_ceil(t.tn) * t.tn;
    let a = pad_features(rows_data, mp, t.d);
    let b = pad_features(cols_data, np, t.d);

    let mut out = Matrix::zeros(m, n);
    for ti in 0..mp / t.tm {
        let arow = &a[ti * t.tm * t.d..(ti + 1) * t.tm * t.d];
        for tj in 0..np / t.tn {
            let brow = &b[tj * t.tn * t.d..(tj + 1) * t.tn * t.d];
            let tile = engine.similarity_tile(metric.tag(), arow, brow)?;
            // copy the valid region of this (tm × tn) tile
            let i0 = ti * t.tm;
            let j0 = tj * t.tn;
            let ih = t.tm.min(m - i0.min(m));
            let jw = t.tn.min(n - j0.min(n));
            if i0 >= m || j0 >= n {
                continue;
            }
            for di in 0..ih {
                let src = &tile[di * t.tn..di * t.tn + jw];
                out.row_mut(i0 + di)[j0..j0 + jw].copy_from_slice(src);
            }
        }
    }
    Ok(out)
}

/// Batched FL marginal gains via the PJRT artifact: pads (n × c) similarity
/// columns and the memoized max-vector up to (GN × GC) and unpads gains.
///
/// Padding correctness: padded *rows* get max_vec = +inf so their relu
/// contribution is 0; padded *columns* produce gains we drop.
pub fn fl_gains(
    engine: &Engine,
    s_cols: &Matrix, // n × c
    max_vec: &[f32],
) -> Result<Vec<f32>> {
    let t = engine.manifest().tile.clone();
    let (n, c) = (s_cols.rows(), s_cols.cols());
    if max_vec.len() != n {
        return Err(SubmodError::Shape(format!("max_vec {} vs n {}", max_vec.len(), n)));
    }
    if c > t.gc {
        return Err(SubmodError::Unsupported(format!(
            "candidate batch {c} exceeds compiled width {}; split the batch",
            t.gc
        )));
    }
    let mut gains = vec![0f32; c];
    // loop row blocks of GN, accumulating
    let blocks = n.div_ceil(t.gn);
    for bi in 0..blocks {
        let r0 = bi * t.gn;
        let rh = t.gn.min(n - r0);
        let mut s_pad = vec![0f32; t.gn * t.gc];
        let mut mv_pad = vec![f32::INFINITY; t.gn];
        for di in 0..rh {
            s_pad[di * t.gc..di * t.gc + c].copy_from_slice(s_cols.row(r0 + di));
        }
        mv_pad[..rh].copy_from_slice(&max_vec[r0..r0 + rh]);
        // padded rows: s=0, mv=+inf → relu(0 − inf) = 0 contribution ✓
        let block_gains = engine.fl_gains(&s_pad, &mv_pad)?;
        for (g, bg) in gains.iter_mut().zip(&block_gains[..c]) {
            *g += bg;
        }
    }
    Ok(gains)
}

//! Cooperative cancellation: bounded-latency compute without clocks.
//!
//! A [`CancelToken`] is a shared atomic flag that compute layers *poll*
//! at their natural claim boundaries — per tile in the `kernel::tile`
//! drivers (including the sparse wavefront's wedge claims), per
//! `GAIN_CHUNK` in `optimizers::batch_gains`, per iteration in the
//! greedy optimizer loops, per item claim in `pool::run_indexed` — and
//! unwind from with a typed [`SubmodError::Cancelled`]. Nothing here
//! preempts anything: a fired token means workers simply stop claiming
//! new work and the Result-returning layer above discards its partial
//! buffers. That keeps every invariant the compute stack already has:
//! no poisoned locks, no partially-filled output ever escapes, the
//! pool's generation protocol completes normally, and memoized function
//! states are only mutated by `update_memoization` calls that were
//! never issued.
//!
//! # No wall-clock below the rim
//!
//! This module contains **no** `Instant`/`SystemTime` — deliberately.
//! Time lives only at the coordinator rim (`coordinator::watchdog`),
//! which arms tokens from request deadlines and shutdown grace budgets;
//! the compute layers see a pure boolean. The `wall-clock` conformance
//! rule is scoped over this file (see `analysis::rules`), so an
//! `Instant::now()` smuggled into token polling fails the tier-1
//! conformance gate.
//!
//! # Determinism contract
//!
//! * A token that **never fires** is inert: every selection and kernel
//!   build is byte-identical to a run with no token at all, at every
//!   pool width and on every compute backend (polls read a flag; they
//!   never reorder claims or change arithmetic).
//! * A token that **fires** aborts the whole operation with
//!   [`SubmodError::Cancelled`] — never a partial result, never a
//!   nondeterministic prefix.
//!
//! # Ambient scope
//!
//! Tokens propagate through the stack as a thread-local *ambient
//! scope* ([`with_scope`]) instead of threading an argument through
//! every signature (kernel constructors like `DenseKernel::from_data`
//! stay non-`Result`; cancellation there surfaces at the nearest
//! Result-returning caller). `pool::run` captures the submitter's
//! ambient token at submission and re-installs it inside each worker
//! invocation, so a job polls the same token on every participant.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

use crate::error::{Result, SubmodError};

/// Why a token fired. First `fire` wins; later reasons are ignored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// Fired directly by a library user.
    Manual,
    /// Fired by the coordinator's deadline watchdog; the coordinator
    /// maps the resulting `Cancelled` back to `DeadlineExceeded`.
    Deadline,
    /// Fired by hard-cancel shutdown after the drain grace budget.
    Shutdown,
}

const UNFIRED: u8 = 0;

impl CancelReason {
    fn code(self) -> u8 {
        match self {
            CancelReason::Manual => 1,
            CancelReason::Deadline => 2,
            CancelReason::Shutdown => 3,
        }
    }

    fn decode(code: u8) -> Option<CancelReason> {
        match code {
            1 => Some(CancelReason::Manual),
            2 => Some(CancelReason::Deadline),
            3 => Some(CancelReason::Shutdown),
            _ => None,
        }
    }
}

/// Shared cooperative-cancellation flag. Cheap to clone (an `Arc`);
/// all clones observe the same fire.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    // Single atomic: 0 = unfired, else the CancelReason code. The token
    // carries no data, only a "stop claiming work" signal, so relaxed
    // ordering is sufficient — visibility is eventual and the compute
    // layers re-poll at every claim boundary anyway.
    state: Arc<AtomicU8>,
}

impl CancelToken {
    /// A fresh, unfired token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Fire the token. First caller's reason sticks; firing an already
    /// fired token is a no-op. Returns whether this call was the one
    /// that fired it.
    pub fn fire(&self, reason: CancelReason) -> bool {
        self.state
            .compare_exchange(UNFIRED, reason.code(), Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    }

    /// Has the token fired? (The poll the compute layers use.)
    pub fn is_fired(&self) -> bool {
        self.state.load(Ordering::Relaxed) != UNFIRED
    }

    /// The reason the token fired, or `None` while unfired.
    pub fn reason(&self) -> Option<CancelReason> {
        CancelReason::decode(self.state.load(Ordering::Relaxed))
    }

    /// `Err(Cancelled)` once fired, `Ok(())` before.
    pub fn check(&self) -> Result<()> {
        if self.is_fired() {
            Err(SubmodError::Cancelled)
        } else {
            Ok(())
        }
    }

    /// Do `self` and `other` observe the same underlying flag?
    pub fn same_as(&self, other: &CancelToken) -> bool {
        Arc::ptr_eq(&self.state, &other.state)
    }
}

thread_local! {
    /// The ambient token for this thread, if any. Installed by
    /// [`with_scope`]; the pool re-installs the submitter's scope
    /// inside worker invocations.
    static SCOPE: std::cell::RefCell<Option<CancelToken>> =
        const { std::cell::RefCell::new(None) };
}

/// Run `f` with `token` as the thread's ambient cancel scope,
/// restoring the previous scope afterwards (also on unwind).
/// `None` runs `f` with no ambient token (shadowing any outer scope) —
/// callers that merely *might* have a token should pass the outer
/// scope through via [`current`] instead of `None`.
pub fn with_scope<R>(token: Option<CancelToken>, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<CancelToken>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0.take();
            SCOPE.with(|s| *s.borrow_mut() = prev);
        }
    }
    let prev = SCOPE.with(|s| std::mem::replace(&mut *s.borrow_mut(), token));
    let _restore = Restore(prev);
    f()
}

/// The ambient token installed on this thread, if any.
pub fn current() -> Option<CancelToken> {
    SCOPE.with(|s| s.borrow().clone())
}

/// Cheap poll: has the ambient token fired? `false` when no scope is
/// installed — code with no token in play never aborts.
pub fn active() -> bool {
    SCOPE.with(|s| s.borrow().as_ref().is_some_and(CancelToken::is_fired))
}

/// `Err(Cancelled)` if the ambient token has fired, else `Ok(())`.
/// The standard poll at Result-returning claim boundaries.
pub fn check_current() -> Result<()> {
    if active() {
        Err(SubmodError::Cancelled)
    } else {
        Ok(())
    }
}

/// Fire the ambient token (if any) with `reason`. Returns whether a
/// scope was installed. Used by the `coordinator::faults` Cancel
/// action so a failpoint can fire *whichever* request's token is in
/// scope at the site — deterministic regardless of which chunk or tile
/// trips first, because the whole operation aborts either way.
pub fn fire_current(reason: CancelReason) -> bool {
    SCOPE.with(|s| match s.borrow().as_ref() {
        Some(t) => {
            t.fire(reason);
            true
        }
        None => false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_fire_wins_and_sticks() {
        let t = CancelToken::new();
        assert!(!t.is_fired());
        assert_eq!(t.reason(), None);
        assert!(t.check().is_ok());
        assert!(t.fire(CancelReason::Deadline));
        assert!(!t.fire(CancelReason::Manual), "second fire is a no-op");
        assert!(t.is_fired());
        assert_eq!(t.reason(), Some(CancelReason::Deadline));
        assert!(matches!(t.check(), Err(SubmodError::Cancelled)));
    }

    #[test]
    fn clones_share_the_flag() {
        let t = CancelToken::new();
        let c = t.clone();
        assert!(t.same_as(&c));
        assert!(!t.same_as(&CancelToken::new()));
        c.fire(CancelReason::Manual);
        assert!(t.is_fired());
    }

    #[test]
    fn scope_installs_nests_and_restores() {
        assert!(current().is_none());
        assert!(!active());
        assert!(check_current().is_ok());
        assert!(!fire_current(CancelReason::Manual), "no scope: nothing to fire");

        let outer = CancelToken::new();
        with_scope(Some(outer.clone()), || {
            assert!(current().unwrap().same_as(&outer));
            let inner = CancelToken::new();
            with_scope(Some(inner.clone()), || {
                assert!(current().unwrap().same_as(&inner));
                // None shadows: no ambient token inside
                with_scope(None, || {
                    assert!(current().is_none());
                    assert!(!active());
                });
                assert!(current().unwrap().same_as(&inner));
                assert!(fire_current(CancelReason::Manual));
                assert!(active());
                assert!(matches!(check_current(), Err(SubmodError::Cancelled)));
            });
            // inner fired, outer untouched
            assert!(!outer.is_fired());
            assert!(!active());
        });
        assert!(current().is_none());
    }

    #[test]
    fn scope_restores_on_unwind() {
        let t = CancelToken::new();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            with_scope(Some(t.clone()), || panic!("boom"));
        }));
        assert!(r.is_err());
        assert!(current().is_none(), "scope restored across unwind");
    }
}

//! Controlled 2-D datasets reproducing the paper's qualitative figures.
//!
//! The paper does not publish coordinates; these are hand-laid-out to
//! match its *descriptions* exactly — "some clusters and some outliers"
//! (Fig 4: 48 ground points + a separate represented set; Fig 6: 46 ground
//! points + query points disjoint from the ground set) — so that the
//! documented behaviours (FL picks cluster centers first and the outlier
//! last; DisparitySum picks remote corners/outliers first; FLQMI at η=0
//! picks one point per query then saturates; GCMI is pure retrieval) are
//! reproducible and *testable*.

use crate::linalg::Matrix;

/// Fig 4 dataset: 48 ground points (4 tight clusters of 11 + 4 outliers)
/// and a 12-point represented set straddling the clusters.
/// Returns (ground, represented, outlier indices).
pub fn fig4_dataset() -> (Matrix, Matrix, Vec<usize>) {
    let mut pts: Vec<[f32; 2]> = Vec::with_capacity(48);
    // 4 clusters of 11 points each around these centers
    let centers = [[2.0f32, 2.0], [8.0, 2.5], [2.5, 8.0], [8.0, 8.0]];
    // deterministic ring layout: center + 10 points on two radii
    for c in &centers {
        pts.push(*c);
        for r in 0..10 {
            let ang = r as f32 * std::f32::consts::TAU / 10.0;
            let rad = if r % 2 == 0 { 0.55 } else { 0.95 };
            pts.push([c[0] + rad * ang.cos(), c[1] + rad * ang.sin()]);
        }
    }
    // 4 outliers far from every cluster
    let outliers_xy = [[13.5f32, 13.0], [-2.5, 12.5], [13.0, -2.0], [5.0, 14.0]];
    let outlier_idx: Vec<usize> = (44..48).collect();
    pts.extend_from_slice(&outliers_xy);
    let ground = matrix_from_xy(&pts);

    // represented set: 12 green points clustered near clusters 0, 1 and 3
    let rep: Vec<[f32; 2]> = vec![
        [2.2, 1.8],
        [1.7, 2.4],
        [2.6, 2.3],
        [8.2, 2.2],
        [7.7, 2.8],
        [8.5, 2.9],
        [7.8, 7.7],
        [8.3, 8.4],
        [7.6, 8.3],
        [8.6, 7.8],
        [2.1, 2.6],
        [8.1, 2.6],
    ];
    (ground, matrix_from_xy(&rep), outlier_idx)
}

/// Fig 6 dataset: 46 ground points (3 clusters + outliers) and 2 query
/// points placed near two *different* clusters, disjoint from the ground
/// set. Returns (ground, queries, per-cluster index ranges, outlier idx).
#[allow(clippy::type_complexity)]
pub fn fig6_dataset() -> (Matrix, Matrix, Vec<std::ops::Range<usize>>, Vec<usize>) {
    let mut pts: Vec<[f32; 2]> = Vec::with_capacity(46);
    let centers = [[2.0f32, 2.0], [9.0, 2.0], [5.5, 9.0]];
    let mut ranges = Vec::new();
    for c in &centers {
        let start = pts.len();
        pts.push(*c);
        for r in 0..13 {
            let ang = r as f32 * std::f32::consts::TAU / 13.0;
            let rad = if r % 2 == 0 { 0.5 } else { 0.9 };
            pts.push([c[0] + rad * ang.cos(), c[1] + rad * ang.sin()]);
        }
        ranges.push(start..pts.len());
    }
    // 4 outliers
    let outlier_idx: Vec<usize> = (42..46).collect();
    pts.extend_from_slice(&[[14.0, 14.0], [-3.0, 13.0], [14.5, -2.5], [-3.5, -3.0]]);
    let ground = matrix_from_xy(&pts);

    // queries near clusters 0 and 1, offset so they are not ground points
    let queries = matrix_from_xy(&[[2.3, 1.6], [8.7, 2.4]]);
    (ground, queries, ranges, outlier_idx)
}

/// Privacy-figure companion dataset: same geometry as fig6 but the two
/// "conditioning" points act as a private set near clusters 1 and 2.
pub fn private_set_for_fig6() -> Matrix {
    matrix_from_xy(&[[9.3, 1.7], [5.2, 9.3]])
}

fn matrix_from_xy(pts: &[[f32; 2]]) -> Matrix {
    let mut m = Matrix::zeros(pts.len(), 2);
    for (i, p) in pts.iter().enumerate() {
        m.set(i, 0, p[0]);
        m.set(i, 1, p[1]);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    #[test]
    fn fig4_counts() {
        let (g, rep, out) = fig4_dataset();
        assert_eq!(g.rows(), 48);
        assert_eq!(rep.rows(), 12);
        assert_eq!(out, vec![44, 45, 46, 47]);
    }

    #[test]
    fn fig4_outliers_are_remote() {
        let (g, _, out) = fig4_dataset();
        // every outlier's nearest non-outlier neighbor is farther than any
        // intra-cluster distance (~<2.0)
        for &o in &out {
            let mut nearest = f32::INFINITY;
            for i in 0..44 {
                nearest = nearest.min(linalg::sq_dist(g.row(o), g.row(i)).sqrt());
            }
            assert!(nearest > 3.0, "outlier {o} too close ({nearest})");
        }
    }

    #[test]
    fn fig6_counts_and_query_disjoint() {
        let (g, q, ranges, out) = fig6_dataset();
        assert_eq!(g.rows(), 46);
        assert_eq!(q.rows(), 2);
        assert_eq!(ranges.len(), 3);
        assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>() + out.len(), 46);
        // queries are not ground points
        for qi in 0..2 {
            for i in 0..46 {
                assert!(linalg::sq_dist(q.row(qi), g.row(i)) > 1e-4);
            }
        }
    }

    #[test]
    fn fig6_queries_near_distinct_clusters() {
        let (g, q, ranges, _) = fig6_dataset();
        let nearest_cluster = |qi: usize| -> usize {
            let mut best = (0usize, f32::INFINITY);
            for (c, r) in ranges.iter().enumerate() {
                for i in r.clone() {
                    let d = linalg::sq_dist(q.row(qi), g.row(i));
                    if d < best.1 {
                        best = (c, d);
                    }
                }
            }
            best.0
        };
        assert_eq!(nearest_cluster(0), 0);
        assert_eq!(nearest_cluster(1), 1);
    }
}

//! Dataset substrate: synthetic generators reproducing the paper's
//! evaluation workloads, controlled 2-D datasets for the qualitative
//! figures, simple I/O, and the aligned SoA point views the SIMD
//! compute backends load from ([`points`]).

pub mod controlled;
pub mod io;
pub mod points;
pub mod synthetic;

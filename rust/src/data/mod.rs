//! Dataset substrate: synthetic generators reproducing the paper's
//! evaluation workloads, controlled 2-D datasets for the qualitative
//! figures, and simple I/O.

pub mod controlled;
pub mod io;
pub mod synthetic;

//! Synthetic dataset generators for the paper's experiments.
//!
//! * [`blobs`] — isotropic Gaussian clusters: the §5.3.5 optimizer-
//!   comparison dataset is `blobs(500, 2, 10, 4.0, seed)` (Figure 3).
//! * [`random_features`] — the §9 timing-analysis dataset: uniformly
//!   random d-dimensional points (paper used 1024-d, n ∈ 50..10000).
//! * [`vgg_like_features`] — the Imagenette/VGG substitution (DESIGN.md
//!   §7): unit-normalized anisotropic clusters in high dimension standing
//!   in for VGG fc2 features of an image collection, plus query items
//!   drawn from designated query clusters.

use crate::linalg::Matrix;
use crate::rng::Pcg64;

/// `n` points in `dim` dimensions from `k` Gaussian blobs with the given
/// standard deviation. Blob centers are spread uniformly in a box scaled
/// to keep blobs distinguishable; points are laid out blob-major (all of
/// blob 0, then blob 1, ...), remainder distributed round-robin.
pub fn blobs(n: usize, dim: usize, k: usize, std_dev: f64, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    let box_side = 10.0 * std_dev.max(1.0) * (k as f64).sqrt();
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..dim).map(|_| (rng.next_f64() - 0.5) * box_side).collect())
        .collect();
    let mut data = Matrix::zeros(n, dim);
    for i in 0..n {
        let c = if n >= k { (i * k) / n.max(1) } else { i % k }.min(k - 1);
        for j in 0..dim {
            data.set(i, j, (centers[c][j] + rng.next_gaussian() * std_dev) as f32);
        }
    }
    data
}

/// Uniformly random features in [0, 1)^dim — the Table 5 workload.
pub fn random_features(n: usize, dim: usize, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed);
    Matrix::from_vec(n, dim, (0..n * dim).map(|_| rng.next_f32()).collect()).unwrap()
}

/// Imagenette/VGG substitution: returns (ground features, query features,
/// ground-truth cluster label per ground item). Clusters are anisotropic
/// (per-axis scales), unit-normalized like VGG fc features after L2 norm.
/// Queries are drawn from the first `n_query_clusters` clusters.
pub fn vgg_like_features(
    n: usize,
    dim: usize,
    k: usize,
    n_queries: usize,
    n_query_clusters: usize,
    seed: u64,
) -> (Matrix, Matrix, Vec<usize>) {
    assert!(n_query_clusters >= 1 && n_query_clusters <= k);
    let mut rng = Pcg64::new(seed);
    // cluster directions: random unit vectors; anisotropy: per-cluster axis scales
    let centers: Vec<Vec<f64>> = (0..k)
        .map(|_| {
            let v: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let nrm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-9);
            v.into_iter().map(|x| x / nrm).collect()
        })
        .collect();
    let scales: Vec<f64> = (0..k).map(|_| 0.05 + 0.10 * rng.next_f64()).collect();

    let sample = |c: usize, rng: &mut Pcg64| -> Vec<f32> {
        let mut v: Vec<f64> = (0..dim)
            .map(|j| centers[c][j] + rng.next_gaussian() * scales[c] / (dim as f64).sqrt())
            .collect();
        let nrm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-9);
        for x in &mut v {
            *x /= nrm;
        }
        v.into_iter().map(|x| x as f32).collect()
    };

    let mut ground = Matrix::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = (i * k) / n.max(1);
        let c = c.min(k - 1);
        ground.row_mut(i).copy_from_slice(&sample(c, &mut rng));
        labels.push(c);
    }
    let mut queries = Matrix::zeros(n_queries, dim);
    for q in 0..n_queries {
        let c = q % n_query_clusters;
        queries.row_mut(q).copy_from_slice(&sample(c, &mut rng));
    }
    (ground, queries, labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg;

    #[test]
    fn blobs_shape_and_determinism() {
        let a = blobs(500, 2, 10, 4.0, 42);
        let b = blobs(500, 2, 10, 4.0, 42);
        assert_eq!(a.rows(), 500);
        assert_eq!(a.cols(), 2);
        assert_eq!(a.as_slice(), b.as_slice());
        let c = blobs(500, 2, 10, 4.0, 43);
        assert_ne!(a.as_slice(), c.as_slice());
    }

    #[test]
    fn blobs_are_clustered() {
        // intra-blob distance should be far below inter-blob on average
        let data = blobs(100, 2, 2, 0.5, 1);
        let per = 50;
        let intra = linalg::sq_dist(data.row(0), data.row(per - 1)).sqrt();
        let inter = linalg::sq_dist(data.row(0), data.row(per + 1)).sqrt();
        assert!(inter > intra, "inter {inter} <= intra {intra}");
    }

    #[test]
    fn random_features_in_unit_box() {
        let m = random_features(100, 8, 3);
        assert!(m.as_slice().iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn vgg_like_unit_norm_and_query_alignment() {
        let (g, q, labels) = vgg_like_features(60, 64, 6, 4, 2, 11);
        assert_eq!(labels.len(), 60);
        for i in 0..60 {
            assert!((linalg::norm(g.row(i)) - 1.0).abs() < 1e-4);
        }
        // queries must be most similar (cosine=dot on unit vectors) to
        // items of their own cluster
        for qi in 0..4 {
            let qc = qi % 2;
            let mut best = (0usize, f32::NEG_INFINITY);
            for i in 0..60 {
                let s = linalg::dot(q.row(qi), g.row(i));
                if s > best.1 {
                    best = (i, s);
                }
            }
            assert_eq!(labels[best.0], qc, "query {qi} nearest to wrong cluster");
        }
    }
}

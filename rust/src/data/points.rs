//! Aligned structure-of-arrays (SoA) point views — the load layout the
//! SIMD compute backends (`kernel::backend`, ISSUE 9 / ROADMAP item 2)
//! vectorize over.
//!
//! The row-major [`Matrix`] keeps one *point* contiguous, which is the
//! right layout for the scalar register-blocked kernels (one dot product
//! walks one row). A vector lane, however, wants eight *consecutive
//! columns* of the gram row at once — eight different points — so the
//! SIMD backends transpose the operand into [`SoaPoints`]: feature-major
//! storage where row `f` holds feature `f` of every point, padded and
//! aligned so an 8-wide load of columns `[j, j+8)` is one contiguous
//! `loadu` from `feature(f)[j..]`.
//!
//! Layout contract:
//!
//! * each feature row is `stride = n.div_ceil(16) * 16` floats long
//!   (64-byte multiples, [`SoaPoints::padded_cols`]); columns `[n, stride)`
//!   are zero,
//! * the first feature row starts on a 64-byte boundary (the buffer
//!   over-allocates [`SOA_LANE`] slack floats and advances to alignment —
//!   plain pointer arithmetic, no `unsafe`), so every feature row is
//!   64-byte aligned (strides are 64-byte multiples),
//! * the backends never *read* the zero padding for results — tails
//!   narrower than a vector are computed by per-column scalar chains with
//!   the same op order — so padding affects layout, never values.
//!
//! The peak-memory models (`kernel::tile::{dense,sparse}_peak_bytes`)
//! account for this buffer via [`SoaPoints::padded_bytes`], and the unit
//! tests here pin that model to the actual allocation.

use crate::linalg::Matrix;

/// Columns per padded group: 16 f32 = 64 bytes, one cache line and two
/// AVX2 vectors. Feature-row strides round up to a multiple of this.
pub const SOA_LANE: usize = 16;

/// Target byte alignment of every feature row.
const ALIGN_BYTES: usize = 64;

/// Feature-major, 64-byte-aligned, column-padded copy of a point set.
#[derive(Debug, Clone)]
pub struct SoaPoints {
    n: usize,
    d: usize,
    stride: usize,
    /// Index of the first aligned element inside `buf`.
    offset: usize,
    buf: Vec<f32>,
}

impl SoaPoints {
    /// Padded column count for `n` points: `n` rounded up to a multiple
    /// of [`SOA_LANE`]. This is the per-feature row stride.
    #[inline]
    pub fn padded_cols(n: usize) -> usize {
        n.div_ceil(SOA_LANE) * SOA_LANE
    }

    /// Total f32 slots an `n × d` view allocates: `d` padded feature
    /// rows plus [`SOA_LANE`] slack slots consumed by alignment.
    #[inline]
    pub fn padded_len(n: usize, d: usize) -> usize {
        d * Self::padded_cols(n) + SOA_LANE
    }

    /// Heap bytes of an `n × d` view — the figure the peak-memory models
    /// in `kernel::tile` add when the active backend wants SoA operands.
    #[inline]
    pub fn padded_bytes(n: usize, d: usize) -> usize {
        4 * Self::padded_len(n, d)
    }

    /// Transpose a row-major `n × d` matrix into feature-major padded
    /// storage. O(n·d) — negligible next to the O(n²·d) builds it feeds.
    pub fn from_matrix(m: &Matrix) -> Self {
        let n = m.rows();
        let d = m.cols();
        let stride = Self::padded_cols(n);
        let buf = vec![0f32; Self::padded_len(n, d)];
        // Advance to the first 64-byte boundary. A Vec<f32> pointer is
        // 4-byte aligned, so the gap to the boundary is a multiple of 4
        // bytes and at most SOA_LANE - 1 elements — inside the slack.
        let addr = buf.as_ptr() as usize;
        let offset = (ALIGN_BYTES - addr % ALIGN_BYTES) % ALIGN_BYTES / 4;
        debug_assert!(offset < SOA_LANE);
        let mut soa = SoaPoints { n, d, stride, offset, buf };
        for i in 0..n {
            let row = m.row(i);
            for (f, &v) in row.iter().enumerate() {
                soa.buf[soa.offset + f * stride + i] = v;
            }
        }
        soa
    }

    /// Feature row `f`: feature `f` of point `j` at index `j`, columns
    /// `[n, stride)` zero. The slice is 64-byte aligned.
    #[inline]
    pub fn feature(&self, f: usize) -> &[f32] {
        debug_assert!(f < self.d);
        let start = self.offset + f * self.stride;
        &self.buf[start..start + self.stride]
    }

    /// Number of (real, unpadded) points.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Feature dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Per-feature row stride in f32 slots.
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Actual heap footprint of the backing buffer, for pinning the
    /// [`padded_bytes`](Self::padded_bytes) model against reality.
    #[inline]
    pub fn heap_bytes(&self) -> usize {
        self.buf.len() * 4
    }
}

/// A point set as the compute backends consume it: the row-major matrix
/// (always present — the scalar backend and every per-column tail read
/// it) plus, when the active backend asked for one, the SoA transpose.
///
/// Built once per kernel build by the `kernel::tile` drivers; whether
/// the SoA copy exists is a *layout* decision only — the backends'
/// per-column op order is identical either way (pinned by
/// tests/backend_parity.rs).
pub struct PointView<'a> {
    mat: &'a Matrix,
    soa: Option<SoaPoints>,
}

impl<'a> PointView<'a> {
    /// Wrap `mat`, transposing an SoA copy iff `with_soa` (the tile
    /// drivers pass the active backend's `wants_soa()`).
    pub fn new(mat: &'a Matrix, with_soa: bool) -> Self {
        let soa = if with_soa && mat.rows() > 0 && mat.cols() > 0 {
            Some(SoaPoints::from_matrix(mat))
        } else {
            None
        };
        PointView { mat, soa }
    }

    /// Number of points.
    #[inline]
    pub fn rows(&self) -> usize {
        self.mat.rows()
    }

    /// Feature dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.mat.cols()
    }

    /// The row-major operand.
    #[inline]
    pub fn mat(&self) -> &'a Matrix {
        self.mat
    }

    /// The SoA operand, if this view was built with one.
    #[inline]
    pub fn soa(&self) -> Option<&SoaPoints> {
        self.soa.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn rand_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        Matrix::from_vec(n, d, (0..n * d).map(|_| rng.next_gaussian() as f32).collect())
            .unwrap()
    }

    #[test]
    fn transpose_round_trips_and_pads_with_zeros() {
        for (n, d) in [(1usize, 1usize), (7, 3), (16, 4), (33, 5), (150, 9)] {
            let m = rand_matrix(n, d, 7 + n as u64);
            let soa = SoaPoints::from_matrix(&m);
            assert_eq!((soa.n(), soa.dim()), (n, d));
            assert_eq!(soa.stride(), SoaPoints::padded_cols(n));
            for f in 0..d {
                let row = soa.feature(f);
                assert_eq!(row.len(), soa.stride());
                for j in 0..n {
                    assert_eq!(row[j].to_bits(), m.get(j, f).to_bits(), "({j},{f})");
                }
                for &pad in &row[n..] {
                    assert_eq!(pad, 0.0, "padding must stay zero");
                }
            }
        }
    }

    #[test]
    fn allocation_matches_the_padded_bytes_model() {
        // the peak-memory satellite: the analytic model must equal the
        // real heap footprint, so tile::*_peak_bytes stays honest
        for (n, d) in [(1usize, 1usize), (12, 2), (64, 128), (100, 7), (500, 128)] {
            let m = rand_matrix(n, d, 31 + d as u64);
            let soa = SoaPoints::from_matrix(&m);
            assert_eq!(soa.heap_bytes(), SoaPoints::padded_bytes(n, d), "n={n} d={d}");
        }
    }

    #[test]
    fn feature_rows_are_cache_line_aligned() {
        let m = rand_matrix(37, 6, 99);
        let soa = SoaPoints::from_matrix(&m);
        for f in 0..6 {
            let addr = soa.feature(f).as_ptr() as usize;
            assert_eq!(addr % 64, 0, "feature row {f} misaligned");
        }
    }

    #[test]
    fn padded_cols_rounds_to_lane_multiples() {
        assert_eq!(SoaPoints::padded_cols(0), 0);
        assert_eq!(SoaPoints::padded_cols(1), 16);
        assert_eq!(SoaPoints::padded_cols(16), 16);
        assert_eq!(SoaPoints::padded_cols(17), 32);
        assert_eq!(SoaPoints::padded_cols(150), 160);
    }

    #[test]
    fn view_without_soa_is_rowmajor_only() {
        let m = rand_matrix(9, 3, 5);
        let plain = PointView::new(&m, false);
        assert!(plain.soa().is_none());
        assert_eq!(plain.rows(), 9);
        assert_eq!(plain.dim(), 3);
        let with = PointView::new(&m, true);
        assert!(with.soa().is_some());
        // degenerate shapes never transpose
        let empty = Matrix::zeros(0, 3);
        assert!(PointView::new(&empty, true).soa().is_none());
    }
}

//! Minimal dataset / result I/O: CSV feature matrices in and out, and the
//! experiment CSV dumps the `repro exp figN` commands write (DESIGN.md §7:
//! figures are replaced by CSVs carrying the same information).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::error::{Result, SubmodError};
use crate::linalg::Matrix;

/// Write a feature matrix as headerless CSV.
pub fn write_matrix_csv(path: impl AsRef<Path>, m: &Matrix) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for i in 0..m.rows() {
        let row: Vec<String> = m.row(i).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a headerless CSV of floats into a matrix.
pub fn read_matrix_csv(path: impl AsRef<Path>) -> Result<Matrix> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for (ln, line) in reader.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let row: std::result::Result<Vec<f32>, _> =
            line.split(',').map(|t| t.trim().parse::<f32>()).collect();
        let row = row.map_err(|e| {
            SubmodError::Io(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("line {}: {e}", ln + 1),
            ))
        })?;
        if let Some(first) = rows.first() {
            if row.len() != first.len() {
                return Err(SubmodError::Shape(format!(
                    "ragged csv at line {}: {} vs {}",
                    ln + 1,
                    row.len(),
                    first.len()
                )));
            }
        }
        rows.push(row);
    }
    let r = rows.len();
    let c = rows.first().map(|x| x.len()).unwrap_or(0);
    Matrix::from_vec(r, c, rows.into_iter().flatten().collect())
}

/// Write a selection trace (the figure-replacement format): one row per
/// selected element: order, element id, x, y (if 2-D), gain.
pub fn write_selection_csv(
    path: impl AsRef<Path>,
    data: &Matrix,
    order: &[(usize, f64)],
) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "order,id,gain,coords")?;
    for (rank, (id, gain)) in order.iter().enumerate() {
        let coords: Vec<String> = data.row(*id).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{rank},{id},{gain},{}", coords.join(";"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_csv_roundtrip() {
        let m = Matrix::from_rows(&[&[1.5, -2.0], &[0.25, 3.0], &[0.0, 0.125]]);
        let dir = std::env::temp_dir().join("submodlib_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.csv");
        write_matrix_csv(&p, &m).unwrap();
        let back = read_matrix_csv(&p).unwrap();
        assert_eq!(back.rows(), 3);
        assert_eq!(back.cols(), 2);
        for i in 0..3 {
            for j in 0..2 {
                assert!((m.get(i, j) - back.get(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn bad_csv_rejected() {
        let dir = std::env::temp_dir().join("submodlib_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "1,2\n3,abc\n").unwrap();
        assert!(read_matrix_csv(&p).is_err());
        std::fs::write(&p, "1,2\n3\n").unwrap();
        assert!(read_matrix_csv(&p).is_err());
    }

    #[test]
    fn selection_csv_written() {
        let m = Matrix::from_rows(&[&[0.0, 1.0], &[2.0, 3.0]]);
        let dir = std::env::temp_dir().join("submodlib_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("sel.csv");
        write_selection_csv(&p, &m, &[(1, 0.5), (0, 0.25)]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("order,id,gain"));
        assert!(text.contains("0,1,0.5,2;3"));
    }
}

//! Clustering substrate.
//!
//! Submodlib's `"clustered"` kernel mode and the generic `ClusteredFunction`
//! (paper §8) need a clustering of the ground set; the library either
//! accepts user-provided cluster labels (supervised subset selection) or
//! clusters internally. We implement k-means++ / Lloyd from scratch.

pub mod kmeans;

pub use kmeans::{kmeans, KMeansResult};

/// Partition element ids by cluster label. Labels must be < k.
pub fn partition(labels: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); k];
    for (i, &l) in labels.iter().enumerate() {
        assert!(l < k, "label {l} >= k {k}");
        out[l].push(i);
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn partition_groups() {
        let parts = super::partition(&[0, 1, 0, 2, 1], 3);
        assert_eq!(parts[0], vec![0, 2]);
        assert_eq!(parts[1], vec![1, 4]);
        assert_eq!(parts[2], vec![3]);
    }
}

//! k-means++ seeding + Lloyd iterations, deterministic given a seed.

use crate::linalg::{self, Matrix};
use crate::rng::Pcg64;

/// Result of a k-means run.
#[derive(Debug, Clone)]
pub struct KMeansResult {
    /// Cluster label per item.
    pub labels: Vec<usize>,
    /// k × d centroid matrix.
    pub centroids: Matrix,
    /// Final within-cluster sum of squared distances.
    pub inertia: f64,
    /// Lloyd iterations executed.
    pub iterations: usize,
}

/// k-means++ / Lloyd. `data` rows are items. Deterministic in `seed`.
pub fn kmeans(data: &Matrix, k: usize, max_iters: usize, seed: u64) -> KMeansResult {
    let n = data.rows();
    let d = data.cols();
    assert!(k >= 1 && k <= n, "k={k} n={n}");
    let mut rng = Pcg64::new(seed);

    // --- k-means++ seeding ---
    let mut centroids = Matrix::zeros(k, d);
    let first = rng.next_below(n);
    centroids.row_mut(0).copy_from_slice(data.row(first));
    let mut d2: Vec<f64> = (0..n)
        .map(|i| linalg::sq_dist(data.row(i), centroids.row(0)) as f64)
        .collect();
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.next_below(n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut idx = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                if target < w {
                    idx = i;
                    break;
                }
                target -= w;
            }
            idx
        };
        centroids.row_mut(c).copy_from_slice(data.row(pick));
        for i in 0..n {
            let nd = linalg::sq_dist(data.row(i), centroids.row(c)) as f64;
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }

    // --- Lloyd ---
    let mut labels = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        // assign
        let mut new_inertia = 0f64;
        for i in 0..n {
            let (mut best, mut bd) = (0usize, f32::INFINITY);
            for c in 0..k {
                let dist = linalg::sq_dist(data.row(i), centroids.row(c));
                if dist < bd {
                    bd = dist;
                    best = c;
                }
            }
            labels[i] = best;
            new_inertia += bd as f64;
        }
        // update
        let mut sums = Matrix::zeros(k, d);
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = labels[i];
            counts[c] += 1;
            let row = data.row(i);
            let srow = sums.row_mut(c);
            for (s, &x) in srow.iter_mut().zip(row) {
                *s += x;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f32;
                for v in centroids.row_mut(c) {
                    *v = 0.0;
                }
                let srow = sums.row(c).to_vec();
                for (cv, sv) in centroids.row_mut(c).iter_mut().zip(srow) {
                    *cv = sv * inv;
                }
            } else {
                // re-seed empty cluster at the farthest point; total_cmp so a
                // NaN feature row (NaN distance) can never panic the compare
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = linalg::sq_dist(data.row(a), centroids.row(labels[a]));
                        let db = linalg::sq_dist(data.row(b), centroids.row(labels[b]));
                        da.total_cmp(&db)
                    })
                    .unwrap();
                let row = data.row(far).to_vec();
                centroids.row_mut(c).copy_from_slice(&row);
            }
        }
        if (inertia - new_inertia).abs() < 1e-9 * (1.0 + inertia.abs()) {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    KMeansResult { labels, centroids, inertia, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn separates_well_separated_blobs() {
        let data = synthetic::blobs(120, 2, 3, 0.2, 7);
        let r = kmeans(&data, 3, 50, 1);
        // every cluster label set should be "pure": all points generated
        // from one blob share a label. blobs() lays points out blob-major.
        let per = 120 / 3;
        for b in 0..3 {
            let l0 = r.labels[b * per];
            for i in 0..per {
                assert_eq!(r.labels[b * per + i], l0, "blob {b} split");
            }
        }
        assert!(r.inertia < 50.0);
    }

    #[test]
    fn deterministic_in_seed() {
        let data = synthetic::blobs(60, 2, 3, 0.5, 9);
        let a = kmeans(&data, 3, 30, 5);
        let b = kmeans(&data, 3, 30, 5);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.inertia, b.inertia);
    }

    #[test]
    fn k_equals_n_zero_inertia() {
        let data = synthetic::blobs(8, 2, 2, 1.0, 3);
        let r = kmeans(&data, 8, 20, 1);
        assert!(r.inertia < 1e-6);
    }

    #[test]
    fn k_one_centroid_is_mean() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[2.0, 0.0], &[0.0, 2.0], &[2.0, 2.0]]);
        let r = kmeans(&data, 1, 10, 1);
        assert!((r.centroids.get(0, 0) - 1.0).abs() < 1e-6);
        assert!((r.centroids.get(0, 1) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn nan_feature_row_neither_panics_nor_scrambles_assignment() {
        // Regression: the empty-cluster reseed compared distances with
        // `partial_cmp().unwrap()`, which panics the moment a NaN feature
        // row makes a NaN distance. A NaN row must degrade gracefully:
        // the run completes, stays deterministic, and identical finite
        // rows still land in the same cluster.
        let data = Matrix::from_rows(&[
            &[0.0, 0.0],
            &[f32::NAN, f32::NAN],
            &[1.0, 1.0],
            &[1.0, 1.0],
        ]);
        for seed in 0..8 {
            let a = kmeans(&data, 3, 10, seed);
            let b = kmeans(&data, 3, 10, seed);
            assert_eq!(a.labels, b.labels, "seed {seed} nondeterministic");
            assert!(a.labels.iter().all(|&l| l < 3), "seed {seed}: {:?}", a.labels);
            assert_eq!(a.labels[2], a.labels[3], "seed {seed} scrambled duplicates");
        }
    }

    use crate::linalg::Matrix;
}
